# Venus build entry points.
#
# The default build needs NOTHING beyond a Rust toolchain: embeddings come
# from the self-contained native backend (`rust/src/backend/native.rs`).
#
# The OPTIONAL PJRT path executes AOT-compiled XLA artifacts instead:
#   1. `make artifacts`  — export HLO-text artifacts + goldens with the
#      Python compile layer (needs jax; run inside the rust_pallas image).
#   2. point the `xla` dependency at the real PJRT bindings instead of the
#      in-tree type-check stub, e.g. in Cargo.toml:
#          xla = { path = "../xla-rs", optional = true }
#      (the stub at rust/xla-stub keeps `--features pjrt` compiling
#      offline; it cannot execute artifacts.)
#   3. `cargo test --features pjrt` — runs the cross-backend parity suite
#      (rust/tests/native_vs_artifact.rs) against the artifacts.

.PHONY: all build test bench lint verify artifacts fmt clean

all: build

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

lint:
	cargo clippy --all-targets -- -D warnings

# Tier-1 verification, exactly what CI runs.
verify: build test

# AOT-export the MEM entry points (embed_image_b{1,8,32}, embed_text_b1,
# embed_fused_b8, scene_feat_b8, similarity_n1024), the concept side
# files, the cross-language goldens, and manifest.json into ./artifacts.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt --all

clean:
	cargo clean
	rm -rf artifacts
