# Venus build entry points.
#
# The default build needs NOTHING beyond a Rust toolchain: embeddings come
# from the self-contained native backend (`rust/src/backend/native.rs`).
#
# The OPTIONAL PJRT path executes AOT-compiled XLA artifacts instead:
#   1. `make artifacts`  — export HLO-text artifacts + goldens with the
#      Python compile layer (needs jax; run inside the rust_pallas image).
#   2. point the `xla` dependency at the real PJRT bindings instead of the
#      in-tree type-check stub, e.g. in Cargo.toml:
#          xla = { path = "../xla-rs", optional = true }
#      (the stub at rust/xla-stub keeps `--features pjrt` compiling
#      offline; it cannot execute artifacts.)
#   3. `cargo test --features pjrt` — runs the cross-backend parity suite
#      (rust/tests/native_vs_artifact.rs) against the artifacts.

.PHONY: all build test bench bench-json bench-diff bench-accept lint verify loadtest camtest artifacts fmt clean

all: build

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Machine-readable perf snapshots: run the hot-path, lifecycle, and
# ANN-scale benches with JSON persistence enabled.  Each target appends
# BENCH_<target>.json under $(BENCH_JSON_DIR) (see util/bench.rs and
# benchmarks/baselines/README.md for the trajectory workflow).
BENCH_JSON_DIR ?= benchmarks/out
bench-json:
	mkdir -p $(BENCH_JSON_DIR)
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench hotpath_micro
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench memory_lifecycle
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench ann_scale
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench wire_throughput
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench ingest_wire
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench fabric_scaling
	BENCH_JSON_DIR=$(BENCH_JSON_DIR) cargo bench --bench fig2_latency_breakdown

# Compare fresh headline scalars in $(BENCH_JSON_DIR) against the
# committed baselines with a relative tolerance (benchmarks/bench_diff.py;
# exits 0 with a note when no baselines are committed yet).  Accept a
# fresh run as the new baseline with bench-accept.
BENCH_DIFF_TOL ?= 0.25
bench-diff:
	python3 benchmarks/bench_diff.py --fresh $(BENCH_JSON_DIR) \
		--baselines benchmarks/baselines --tolerance $(BENCH_DIFF_TOL)

bench-accept:
	@ls $(BENCH_JSON_DIR)/BENCH_*.json >/dev/null 2>&1 \
		|| { echo "no snapshots in $(BENCH_JSON_DIR); run make bench-json first"; exit 1; }
	cp $(BENCH_JSON_DIR)/BENCH_*.json benchmarks/baselines/
	@echo "accepted $$(ls $(BENCH_JSON_DIR)/BENCH_*.json | wc -l) snapshot(s) into benchmarks/baselines/"

# Invariant lint (tools/vlint: panic policy, lock discipline, config-key
# hygiene, wire-tag coverage — see DESIGN.md §Static-Analysis), then
# clippy, then formatting.
lint:
	cargo run --quiet --release -p vlint -- --root .
	cargo clippy --all-targets -- -D warnings
	cargo fmt --all -- --check

# Tier-1 verification, exactly what CI runs.
verify: build test

# Wire load test: spawn a release server on a local port, drive it with
# the open-loop load generator for a fixed duration, then stop it
# gracefully over the wire (the server drains and flushes before exit).
# Override: make loadtest LOADTEST_ADDR=127.0.0.1:7733 LOADTEST_SECS=30
LOADTEST_ADDR ?= 127.0.0.1:7661
LOADTEST_SECS ?= 10
loadtest: build
	@echo "starting venus serve --listen $(LOADTEST_ADDR) ..."
	@./target/release/venus serve --listen $(LOADTEST_ADDR) --queries 16 < /dev/null & \
	SERVER_PID=$$!; \
	trap 'kill $$SERVER_PID 2>/dev/null || true' EXIT; \
	for i in $$(seq 1 120); do \
		kill -0 $$SERVER_PID 2>/dev/null || { echo "server exited before listening"; exit 1; }; \
		./target/release/venus query --connect $(LOADTEST_ADDR) --ping >/dev/null 2>&1 && break; \
		sleep 1; \
	done; \
	./target/release/venus loadgen --connect $(LOADTEST_ADDR) \
		--clients 8 --rate 64 --duration-secs $(LOADTEST_SECS) --shutdown \
		|| kill $$SERVER_PID 2>/dev/null; \
	wait $$SERVER_PID

# Live-ingest smoke test: spawn a release server, push frames through a
# real `venus camera` client WHILE `venus loadgen` drives query traffic
# at the same gateway, then assert the freshness gauges surfaced over
# the wire and stop the server gracefully.  The camera opens stream 0 on
# top of the preset the server pre-ingested (`--frames` counts from the
# stream's current watermark); `--fps 64` with a 1 s partition bound
# seals a partition every 64 frames so freshness tails appear mid-run.
# Override: make camtest CAMTEST_ADDR=127.0.0.1:7734
CAMTEST_ADDR ?= 127.0.0.1:7662
camtest: build
	@echo "starting venus serve --listen $(CAMTEST_ADDR) ..."
	@printf '[ingest]\nmax_partition_s = 1.0\n' > target/camtest.toml; \
	./target/release/venus serve --listen $(CAMTEST_ADDR) \
		--config target/camtest.toml --queries 16 < /dev/null & \
	SERVER_PID=$$!; \
	trap 'kill $$SERVER_PID 2>/dev/null || true' EXIT; \
	for i in $$(seq 1 120); do \
		kill -0 $$SERVER_PID 2>/dev/null || { echo "server exited before listening"; exit 1; }; \
		./target/release/venus query --connect $(CAMTEST_ADDR) --ping >/dev/null 2>&1 && break; \
		sleep 1; \
	done; \
	./target/release/venus camera --connect $(CAMTEST_ADDR) \
		--config target/camtest.toml --stream 0 --fps 64 --frames 192 --batch 16 & \
	CAMERA_PID=$$!; \
	./target/release/venus loadgen --connect $(CAMTEST_ADDR) \
		--clients 4 --rate 32 --duration-secs 5 \
		|| { echo "loadgen failed under live ingest"; exit 1; }; \
	wait $$CAMERA_PID || { echo "camera failed"; exit 1; }; \
	for i in $$(seq 1 60); do \
		./target/release/venus query --connect $(CAMTEST_ADDR) --stats --json \
			| grep -q '"freshness_p95_ms"' && break; \
		[ $$i -lt 60 ] || { echo "freshness gauges never appeared in stats"; exit 1; }; \
		sleep 1; \
	done; \
	FRESH=$$(./target/release/venus query --connect $(CAMTEST_ADDR) --stats --json \
		| sed -n 's/.*"freshness_p95_ms":\([0-9.eE+-]*\).*/\1/p' | head -1); \
	echo "capture->queryable freshness p95: $$FRESH ms"; \
	awk -v f="$$FRESH" 'BEGIN { exit !(f > 0 && f < 30000) }' \
		|| { echo "freshness p95 $$FRESH ms outside (0, 30000)"; exit 1; }; \
	./target/release/venus query --connect $(CAMTEST_ADDR) --shutdown; \
	wait $$SERVER_PID

# AOT-export the MEM entry points (embed_image_b{1,8,32}, embed_text_b1,
# embed_fused_b8, scene_feat_b8, similarity_n1024), the concept side
# files, the cross-language goldens, and manifest.json into ./artifacts.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

fmt:
	cargo fmt --all

clean:
	cargo clean
	rm -rf artifacts
