#!/usr/bin/env python3
"""Compare fresh BENCH_*.json snapshots against committed baselines.

Usage:
    python3 benchmarks/bench_diff.py [--fresh benchmarks/out] \
        [--baselines benchmarks/baselines] [--tolerance 0.25]

Both directories hold one `BENCH_<target>.json` per bench target, one
JSON object per line (see rust/src/util/bench.rs).  Two line shapes
share the stream:

  * per-iteration timings: {"name", "iters", "mean_s", "p50_s", ...}
  * headline scalars:      {"name", "value", "unit"}

For every (target, name) present in both trees, the fresh number must
not be WORSE than the baseline by more than the relative tolerance.
Direction comes from the unit: timings (`*_s` rows and `us`/`ms`/`s`
scalars) regress upward; rates (`fps`/`qps`/`x`) regress downward.
Improvements and new/retired rows never fail — only regressions do.

Exit status: 0 = no regressions (including "no baselines committed
yet"), 1 = at least one regression, 2 = usage/parse error.
"""

import argparse
import glob
import json
import os
import sys

LOWER_IS_BETTER_UNITS = {"us", "ms", "s", "ns"}
HIGHER_IS_BETTER_UNITS = {"fps", "qps", "x", "hz", "rows/s", "inserts/s"}


def load_dir(path):
    """{target: {name: (value, lower_is_better, label)}} for a JSON dir."""
    out = {}
    for fp in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        target = os.path.basename(fp)[len("BENCH_"):-len(".json")]
        rows = {}
        with open(fp, encoding="utf-8") as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError as e:
                    print(f"error: {fp}:{ln}: {e}", file=sys.stderr)
                    sys.exit(2)
                name = obj.get("name")
                if not isinstance(name, str):
                    continue
                if "value" in obj:  # headline scalar
                    unit = str(obj.get("unit", ""))
                    if unit in HIGHER_IS_BETTER_UNITS:
                        lower = False
                    elif unit in LOWER_IS_BETTER_UNITS:
                        lower = True
                    else:
                        # unknown unit: treat like a timing (conservative)
                        lower = True
                    rows[name] = (float(obj["value"]), lower, unit or "?")
                elif "mean_s" in obj:  # Bench per-iteration timing
                    rows[name] = (float(obj["mean_s"]), True, "mean_s")
        if rows:
            out[target] = rows
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="benchmarks/out")
    ap.add_argument("--baselines", default="benchmarks/baselines")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed relative regression (0.25 = 25%%)",
    )
    args = ap.parse_args()

    base = load_dir(args.baselines)
    fresh = load_dir(args.fresh)
    if not base:
        print(
            f"bench-diff: no baselines under {args.baselines} — nothing to "
            "compare.\nSeed them on the target hardware with: "
            "make bench-json && make bench-accept"
        )
        return 0
    if not fresh:
        print(
            f"bench-diff: no fresh snapshots under {args.fresh} — run "
            "`make bench-json` first",
            file=sys.stderr,
        )
        return 2

    regressions = []
    compared = 0
    for target, names in sorted(base.items()):
        if target not in fresh:
            print(f"  ~ {target}: no fresh snapshot (bench not run) — skipped")
            continue
        for name, (bval, lower, unit) in sorted(names.items()):
            got = fresh[target].get(name)
            if got is None:
                print(f"  ~ {target}/{name}: retired (absent from fresh run)")
                continue
            fval = got[0]
            compared += 1
            if bval == 0:
                continue
            change = (fval - bval) / abs(bval)
            worse = change if lower else -change
            marker = "REGRESSED" if worse > args.tolerance else "ok"
            if marker == "REGRESSED" or abs(change) > args.tolerance:
                print(
                    f"  {'!' if marker == 'REGRESSED' else '+'} {target}/{name}: "
                    f"{bval:.6g} -> {fval:.6g} {unit} ({change:+.1%}) {marker}"
                )
            if marker == "REGRESSED":
                regressions.append((target, name, bval, fval, unit, change))

    print(
        f"bench-diff: {compared} scalars compared against {args.baselines} "
        f"(tolerance {args.tolerance:.0%}): {len(regressions)} regression(s)"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
