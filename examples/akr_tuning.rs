//! akr_tuning — explores the AKR parameter space (θ, β, τ) on a real
//! ingested memory, showing the cost/accuracy trade-off surface the
//! paper's Fig. 11 picks one point from.
//!
//! Run: `cargo run --release --example akr_tuning`

use std::sync::Arc;

use venus::cloud::{VlmClient, VlmPersonality};
use venus::config::{CloudConfig, VenusConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::eval::prepare_case;
use venus::util::stats::Table;

fn main() -> venus::Result<()> {
    println!("=== AKR parameter exploration ===");
    let mut cfg = VenusConfig::default();
    let case = prepare_case(
        venus::video::workload::DatasetPreset::VideoMmeShort,
        &cfg,
        80,
        1337,
    )?;

    let cloud =
        CloudConfig { vlm: VlmPersonality::Qwen2Vl7b.name().into(), ..Default::default() };

    let mut table = Table::new(vec![
        "theta", "beta", "tau", "accuracy %", "mean frames", "mean draws",
    ]);
    for theta in [0.7, 0.8, 0.9, 0.95] {
        for beta in [2.0, 4.0] {
            for tau in [0.04f32, 0.07, 0.12] {
                cfg.retrieval.theta = theta;
                cfg.retrieval.beta = beta;
                cfg.retrieval.tau = tau;
                let mut qe = QueryEngine::new(
                    EmbedEngine::default_backend(true)?,
                    Arc::clone(&case.fabric),
                    cfg.retrieval.clone(),
                    3,
                );
                let mut vlm = VlmClient::new(cloud.clone(), 9);
                let mut correct = 0usize;
                let mut frames = 0usize;
                let mut draws = 0usize;
                for q in &case.queries {
                    let out = qe.retrieve_with(&q.text, RetrievalMode::Akr)?;
                    frames += out.selection.frames.len();
                    draws += out.draws;
                    let (ok, _) =
                        vlm.judge(q, case.synth.script(), &out.selection.frame_indices());
                    correct += ok as usize;
                }
                let n = case.queries.len() as f64;
                table.row(vec![
                    format!("{theta}"),
                    format!("{beta}"),
                    format!("{tau}"),
                    format!("{:.1}", 100.0 * correct as f64 / n),
                    format!("{:.1}", frames as f64 / n),
                    format!("{:.1}", draws as f64 / n),
                ]);
            }
        }
    }
    print!("{table}");
    println!("(paper operating point: θ=0.9, β=4, τ=0.07 — accuracy ≈ fixed-32 at ~half the frames)");
    Ok(())
}
