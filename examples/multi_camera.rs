//! multi_camera — campus-surveillance scenario over the memory fabric.
//!
//! Four campus cameras (quad, library, cafeteria, parking) stream
//! concurrently, each with a DISJOINT concept schedule (a concept — a
//! person, a vehicle, an activity — appears on exactly one camera).  All
//! four pipelines feed ONE shared embed pool over the ONE process-shared
//! backend; each camera's partitions land in its own memory shard.
//!
//! Then the operator asks:
//!   * per-camera questions (`StreamScope::One`) — answers cite only that
//!     camera's footage;
//!   * a cross-camera question naming concepts seen on different cameras
//!     (`StreamScope::All`) — the scatter-gather query merges every
//!     shard's Eq. 4–5 scores into one distribution, so the answer cites
//!     evidence frames from MULTIPLE cameras at once.
//!
//! Run: `cargo run --release --example multi_camera`
//! Works on a bare checkout — the native backend needs no artifacts.

use std::sync::Arc;

use venus::backend::{self, EmbedBackend};
use venus::config::VenusConfig;
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::ingest::{EmbedPool, Pipeline};
use venus::memory::{
    FrameId, MemoryFabric, RawStore, StreamId, StreamScope, SynthBackedRaw,
};
use venus::util::stats::fmt_duration;
use venus::video::synth::{SynthConfig, VideoSynth};

const CAMERAS: [&str; 4] = ["quad", "library", "cafeteria", "parking"];
const DURATION_S: f64 = 30.0;

/// Build camera `i`'s stream with a disjoint concept schedule: the
/// script's randomly-drawn concept events are remapped into camera `i`'s
/// private slice of the concept vocabulary.
fn camera_stream(i: usize, codes: &[Vec<f32>], patch: usize) -> Arc<VideoSynth> {
    let n_cameras = CAMERAS.len();
    let per_cam = codes.len() / n_cameras;
    assert!(per_cam >= 1, "concept vocabulary too small to partition");
    let cfg = SynthConfig {
        duration_s: DURATION_S,
        seed: 0xcafe + i as u64 * 7919,
        ..Default::default()
    };
    let mut script = venus::video::synth::SceneScript::generate(&cfg, codes.len());
    for scene in &mut script.scenes {
        for ev in &mut scene.events {
            // fold any concept into this camera's private range
            ev.concept = i * per_cam + ev.concept % per_cam;
        }
    }
    // a camera whose random schedule drew zero events still needs one
    // observable concept (the cross-camera query names one per camera)
    if script.concept_census().is_empty() {
        let scene = &mut script.scenes[0];
        scene.events.push(venus::video::synth::ConceptEvent {
            concept: i * per_cam,
            start: scene.start,
            end: scene.start + (scene.len / 2).max(1),
            slot: 0,
        });
    }
    Arc::new(VideoSynth::with_script(cfg, script, codes.to_vec(), patch))
}

/// A concept that actually appears on camera `i` (for query text).
fn visible_concept(synth: &VideoSynth) -> usize {
    synth
        .script()
        .concept_census()
        .first()
        .map(|&(c, _)| c)
        .expect("every camera script plants at least one concept")
}

fn main() -> venus::Result<()> {
    println!("=== Venus multi-camera fabric: campus surveillance ===");
    let cfg = VenusConfig::default();

    // ONE backend for the whole process: d_embed probe, embed pool, and
    // the query engine all share it
    let be = backend::shared_default()?;
    let codes = be.concept_codes()?;
    let patch = be.model().patch;
    let d_embed = be.model().d_embed;

    let synths: Vec<Arc<VideoSynth>> = (0..CAMERAS.len())
        .map(|i| camera_stream(i, &codes, patch))
        .collect();
    for (name, synth) in CAMERAS.iter().zip(&synths) {
        let concepts: Vec<usize> =
            synth.script().concept_census().iter().map(|&(c, _)| c).collect();
        println!(
            "camera {name:<10} {:>4} frames, {} scenes, concepts {concepts:?}",
            synth.total_frames(),
            synth.script().scenes.len()
        );
    }

    // K-shard fabric + shared embed pool
    let raws: Vec<Box<dyn RawStore>> = synths
        .iter()
        .map(|s| Box::new(SynthBackedRaw::new(Arc::clone(s))) as Box<dyn RawStore>)
        .collect();
    let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d_embed, raws)?);
    let workers = cfg.fabric.resolved_pool_workers().max(CAMERAS.len().min(2));
    let pool = EmbedPool::start(
        Arc::clone(&be),
        cfg.ingest.aux_models,
        workers,
        cfg.ingest.queue_capacity,
    )?;

    // concurrent ingestion: one pipeline thread per camera
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (i, synth) in synths.iter().enumerate() {
        let shard = Arc::clone(fabric.shard(StreamId(i as u16))?);
        let mut pipe =
            Pipeline::attach(&cfg.ingest, synth.config().fps, &pool, shard)?;
        let synth = Arc::clone(synth);
        handles.push(std::thread::spawn(
            move || -> venus::Result<venus::ingest::IngestStats> {
                for f in 0..synth.total_frames() {
                    pipe.push_frame(f, &synth.frame(f))?;
                }
                pipe.finish()
            },
        ));
    }
    let mut total_frames = 0u64;
    for (name, h) in CAMERAS.iter().zip(handles) {
        let stats = h.join().expect("ingest thread")?;
        total_frames += stats.frames;
        println!(
            "ingested {name:<10} {:>4} frames -> {:>3} index vectors ({} partitions)",
            stats.frames, stats.embedded, stats.partitions
        );
    }
    pool.shutdown()?;
    fabric.check_invariants()?;
    println!(
        "fabric: {} cameras, {} frames, {} index vectors in {} (shared pool, {} workers)",
        fabric.n_streams(),
        total_frames,
        fabric.total_indexed(),
        fmt_duration(t0.elapsed().as_secs_f64()),
        workers,
    );

    let mut qe = QueryEngine::new(
        EmbedEngine::new(be, cfg.ingest.aux_models)?,
        Arc::clone(&fabric),
        cfg.retrieval.clone(),
        42,
    );

    // ---- per-camera questions (One scope) ----
    println!();
    for (i, name) in CAMERAS.iter().enumerate() {
        let concept = visible_concept(&synths[i]);
        let text = format!("what happened with concept{concept:02} in the video");
        let out = qe.retrieve_scoped_with(
            &text,
            StreamScope::One(StreamId(i as u16)),
            RetrievalMode::Akr,
        )?;
        assert!(
            out.selection.frames.iter().all(|f| f.stream == StreamId(i as u16)),
            "One-scope answer cited a foreign camera"
        );
        println!(
            "[{name}] \"{text}\" -> {} frames from this camera only ({} AKR draws, {})",
            out.selection.frames.len(),
            out.draws,
            fmt_duration(out.timings.total_s()),
        );
    }

    // ---- the cross-camera question (All scope) ----
    let (cam_a, cam_b) = (0usize, 2usize);
    let (ca, cb) = (visible_concept(&synths[cam_a]), visible_concept(&synths[cam_b]));
    let text = format!("what happened with concept{ca:02} and concept{cb:02} in the video");
    println!();
    println!(
        "cross-camera query (\"{}\" is only on {}, \"concept{cb:02}\" only on {}):",
        format_args!("concept{ca:02}"),
        CAMERAS[cam_a],
        CAMERAS[cam_b]
    );
    let mut out = qe.retrieve_scoped_with(
        &text,
        StreamScope::All,
        RetrievalMode::FixedSampling(48),
    )?;
    if out.selection.streams().len() < 2 {
        // one camera's peak can dominate a sharp softmax; a warmer τ
        // spreads the draw mass over both named concepts' clusters
        let mut warm = cfg.retrieval.clone();
        warm.tau *= 3.0;
        qe.set_config(warm);
        out = qe.retrieve_scoped_with(
            &text,
            StreamScope::All,
            RetrievalMode::FixedSampling(48),
        )?;
    }
    let streams = out.selection.streams();
    let by_cam: Vec<String> = streams
        .iter()
        .map(|s| {
            let n = out.selection.frames.iter().filter(|f| f.stream == *s).count();
            format!("{}={n}", CAMERAS[s.index()])
        })
        .collect();
    println!(
        "  \"{text}\"\n  -> {} frames across {} cameras ({}) in {}",
        out.selection.frames.len(),
        streams.len(),
        by_cam.join(", "),
        fmt_duration(out.timings.total_s()),
    );
    let sample: Vec<FrameId> = out.selection.frames.iter().take(8).copied().collect();
    println!("  evidence sample: {sample:?}");
    assert!(
        streams.len() >= 2,
        "an All-scope answer to a two-camera question must cite ≥2 cameras, got {streams:?}"
    );
    println!();
    println!("cross-camera scatter-gather OK: one answer, evidence from {} cameras", streams.len());
    Ok(())
}
