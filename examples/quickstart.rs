//! Quickstart: the smallest complete Venus program.
//!
//! Builds a synthetic 90-second home-camera stream, ingests it through
//! the real pipeline (scene segmentation → clustering → MEM embedding →
//! hierarchical memory), then answers one natural-language query and
//! prints the latency breakdown.
//!
//! Run: `cargo run --release --example quickstart`
//! No artifacts or model files needed: the default native backend is
//! self-contained (`make artifacts` + `--features pjrt` switches the
//! embedding path to the AOT-compiled XLA runtime).

use venus::config::VenusConfig;
use venus::coordinator::Venus;
use venus::eval::build_synth;
use venus::memory::SynthBackedRaw;
use venus::util::stats::fmt_duration;
use venus::video::workload::{DatasetPreset, WorkloadGen};

fn main() -> venus::Result<()> {
    // 1. a synthetic edge-camera stream (stands in for the camera feed)
    let synth = build_synth(DatasetPreset::VideoMmeShort, 42)?;
    println!(
        "stream: {:.0} s at {} FPS = {} frames, {} scenes",
        synth.config().duration_s,
        synth.config().fps,
        synth.total_frames(),
        synth.script().scenes.len()
    );

    // 2. assemble Venus from the default config
    let cfg = VenusConfig::default();
    let raw = Box::new(SynthBackedRaw::new(std::sync::Arc::clone(&synth)));
    let mut venus = Venus::new(cfg, raw, 7)?;

    // 3. ingestion stage: stream the video through the pipeline
    let stats = venus.ingest_stream(&synth, u64::MAX)?;
    println!(
        "ingested: {} frames -> {} partitions -> {} indexed vectors ({}x compression)",
        stats.frames,
        stats.partitions,
        stats.embedded,
        venus.memory().read().unwrap().sparsity().round()
    );

    // 4. querying stage: ask about a concept the generator planted
    let q = WorkloadGen::new(1, DatasetPreset::VideoMmeShort)
        .generate(synth.script(), 1)
        .remove(0);
    println!("query: \"{}\"", q.text);
    let (outcome, breakdown) = venus.query(&q.text)?;
    println!(
        "selected {} keyframes (AKR used {} draws): {:?}",
        outcome.selection.frames.len(),
        outcome.draws,
        outcome.selection.frames
    );
    println!(
        "latency: edge {} (measured) + upload {} + VLM {} = {} total",
        fmt_duration(breakdown.edge.total_s()),
        fmt_duration(breakdown.upload_s),
        fmt_duration(breakdown.vlm_s),
        fmt_duration(breakdown.total_s())
    );

    // 5. did we actually retrieve the evidence?
    let covered = outcome
        .selection
        .frames
        .iter()
        .filter(|f| q.covers(f.idx))
        .count();
    println!(
        "ground truth: {covered}/{} selected frames fall in the evidence spans {:?}",
        outcome.selection.frames.len(),
        q.evidence
    );
    Ok(())
}
