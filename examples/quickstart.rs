//! Quickstart: the smallest complete Venus program, on Serving API v1
//! over a durable memory fabric.
//!
//! Builds a synthetic 90-second home-camera stream, ingests it through
//! the real pipeline (scene segmentation → clustering → MEM embedding →
//! hierarchical memory) into an on-disk data dir, starts the query
//! service, and answers typed queries through a client session:
//!   * a `QueryRequest` built with the builder API (priority, deadline,
//!     per-query sampling budget),
//!   * a structured `QueryResponse` with per-frame evidence
//!     (stream, timestamp, Eq. 4–5 score) and the latency breakdown,
//!   * the same question asked again — served from the semantic query
//!     cache, skipping the whole edge hot path,
//!   * a restart: the fabric is flushed, dropped, and *recovered* from
//!     disk — the same query returns the identical selection without
//!     re-ingesting a single frame.
//!
//! Run: `cargo run --release --example quickstart [-- --data-dir DIR]`
//! (default data dir: a per-process temp directory).  Run it twice with
//! an explicit `--data-dir` and the second run skips ingestion entirely.
//! No artifacts or model files needed: the default native backend is
//! self-contained (`make artifacts` + `--features pjrt` switches the
//! embedding path to the AOT-compiled XLA runtime).
//!
//! The same service is reachable over TCP (DESIGN.md §Wire-Protocol) —
//! two terminals:
//!   terminal 1:  venus serve --listen 127.0.0.1:7661
//!   terminal 2:  venus query --connect 127.0.0.1:7661 "what happened with concept01"
//!                venus query --connect 127.0.0.1:7661 --stats
//!                venus loadgen --connect 127.0.0.1:7661 --clients 8 --rate 64
//! (`examples/wire_demo.rs` runs the whole wire path in one process.)

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use venus::api::{Client, Priority, QueryRequest};
use venus::config::VenusConfig;
use venus::eval::prepare_case_at;
use venus::server::Service;
use venus::util::stats::fmt_duration;
use venus::video::workload::DatasetPreset;

fn data_dir_from_args() -> PathBuf {
    let args: Vec<String> = std::env::args().collect();
    for i in 0..args.len() {
        if args[i] == "--data-dir" {
            if let Some(dir) = args.get(i + 1) {
                return PathBuf::from(dir);
            }
        }
    }
    // stable default (no pid suffix): reruns recover the same memory
    // instead of stranding a fresh frame log in the temp dir each time
    std::env::temp_dir().join("venus-quickstart")
}

fn main() -> venus::Result<()> {
    // 1. a synthetic edge-camera stream, ingested through the real
    //    pipeline into a DURABLE hierarchical memory (raw frames + index
    //    inserts stream to disk; a pre-existing data dir is recovered
    //    instead of re-ingested)
    let cfg = VenusConfig::default();
    let data_dir = data_dir_from_args();
    let case = prepare_case_at(DatasetPreset::VideoMmeShort, &cfg, 4, 42, Some(&data_dir))?;
    let recovered = case.ingest_stats.frames == 0 && case.memory.read().len() > 0;
    println!(
        "stream: {:.0} s = {} frames -> {} index vectors ({}x compression){}",
        case.synth.config().duration_s,
        case.synth.total_frames(),
        case.memory.read().len(),
        case.memory.read().sparsity().round(),
        if recovered {
            format!(" — recovered from {}", data_dir.display())
        } else {
            format!(" — persisted to {}", data_dir.display())
        }
    );

    // 2. the serving loop + a typed client session over it (evidence
    //    timestamps follow the stream's real frame rate).  One worker so
    //    the sampling rng is deterministic — step 6 asserts the recovered
    //    fabric reproduces this run's selection draw-for-draw.
    let mut cfg = cfg;
    cfg.api.fps = case.synth.config().fps;
    cfg.server.workers = 1;
    let service = Service::start(&cfg, Arc::clone(&case.fabric), 7)?;
    let client = Client::new(&service);
    let mut session = client.session();

    // 3. a typed query: interactive priority, a 10 s deadline, and a
    //    per-query sampling budget of 24 draws
    let q = &case.queries[0];
    println!("query: \"{}\"", q.text);
    let request = QueryRequest::new(&q.text)
        .priority(Priority::Interactive)
        .deadline(Duration::from_secs(10))
        .budget(24);
    let response = session.ask(request.clone())?;
    println!(
        "selected {} keyframes ({} draws, cache {}):",
        response.evidence.len(),
        response.draws,
        response.cache
    );
    for e in response.evidence.iter().take(5) {
        println!(
            "  {:?} at {:>6} (score {:.4})",
            e.frame,
            fmt_duration(e.time_s),
            e.score
        );
    }
    println!(
        "latency: queue {} + edge {} (measured) + upload {} + VLM {} = {} total",
        fmt_duration(response.queue_wait_s),
        fmt_duration(response.edge.total_s()),
        fmt_duration(response.upload_s),
        fmt_duration(response.vlm_s),
        fmt_duration(response.total_s())
    );

    // 4. did we actually retrieve the evidence?
    let covered = response.evidence.iter().filter(|e| q.covers(e.frame.idx)).count();
    println!(
        "ground truth: {covered}/{} selected frames fall in the evidence spans {:?}",
        response.evidence.len(),
        q.evidence
    );

    // 5. ask the same question again: the semantic query cache serves it
    //    without re-running the edge hot path (no embed, no scoring)
    let warm = session.ask(request)?;
    assert!(warm.cache.is_hit(), "repeat query must hit the cache");
    assert_eq!(warm.frame_indices(), response.frame_indices());
    println!(
        "repeat query: cache {} in {} edge (cold edge was {}); session history {} turns, {} cache hits",
        warm.cache,
        fmt_duration(warm.edge.total_s()),
        fmt_duration(response.edge.total_s()),
        session.history().len(),
        session.cache_hits()
    );
    println!("{}", client.cache_stats().render());

    let snapshot = service.shutdown();
    println!("server metrics: {}", snapshot.render());

    // 6. restart recovery: flush, drop the whole fabric, reopen it from
    //    disk, and ask the same question — the recovered memory returns
    //    the identical selection with zero ingestion work
    let question = q.text.clone();
    case.fabric.flush()?;
    drop(case);
    let reopened = prepare_case_at(DatasetPreset::VideoMmeShort, &cfg, 4, 42, Some(&data_dir))?;
    assert_eq!(reopened.ingest_stats.frames, 0, "recovery must skip ingestion");
    let service = Service::start(&cfg, Arc::clone(&reopened.fabric), 7)?;
    let after = Client::new(&service)
        .session()
        .ask(QueryRequest::new(&question).budget(24))?;
    assert_eq!(
        after.frame_indices(),
        response.frame_indices(),
        "recovered memory must reproduce the pre-restart selection"
    );
    println!(
        "after restart: recovered {} vectors from disk, same {} evidence frames selected",
        reopened.memory.read().len(),
        after.evidence.len()
    );
    service.shutdown();
    Ok(())
}
