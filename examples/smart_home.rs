//! smart_home — the END-TO-END serving driver (EXPERIMENTS.md §E2E).
//!
//! Models the paper's Fig. 1 deployment: a home edge camera streams
//! continuously; family members issue natural-language queries at any
//! time.  This driver:
//!   1. ingests a multi-minute synthetic home stream through the real
//!      threaded pipeline (backend MEM embeddings on the index path),
//!   2. starts the multi-worker query service with admission control,
//!   3. replays a batch of online queries (localized + dispersed mix),
//!   4. reports accuracy vs ground truth, per-stage latency percentiles,
//!      throughput, and the paper-scale simulated totals.
//!
//! Run: `cargo run --release --example smart_home`

use std::sync::Arc;
use std::time::Duration;

use venus::api::{Priority, QueryRequest};
use venus::backend::{self, EmbedBackend};
use venus::cloud::{SelectionStats, VlmClient};
use venus::config::VenusConfig;
use venus::embed::EmbedEngine;
use venus::ingest::Pipeline;
use venus::memory::{Hierarchy, MemoryFabric, SynthBackedRaw};
use venus::server::Service;
use venus::util::stats::{fmt_duration, Samples, Table};
use venus::util::sync::{ranks, OrderedRwLock};
use venus::video::synth::{SynthConfig, VideoSynth};
use venus::video::workload::{DatasetPreset, WorkloadGen};

const STREAM_S: f64 = 240.0; // 4 minutes of home video
const N_QUERIES: usize = 48;

fn main() -> venus::Result<()> {
    println!("=== Venus smart-home serving driver ===");
    let cfg = VenusConfig::default();

    // ---- the home camera stream ----
    let be = backend::shared_default()?;
    let codes = be.concept_codes()?;
    let patch = be.model().patch;
    let d_embed = be.model().d_embed;
    let synth = Arc::new(VideoSynth::new(
        SynthConfig { duration_s: STREAM_S, seed: 4242, ..Default::default() },
        codes,
        patch,
    ));
    println!(
        "camera: {:.0} s @ {} FPS ({} frames, {} scenes)",
        STREAM_S,
        synth.config().fps,
        synth.total_frames(),
        synth.script().scenes.len()
    );

    // ---- ingestion stage (real pipeline) ----
    let memory = Arc::new(OrderedRwLock::new(
        ranks::shard(0),
        Hierarchy::new(
            &cfg.memory,
            d_embed,
            Box::new(SynthBackedRaw::new(Arc::clone(&synth))),
        )?,
    ));
    let engine = EmbedEngine::new(be, cfg.ingest.aux_models)?;
    let mut pipe =
        Pipeline::new(&cfg.ingest, synth.config().fps, engine, Arc::clone(&memory))?;
    let t0 = std::time::Instant::now();
    for i in 0..synth.total_frames() {
        pipe.push_frame(i, &synth.frame(i))?;
    }
    let stats = pipe.finish()?;
    let ingest_wall = t0.elapsed().as_secs_f64();
    let realtime_factor = STREAM_S / ingest_wall;
    println!(
        "ingestion: {} frames -> {} clusters in {} ({:.1}× real-time on this host; \
         mean embed batch {})",
        stats.frames,
        stats.embedded,
        fmt_duration(ingest_wall),
        realtime_factor,
        fmt_duration(stats.mean_embed_batch_s),
    );
    memory.read().check_invariants()?;

    // ---- online querying stage ----
    let queries = WorkloadGen::new(77, DatasetPreset::VideoMmeShort)
        .generate(synth.script(), N_QUERIES);
    let fabric = Arc::new(MemoryFabric::single(Arc::clone(&memory)));
    let mut cfg = cfg;
    cfg.api.fps = synth.config().fps; // evidence timestamps at the camera rate
    let service = Service::start(&cfg, fabric, 99)?;
    let mut vlm = VlmClient::new(cfg.cloud.clone(), 1234);

    let mut edge = Samples::default();
    let mut totals = Samples::default();
    let mut frames_used = Samples::default();
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        // a family member waiting at the console vs background analytics:
        // interactive turns carry a deadline and jump the batch lane
        let request = if i % 2 == 0 {
            QueryRequest::new(&q.text)
                .priority(Priority::Interactive)
                .deadline(Duration::from_secs(30))
        } else {
            QueryRequest::new(&q.text).priority(Priority::Batch)
        };
        receivers.push((q, service.submit_request(request).expect("queue accepts")));
    }
    for (q, rx) in receivers {
        let res = rx.recv()??;
        edge.push(res.edge.total_s());
        totals.push(res.total_s());
        frames_used.push(res.evidence.len() as f64);
        let picked = res.frame_indices();
        let (ok, _) = vlm.judge(q, synth.script(), &picked);
        correct += ok as usize;
        let st = SelectionStats::compute(q, synth.script(), &picked, 4);
        let _ = st;
    }
    let wall = t0.elapsed().as_secs_f64();
    let cache_stats = service.cache.stats();
    let snap = service.shutdown();

    // ---- report ----
    println!();
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["queries completed".to_string(), format!("{}", snap.completed())]);
    t.row(vec![
        "per lane (interactive/batch)".to_string(),
        format!("{}/{}", snap.interactive.completed, snap.batch.completed),
    ]);
    t.row(vec!["accuracy vs ground truth".to_string(),
               format!("{:.1}%", 100.0 * correct as f64 / queries.len() as f64)]);
    t.row(vec!["mean frames shipped/query".to_string(), format!("{:.1}", frames_used.mean())]);
    t.row(vec!["edge latency p50 (measured)".to_string(), fmt_duration(edge.p50())]);
    t.row(vec!["edge latency p99 (measured)".to_string(), fmt_duration(edge.p99())]);
    t.row(vec!["total latency p50 (incl. simulated net+VLM)".to_string(),
               fmt_duration(totals.p50())]);
    t.row(vec!["total latency p99".to_string(), fmt_duration(totals.p99())]);
    t.row(vec!["service throughput (edge-bound)".to_string(),
               format!("{:.1} queries/s", queries.len() as f64 / wall)]);
    t.row(vec!["ingest real-time factor".to_string(), format!("{realtime_factor:.1}×")]);
    print!("{t}");
    println!("{}", cache_stats.render());
    println!("server metrics: {}", snap.render());
    assert!(snap.completed() == queries.len() as u64 && snap.failed == 0);
    assert_eq!(snap.deadline_shed(), 0, "30 s deadlines never shed on a drained queue");
    Ok(())
}
