//! surveillance_marathon — long-stream ingestion stress driver.
//!
//! Models a fixed-view surveillance camera running for an hour-scale
//! session (the Video-MME-long regime where the paper's baselines need
//! 200+ minutes per query).  Demonstrates:
//!   * sustained real-time ingestion (the paper's challenge ①),
//!   * bounded memory growth: raw archive off-RAM (NVMe model), sparse
//!     index growth vs stream length,
//!   * query latency staying flat as the memory grows (hierarchical
//!     memory + sparse index property).
//!
//! Run: `cargo run --release --example surveillance_marathon`

use std::sync::Arc;

use venus::backend::{self, EmbedBackend};
use venus::config::VenusConfig;
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::ingest::Pipeline;
use venus::memory::{Hierarchy, SynthBackedRaw};
use venus::util::stats::{fmt_duration, Table};
use venus::util::sync::{ranks, OrderedRwLock};
use venus::video::synth::{SynthConfig, VideoSynth};
use venus::video::workload::{DatasetPreset, WorkloadGen};

const STREAM_S: f64 = 1800.0; // 30-minute marathon
const CHECKPOINTS: usize = 6;

fn main() -> venus::Result<()> {
    println!("=== Venus surveillance marathon ({} min stream) ===", STREAM_S / 60.0);
    let cfg = VenusConfig::default();

    let be = backend::shared_default()?;
    let codes = be.concept_codes()?;
    let patch = be.model().patch;
    let d_embed = be.model().d_embed;
    let synth = Arc::new(VideoSynth::new(
        SynthConfig {
            duration_s: STREAM_S,
            // surveillance: slower scene changes, frequent static stretches
            scene_len_s: (10.0, 30.0),
            seed: 90210,
            ..Default::default()
        },
        codes,
        patch,
    ));
    let total = synth.total_frames();

    let memory = Arc::new(OrderedRwLock::new(
        ranks::shard(0),
        Hierarchy::new(
            &cfg.memory,
            d_embed,
            Box::new(SynthBackedRaw::new(Arc::clone(&synth))),
        )?,
    ));
    let engine = EmbedEngine::new(be, cfg.ingest.aux_models)?;
    let mut pipe =
        Pipeline::new(&cfg.ingest, synth.config().fps, engine, Arc::clone(&memory))?;

    let mut qe = QueryEngine::over_memory(
        EmbedEngine::default_backend(cfg.ingest.aux_models)?,
        Arc::clone(&memory),
        cfg.retrieval.clone(),
        5,
    );
    let queries = WorkloadGen::new(17, DatasetPreset::VideoMmeLong)
        .generate(synth.script(), 32);

    let mut table = Table::new(vec![
        "stream pos", "frames", "index vectors", "compression", "raw RAM",
        "ingest ×RT", "query p50 (measured)",
    ]);

    let chunk = total / CHECKPOINTS as u64;
    let mut pushed = 0u64;
    let started = std::time::Instant::now();
    for cp in 1..=CHECKPOINTS {
        let until = (cp as u64 * chunk).min(total);
        while pushed < until {
            pipe.push_frame(pushed, &synth.frame(pushed))?;
            pushed += 1;
        }
        // probe query latency at this memory size (use queries whose
        // evidence is already ingested)
        let mut lat = venus::util::stats::Samples::default();
        for q in queries.iter().filter(|q| q.evidence[0].1 < pushed).take(8) {
            let out = qe.retrieve_with(&q.text, RetrievalMode::Akr)?;
            lat.push(out.timings.total_s());
        }
        let (n_index, sparsity, raw_bytes) = {
            let m = memory.read();
            (m.len(), m.sparsity(), m.raw_resident_bytes())
        };
        let wall = started.elapsed().as_secs_f64();
        let stream_time = pushed as f64 / synth.config().fps;
        table.row(vec![
            format!("{:.0} min", stream_time / 60.0),
            pushed.to_string(),
            n_index.to_string(),
            format!("{sparsity:.0}×"),
            format!("{} B", raw_bytes),
            format!("{:.1}×", stream_time / wall),
            if lat.is_empty() { "—".into() } else { fmt_duration(lat.p50()) },
        ]);
    }
    let stats = pipe.finish()?;
    print!("{table}");
    println!(
        "final: {} frames, {} partitions, {} indexed vectors, wall {}",
        stats.frames,
        stats.partitions,
        stats.embedded,
        fmt_duration(stats.wall_s)
    );
    memory.read().check_invariants()?;
    Ok(())
}
