//! Wire demo: the TCP serving path, end to end, in one process.
//!
//! Ingests a synthetic stream, starts the query service, exposes it
//! through the TCP gateway on an ephemeral localhost port, and then
//! talks to it the way a *remote* client would — over a real socket
//! with the length-prefixed JSON wire protocol:
//!   * handshake (protocol version + session id),
//!   * a typed query with evidence + latency breakdown,
//!   * the same query again, served by the semantic cache,
//!   * a `Stats` round trip (lane counters, live queue depths, memory
//!     gauges),
//!   * graceful remote shutdown with durability-safe teardown order.
//!
//! Run: `cargo run --release --example wire_demo`
//!
//! The two-terminal equivalent against a standalone server:
//!   terminal 1:  venus serve --listen 127.0.0.1:7661
//!   terminal 2:  venus query --connect 127.0.0.1:7661 "what happened with concept01"

use std::sync::Arc;

use venus::api::QueryRequest;
use venus::config::VenusConfig;
use venus::eval::prepare_case;
use venus::net::wire::{Gateway, WireClient};
use venus::server::Service;
use venus::util::stats::fmt_duration;
use venus::video::workload::DatasetPreset;

fn main() -> venus::Result<()> {
    // 1. memory + service, exactly as in the quickstart
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into(); // ephemeral port
    let case = prepare_case(DatasetPreset::VideoMmeShort, &cfg, 4, 42)?;
    cfg.api.fps = case.synth.config().fps;
    let service = Arc::new(Service::start(&cfg, Arc::clone(&case.fabric), 7)?);

    // 2. the TCP gateway: remote traffic flows into the same priority
    //    lanes, deadline shedding, and semantic cache as local calls
    let gateway = Gateway::start(&cfg.wire, Arc::clone(&service))?;
    let addr = gateway.local_addr();
    println!("gateway listening on {addr}");

    // 3. a wire client: real socket, real frames, typed protocol
    let mut client = WireClient::connect(addr)?;
    println!(
        "connected: session {} over a {}-stream fabric",
        client.session_id(),
        client.streams()
    );

    let text = &case.queries[0].text;
    println!("query: \"{text}\"");
    let cold = client.query(QueryRequest::new(text).budget(24))?.expect("query served");
    println!(
        "  {} evidence frames, cache {}, total {}",
        cold.evidence.len(),
        cold.cache,
        fmt_duration(cold.total_s())
    );
    for e in cold.evidence.iter().take(3) {
        println!(
            "    stream {} frame {:>5} at {:>7} (score {:.4})",
            e.frame.stream.0,
            e.frame.idx,
            fmt_duration(e.time_s),
            e.score
        );
    }

    // 4. the repeat is a cache hit — across the wire too
    let warm = client.query(QueryRequest::new(text).budget(24))?.expect("repeat served");
    assert!(warm.cache.is_hit(), "repeat query must hit the cache");
    println!(
        "  repeat: cache {} (session history {} turns, {} cache hits)",
        warm.cache,
        client.history().len(),
        client.cache_hits()
    );

    // 5. server-side stats over the wire
    let stats = client.stats()?;
    println!("server stats: {}", stats.render());

    // 6. remote graceful shutdown, then durability-safe teardown:
    //    gateway first (wire quiet), lanes drained, fabric flushable
    client.shutdown_server()?;
    gateway.wait_for_shutdown_request();
    let wire = gateway.shutdown();
    println!("{}", wire.render());
    let service = match Arc::try_unwrap(service) {
        Ok(s) => s,
        Err(_) => anyhow::bail!("gateway still holds the service"),
    };
    let snap = service.shutdown();
    println!("final: {}", snap.render());
    Ok(())
}
