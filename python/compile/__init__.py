"""Build-time Python package for Venus (L1 Pallas kernels + L2 JAX model).

Runs exactly once, at `make artifacts` time; the Rust coordinator never
imports Python on the request path.
"""
