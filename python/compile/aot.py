"""AOT export: lower every MEM entry point to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Model parameters are *closed over* at trace time and therefore baked into
the HLO as constants: each artifact is a self-contained executable that the
Rust runtime feeds only runtime inputs (frames / tokens / query vectors).

Outputs (under --out-dir, default ../artifacts):
  embed_image_b{1,8,32}.hlo.txt   image tower
  embed_text_b1.hlo.txt           text tower (query path)
  embed_fused_b8.hlo.txt          image tower + aux-prompt fusion (Eq. 3)
  scene_feat_b8.hlo.txt           Eq. 1 perception features
  similarity_n1024.hlo.txt        Eq. 4-5 fused retrieval scoring
  concept_codes.bin               f32 LE [C, patch_dim] planted pixel codes
  concept_dirs.bin                f32 LE [C, d_embed] embedding directions
  golden_*.bin                    cross-language numeric goldens
  manifest.json                   shapes, dtypes, config hash, file list
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.config import MemConfig, SCENE_FEAT_DIM, DEFAULT
from compile import model, params as params_mod, tokenizer


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: model weights are closed over at trace time
    # and baked into the module; the default printer elides them as
    # `constant({...})`, which would NOT round-trip through the text parser.
    return comp.as_hlo_text(True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_desc(avals):
    return [
        {"dtype": str(a.dtype), "shape": list(a.shape)}
        for a in avals
    ]


def golden_image(cfg: MemConfig, codes: np.ndarray, concept: int) -> np.ndarray:
    """Deterministic test frame: smooth gradient background with
    ``codes[concept]`` planted in the top-left watermark patch.  The Rust
    integration tests regenerate this image bit-for-bit."""
    s = cfg.img_size
    yy, xx = np.meshgrid(np.arange(s), np.arange(s), indexing="ij")
    img = np.stack(
        [
            0.25 + 0.5 * xx / (s - 1),
            0.25 + 0.5 * yy / (s - 1),
            0.5 + 0.25 * np.sin(2.0 * np.pi * xx / 16.0),
        ],
        axis=-1,
    ).astype(np.float32)
    # plant the code verbatim (blend weight 1.0 for the golden)
    p = cfg.patch
    img[0:p, 0:p, :] = codes[concept].reshape(p, p, 3)
    return img


def build_artifacts(cfg: MemConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    prm = params_mod.init_params(cfg)

    entries = {}

    def export(name, fn, specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        entries[name] = {
            "file": fname,
            "inputs": _io_desc(specs),
            "outputs": _io_desc(out_avals),
        }
        print(f"  {fname:28s} {len(text):>9d} chars")

    s, t = cfg.img_size, cfg.seq_len

    for b in cfg.image_batches:
        export(
            f"embed_image_b{b}",
            functools.partial(model.image_tower, cfg, prm),
            [_spec((b, s, s, 3))],
        )
    export(
        "embed_text_b1",
        functools.partial(model.text_tower, cfg, prm),
        [_spec((1, t), jnp.int32)],
    )
    for b in cfg.fused_batches:
        export(
            f"embed_fused_b{b}",
            lambda imgs, toks: model.image_tower(cfg, prm, imgs, aux_tokens=toks),
            [_spec((b, s, s, 3)), _spec((b, t), jnp.int32)],
        )
    for b in cfg.scene_batches:
        export(f"scene_feat_b{b}", model.scene_feat, [_spec((b, s, s, 3))])
    export(
        "similarity_n1024",
        model.similarity,
        [
            _spec((cfg.d_embed,)),
            _spec((cfg.sim_rows, cfg.d_embed)),
            _spec((1,)),
            _spec((1,)),
        ],
    )

    # --- binary side-files ---
    codes = np.asarray(prm["sem"]["codes"], dtype="<f4")
    dirs = np.asarray(params_mod.concept_directions(prm), dtype="<f4")
    codes.tofile(os.path.join(out_dir, "concept_codes.bin"))
    dirs.tofile(os.path.join(out_dir, "concept_dirs.bin"))

    # --- cross-language goldens ---
    gimg = golden_image(cfg, codes, concept=5)
    gemb = np.asarray(
        model.image_tower_ref(cfg, prm, jnp.asarray(gimg)[None]), dtype="<f4"
    )[0]
    gtext = "when did concept05 happen in the kitchen"
    gtok = np.asarray([tokenizer.tokenize(gtext, cfg)], dtype="<i4")
    gtemb = np.asarray(model.text_tower_ref(cfg, prm, jnp.asarray(gtok)), dtype="<f4")[0]
    gfeat = np.asarray(model.scene_feat(jnp.asarray(gimg)[None].repeat(8, 0)), dtype="<f4")[0]
    gimg.astype("<f4").tofile(os.path.join(out_dir, "golden_image.bin"))
    gemb.tofile(os.path.join(out_dir, "golden_image_emb.bin"))
    gtok.astype("<i4").tofile(os.path.join(out_dir, "golden_tokens.bin"))
    gtemb.tofile(os.path.join(out_dir, "golden_text_emb.bin"))
    gfeat.tofile(os.path.join(out_dir, "golden_scene_feat.bin"))

    manifest = {
        "config_hash": cfg.config_hash(),
        "model": {
            "img_size": cfg.img_size,
            "patch": cfg.patch,
            "d_embed": cfg.d_embed,
            "seq_len": cfg.seq_len,
            "vocab": cfg.vocab,
            "n_concepts": cfg.n_concepts,
            "concept_token_base": cfg.concept_token_base,
            "sim_rows": cfg.sim_rows,
            "scene_feat_dim": SCENE_FEAT_DIM,
            "sem_weight": cfg.sem_weight,
            "content_weight": cfg.content_weight,
            "aux_weight": cfg.aux_weight,
        },
        "entries": entries,
        "files": {
            "concept_codes": {"file": "concept_codes.bin",
                              "shape": [cfg.n_concepts, cfg.patch_dim]},
            "concept_dirs": {"file": "concept_dirs.bin",
                             "shape": [cfg.n_concepts, cfg.d_embed]},
            "golden_image": {"file": "golden_image.bin",
                             "shape": [cfg.img_size, cfg.img_size, 3]},
            "golden_image_emb": {"file": "golden_image_emb.bin",
                                 "shape": [cfg.d_embed], "concept": 5},
            "golden_tokens": {"file": "golden_tokens.bin",
                              "shape": [1, cfg.seq_len], "dtype": "i32",
                              "text": gtext},
            "golden_text_emb": {"file": "golden_text_emb.bin",
                                "shape": [cfg.d_embed]},
            "golden_scene_feat": {"file": "golden_scene_feat.bin",
                                  "shape": [SCENE_FEAT_DIM]},
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  manifest.json                config={cfg.config_hash()}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file target; triggers full export "
                         "into its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    print(f"AOT export -> {out_dir}")
    build_artifacts(DEFAULT, out_dir)


if __name__ == "__main__":
    main()
