"""Model configuration shared by the L1 kernels, L2 model, and AOT export.

All shapes are fixed at AOT time; the Rust runtime validates them against
the manifest emitted by :mod:`compile.aot`.  The semantic-projection scheme
(concept codes planted in frames by the Rust synthetic video generator and
read out by the image tower) is what lets a randomly-initialized dual
encoder behave like a *trained* multimodal embedding model: image/text
pairs that share a concept land near each other in the shared space by
construction.  See DESIGN.md §1 ("BGE-VL-large" row).
"""

from dataclasses import dataclass, field, asdict
import hashlib
import json


@dataclass(frozen=True)
class MemConfig:
    """Configuration of the compact CLIP-style dual encoder (the MEM)."""

    # --- image tower ---
    img_size: int = 64           # square RGB input
    patch: int = 8               # patch side; 64 patches per image
    d_model: int = 128           # transformer width
    n_heads: int = 4
    n_blocks_img: int = 2
    d_mlp: int = 512
    # --- text tower ---
    vocab: int = 512
    seq_len: int = 16
    n_blocks_txt: int = 1
    # --- shared embedding space ---
    d_embed: int = 64
    # --- semantic projection (emulates trained cross-modal alignment) ---
    n_concepts: int = 32         # planted concept vocabulary
    concept_token_base: int = 2  # token ids [base, base+n_concepts) are concepts
    sem_weight: float = 4.0      # beta: semantic readout weight
    content_weight: float = 1.0  # gamma: transformer content weight
    aux_weight: float = 0.5      # lambda: aux-prompt fusion weight (Eq. 3)
    # --- misc ---
    seed: int = 20250710
    # batch sizes exported for the image tower
    image_batches: tuple = (1, 8, 32)
    fused_batches: tuple = (8,)
    scene_batches: tuple = (8,)
    sim_rows: int = 1024         # padded index size for the similarity kernel

    @property
    def n_patches(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def config_hash(self) -> str:
        """Stable hash recorded in the manifest; Rust refuses mismatched artifacts."""
        blob = json.dumps(asdict(self), sort_keys=True, default=list)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


# Scene-feature geometry (Eq. 1): the frame is divided into a POOL x POOL
# grid; per cell we emit mean Hue, Saturation, Lightness and Sobel edge
# energy, giving a 4 * POOL^2 feature vector per frame.
SCENE_POOL = 4
SCENE_FEAT_DIM = 4 * SCENE_POOL * SCENE_POOL  # 64

DEFAULT = MemConfig()
