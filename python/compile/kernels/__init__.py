"""L1 Pallas kernels for the Venus MEM and perception front-end.

- :mod:`compile.kernels.fused_block` — fused transformer block (MHA+MLP)
- :mod:`compile.kernels.similarity`  — fused cosine similarity + softmax
- :mod:`compile.kernels.scene_score` — Eq. 1 pooled HSL/edge features
- :mod:`compile.kernels.ref`         — pure-jnp oracles for all of the above
"""

from compile.kernels import fused_block, similarity, scene_score, ref  # noqa: F401
