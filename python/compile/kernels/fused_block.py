"""L1 Pallas kernel: fused pre-LN transformer block (MHA + MLP).

One grid step processes one sequence of the batch entirely in VMEM:
LN1 → QKV projection → scaled-dot-product attention → output projection →
residual → LN2 → MLP (GELU) → residual, with no HBM round-trips between
the stages.  This is the TPU re-think of the paper's edge-GPU embedding
hot-spot (DESIGN.md §Hardware-Adaptation): the CUDA version would stage
tiles through shared memory per threadblock; here the whole (T=64, D=128)
activation tile plus the weight tiles are VMEM-resident and every matmul
is MXU-shaped (multiples-of-8 × 128 operands).

VMEM budget per grid step (f32):
  activations  T×D × ~6 live tensors   ≈ 64·128·4·6   = 196 KiB
  weights      4·D·D + 2·D·4D + norms  ≈ (65.5k+131k)·4 = 786 KiB
  attention    H·T·T logits            = 4·64·64·4    = 64 KiB
  total ≈ 1.05 MiB  — comfortably inside a 16 MiB VMEM core, leaving room
  for double-buffering the next sequence's activations.

Must run with interpret=True on CPU (Mosaic custom-calls cannot execute on
the CPU PJRT plugin); the BlockSpecs still express the real HBM↔VMEM
schedule used for the §Perf estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_kernel(
    x_ref, ln1g_ref, ln1b_ref, wq_ref, wk_ref, wv_ref, wo_ref,
    ln2g_ref, ln2b_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref,
    *, n_heads: int,
):
    """Kernel body: x_ref is the [1, T, D] block for this grid step."""
    x = x_ref[0]                                   # [T, D] in VMEM
    t, d = x.shape
    dh = d // n_heads

    def ln(v, g, b):
        mu = jnp.mean(v, axis=-1, keepdims=True)
        var = jnp.mean((v - mu) ** 2, axis=-1, keepdims=True)
        return (v - mu) * jax.lax.rsqrt(var + 1e-6) * g + b

    # --- attention, fused ---
    xn = ln(x, ln1g_ref[...], ln1b_ref[...])
    q = (xn @ wq_ref[...]).reshape(t, n_heads, dh).transpose(1, 0, 2)
    k = (xn @ wk_ref[...]).reshape(t, n_heads, dh).transpose(1, 0, 2)
    v = (xn @ wv_ref[...]).reshape(t, n_heads, dh).transpose(1, 0, 2)
    logits = jnp.einsum("htd,hsd->hts", q, k) * (1.0 / jnp.sqrt(float(dh)))
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    att = jnp.einsum("hts,hsd->htd", p, v).transpose(1, 0, 2).reshape(t, d)
    h = x + att @ wo_ref[...]

    # --- MLP, fused ---
    z = ln(h, ln2g_ref[...], ln2b_ref[...])
    z = z @ w1_ref[...] + b1_ref[...]
    z = jax.nn.gelu(z, approximate=True)
    o_ref[0] = h + z @ w2_ref[...] + b2_ref[...]


def transformer_block(x, p, n_heads: int, *, interpret: bool = True):
    """Fused transformer block.  x: [B, T, D]; p: param dict (see ref.py).

    Grid = (B,): one sequence per step; weights are broadcast to every step
    (constant index_map) so Mosaic keeps them VMEM-resident across steps.
    """
    b, t, d = x.shape
    d_mlp = p["w1"].shape[1]

    def bcast(shape):
        # weight blocks: same block for every grid step
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    return pl.pallas_call(
        functools.partial(_block_kernel, n_heads=n_heads),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),   # x: one sequence
            bcast((d,)), bcast((d,)),                        # ln1 g/b
            bcast((d, d)), bcast((d, d)), bcast((d, d)), bcast((d, d)),  # wq wk wv wo
            bcast((d,)), bcast((d,)),                        # ln2 g/b
            bcast((d, d_mlp)), bcast((d_mlp,)),              # w1 b1
            bcast((d_mlp, d)), bcast((d,)),                  # w2 b2
        ],
        out_specs=pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), x.dtype),
        interpret=interpret,
    )(
        x, p["ln1_g"], p["ln1_b"], p["wq"], p["wk"], p["wv"], p["wo"],
        p["ln2_g"], p["ln2_b"], p["w1"], p["b1"], p["w2"], p["b2"],
    )
