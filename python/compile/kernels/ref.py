"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must
match its oracle to float tolerance under pytest (including hypothesis
shape/parameter sweeps).  The oracles are written for clarity, not speed.
"""

import jax.numpy as jnp
import jax

from compile.config import SCENE_POOL


# ---------------------------------------------------------------------------
# Transformer block (pre-LN MHA + MLP with residuals)
# ---------------------------------------------------------------------------

def layer_norm(x, gamma, beta, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(x, wq, wk, wv, wo, n_heads: int):
    """Multi-head self attention over x: [T, D]."""
    t, d = x.shape
    dh = d // n_heads
    q = (x @ wq).reshape(t, n_heads, dh).transpose(1, 0, 2)  # [H, T, dh]
    k = (x @ wk).reshape(t, n_heads, dh).transpose(1, 0, 2)
    v = (x @ wv).reshape(t, n_heads, dh).transpose(1, 0, 2)
    logits = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(float(dh))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hts,hsd->htd", probs, v)               # [H, T, dh]
    out = out.transpose(1, 0, 2).reshape(t, d)
    return out @ wo


def transformer_block(x, p, n_heads: int):
    """Reference block for one sequence x: [T, D]; p is the param dict."""
    h = x + attention(
        layer_norm(x, p["ln1_g"], p["ln1_b"]),
        p["wq"], p["wk"], p["wv"], p["wo"], n_heads,
    )
    z = layer_norm(h, p["ln2_g"], p["ln2_b"])
    z = jax.nn.gelu(z @ p["w1"] + p["b1"], approximate=True) @ p["w2"] + p["b2"]
    return h + z


def transformer_block_batched(x, p, n_heads: int):
    """x: [B, T, D]."""
    return jax.vmap(lambda xi: transformer_block(xi, p, n_heads))(x)


# ---------------------------------------------------------------------------
# Fused similarity + temperature softmax (Eq. 4–5)
# ---------------------------------------------------------------------------

def similarity_softmax(q, index, tau, n_valid):
    """Cosine scores of q vs rows of index, and softmax(s / tau) over the
    first ``n_valid`` rows (padding rows get score 0 / prob 0).

    q: [D] (assumed L2-normalized), index: [N, D] (rows L2-normalized),
    tau: scalar > 0, n_valid: scalar count (float for AOT friendliness).
    Returns (scores [N], probs [N]).
    """
    n = index.shape[0]
    scores = index @ q                                    # cosine: inputs normalized
    valid = jnp.arange(n, dtype=jnp.float32) < n_valid
    masked = jnp.where(valid, scores / tau, -jnp.inf)
    m = jnp.max(masked)
    e = jnp.where(valid, jnp.exp(masked - m), 0.0)
    probs = e / jnp.sum(e)
    scores = jnp.where(valid, scores, 0.0)
    return scores, probs


# ---------------------------------------------------------------------------
# Scene features (Eq. 1): pooled H, S, L, Sobel-edge maps
# ---------------------------------------------------------------------------

def rgb_to_hsl(rgb):
    """rgb: [..., 3] in [0,1] -> (h, s, l) each [...], h in [0,1]."""
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    c = mx - mn
    l = 0.5 * (mx + mn)
    s = jnp.where(c < 1e-8, 0.0, c / (1.0 - jnp.abs(2.0 * l - 1.0) + 1e-8))
    safe_c = jnp.where(c < 1e-8, 1.0, c)
    hr = jnp.mod((g - b) / safe_c, 6.0)
    hg = (b - r) / safe_c + 2.0
    hb = (r - g) / safe_c + 4.0
    h = jnp.where(mx == r, hr, jnp.where(mx == g, hg, hb))
    h = jnp.where(c < 1e-8, 0.0, h / 6.0)
    return h, s, l


def sobel_energy(l):
    """l: [H, W] lightness -> per-pixel Sobel gradient magnitude (edge pad)."""
    lp = jnp.pad(l, 1, mode="edge")
    tl, tc, tr = lp[:-2, :-2], lp[:-2, 1:-1], lp[:-2, 2:]
    ml, mr = lp[1:-1, :-2], lp[1:-1, 2:]
    bl, bc, br = lp[2:, :-2], lp[2:, 1:-1], lp[2:, 2:]
    gx = (tr + 2.0 * mr + br) - (tl + 2.0 * ml + bl)
    gy = (bl + 2.0 * bc + br) - (tl + 2.0 * tc + tr)
    return jnp.sqrt(gx * gx + gy * gy + 1e-12)


def scene_features_one(frame, pool: int = SCENE_POOL):
    """frame: [H, W, 3] in [0,1] -> [4 * pool^2] pooled (H, S, L, E) means.

    Layout: [h_cells..., s_cells..., l_cells..., e_cells...] (row-major cells).
    """
    h, s, l = rgb_to_hsl(frame)
    e = sobel_energy(l)
    size = frame.shape[0]
    cell = size // pool

    def pooled(m):
        return m.reshape(pool, cell, pool, cell).mean(axis=(1, 3)).reshape(-1)

    return jnp.concatenate([pooled(h), pooled(s), pooled(l), pooled(e)])


def scene_features(frames, pool: int = SCENE_POOL):
    """frames: [B, H, W, 3] -> [B, 4 * pool^2]."""
    return jax.vmap(lambda f: scene_features_one(f, pool))(frames)


def scene_score(feat_a, feat_b, weights):
    """Eq. 1: phi = ||w ⊙ (v_i − v_{i−1})||_1 / ||w||_1 with per-channel
    weights broadcast over pooled cells.  feats: [4*P^2], weights: [4]."""
    p2 = feat_a.shape[0] // 4
    w = jnp.repeat(weights, p2)
    return jnp.sum(w * jnp.abs(feat_a - feat_b)) / jnp.sum(w)
