"""L1 Pallas kernel: Eq. 1 scene features (pooled HSL + Sobel edge maps).

One grid step converts one frame to its 4·P² feature vector entirely in
VMEM: RGB→HSL (elementwise, VPU work), Sobel on the lightness plane
(shift-and-add stencil), then P×P average pooling of the four planes.
A 64×64×3 f32 frame is 48 KiB; all intermediate planes add ~64 KiB —
a single frame's working set is ≈ 160 KiB, so the kernel can double-buffer
many frames ahead of the VPU.

This is the perception front-end the paper runs on every captured frame
(25–60 FPS), so it must be cheap: there is no matmul at all, only
elementwise math and pooling reductions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.config import SCENE_POOL


def _scene_kernel(f_ref, o_ref, *, pool: int):
    frame = f_ref[0]                          # [H, W, 3]
    r, g, b = frame[..., 0], frame[..., 1], frame[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    c = mx - mn
    l = 0.5 * (mx + mn)
    s = jnp.where(c < 1e-8, 0.0, c / (1.0 - jnp.abs(2.0 * l - 1.0) + 1e-8))
    safe_c = jnp.where(c < 1e-8, 1.0, c)
    hr = jnp.mod((g - b) / safe_c, 6.0)
    hg = (b - r) / safe_c + 2.0
    hb = (r - g) / safe_c + 4.0
    h = jnp.where(mx == r, hr, jnp.where(mx == g, hg, hb))
    h = jnp.where(c < 1e-8, 0.0, h / 6.0)

    # Sobel magnitude on lightness (edge-padded stencil)
    lp = jnp.pad(l, 1, mode="edge")
    tl, tc, tr = lp[:-2, :-2], lp[:-2, 1:-1], lp[:-2, 2:]
    ml, mr = lp[1:-1, :-2], lp[1:-1, 2:]
    bl, bc, br = lp[2:, :-2], lp[2:, 1:-1], lp[2:, 2:]
    gx = (tr + 2.0 * mr + br) - (tl + 2.0 * ml + bl)
    gy = (bl + 2.0 * bc + br) - (tl + 2.0 * tc + tr)
    e = jnp.sqrt(gx * gx + gy * gy + 1e-12)

    size = frame.shape[0]
    cell = size // pool

    def pooled(m):
        return m.reshape(pool, cell, pool, cell).mean(axis=(1, 3)).reshape(-1)

    o_ref[0] = jnp.concatenate([pooled(h), pooled(s), pooled(l), pooled(e)])


def scene_features(frames, *, pool: int = SCENE_POOL, interpret: bool = True):
    """frames: [B, H, W, 3] in [0,1] -> [B, 4·pool²] feature vectors."""
    b, hgt, wid, _ = frames.shape
    feat = 4 * pool * pool
    return pl.pallas_call(
        functools.partial(_scene_kernel, pool=pool),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, hgt, wid, 3), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, feat), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, feat), jnp.float32),
        interpret=interpret,
    )(frames)
