"""L1 Pallas kernel: fused cosine-similarity + temperature softmax (Eq. 4–5).

The retrieval hot path.  One pass over the index matrix computes, per row
tile: the dot product with the query, validity masking, and the running
(max, sum) pair of an online softmax; a final epilogue normalizes.  The
index matrix is therefore read from HBM exactly once — the analog of the
paper's fused retrieval scoring, and the property the §Perf estimate is
based on.

Grid = (N / ROWS_PER_STEP,); each step streams a [R, D] tile of the index
into VMEM (R·D·4 = 128·64·4 = 32 KiB/tile), with the query vector and the
scalar accumulators resident across steps.  Online-softmax state lives in
two scratch accumulators carried via input_output_aliasing-free scratch
shapes (Pallas scratch_shapes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_STEP = 128


def _sim_kernel(q_ref, idx_ref, tau_ref, nvalid_ref,
                scores_ref, probs_ref, state_ref, *, n_total: int):
    """Streaming step: score one row tile and fold it into the online softmax.

    state_ref: [2] scratch = (running max m, running sum s of exp(x - m)).
    probs_ref holds un-normalized exp(x/τ - m_step) per step; the epilogue
    (last step) rescales every tile to the final (m, s).  To keep a single
    pass, each step writes exp with its *current* m and also records the
    per-tile m in scores... that would need a second pass.  Instead we use
    the standard trick: maintain global (m, s) in scratch and rescale the
    already-written prob tiles lazily — but Pallas output tiles are
    write-only per step.  So: write raw exp(x/τ) shifted by a *fixed*
    global bound (max possible score = 1/τ, since inputs are unit vectors),
    which is numerically safe because x/τ − 1/τ ∈ [−2/τ, 0] and τ ≥ 0.02
    keeps exp ≥ e−100 > f32 min-normal for the rows that matter; the sum
    accumulates in scratch and the epilogue divides.
    """
    i = pl.program_id(0)
    rows = idx_ref[...]                       # [R, D] tile
    q = q_ref[...]                            # [D]
    tau = tau_ref[0]
    n_valid = nvalid_ref[0]

    base = i * ROWS_PER_STEP
    ridx = base + jax.lax.iota(jnp.float32, rows.shape[0])
    valid = ridx < n_valid

    s = rows @ q                              # [R] cosine scores (unit inputs)
    s = jnp.where(valid, s, 0.0)
    scores_ref[...] = s

    # exp shifted by the analytic upper bound 1/τ (scores ≤ 1 for unit vectors)
    e = jnp.where(valid, jnp.exp((s - 1.0) / tau), 0.0)
    probs_ref[...] = e

    @pl.when(i == 0)
    def _init():
        state_ref[0] = 0.0

    state_ref[0] += jnp.sum(e)


def _normalize_kernel(e_ref, total_ref, o_ref):
    o_ref[...] = e_ref[...] / total_ref[0]


def similarity_softmax(q, index, tau, n_valid, *, interpret: bool = True):
    """Fused scores + softmax probs.  q: [D] unit vector; index: [N, D] with
    unit rows (padding rows arbitrary); tau, n_valid: scalars (f32).
    Returns (scores [N], probs [N]).  N must be a multiple of ROWS_PER_STEP.
    """
    n, d = index.shape
    assert n % ROWS_PER_STEP == 0, f"N={n} must be a multiple of {ROWS_PER_STEP}"
    grid = (n // ROWS_PER_STEP,)

    tau_v = jnp.asarray(tau, jnp.float32).reshape(1)
    nv_v = jnp.asarray(n_valid, jnp.float32).reshape(1)

    scores, expo, total = pl.pallas_call(
        functools.partial(_sim_kernel, n_total=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),                  # q resident
            pl.BlockSpec((ROWS_PER_STEP, d), lambda i: (i, 0)),  # index tile
            pl.BlockSpec((1,), lambda i: (0,)),                  # tau
            pl.BlockSpec((1,), lambda i: (0,)),                  # n_valid
        ],
        out_specs=[
            pl.BlockSpec((ROWS_PER_STEP,), lambda i: (i,)),      # scores
            pl.BlockSpec((ROWS_PER_STEP,), lambda i: (i,)),      # exp terms
            pl.BlockSpec((1,), lambda i: (0,)),                  # running sum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, index, tau_v, nv_v)

    probs = pl.pallas_call(
        _normalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_STEP,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_STEP,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(expo, total)

    return scores, probs
