"""L2: the Venus multimodal embedding model (MEM) as pure JAX functions.

A compact CLIP-style dual encoder.  Both towers run the L1 fused Pallas
transformer block (interpret=True, so it lowers to plain HLO that the Rust
CPU PJRT client can execute) and combine a *content* path (the transformer)
with a *semantic* path (the concept-code readout described in params.py).

Entry points exported by aot.py:
  - embed_image(images)            ingestion/ablation path, image only
  - embed_text(tokens)             query path
  - embed_fused(images, aux_toks)  ingestion path with aux prompts (Eq. 2–3)
  - scene_feat(frames)             Eq. 1 perception features
  - similarity(q, index, tau, nv)  Eq. 4–5 fused retrieval scoring
"""

import jax
import jax.numpy as jnp

from compile.config import MemConfig, SCENE_POOL
from compile.kernels import fused_block, similarity as sim_kernel, scene_score


def _l2norm(x, axis=-1, eps: float = 1e-8):
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def patchify(cfg: MemConfig, images):
    """images: [B, S, S, 3] -> [B, n_patches, patch_dim] (row-major patches)."""
    b = images.shape[0]
    g = cfg.img_size // cfg.patch
    x = images.reshape(b, g, cfg.patch, g, cfg.patch, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5)                 # [B, g, g, p, p, 3]
    return x.reshape(b, g * g, cfg.patch_dim)


# Watermark regions: patch 0 (top-left) and patch g-1 (top-right) carry the
# planted concept codes.  The Rust generator writes codes to these patches.
def watermark_patches(cfg: MemConfig):
    g = cfg.img_size // cfg.patch
    return (0, g - 1)


def image_tower(cfg: MemConfig, params, images, aux_tokens=None):
    """images: [B, S, S, 3] in [0,1] -> L2-normalized [B, d_embed].

    If aux_tokens is given ([B, seq_len] i32), their concept readout is
    fused into the semantic path with weight cfg.aux_weight (Eq. 3).
    """
    p_img, p_sem = params["img"], params["sem"]
    patches = patchify(cfg, images)                   # [B, T, patch_dim]

    # --- semantic path: watermark readout through w_r ---
    w0, w1 = watermark_patches(cfg)
    r0 = (patches[:, w0, :] - 0.5) @ p_sem["w_r"]     # [B, d_embed]
    r1 = (patches[:, w1, :] - 0.5) @ p_sem["w_r"]
    sem = r0 + r1
    if aux_tokens is not None:
        sem = sem + cfg.aux_weight * _text_semantic(cfg, params, aux_tokens)

    # --- content path: transformer over patch embeddings ---
    x = patches @ p_img["patch_proj"] + p_img["patch_bias"] + p_img["pos"]
    for blk in p_img["blocks"]:
        x = fused_block.transformer_block(x, blk, cfg.n_heads)
    content = _l2norm(jnp.mean(x, axis=1) @ p_img["content_proj"])

    return _l2norm(cfg.sem_weight * sem + cfg.content_weight * content)


def _text_semantic(cfg: MemConfig, params, tokens):
    """Concept-count readout: [B, seq] i32 -> [B, d_embed] (sum of concept
    directions for each concept token present, counted with multiplicity)."""
    p_sem = params["sem"]
    u = (p_sem["codes"] - 0.5) @ p_sem["w_r"]         # [C, d_embed]
    cids = cfg.concept_token_base + jnp.arange(cfg.n_concepts)
    counts = jnp.sum(
        (tokens[:, :, None] == cids[None, None, :]).astype(jnp.float32), axis=1
    )                                                  # [B, C]
    # normalize by count so repeated mentions don't dominate
    counts = counts / jnp.maximum(jnp.sum(counts, axis=1, keepdims=True), 1.0)
    return counts @ u


def text_tower(cfg: MemConfig, params, tokens):
    """tokens: [B, seq_len] i32 -> L2-normalized [B, d_embed]."""
    p_txt = params["txt"]
    sem = _text_semantic(cfg, params, tokens)

    x = p_txt["embed"][tokens] + p_txt["pos"]         # [B, T, D]
    for blk in p_txt["blocks"]:
        x = fused_block.transformer_block(x, blk, cfg.n_heads)
    content = _l2norm(jnp.mean(x, axis=1) @ p_txt["content_proj"])

    return _l2norm(cfg.sem_weight * sem + cfg.content_weight * content)


def scene_feat(frames):
    """Eq. 1 features, Pallas kernel: [B, S, S, 3] -> [B, 4·P²]."""
    return scene_score.scene_features(frames, pool=SCENE_POOL)


def similarity(q, index, tau, n_valid):
    """Eq. 4–5 fused retrieval scoring, Pallas kernel.
    q: [d_embed]; index: [N, d_embed]; scalars tau, n_valid."""
    return sim_kernel.similarity_softmax(q, index, tau, n_valid)


# ---------------------------------------------------------------------------
# Reference (pure-jnp) towers for pytest parity with the Pallas-kernel towers
# ---------------------------------------------------------------------------

def image_tower_ref(cfg: MemConfig, params, images, aux_tokens=None):
    from compile.kernels import ref
    p_img, p_sem = params["img"], params["sem"]
    patches = patchify(cfg, images)
    w0, w1 = watermark_patches(cfg)
    sem = (patches[:, w0, :] - 0.5) @ p_sem["w_r"] + (patches[:, w1, :] - 0.5) @ p_sem["w_r"]
    if aux_tokens is not None:
        sem = sem + cfg.aux_weight * _text_semantic(cfg, params, aux_tokens)
    x = patches @ p_img["patch_proj"] + p_img["patch_bias"] + p_img["pos"]
    for blk in p_img["blocks"]:
        x = ref.transformer_block_batched(x, blk, cfg.n_heads)
    content = _l2norm(jnp.mean(x, axis=1) @ p_img["content_proj"])
    return _l2norm(cfg.sem_weight * sem + cfg.content_weight * content)


def text_tower_ref(cfg: MemConfig, params, tokens):
    from compile.kernels import ref
    p_txt = params["txt"]
    sem = _text_semantic(cfg, params, tokens)
    x = p_txt["embed"][tokens] + p_txt["pos"]
    for blk in p_txt["blocks"]:
        x = ref.transformer_block_batched(x, blk, cfg.n_heads)
    content = _l2norm(jnp.mean(x, axis=1) @ p_txt["content_proj"])
    return _l2norm(cfg.sem_weight * sem + cfg.content_weight * content)
