"""Deterministic parameter construction for the dual-encoder MEM.

Weights are generated from `MemConfig.seed` with jax.random, so every
`make artifacts` run produces bit-identical artifacts for a given config
(the manifest records the config hash).  The *semantic projection* params
(`w_r`, `codes`) implement the trained-alignment emulation described in
DESIGN.md §1: the Rust synthetic video generator plants `codes[c]` pixels
into watermark regions of frames showing concept `c`, and both towers read
concepts out through the same `w_r`, guaranteeing cross-modal alignment.
"""

import jax
import jax.numpy as jnp

from compile.config import MemConfig


def _block_params(key, d_model: int, d_mlp: int):
    ks = jax.random.split(key, 6)
    sd = d_model ** -0.5
    return {
        "ln1_g": jnp.ones((d_model,), jnp.float32),
        "ln1_b": jnp.zeros((d_model,), jnp.float32),
        "wq": jax.random.normal(ks[0], (d_model, d_model), jnp.float32) * sd,
        "wk": jax.random.normal(ks[1], (d_model, d_model), jnp.float32) * sd,
        "wv": jax.random.normal(ks[2], (d_model, d_model), jnp.float32) * sd,
        "wo": jax.random.normal(ks[3], (d_model, d_model), jnp.float32) * sd,
        "ln2_g": jnp.ones((d_model,), jnp.float32),
        "ln2_b": jnp.zeros((d_model,), jnp.float32),
        "w1": jax.random.normal(ks[4], (d_model, d_mlp), jnp.float32) * sd,
        "b1": jnp.zeros((d_mlp,), jnp.float32),
        "w2": jax.random.normal(ks[5], (d_mlp, d_model), jnp.float32) * (d_mlp ** -0.5),
        "b2": jnp.zeros((d_model,), jnp.float32),
    }


def init_params(cfg: MemConfig):
    root = jax.random.PRNGKey(cfg.seed)
    k_img, k_txt, k_sem = jax.random.split(root, 3)

    # --- image tower ---
    ki = jax.random.split(k_img, 3 + cfg.n_blocks_img)
    img = {
        "patch_proj": jax.random.normal(
            ki[0], (cfg.patch_dim, cfg.d_model), jnp.float32) * (cfg.patch_dim ** -0.5),
        "patch_bias": jnp.zeros((cfg.d_model,), jnp.float32),
        "pos": jax.random.normal(
            ki[1], (cfg.n_patches, cfg.d_model), jnp.float32) * 0.02,
        "content_proj": jax.random.normal(
            ki[2], (cfg.d_model, cfg.d_embed), jnp.float32) * (cfg.d_model ** -0.5),
        "blocks": [
            _block_params(ki[3 + i], cfg.d_model, cfg.d_mlp)
            for i in range(cfg.n_blocks_img)
        ],
    }

    # --- text tower ---
    kt = jax.random.split(k_txt, 3 + cfg.n_blocks_txt)
    txt = {
        "embed": jax.random.normal(
            kt[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.5,
        "pos": jax.random.normal(
            kt[1], (cfg.seq_len, cfg.d_model), jnp.float32) * 0.02,
        "content_proj": jax.random.normal(
            kt[2], (cfg.d_model, cfg.d_embed), jnp.float32) * (cfg.d_model ** -0.5),
        "blocks": [
            _block_params(kt[3 + i], cfg.d_model, cfg.d_mlp)
            for i in range(cfg.n_blocks_txt)
        ],
    }

    # --- semantic projection ---
    ks = jax.random.split(k_sem, 2)
    # w_r scaled so that || w_r^T (code - 0.5) || ~= 1 for uniform codes
    # (per-coord var 1/d_embed  =>  std = sqrt(12 / (patch_dim * d_embed)))
    wr_std = (12.0 / (cfg.patch_dim * cfg.d_embed)) ** 0.5
    sem = {
        "w_r": jax.random.normal(
            ks[0], (cfg.patch_dim, cfg.d_embed), jnp.float32) * wr_std,
        # codes in [0,1]: pixel values the Rust generator plants verbatim
        "codes": jax.random.uniform(
            ks[1], (cfg.n_concepts, cfg.patch_dim), jnp.float32),
    }

    return {"img": img, "txt": txt, "sem": sem}


def concept_directions(params):
    """U[c] = w_r^T (codes[c] - 0.5): the embedding-space direction of each
    concept.  Shared by the image readout, the text semantic path, and the
    Rust-side tests (exported via artifacts/concept_codes.bin)."""
    sem = params["sem"]
    return (sem["codes"] - 0.5) @ sem["w_r"]          # [C, d_embed]
