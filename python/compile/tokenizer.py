"""Shared tokenizer spec (mirrored bit-for-bit by rust/src/embed/tokenizer.rs).

Vocabulary layout:
  0                                   PAD
  1                                   UNK (never produced; reserved)
  [concept_token_base, base+C)        concept tokens ("concept00".."concept31"
                                      plus Rust-side aliases)
  [base+C, vocab)                     hashed word ids: FNV-1a(32) of the
                                      lowercased utf-8 word, mod the range

Both sides must produce identical ids for identical words — verified by the
tokenizer goldens in artifacts/manifest.json.
"""

from compile.config import MemConfig

FNV_OFFSET = 0x811C9DC5
FNV_PRIME = 0x01000193


def fnv1a(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & 0xFFFFFFFF
    return h


def concept_word(c: int) -> str:
    return f"concept{c:02d}"


def tokenize(text: str, cfg: MemConfig):
    """Lowercase whitespace split -> fixed-length id list (PAD-padded)."""
    base = cfg.concept_token_base
    hash_base = base + cfg.n_concepts
    hash_range = cfg.vocab - hash_base
    ids = []
    for word in text.lower().split():
        word = word.strip(".,?!\"'")
        if not word:
            continue
        if word.startswith("concept") and word[7:].isdigit():
            c = int(word[7:])
            if c < cfg.n_concepts:
                ids.append(base + c)
                continue
        ids.append(hash_base + fnv1a(word.encode()) % hash_range)
    ids = ids[: cfg.seq_len]
    ids += [0] * (cfg.seq_len - len(ids))
    return ids
