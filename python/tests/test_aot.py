"""AOT artifact validation: manifest integrity, HLO text round-trip safety
(no elided constants), golden files, and executable parity of the lowered
modules against the reference towers."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import DEFAULT as CFG
from compile import aot, model, params as params_mod, tokenizer

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_config_hash_matches(self, manifest):
        assert manifest["config_hash"] == CFG.config_hash()

    def test_all_entries_present(self, manifest):
        expected = {
            "embed_image_b1", "embed_image_b8", "embed_image_b32",
            "embed_text_b1", "embed_fused_b8", "scene_feat_b8",
            "similarity_n1024",
        }
        assert expected == set(manifest["entries"])

    def test_entry_files_exist_and_shapes_sane(self, manifest):
        for name, e in manifest["entries"].items():
            path = os.path.join(ART, e["file"])
            assert os.path.exists(path), name
            assert e["outputs"], name
            for io in e["inputs"] + e["outputs"]:
                assert all(d > 0 for d in io["shape"]), (name, io)

    def test_no_elided_constants(self, manifest):
        """`constant({...})` in the text means weights were dropped."""
        for name, e in manifest["entries"].items():
            with open(os.path.join(ART, e["file"])) as f:
                text = f.read()
            assert "constant({...})" not in text, name

    def test_side_files(self, manifest):
        for key, meta in manifest["files"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), key
            n = int(np.prod(meta["shape"]))
            itemsize = 4
            assert os.path.getsize(path) == n * itemsize, key


class TestGoldens:
    def test_golden_image_embedding(self, manifest):
        prm = params_mod.init_params(CFG)
        codes = np.asarray(prm["sem"]["codes"], dtype=np.float32)
        img = aot.golden_image(CFG, codes, concept=5)
        want = np.fromfile(os.path.join(ART, "golden_image_emb.bin"), "<f4")
        got = np.asarray(
            model.image_tower_ref(CFG, prm, jnp.asarray(img)[None])
        )[0]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_golden_tokens_match_tokenizer(self, manifest):
        text = manifest["files"]["golden_tokens"]["text"]
        want = np.fromfile(os.path.join(ART, "golden_tokens.bin"), "<i4")
        assert tokenizer.tokenize(text, CFG) == want.tolist()

    def test_concept_dirs_consistent(self, manifest):
        codes = np.fromfile(
            os.path.join(ART, "concept_codes.bin"), "<f4"
        ).reshape(CFG.n_concepts, CFG.patch_dim)
        dirs = np.fromfile(
            os.path.join(ART, "concept_dirs.bin"), "<f4"
        ).reshape(CFG.n_concepts, CFG.d_embed)
        prm = params_mod.init_params(CFG)
        want = (codes - 0.5) @ np.asarray(prm["sem"]["w_r"])
        np.testing.assert_allclose(dirs, want, rtol=1e-4, atol=1e-5)


class TestHloTextRoundTrip:
    """The emitted text must parse back into an HloModule (the exact parser
    the Rust xla crate invokes via HloModuleProto::from_text_file).  Full
    numeric parity of the Rust execution path is asserted by
    rust/tests/runtime_goldens.rs against the golden_*.bin files."""

    def test_all_artifacts_parse(self, manifest):
        from jax._src.lib import xla_client as xc
        for name, e in manifest["entries"].items():
            with open(os.path.join(ART, e["file"])) as f:
                text = f.read()
            mod = xc._xla.hlo_module_from_text(text)
            assert mod is not None, name

    def test_entry_parameter_layout_matches_manifest(self, manifest):
        """The HLO entry computation's parameters appear in manifest order."""
        for name, e in manifest["entries"].items():
            with open(os.path.join(ART, e["file"])) as f:
                head = f.read(4096)
            # entry_computation_layout={(<in0>,<in1>,...)->...}
            assert "entry_computation_layout=" in head, name
            for io in e["inputs"]:
                dt = {"float32": "f32", "int32": "s32"}[io["dtype"]]
                token = dt + "[" + ",".join(str(d) for d in io["shape"]) + "]"
                assert token in head, (name, token)
