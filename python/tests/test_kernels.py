"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Includes hypothesis sweeps over shapes, temperatures, and input scales, as
well as hand-picked edge cases (single valid row, saturated colors, etc.).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import MemConfig, SCENE_POOL
from compile.kernels import fused_block, similarity, scene_score, ref
from compile import params as params_mod

CFG = MemConfig()
RNG = np.random.default_rng(0)


def _rand(*shape, scale=1.0, rng=RNG):
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


@pytest.fixture(scope="module")
def block_params():
    return params_mod.init_params(CFG)["img"]["blocks"][0]


# ---------------------------------------------------------------------------
# fused transformer block
# ---------------------------------------------------------------------------

class TestFusedBlock:
    def test_matches_ref(self, block_params):
        x = _rand(2, CFG.n_patches, CFG.d_model)
        got = fused_block.transformer_block(x, block_params, CFG.n_heads)
        want = ref.transformer_block_batched(x, block_params, CFG.n_heads)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_batch_one(self, block_params):
        x = _rand(1, CFG.n_patches, CFG.d_model)
        got = fused_block.transformer_block(x, block_params, CFG.n_heads)
        want = ref.transformer_block_batched(x, block_params, CFG.n_heads)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_batch_independence(self, block_params):
        """Row i of the batched output equals the single-sequence output."""
        x = _rand(4, CFG.n_patches, CFG.d_model)
        full = fused_block.transformer_block(x, block_params, CFG.n_heads)
        one = fused_block.transformer_block(x[2:3], block_params, CFG.n_heads)
        np.testing.assert_allclose(full[2:3], one, rtol=1e-5, atol=1e-5)

    def test_deterministic(self, block_params):
        x = _rand(2, CFG.n_patches, CFG.d_model)
        a = fused_block.transformer_block(x, block_params, CFG.n_heads)
        b = fused_block.transformer_block(x, block_params, CFG.n_heads)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 4),
        scale=st.floats(0.01, 4.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, block_params, b, scale, seed):
        rng = np.random.default_rng(seed)
        x = _rand(b, CFG.n_patches, CFG.d_model, scale=scale, rng=rng)
        got = fused_block.transformer_block(x, block_params, CFG.n_heads)
        want = ref.transformer_block_batched(x, block_params, CFG.n_heads)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale)

    @settings(max_examples=6, deadline=None)
    @given(heads=st.sampled_from([1, 2, 4, 8]), t=st.sampled_from([8, 16, 64]))
    def test_shape_sweep(self, heads, t):
        """Kernel handles different head counts and sequence lengths."""
        d, d_mlp = 64, 128
        rng = np.random.default_rng(heads * 1000 + t)
        sd = d ** -0.5
        p = {
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "wq": _rand(d, d, scale=sd, rng=rng), "wk": _rand(d, d, scale=sd, rng=rng),
            "wv": _rand(d, d, scale=sd, rng=rng), "wo": _rand(d, d, scale=sd, rng=rng),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
            "w1": _rand(d, d_mlp, scale=sd, rng=rng), "b1": jnp.zeros((d_mlp,)),
            "w2": _rand(d_mlp, d, scale=d_mlp ** -0.5, rng=rng), "b2": jnp.zeros((d,)),
        }
        x = _rand(2, t, d, rng=rng)
        got = fused_block.transformer_block(x, p, heads)
        want = ref.transformer_block_batched(x, p, heads)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# fused similarity + softmax
# ---------------------------------------------------------------------------

def _unit_rows(n, d, rng):
    m = rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(m / np.linalg.norm(m, axis=1, keepdims=True))


class TestSimilarity:
    def _check(self, n, n_valid, tau, seed=0):
        rng = np.random.default_rng(seed)
        index = _unit_rows(n, CFG.d_embed, rng)
        q = _unit_rows(1, CFG.d_embed, rng)[0]
        got_s, got_p = similarity.similarity_softmax(q, index, tau, float(n_valid))
        want_s, want_p = ref.similarity_softmax(q, index, tau, float(n_valid))
        np.testing.assert_allclose(got_s, want_s, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-6)
        assert abs(float(jnp.sum(got_p)) - 1.0) < 1e-4
        # padding rows have zero probability and zero score
        np.testing.assert_allclose(got_p[n_valid:], 0.0)
        np.testing.assert_allclose(got_s[n_valid:], 0.0)

    def test_full(self):
        self._check(1024, 1024, 0.1)

    def test_partial_valid(self):
        self._check(1024, 700, 0.1)

    def test_single_valid_row(self):
        self._check(256, 1, 0.5)
        # one valid row -> its probability is exactly 1
        rng = np.random.default_rng(7)
        index = _unit_rows(256, CFG.d_embed, rng)
        q = _unit_rows(1, CFG.d_embed, rng)[0]
        _, p = similarity.similarity_softmax(q, index, 0.5, 1.0)
        assert abs(float(p[0]) - 1.0) < 1e-5

    def test_small_tile_count(self):
        self._check(128, 128, 0.2)

    def test_uniform_when_tau_large(self):
        """tau -> inf gives a uniform distribution over valid rows."""
        rng = np.random.default_rng(3)
        index = _unit_rows(512, CFG.d_embed, rng)
        q = _unit_rows(1, CFG.d_embed, rng)[0]
        _, p = similarity.similarity_softmax(q, index, 1e6, 512.0)
        np.testing.assert_allclose(p, 1.0 / 512.0, rtol=1e-3)

    def test_identical_query_row_dominates(self):
        """With small tau, an exact-match row takes nearly all the mass."""
        rng = np.random.default_rng(4)
        index = np.asarray(_unit_rows(256, CFG.d_embed, rng))
        q = index[37]
        _, p = similarity.similarity_softmax(
            jnp.asarray(q), jnp.asarray(index), 0.02, 256.0
        )
        assert int(jnp.argmax(p)) == 37

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([128, 256, 512, 1024]),
        frac=st.floats(0.01, 1.0),
        tau=st.floats(0.05, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, frac, tau, seed):
        n_valid = max(1, int(n * frac))
        self._check(n, n_valid, tau, seed)


# ---------------------------------------------------------------------------
# scene features
# ---------------------------------------------------------------------------

class TestSceneFeatures:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        frames = jnp.asarray(rng.random((4, 64, 64, 3)).astype(np.float32))
        got = scene_score.scene_features(frames)
        want = ref.scene_features(frames)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_constant_frame_has_no_edges(self):
        frames = jnp.full((1, 64, 64, 3), 0.5, jnp.float32)
        feat = np.asarray(scene_score.scene_features(frames))[0]
        p2 = SCENE_POOL * SCENE_POOL
        np.testing.assert_allclose(feat[3 * p2:], 0.0, atol=1e-4)   # edges ~ 0
        np.testing.assert_allclose(feat[1 * p2: 2 * p2], 0.0, atol=1e-6)  # sat 0
        np.testing.assert_allclose(feat[2 * p2: 3 * p2], 0.5, atol=1e-6)  # light

    def test_saturated_primaries(self):
        """Pure red/green/blue frames give the canonical hues (0, 1/3, 2/3)."""
        p2 = SCENE_POOL * SCENE_POOL
        for rgb, hue in [((1, 0, 0), 0.0), ((0, 1, 0), 1 / 3), ((0, 0, 1), 2 / 3)]:
            f = np.zeros((1, 64, 64, 3), np.float32)
            f[..., 0], f[..., 1], f[..., 2] = rgb
            feat = np.asarray(scene_score.scene_features(jnp.asarray(f)))[0]
            np.testing.assert_allclose(feat[:p2], hue, atol=1e-5)
            np.testing.assert_allclose(feat[p2: 2 * p2], 1.0, atol=1e-4)

    def test_vertical_edge_detected(self):
        f = np.zeros((1, 64, 64, 3), np.float32)
        f[:, :, 32:, :] = 1.0
        feat = np.asarray(scene_score.scene_features(jnp.asarray(f)))[0]
        p2 = SCENE_POOL * SCENE_POOL
        edges = feat[3 * p2:].reshape(SCENE_POOL, SCENE_POOL)
        # edge energy concentrates in the middle columns
        assert edges[:, 1:3].sum() > 10 * edges[:, 0].sum()

    def test_scene_score_metric(self):
        """Eq. 1 score is 0 for identical frames and positive otherwise."""
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.random((64, 64, 3)).astype(np.float32))
        b = jnp.asarray(rng.random((64, 64, 3)).astype(np.float32))
        fa = ref.scene_features_one(a)
        fb = ref.scene_features_one(b)
        w = jnp.asarray([1.0, 1.0, 1.0, 2.0])
        assert float(ref.scene_score(fa, fa, w)) == pytest.approx(0.0, abs=1e-7)
        assert float(ref.scene_score(fa, fb, w)) > 0.0

    @settings(max_examples=8, deadline=None)
    @given(b=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, b, seed):
        rng = np.random.default_rng(seed)
        frames = jnp.asarray(rng.random((b, 64, 64, 3)).astype(np.float32))
        got = scene_score.scene_features(frames)
        want = ref.scene_features(frames)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
