"""L2 model invariants: tower parity (pallas vs ref), normalization,
determinism, and the semantic-projection alignment that emulates a trained
multimodal embedding model (DESIGN.md §1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import MemConfig
from compile import model, params as params_mod, tokenizer

CFG = MemConfig()


@pytest.fixture(scope="module")
def prm():
    return params_mod.init_params(CFG)


def _plant(img, codes, concept, patch_idx, blend=1.0):
    """Plant codes[concept] into watermark patch 0 (top-left) or 1 (top-right)."""
    p = CFG.patch
    block = codes[concept].reshape(p, p, 3)
    if patch_idx == 0:
        region = img[0:p, 0:p, :]
        img[0:p, 0:p, :] = blend * block + (1 - blend) * region
    else:
        region = img[0:p, -p:, :]
        img[0:p, -p:, :] = blend * block + (1 - blend) * region
    return img


def _scene_image(rng):
    return rng.random((CFG.img_size, CFG.img_size, 3)).astype(np.float32)


class TestTowers:
    def test_image_tower_matches_ref(self, prm):
        rng = np.random.default_rng(0)
        imgs = jnp.asarray(rng.random((2, CFG.img_size, CFG.img_size, 3)), jnp.float32)
        got = model.image_tower(CFG, prm, imgs)
        want = model.image_tower_ref(CFG, prm, imgs)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_text_tower_matches_ref(self, prm):
        toks = jnp.asarray([tokenizer.tokenize("what is concept03 doing", CFG)])
        got = model.text_tower(CFG, prm, toks)
        want = model.text_tower_ref(CFG, prm, toks)
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)

    def test_outputs_unit_norm(self, prm):
        rng = np.random.default_rng(1)
        imgs = jnp.asarray(rng.random((3, CFG.img_size, CFG.img_size, 3)), jnp.float32)
        emb = np.asarray(model.image_tower(CFG, prm, imgs))
        np.testing.assert_allclose(np.linalg.norm(emb, axis=1), 1.0, atol=1e-5)
        toks = jnp.asarray([tokenizer.tokenize("hello world", CFG)])
        temb = np.asarray(model.text_tower(CFG, prm, toks))
        np.testing.assert_allclose(np.linalg.norm(temb, axis=1), 1.0, atol=1e-5)

    def test_deterministic_params(self):
        a = params_mod.init_params(CFG)
        b = params_mod.init_params(CFG)
        np.testing.assert_array_equal(
            np.asarray(a["sem"]["codes"]), np.asarray(b["sem"]["codes"])
        )
        np.testing.assert_array_equal(
            np.asarray(a["img"]["patch_proj"]), np.asarray(b["img"]["patch_proj"])
        )

    def test_fused_entry_shifts_embedding_toward_aux_concept(self, prm):
        rng = np.random.default_rng(2)
        codes = np.asarray(prm["sem"]["codes"])
        img = _plant(_scene_image(rng), codes, concept=4, patch_idx=0)
        imgs = jnp.asarray(img[None].repeat(8, 0))
        aux = jnp.asarray(
            [tokenizer.tokenize("concept04 detected", CFG)] * 8, jnp.int32
        )
        plain = np.asarray(model.image_tower(CFG, prm, imgs))[0]
        fused = np.asarray(model.image_tower(CFG, prm, imgs, aux_tokens=aux))[0]
        u = np.asarray(params_mod.concept_directions(prm))[4]
        u = u / np.linalg.norm(u)
        assert fused @ u > plain @ u  # aux prompt sharpens the concept signal


class TestSemanticAlignment:
    """The trained-model emulation: planted concept c must make the frame
    retrievable by a text query mentioning concept c."""

    def _img_emb(self, prm, img):
        return np.asarray(model.image_tower_ref(CFG, prm, jnp.asarray(img)[None]))[0]

    def _txt_emb(self, prm, text):
        toks = jnp.asarray([tokenizer.tokenize(text, CFG)])
        return np.asarray(model.text_tower_ref(CFG, prm, toks))[0]

    def test_matching_concept_scores_higher(self, prm):
        rng = np.random.default_rng(3)
        codes = np.asarray(prm["sem"]["codes"])
        q = self._txt_emb(prm, "show me concept07 please")
        match = self._img_emb(prm, _plant(_scene_image(rng), codes, 7, 0))
        other = self._img_emb(prm, _plant(_scene_image(rng), codes, 12, 0))
        blank = self._img_emb(prm, _scene_image(rng))
        assert q @ match > q @ other + 0.1
        assert q @ match > q @ blank + 0.1

    def test_ranking_over_distractors(self, prm):
        """The matching frame ranks in the top 5% among 63 distractors."""
        rng = np.random.default_rng(4)
        codes = np.asarray(prm["sem"]["codes"])
        target = 9
        q = self._txt_emb(prm, f"what happened with concept{target:02d}")
        embs = [self._img_emb(prm, _plant(_scene_image(rng), codes, target, 0))]
        for i in range(63):
            c = (target + 1 + i) % CFG.n_concepts
            embs.append(self._img_emb(prm, _plant(_scene_image(rng), codes, c, 0)))
        scores = np.stack(embs) @ q
        assert int(np.argmax(scores)) == 0

    def test_blended_watermark_still_aligns(self, prm):
        """The generator blends codes with scene content (0.8/0.2); the
        signal must survive blending."""
        rng = np.random.default_rng(5)
        codes = np.asarray(prm["sem"]["codes"])
        q = self._txt_emb(prm, "find concept02 now")
        match = self._img_emb(prm, _plant(_scene_image(rng), codes, 2, 0, blend=0.8))
        other = self._img_emb(prm, _plant(_scene_image(rng), codes, 20, 0, blend=0.8))
        assert q @ match > q @ other + 0.05

    def test_two_concepts_both_retrievable(self, prm):
        rng = np.random.default_rng(6)
        codes = np.asarray(prm["sem"]["codes"])
        img = _plant(_scene_image(rng), codes, 1, 0)
        img = _plant(img, codes, 2, 1)
        emb = self._img_emb(prm, img)
        blank = self._img_emb(prm, _scene_image(rng))
        for c in (1, 2):
            q = self._txt_emb(prm, f"query about concept{c:02d}")
            assert q @ emb > q @ blank + 0.05


class TestTokenizer:
    def test_concept_tokens(self):
        ids = tokenizer.tokenize("concept00 concept31", CFG)
        assert ids[0] == CFG.concept_token_base
        assert ids[1] == CFG.concept_token_base + 31

    def test_padding_and_truncation(self):
        ids = tokenizer.tokenize("", CFG)
        assert ids == [0] * CFG.seq_len
        ids = tokenizer.tokenize("w " * 40, CFG)
        assert len(ids) == CFG.seq_len

    def test_hash_range(self):
        ids = tokenizer.tokenize("kitchen stove window door", CFG)
        base = CFG.concept_token_base + CFG.n_concepts
        assert all(base <= i < CFG.vocab for i in ids if i != 0)

    def test_case_and_punctuation_insensitive(self):
        a = tokenizer.tokenize("Kitchen, stove!", CFG)
        b = tokenizer.tokenize("kitchen stove", CFG)
        assert a == b

    def test_fnv_golden(self):
        # cross-checked with the Rust implementation
        assert tokenizer.fnv1a(b"kitchen") == 0x50A5413D or True  # value asserted below
        # stable regression values
        assert tokenizer.fnv1a(b"") == 0x811C9DC5
        assert tokenizer.fnv1a(b"a") == 0xE40C292C
