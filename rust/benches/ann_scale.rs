//! ANN-scale bench: what SQ8 quantization + coarse segment-skipping buy
//! on a cold-heavy shard at ~10⁵ records.
//!
//! Two durable shards ingest the same cluster-coherent stream (the
//! camera dwells on one scene per segment-sized run, so sealed segments
//! have structure the coarse index can route on), with a hot budget of
//! ~2 segments so ≳95% of records score through the cold tier:
//!
//!  * exact     — `quantization = "none"`, `coarse_nprobe = 0`: the
//!    selection-bit-identical baseline (full f32 scan of every segment);
//!  * sq8+coarse — `quantization = "sq8"`,
//!    `coarse_centroids_per_segment = 8`, `coarse_nprobe = 8`: u8 codes
//!    scored asymmetrically, only the top-8 segments by centroid score
//!    fully scanned.
//!
//! Reported: recall@k of the approximate scan against exact selection
//! (k = the retrieval sampling budget — the gate the tier-1
//! `ann_quantization` test enforces at smaller scale), score-throughput
//! speedup, and the p50/p95 latency ratio.
//!
//! Run: `cargo bench --bench ann_scale`  (`make bench-json` persists
//! `BENCH_ann_scale.json`).  Env knobs:
//!  * `ANN_SCALE_N`       record count (default 100_000; CI uses less)
//!  * `ANN_SCALE_ASSERT=1` enforce the ≥4× throughput / ≥2× p95 /
//!    ≥0.95 recall acceptance thresholds (off by default: shared CI
//!    runners make wall-clock ratios noisy)

use std::path::PathBuf;
use std::time::Instant;

use venus::config::{MemoryConfig, RetrievalConfig};
use venus::memory::{ClusterRecord, Hierarchy, StreamId};
use venus::util::bench::{note, section, Bench};
use venus::util::rng::Pcg64;
use venus::util::stats::{fmt_bytes, Samples};
use venus::video::frame::Frame;

const D: usize = 64;
const FRAME: usize = 8;
const CLUSTERS: usize = 64;
const SEG: usize = 1024; // records per sealed segment == cluster run length

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir()
            .join(format!("venus-annscale-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn centers(rng: &mut Pcg64) -> Vec<Vec<f32>> {
    (0..CLUSTERS)
        .map(|_| {
            let mut c: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut c);
            c
        })
        .collect()
}

fn cfg(quantized: bool) -> MemoryConfig {
    let rec_bytes = D * 4 + std::mem::size_of::<ClusterRecord>() + 8;
    MemoryConfig {
        segment_records: SEG,
        hot_budget_bytes: 2 * SEG * rec_bytes,
        // every cold block stays resident: the comparison is CPU-bound
        // kernels + segment skipping, not cache-miss IO
        cold_cache_segments: 256,
        quantization: if quantized { "sq8".into() } else { "none".into() },
        coarse_nprobe: if quantized { 8 } else { 0 },
        coarse_centroids_per_segment: if quantized { 8 } else { 0 },
        ..Default::default()
    }
}

/// Ingest `n` records in segment-aligned cluster runs; returns inserts/s.
fn ingest(h: &mut Hierarchy, n: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    let cs = centers(&mut rng);
    let t0 = Instant::now();
    for i in 0..n {
        let c = &cs[(i / SEG) % CLUSTERS];
        let mut v: Vec<f32> = c.iter().map(|x| x + 0.15 * rng.normal()).collect();
        venus::util::l2_normalize(&mut v);
        h.archive_frame(i as u64, &Frame::filled(FRAME, [0.5; 3])).unwrap();
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: i,
                centroid_frame: i as u64,
                members: vec![i as u64],
            },
        )
        .unwrap();
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

fn topk(scores: &[f32], k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order.truncate(k);
    order
}

fn main() {
    let n: usize = std::env::var("ANN_SCALE_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let enforce = std::env::var("ANN_SCALE_ASSERT").as_deref() == Ok("1");
    let k = RetrievalConfig::default().budget;

    section("ann_scale — quantized cold tier + coarse segment skipping");
    note(&format!(
        "{n} records, d={D}, {CLUSTERS} scene clusters, segment={SEG} records"
    ));

    let tmp = TempDir::new("bench");
    let mut exact =
        Hierarchy::durable(&cfg(false), D, StreamId(0), &tmp.0.join("exact"), FRAME).unwrap();
    let exact_ips = ingest(&mut exact, n, 42);
    let mut approx =
        Hierarchy::durable(&cfg(true), D, StreamId(0), &tmp.0.join("approx"), FRAME).unwrap();
    let approx_ips = ingest(&mut approx, n, 42);
    let ts = approx.tier_stats();
    note(&format!(
        "ingest: exact {exact_ips:.0}/s, sq8+coarse {approx_ips:.0}/s \
         (seal-time quantization + centroid training cost)"
    ));
    note(&format!(
        "tier split: {} hot / {} cold in {} segments; cold resident {} (sq8) vs {} (exact)",
        ts.hot_records,
        ts.cold_records,
        ts.cold_segments,
        fmt_bytes(ts.cold_resident_bytes),
        fmt_bytes(exact.tier_stats().cold_resident_bytes),
    ));

    // fixed query set near cluster centers (what real queries look like:
    // "the forklift scene", not isotropic noise)
    let cs = centers(&mut Pcg64::seeded(42));
    let mut qrng = Pcg64::seeded(7);
    let queries: Vec<Vec<f32>> = (0..64)
        .map(|qi| {
            let mut q: Vec<f32> =
                cs[qi % CLUSTERS].iter().map(|x| x + 0.1 * qrng.normal()).collect();
            venus::util::l2_normalize(&mut q);
            q
        })
        .collect();

    // recall@k of approximate selection vs exact selection
    let (mut se, mut sa) = (Vec::new(), Vec::new());
    let mut overlap = 0usize;
    for q in &queries {
        exact.score_all(q, &mut se).unwrap();
        approx.score_all(q, &mut sa).unwrap();
        let want = topk(&se, k);
        let got = topk(&sa, k);
        overlap += want.iter().filter(|id| got.contains(id)).count();
    }
    let recall = overlap as f64 / (queries.len() * k) as f64;

    // latency distributions (per-query full score_all)
    let mut scores = Vec::new();
    let mut run = |h: &Hierarchy| {
        let mut lat = Samples::default();
        for _ in 0..3 {
            for q in &queries {
                let t0 = Instant::now();
                h.score_all(q, &mut scores).unwrap();
                std::hint::black_box(scores.len());
                lat.push(t0.elapsed().as_secs_f64());
            }
        }
        lat
    };
    let le = run(&exact);
    let la = run(&approx);
    let speedup = le.mean() / la.mean();
    let p95_ratio = le.p95() / la.p95();

    println!();
    println!(
        "  exact       p50 {:>9.1} µs   p95 {:>9.1} µs   {:>12.0} rows/s",
        le.p50() * 1e6,
        le.p95() * 1e6,
        n as f64 / le.mean()
    );
    println!(
        "  sq8+coarse  p50 {:>9.1} µs   p95 {:>9.1} µs   {:>12.0} rows/s (vs full scan)",
        la.p50() * 1e6,
        la.p95() * 1e6,
        n as f64 / la.mean()
    );
    let ts = approx.tier_stats();
    println!(
        "  recall@{k} {recall:.4}   throughput x{speedup:.1}   p95 x{p95_ratio:.1}   \
         scanned {}/{} segment probes",
        ts.cold_probe_segments, ts.cold_probe_candidates
    );

    // the Bench runner persists the machine-readable trajectory
    // (BENCH_ann_scale.json) when BENCH_JSON_DIR is set
    let mut b = Bench::quick();
    let q = &queries[0];
    b.run("score_all exact (full f32 scan)", || {
        exact.score_all(q, &mut scores).unwrap();
        scores.len()
    });
    b.run("score_all sq8+coarse (nprobe=8)", || {
        approx.score_all(q, &mut scores).unwrap();
        scores.len()
    });

    assert!(
        recall >= 0.95,
        "recall@{k} = {recall:.3} below the 0.95 gate"
    );
    if enforce {
        assert!(
            speedup >= 4.0,
            "score throughput x{speedup:.2} below the 4x acceptance bar"
        );
        assert!(
            p95_ratio >= 2.0,
            "p95 ratio x{p95_ratio:.2} below the 2x acceptance bar"
        );
    }
}
