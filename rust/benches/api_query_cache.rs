//! api_query_cache — semantic query-cache bench (Serving API v1).
//!
//! Online video-QA traffic is highly repetitive; the cache turns a
//! repeat query into a hash lookup + watermark check instead of a text
//! embed + scatter-gather score + selection + raw fetch.  This bench
//! ingests a real stream, then measures the edge-side query latency of
//!   * cold queries (cache miss: full edge path + insert),
//!   * warm repeats (exact-tier hit: everything skipped),
//!   * near-duplicate rewordings (semantic-tier hit: embed only),
//! and reports the speedup.  Acceptance target: warm-repeat p50 at
//! least 5× lower than cold p50.

use std::sync::Arc;

use venus::api::{CacheStatus, QueryCache};
use venus::config::VenusConfig;
use venus::coordinator::query::QueryEngine;
use venus::embed::EmbedEngine;
use venus::eval::prepare_case;
use venus::memory::StreamScope;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Samples, Table};
use venus::video::workload::DatasetPreset;

const QUERIES: usize = 16;
const WARM_ROUNDS: usize = 3;

fn main() {
    section("api_query_cache — cold vs cache-hit edge query latency");
    let cfg = VenusConfig::default();
    note(&format!(
        "cache: {} entries, threshold {}, staleness bound {} inserts/shard",
        cfg.api.cache_entries, cfg.api.cache_threshold, cfg.api.cache_max_stale
    ));

    eprintln!("  ingesting the stream...");
    let case = prepare_case(DatasetPreset::VideoMmeShort, &cfg, QUERIES, 0xcac4e)
        .expect("prepare case");
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(cfg.ingest.aux_models).expect("engine"),
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        0x51,
    );
    let cache = QueryCache::from_config(&cfg.api);

    // distinct texts only: the generator may phrase two queries
    // identically, which would (correctly) hit on first sight
    let mut texts: Vec<String> = case.queries.iter().map(|q| q.text.clone()).collect();
    texts.sort();
    texts.dedup();

    // cold pass: every query misses and is inserted
    let mut cold = Samples::default();
    for text in &texts {
        let t0 = std::time::Instant::now();
        let (_, status) = qe
            .retrieve_request(text, StreamScope::All, None, None, Some(&cache))
            .expect("cold query");
        cold.push(t0.elapsed().as_secs_f64());
        assert_eq!(status, CacheStatus::Miss, "first sight of a query must miss");
    }

    // warm passes: exact repeats hit the text tier
    let mut warm = Samples::default();
    for _ in 0..WARM_ROUNDS {
        for text in &texts {
            let t0 = std::time::Instant::now();
            let (_, status) = qe
                .retrieve_request(text, StreamScope::All, None, None, Some(&cache))
                .expect("warm query");
            warm.push(t0.elapsed().as_secs_f64());
            assert_eq!(status, CacheStatus::HitExact, "repeat must hit the exact tier");
        }
    }

    // semantic pass: reworded near-duplicates (case/spacing changes keep
    // the same normalized key, so perturb harder: prepend words) — these
    // pay the embed but skip scoring + selection + fetch
    let mut semantic = Samples::default();
    let mut semantic_hits = 0usize;
    for text in &texts {
        let reworded = format!("tell me {text}");
        let t0 = std::time::Instant::now();
        let (_, status) = qe
            .retrieve_request(&reworded, StreamScope::All, None, None, Some(&cache))
            .expect("semantic query");
        semantic.push(t0.elapsed().as_secs_f64());
        if status == CacheStatus::HitSemantic {
            semantic_hits += 1;
        }
    }

    let mut table = Table::new(vec!["pass", "queries", "p50", "p95", "mean"]);
    for (name, s) in [
        ("cold (miss)", &cold),
        ("warm repeat (exact hit)", &warm),
        ("reworded (semantic tier)", &semantic),
    ] {
        table.row(vec![
            name.to_string(),
            s.len().to_string(),
            fmt_duration(s.p50()),
            fmt_duration(s.p95()),
            fmt_duration(s.mean()),
        ]);
    }
    print!("{table}");

    let speedup = cold.p50() / warm.p50().max(1e-12);
    note(&format!(
        "warm-repeat p50 speedup over cold: {speedup:.0}×; target ≥ 5×: {}",
        if speedup >= 5.0 { "MET" } else { "MISSED" }
    ));
    note(&format!(
        "semantic tier: {semantic_hits}/{} rewordings reused a cached selection \
         (threshold {}); the rest ran cold",
        texts.len(),
        cfg.api.cache_threshold
    ));
    note(&format!("final {}", cache.stats().render()));
    assert!(
        speedup >= 5.0,
        "cache-hit p50 must undercut cold p50 by ≥5× (got {speedup:.1}×)"
    );
}
