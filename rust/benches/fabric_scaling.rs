//! fabric_scaling — multi-camera memory-fabric scaling bench.
//!
//! For 1/2/4/8 camera streams: ingest each stream at the paper's 8 FPS
//! camera rate (one pipeline thread per camera, one shared embed pool)
//! and measure
//!   * sustained aggregate ingest FPS (frames / slowest-stream wall) —
//!     the serving claim: how many real-time feeds the node sustains;
//!   * offline real-time factor (how much faster than the camera each
//!     stream *could* be driven — headroom);
//!   * measured query latency p50/p95 against the ingested fabric, for
//!     `All`-scope scatter-gather and `One`-scope per-camera queries.
//!
//! The scaling target: 8-stream aggregate ingest FPS ≥ 3× the
//! single-stream figure on the same host (it lands at ~8× when the host
//! keeps up, since each stream is paced identically).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use venus::backend;
use venus::backend::EmbedBackend;
use venus::config::{FabricConfig, MemoryConfig, VenusConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::eval::build_synth;
use venus::ingest::{EmbedPool, Pipeline};
use venus::memory::{
    ClusterRecord, MemoryFabric, RawStore, StreamId, StreamScope, SynthBackedRaw,
};
use venus::util::bench::{note, persist_metric, section};
use venus::util::rng::Pcg64;
use venus::util::scorer::ScorePool;
use venus::util::stats::{fmt_duration, Samples, Table};
use venus::video::synth::VideoSynth;
use venus::video::workload::{DatasetPreset, WorkloadGen};

const STREAM_COUNTS: [usize; 4] = [1, 2, 4, 8];
const DURATION_S: f64 = 12.0;
const QUERIES: usize = 24;

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir()
            .join(format!("venus-fabscale-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

struct Cell {
    streams: usize,
    sustained_fps: f64,
    offline_rt: f64,
    all_p50: f64,
    all_p95: f64,
    one_p95: f64,
}

fn run_config(cfg: &VenusConfig, n: usize, seed: u64) -> Cell {
    let be = backend::shared_default().expect("backend");
    let d = be.model().d_embed;

    // per-camera synthetic streams, clipped to the bench duration
    let synths: Vec<Arc<VideoSynth>> = (0..n)
        .map(|i| {
            let full = build_synth(DatasetPreset::VideoMmeShort, seed + i as u64 * 131)
                .expect("synth");
            // rebuild at bench duration with the same codes
            Arc::new(VideoSynth::new(
                venus::video::synth::SynthConfig {
                    duration_s: DURATION_S,
                    ..full.config().clone()
                },
                full.codes().to_vec(),
                full.patch(),
            ))
        })
        .collect();
    let fps = synths[0].config().fps;

    let raws: Vec<Box<dyn RawStore>> = synths
        .iter()
        .map(|s| Box::new(SynthBackedRaw::new(Arc::clone(s))) as Box<dyn RawStore>)
        .collect();
    let fabric =
        Arc::new(MemoryFabric::new(&cfg.memory, d, raws).expect("fabric"));
    let workers =
        FabricConfig { streams: n, pool_workers: cfg.fabric.pool_workers }
            .resolved_pool_workers();
    let pool = EmbedPool::start(
        Arc::clone(&be),
        cfg.ingest.aux_models,
        workers,
        cfg.ingest.queue_capacity,
    )
    .expect("pool");

    // paced ingest: one thread per camera at the camera's real FPS
    let mut handles = Vec::new();
    for (i, synth) in synths.iter().enumerate() {
        let shard = Arc::clone(fabric.shard(StreamId(i as u16)).unwrap());
        let mut pipe =
            Pipeline::attach(&cfg.ingest, fps, &pool, shard).expect("pipeline");
        let synth = Arc::clone(synth);
        handles.push(std::thread::spawn(move || {
            let start = Instant::now();
            let mut busy = 0.0f64; // wall spent actually working (offline estimate)
            for f in 0..synth.total_frames() {
                let target = f as f64 / synth.config().fps;
                let elapsed = start.elapsed().as_secs_f64();
                if target > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(target - elapsed));
                }
                let t0 = Instant::now();
                let frame = synth.frame(f);
                pipe.push_frame(f, &frame).expect("push");
                busy += t0.elapsed().as_secs_f64();
            }
            let stats = pipe.finish().expect("finish");
            (stats, start.elapsed().as_secs_f64(), busy)
        }));
    }
    let mut total_frames = 0u64;
    let mut max_wall = 0.0f64;
    let mut busy_total = 0.0f64;
    for h in handles {
        let (stats, wall, busy) = h.join().expect("ingest thread");
        total_frames += stats.frames;
        max_wall = max_wall.max(wall);
        busy_total += busy;
    }
    pool.shutdown().expect("pool shutdown");
    fabric.check_invariants().expect("invariants");

    let sustained_fps = total_frames as f64 / max_wall;
    // offline headroom: how many × the camera rate the busy time alone
    // would sustain (push-path cost only; the pool overlaps it)
    let offline_rt = if busy_total > 0.0 {
        (total_frames as f64 / busy_total) / fps
    } else {
        0.0
    };

    // measured query latency against the ingested fabric
    let mut qe = QueryEngine::new(
        EmbedEngine::new(be, cfg.ingest.aux_models).expect("engine"),
        Arc::clone(&fabric),
        cfg.retrieval.clone(),
        seed ^ 0x51,
    );
    let queries = WorkloadGen::new(seed ^ 0x7, DatasetPreset::VideoMmeShort)
        .generate(synths[0].script(), QUERIES);
    let (mut all_lat, mut one_lat) = (Samples::default(), Samples::default());
    for (qi, q) in queries.iter().enumerate() {
        let out = qe
            .retrieve_scoped_with(&q.text, StreamScope::All, RetrievalMode::Akr)
            .expect("all query");
        all_lat.push(out.timings.total_s());
        let scope = StreamScope::One(StreamId((qi % n) as u16));
        let out = qe
            .retrieve_scoped_with(&q.text, scope, RetrievalMode::Akr)
            .expect("one query");
        one_lat.push(out.timings.total_s());
    }

    Cell {
        streams: n,
        sustained_fps,
        offline_rt,
        all_p50: all_lat.p50(),
        all_p95: all_lat.p95(),
        one_p95: one_lat.p95(),
    }
}

const POOL_STREAMS: usize = 4;
const POOL_ROWS_PER_SHARD: usize = 4096;
const POOL_QUERIES: usize = 32;

/// All-scope cold-heavy scoring, serial vs pooled (the ISSUE 9
/// headline): a 4-shard durable fabric whose sealed segments outnumber
/// the block cache, so every query pays real segment I/O — which the
/// pool's readahead overlaps with compute.  Rows go straight into the
/// shards (no embed pipeline; this phase isolates the scoring stage),
/// and the reported latency is the engine's search phase
/// (`EdgeTimings::search_s`).  With `SCORE_SCALE_ASSERT=1` the ≥2×
/// p50 speedup at 4 shards is enforced (needs a ≥4-core host).
fn scoring_pool_phase(cfg: &VenusConfig) {
    let be = backend::shared_default().expect("backend");
    let d = be.model().d_embed;
    let tmp = TempDir::new("coldpool");
    let mem = MemoryConfig {
        segment_records: 256,
        hot_budget_bytes: 2 * 256 * (d * 4 + std::mem::size_of::<ClusterRecord>() + 8),
        cold_cache_segments: 4,
        ..Default::default()
    };
    let fabric =
        Arc::new(MemoryFabric::open(&mem, d, POOL_STREAMS, 8, &tmp.0).expect("fabric"));
    let mut rng = Pcg64::seeded(0xc01d);
    for shard in fabric.shards() {
        let mut g = shard.write();
        let stream = g.stream();
        for i in 0..POOL_ROWS_PER_SHARD {
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            g.archive_frame(i as u64, &venus::video::frame::Frame::filled(8, [0.5; 3]))
                .expect("archive");
            g.insert(
                &v,
                ClusterRecord {
                    stream,
                    scene_id: i,
                    centroid_frame: i as u64,
                    members: vec![i as u64],
                },
            )
            .expect("insert");
        }
    }
    let ts = fabric.tier_stats();
    note(&format!(
        "{POOL_STREAMS} shards × {POOL_ROWS_PER_SHARD} rows: {} cold segments ({} cold rows), block cache {} segments",
        ts.cold_segments, ts.cold_records, mem.cold_cache_segments
    ));

    let measure = |pool: Option<Arc<ScorePool>>| -> (f64, f64) {
        let mut qe = QueryEngine::new(
            EmbedEngine::new(Arc::clone(&be), cfg.ingest.aux_models).expect("engine"),
            Arc::clone(&fabric),
            cfg.retrieval.clone(),
            0x9e4,
        );
        if let Some(p) = pool {
            qe = qe.with_pool(p);
        }
        let mut lat = Samples::default();
        for i in 0..POOL_QUERIES {
            let text = format!("what happened with concept{:02}", i % 16);
            let out = qe
                .retrieve_scoped_with(&text, StreamScope::All, RetrievalMode::Akr)
                .expect("query");
            lat.push(out.timings.search_s);
        }
        (lat.p50(), lat.p95())
    };

    let (serial_p50, serial_p95) = measure(None);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let mut table = Table::new(vec!["score workers", "All p50 (score)", "All p95 (score)", "p50 speedup"]);
    table.row(vec![
        "serial".into(),
        fmt_duration(serial_p50),
        fmt_duration(serial_p95),
        "1.0×".into(),
    ]);
    persist_metric("all_cold_score_p50_us_serial", serial_p50 * 1e6, "us");
    persist_metric("all_cold_score_p95_us_serial", serial_p95 * 1e6, "us");
    let mut speedup_at_4 = 0.0;
    for workers in [1usize, 2, 4] {
        let (p50, p95) = measure(Some(Arc::new(ScorePool::new(workers))));
        let speedup = serial_p50 / p50.max(1e-12);
        if workers == 4 {
            speedup_at_4 = speedup;
        }
        table.row(vec![
            workers.to_string(),
            fmt_duration(p50),
            fmt_duration(p95),
            format!("{speedup:.2}×"),
        ]);
        persist_metric(&format!("all_cold_score_p50_us_{workers}w"), p50 * 1e6, "us");
        persist_metric(&format!("all_cold_score_p95_us_{workers}w"), p95 * 1e6, "us");
    }
    print!("{table}");
    persist_metric("all_cold_score_p50_speedup_4w", speedup_at_4, "x");
    note(&format!(
        "4-worker All-scope cold-heavy scoring p50 speedup = {speedup_at_4:.2}× (host has {cores} cores; target ≥ 2× on ≥4 cores)"
    ));
    if std::env::var("SCORE_SCALE_ASSERT").as_deref() == Ok("1") && cores >= 4 {
        assert!(
            speedup_at_4 >= 2.0,
            "scoring-pool speedup regressed: {speedup_at_4:.2}× < 2× at 4 workers / {POOL_STREAMS} shards"
        );
        note("SCORE_SCALE_ASSERT: ≥2× speedup target MET");
    }
}

fn main() {
    section("fabric_scaling — ingest FPS and query p95 vs camera streams");
    note(&format!(
        "each camera paced at 8 FPS for {DURATION_S:.0} s; shared embed pool sized min(streams, cores)"
    ));
    let cfg = VenusConfig::default();

    let mut table = Table::new(vec![
        "streams",
        "sustained ingest FPS",
        "vs 1-stream",
        "offline headroom ×RT",
        "All query p50",
        "All query p95",
        "One query p95",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    for &n in &STREAM_COUNTS {
        eprintln!("  ingesting {n} stream(s)...");
        let cell = run_config(&cfg, n, 0x5ca1e);
        cells.push(cell);
    }
    let base = cells[0].sustained_fps;
    for c in &cells {
        table.row(vec![
            c.streams.to_string(),
            format!("{:.1}", c.sustained_fps),
            format!("{:.1}×", c.sustained_fps / base),
            format!("{:.1}×", c.offline_rt),
            fmt_duration(c.all_p50),
            fmt_duration(c.all_p95),
            fmt_duration(c.one_p95),
        ]);
    }
    print!("{table}");
    let last = cells.last().unwrap();
    let ratio = last.sustained_fps / base;
    note(&format!(
        "8-stream aggregate ingest FPS = {:.1} ({ratio:.1}× the single-stream {:.1}); target ≥ 3×: {}",
        last.sustained_fps,
        base,
        if ratio >= 3.0 { "MET" } else { "MISSED (host saturated)" }
    ));
    note("One-scope p95 stays flat vs stream count (per-shard isolation);");
    note("All-scope p95 grows with total index size (merged softmax), bounded by the shortlist");
    for c in &cells {
        persist_metric(&format!("ingest_fps_{}streams", c.streams), c.sustained_fps, "fps");
        persist_metric(&format!("all_query_p50_us_{}streams", c.streams), c.all_p50 * 1e6, "us");
        persist_metric(&format!("all_query_p95_us_{}streams", c.streams), c.all_p95 * 1e6, "us");
    }

    section("scoring pool — All-scope cold-heavy scoring p50, serial vs pooled");
    scoring_pool_phase(&cfg);
}
