//! Fig. 10 regenerator: greedy Top-K vs sampling-based retrieval with a
//! fixed budget of 8 frames — the coverage case study.
//!
//! Faithful to the paper's setup: the *vanilla* selector runs greedy
//! Top-K over a dense per-frame vector database (256 uniformly sampled
//! frames, REAL PJRT embeddings — the §III architecture without scene
//! clustering), while Venus samples from its clustered memory.  The
//! pathology reproduced: dense near-duplicate vectors make greedy Top-K
//! concentrate on adjacent timestamps, missing other relevant regions.

use std::sync::Arc;

use venus::cloud::SelectionStats;
use venus::config::VenusConfig;
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::embed::EmbedEngine;
use venus::eval::prepare_case;
use venus::util::bench::{note, section};
use venus::util::stats::Table;
use venus::video::frame::Frame;
use venus::video::workload::DatasetPreset;

const BUDGET: usize = 8;
const DENSE_SAMPLES: usize = 256;

fn main() {
    section("Fig. 10 — greedy Top-K (dense per-frame DB) vs Venus sampling (budget 8)");
    let cfg = VenusConfig::default();
    let case =
        prepare_case(DatasetPreset::VideoMmeShort, &cfg, 80, 5100).expect("prepare");
    let total = case.synth.total_frames();

    // ---- vanilla dense DB: 256 uniform frames, real embeddings ----
    let mut engine = EmbedEngine::default_backend(false).unwrap();
    let dense_ids = venus::baselines::uniform::select(total, DENSE_SAMPLES);
    let frames: Vec<Frame> = dense_ids.iter().map(|&i| case.synth.frame(i)).collect();
    let refs: Vec<&Frame> = frames.iter().collect();
    eprintln!("  embedding {} dense frames...", refs.len());
    let dense_embs = engine.embed_index_frames(&refs).unwrap();

    // ---- Venus sampling over its clustered memory ----
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        3,
    );

    let multi_span: Vec<_> = case
        .queries
        .iter()
        .filter(|q| q.evidence.len() >= 2)
        .collect();
    assert!(!multi_span.is_empty(), "need multi-span queries");

    let mut table = Table::new(vec![
        "selector",
        "mean spans covered",
        "mean coverage %",
        "adjacent-pair %",
        "mean temporal spread",
    ]);
    let mut example = String::new();

    // Top-K over the dense DB
    let mut stats_rows: Vec<(String, Vec<Vec<u64>>)> = Vec::new();
    let mut topk_sels = Vec::new();
    for q in &multi_span {
        let qvec = engine.embed_query(&q.text).unwrap();
        let mut scored: Vec<(usize, f32)> = dense_embs
            .iter()
            .enumerate()
            .map(|(i, e)| (i, venus::util::dot(&qvec, e)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut sel: Vec<u64> = scored
            .iter()
            .take(BUDGET)
            .map(|&(i, _)| dense_ids[i])
            .collect();
        sel.sort_unstable();
        topk_sels.push(sel);
    }
    stats_rows.push(("Top-K (dense greedy)".into(), topk_sels));

    let mut samp_sels = Vec::new();
    for q in &multi_span {
        let out = qe
            .retrieve_with(&q.text, RetrievalMode::FixedSampling(BUDGET))
            .unwrap();
        samp_sels.push(out.selection.frame_indices());
    }
    stats_rows.push(("Sampling (Venus)".into(), samp_sels));

    for (name, sels) in &stats_rows {
        let mut spans = 0.0;
        let mut cov = 0.0;
        let mut adjacent = 0.0;
        let mut spread = 0.0;
        for (q, sel) in multi_span.iter().zip(sels) {
            let st = SelectionStats::compute(q, case.synth.script(), sel, 8);
            spans += st.covered_spans as f64;
            cov += st.coverage;
            adjacent += st.redundancy;
            if sel.len() > 1 {
                spread += (sel[sel.len() - 1] - sel[0]) as f64 / total as f64;
            }
        }
        let n = multi_span.len() as f64;
        table.row(vec![
            name.clone(),
            format!("{:.2}", spans / n),
            format!("{:.0}%", 100.0 * cov / n),
            format!("{:.0}%", 100.0 * adjacent / n),
            format!("{:.2}", spread / n),
        ]);
        example.push_str(&format!(
            "  {name}: query \"{}\" -> frames {:?}\n",
            multi_span[0].text, sels[0]
        ));
    }
    print!("{table}");
    println!("case study (evidence spans {:?}):", multi_span[0].evidence);
    print!("{example}");
    note("paper shape: greedy fixates on one segment (adjacent timestamps);");
    note("sampling spreads over more answer-option content");
}
