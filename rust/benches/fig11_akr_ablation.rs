//! Fig. 11 regenerator: the AKR ablation.
//!
//! Venus with AKR (N_max = 32) vs fixed sampling budgets of 32 and 64 on
//! (i) the Video-MME-short-like workload and (ii) a curated subset of
//! localized queries (the paper's 60 ChatGPT-4o-picked scene-specific
//! questions) — reporting accuracy, mean selected frames, and the modeled
//! inference+communication latency reduction.

use venus::cloud::{VlmClient, VlmPersonality};
use venus::config::{CloudConfig, NetConfig, VenusConfig};
use venus::edge::AGX_ORIN;
use venus::eval::{eval_venus, prepare_case, LatencyModel, VenusMode};
use venus::net::Link;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Table};
use venus::video::workload::{DatasetPreset, QueryType};

fn main() {
    section("Fig. 11 — adaptive keyframe retrieval ablation");
    let mut cfg = VenusConfig::default();
    cfg.retrieval.n_max = 32;

    let case = prepare_case(DatasetPreset::VideoMmeShort, &cfg, 150, 6100).expect("prepare");

    // curated subset: localized scene-specific queries (paper's 60-query set)
    let mut subset_case = venus::eval::VideoCase {
        synth: std::sync::Arc::clone(&case.synth),
        fabric: std::sync::Arc::clone(&case.fabric),
        memory: std::sync::Arc::clone(&case.memory),
        queries: case
            .queries
            .iter()
            .filter(|q| q.qtype == QueryType::Localized)
            .take(60)
            .cloned()
            .collect(),
        ingest_stats: case.ingest_stats.clone(),
        preset: case.preset,
    };
    // reindex query ids for the subset
    for (i, q) in subset_case.queries.iter_mut().enumerate() {
        q.id = i;
    }

    let lat = LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0);
    let vlm = VlmClient::new(CloudConfig::default(), 2);

    for (label, c) in [("Video-MME (full workload)", &case), ("curated subset (localized)", &subset_case)] {
        println!();
        println!("--- {label} ({} queries) ---", c.queries.len());
        let mut table = Table::new(vec![
            "variant", "accuracy %", "mean frames", "infer+comm latency", "reduction",
        ]);
        let mut fixed64_cost = 0.0f64;
        for (name, mode) in [
            ("fixed N=64", VenusMode::FixedSampling(64)),
            ("fixed N=32", VenusMode::FixedSampling(32)),
            ("AKR (N_max=32)", VenusMode::Akr),
        ] {
            let out = eval_venus(c, mode, &cfg, VlmPersonality::Qwen2Vl7b, 13)
                .expect("venus eval");
            let n = out.mean_frames.round() as usize;
            let cost = lat.venus_parts(n.max(1), &vlm, None).comm_s
                + vlm.infer_latency_s(n.max(1), 32);
            if name == "fixed N=64" {
                fixed64_cost = cost;
            }
            table.row(vec![
                name.to_string(),
                format!("{:.1}", out.accuracy() * 100.0),
                format!("{:.1}", out.mean_frames),
                fmt_duration(cost),
                format!("{:.1}×", fixed64_cost / cost),
            ]);
        }
        print!("{table}");
    }
    note("paper shape: AKR ≈ fixed-budget accuracy with ~17 frames on average,");
    note("1.6×–3.3× lower inference+comm cost, larger gains on the curated subset");
}
