//! Fig. 12 regenerator: end-to-end query latency breakdown per processing
//! step for Venus and every baseline, on the Video-MME-short workload.
//!
//! Venus's edge steps are MEASURED on this host (backend query embedding,
//! index search, sampling, raw-frame fetch); its upload/VLM terms and all
//! baseline terms come from the calibrated deployment models.  Both
//! flavors are reported side by side in EXPERIMENTS.md.

use std::sync::Arc;

use venus::baselines::Method;
use venus::cloud::VlmClient;
use venus::config::{CloudConfig, NetConfig, VenusConfig};
use venus::coordinator::query::{QueryEngine, RetrievalMode};
use venus::edge::AGX_ORIN;
use venus::embed::EmbedEngine;
use venus::eval::{prepare_case, Deployment, LatencyModel};
use venus::net::Link;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Table};
use venus::video::workload::DatasetPreset;

const BUDGET: usize = 32;

fn main() {
    section("Fig. 12 — end-to-end query latency breakdown (Video-MME short)");
    let cfg = VenusConfig::default();
    let case =
        prepare_case(DatasetPreset::VideoMmeShort, &cfg, 40, 7100).expect("prepare");
    let clip_s = case.preset.duration_s();

    let lat = LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0);
    let vlm = VlmClient::new(CloudConfig::default(), 3);

    // ---- Venus measured edge steps ----
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        19,
    );
    let mut embed = 0.0;
    let mut search = 0.0;
    let mut select = 0.0;
    let mut fetch = 0.0;
    let n_q = case.queries.len();
    for q in &case.queries {
        let out = qe
            .retrieve_with(&q.text, RetrievalMode::FixedSampling(BUDGET))
            .expect("retrieve");
        embed += out.timings.embed_query_s;
        search += out.timings.search_s;
        select += out.timings.select_s;
        fetch += out.timings.fetch_s;
    }
    let nf = n_q as f64;
    let (embed, search, select, fetch) = (embed / nf, search / nf, select / nf, fetch / nf);
    let venus_parts = lat.venus_parts(BUDGET, &vlm, Some(embed + search + select + fetch));

    println!();
    println!("Venus per-step (edge steps MEASURED on this host):");
    let mut vt = Table::new(vec!["step", "latency", "source"]);
    vt.row(vec!["query embed (text tower)".to_string(), fmt_duration(embed), "measured".into()]);
    vt.row(vec!["index search (score_all)".to_string(), fmt_duration(search), "measured".into()]);
    vt.row(vec!["sampling retrieval".to_string(), fmt_duration(select), "measured".into()]);
    vt.row(vec!["raw-frame fetch".to_string(), fmt_duration(fetch), "measured".into()]);
    vt.row(vec!["upload (32 frames, 100 Mbps)".to_string(), fmt_duration(venus_parts.comm_s), "model".into()]);
    vt.row(vec!["cloud VLM inference".to_string(), fmt_duration(venus_parts.cloud_s), "model".into()]);
    vt.row(vec!["TOTAL".to_string(), fmt_duration(venus_parts.total_s()), "".into()]);
    print!("{vt}");

    // ---- all methods side by side ----
    println!();
    let mut table = Table::new(vec![
        "method", "on-device", "communication", "cloud", "total", "speedup of Venus",
    ]);
    let venus_total = venus_parts.total_s();
    let mut rows = vec![(
        "Venus".to_string(),
        venus_parts,
    )];
    for (m, dep) in [
        (Method::Aks, Deployment::CloudOnly),
        (Method::Aks, Deployment::EdgeCloud),
        (Method::Bolt, Deployment::CloudOnly),
        (Method::Bolt, Deployment::EdgeCloud),
        (Method::VideoRag, Deployment::CloudOnly),
        (Method::Vanilla, Deployment::EdgeCloud),
    ] {
        rows.push((
            format!("{} ({})", m.name(), dep.name()),
            lat.baseline_parts(m, dep, clip_s, BUDGET, &vlm),
        ));
    }
    let mut speedups = Vec::new();
    for (name, p) in rows {
        let sp = p.total_s() / venus_total;
        if name != "Venus" {
            speedups.push(sp);
        }
        table.row(vec![
            name,
            fmt_duration(p.on_device_s),
            fmt_duration(p.comm_s),
            fmt_duration(p.cloud_s),
            fmt_duration(p.total_s()),
            if sp > 1.01 { format!("{sp:.0}×") } else { "—".to_string() },
        ]);
    }
    print!("{table}");
    let lo = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = speedups.iter().cloned().fold(0.0, f64::max);
    note(&format!(
        "Venus speedup on this dataset: {lo:.0}×–{hi:.0}× (paper headline across datasets: 15×–131×)"
    ));
}
