//! Fig. 2 regenerator: response-latency breakdown (communication / cloud /
//! on-device) for Video-RAG, BOLT, and AKS under Cloud-Only and
//! Edge-Cloud deployment, on an EgoSchema-like clip at 8 FPS with 32
//! selected frames — the motivation figure.
//!
//! A second, MEASURED section drives the served engine with tracing at
//! sample rate 1 and rebuilds the same per-stage breakdown from real
//! span trees (DESIGN.md §Observability), persisting `fig2_e2e_*`
//! scalars so `make bench-json` carries a per-stage perf trajectory.

use std::collections::BTreeMap;
use std::sync::Arc;

use venus::api::QueryRequest;
use venus::baselines::Method;
use venus::cloud::VlmClient;
use venus::config::{CloudConfig, NetConfig, VenusConfig};
use venus::edge::AGX_ORIN;
use venus::eval::{prepare_case, Deployment, LatencyModel};
use venus::net::Link;
use venus::obs::stage;
use venus::server::Service;
use venus::util::bench::{note, persist_metric, section};
use venus::util::stats::{fmt_duration, Samples, Table};
use venus::video::workload::DatasetPreset;

fn main() {
    section("Fig. 2 — latency breakdown for existing methods (EgoSchema, 32 frames)");

    let lat = LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0);
    let vlm = VlmClient::new(CloudConfig::default(), 1);
    let clip_s = DatasetPreset::EgoSchema.duration_s();

    let mut table = Table::new(vec![
        "Method", "Deployment", "On-device", "Communication", "Cloud", "Total", "Comm %",
    ]);
    for method in [Method::VideoRag, Method::Bolt, Method::Aks] {
        for dep in [Deployment::CloudOnly, Deployment::EdgeCloud] {
            let p = lat.baseline_parts(method, dep, clip_s, 32, &vlm);
            table.row(vec![
                method.name().to_string(),
                dep.name().to_string(),
                fmt_duration(p.on_device_s),
                fmt_duration(p.comm_s),
                fmt_duration(p.cloud_s),
                fmt_duration(p.total_s()),
                format!("{:.0}%", 100.0 * p.comm_s / p.total_s()),
            ]);
        }
    }
    // Venus for contrast (the paper overlays it in Fig. 12)
    let v = lat.venus_parts(32, &vlm, None);
    table.row(vec![
        "Venus".into(),
        "Edge-Cloud".into(),
        fmt_duration(v.on_device_s),
        fmt_duration(v.comm_s),
        fmt_duration(v.cloud_s),
        fmt_duration(v.total_s()),
        format!("{:.0}%", 100.0 * v.comm_s / v.total_s()),
    ]);
    print!("{table}");
    note("paper shape: Cloud-Only comm ≈ 80% of total; Edge-Cloud on-device ≈ 900 s");

    measured_stage_breakdown();
}

/// The span-derived counterpart: ingest a preset, run every distinct
/// query against the served engine with tracing at sample rate 1, and
/// rebuild the Fig. 2 stage split from the recorded span trees.
fn measured_stage_breakdown() {
    section("Fig. 2 (measured) — span-derived Venus per-stage breakdown");
    let mut cfg = VenusConfig::default();
    // no semantic cache: a hit would short-circuit embed/score/select
    // and the split would mix two very different pipelines
    cfg.api.cache_entries = 0;

    eprintln!("  ingesting the stream...");
    let case =
        prepare_case(DatasetPreset::VideoMmeShort, &cfg, 16, 0xf162).expect("prepare case");
    cfg.api.fps = case.synth.config().fps;
    let service = Service::start(&cfg, Arc::clone(&case.fabric), 0xf162).expect("service");

    let mut texts: Vec<String> = case.queries.iter().map(|q| q.text.clone()).collect();
    texts.sort();
    texts.dedup();
    for text in &texts {
        service.call(QueryRequest::new(text.clone())).expect("traced query");
    }

    let traces = service.tracer.recent(usize::MAX);
    assert!(!traces.is_empty(), "default sampling must trace every query");
    let mut totals = Samples::default();
    let mut per_stage: BTreeMap<String, Samples> = BTreeMap::new();
    for t in &traces {
        totals.push(t.total_us as f64 / 1e3);
        for s in t.spans.iter().filter(|s| !s.is_child()) {
            per_stage.entry(s.stage.clone()).or_default().push(s.dur_us as f64 / 1e3);
        }
    }

    let mut table = Table::new(vec!["Stage", "p50", "p95", "share of p50 total"]);
    for st in stage::QUERY_ORDER {
        let Some(s) = per_stage.get(*st) else { continue };
        table.row(vec![
            st.to_string(),
            fmt_duration(s.p50() / 1e3),
            fmt_duration(s.p95() / 1e3),
            format!("{:.1}%", 100.0 * s.p50() / totals.p50()),
        ]);
        persist_metric(&format!("fig2_e2e_{st}_p50_ms"), s.p50(), "ms");
    }
    table.row(vec![
        "total".to_string(),
        fmt_duration(totals.p50() / 1e3),
        fmt_duration(totals.p95() / 1e3),
        "100%".to_string(),
    ]);
    persist_metric("fig2_e2e_total_p50_ms", totals.p50(), "ms");
    print!("{table}");
    note(&format!(
        "{} traced queries; modeled upload+vlm dominate — the on-device stages are the ones \
         this trajectory watches",
        traces.len()
    ));
    service.shutdown();
}
