//! Fig. 2 regenerator: response-latency breakdown (communication / cloud /
//! on-device) for Video-RAG, BOLT, and AKS under Cloud-Only and
//! Edge-Cloud deployment, on an EgoSchema-like clip at 8 FPS with 32
//! selected frames — the motivation figure.

use venus::baselines::Method;
use venus::cloud::VlmClient;
use venus::config::{CloudConfig, NetConfig};
use venus::edge::AGX_ORIN;
use venus::eval::{Deployment, LatencyModel};
use venus::net::Link;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Table};
use venus::video::workload::DatasetPreset;

fn main() {
    section("Fig. 2 — latency breakdown for existing methods (EgoSchema, 32 frames)");

    let lat = LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0);
    let vlm = VlmClient::new(CloudConfig::default(), 1);
    let clip_s = DatasetPreset::EgoSchema.duration_s();

    let mut table = Table::new(vec![
        "Method", "Deployment", "On-device", "Communication", "Cloud", "Total", "Comm %",
    ]);
    for method in [Method::VideoRag, Method::Bolt, Method::Aks] {
        for dep in [Deployment::CloudOnly, Deployment::EdgeCloud] {
            let p = lat.baseline_parts(method, dep, clip_s, 32, &vlm);
            table.row(vec![
                method.name().to_string(),
                dep.name().to_string(),
                fmt_duration(p.on_device_s),
                fmt_duration(p.comm_s),
                fmt_duration(p.cloud_s),
                fmt_duration(p.total_s()),
                format!("{:.0}%", 100.0 * p.comm_s / p.total_s()),
            ]);
        }
    }
    // Venus for contrast (the paper overlays it in Fig. 12)
    let v = lat.venus_parts(32, &vlm, None);
    table.row(vec![
        "Venus".into(),
        "Edge-Cloud".into(),
        fmt_duration(v.on_device_s),
        fmt_duration(v.comm_s),
        fmt_duration(v.cloud_s),
        fmt_duration(v.total_s()),
        format!("{:.0}%", 100.0 * v.comm_s / v.total_s()),
    ]);
    print!("{table}");
    note("paper shape: Cloud-Only comm ≈ 80% of total; Edge-Cloud on-device ≈ 900 s");
}
