//! Fig. 4 regenerator: embedding latency vs stream FPS per edge device,
//! with the real-time threshold each device sustains.
//!
//! The device model is anchored to the paper's measured ceilings
//! (0.3 / 0.7 / 1.8 FPS); the `host` row reports the MEASURED default
//! backend on this machine for comparison (our MEM is far smaller than
//! BGE-VL-large, hence the much higher ceiling).

use venus::edge::DeviceProfile;
use venus::embed::EmbedEngine;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Table};
use venus::video::frame::Frame;

fn main() {
    section("Fig. 4 — embedding latency vs FPS across edge devices");

    let fps_grid = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 25.0];
    let window_s = 60.0; // backlog accumulated over a 60 s stream

    let mut table = Table::new(vec![
        "Device", "f=0.25", "f=0.5", "f=1", "f=2", "f=4", "f=8", "f=16", "f=25", "max real-time FPS",
    ]);
    for d in DeviceProfile::edge_boards() {
        let mut row = vec![d.name.to_string()];
        for &f in &fps_grid {
            row.push(fmt_duration(d.embed_backlog_delay_s(f, window_s)));
        }
        row.push(format!("{:.1}", d.realtime_embed_fps()));
        table.row(row);
    }

    // measured host encoder
    let mut engine = EmbedEngine::default_backend(false).expect("engine");
    let frame = Frame::filled(64, [0.4, 0.5, 0.6]);
    let frames: Vec<&Frame> = std::iter::repeat(&frame).take(32).collect();
    // warm-up compile + steady-state measurement
    engine.embed_index_frames(&frames).unwrap();
    let t0 = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        engine.embed_index_frames(&frames).unwrap();
    }
    let per_frame = t0.elapsed().as_secs_f64() / (reps * frames.len()) as f64;
    let host_fps = 1.0 / per_frame;
    let mut row = vec!["host (measured)".to_string()];
    for &f in &fps_grid {
        let backlog = (f * window_s - host_fps * window_s).max(0.0) * per_frame;
        row.push(fmt_duration(backlog));
    }
    row.push(format!("{host_fps:.1}"));
    table.row(row);

    print!("{table}");
    note("paper thresholds: TX2 0.3 / Xavier-NX 0.7 / AGX-Orin 1.8 FPS");
    note(&format!(
        "host measured: {} per frame (batch-32 image tower, default backend)",
        fmt_duration(per_frame)
    ));
}
