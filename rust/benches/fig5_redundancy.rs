//! Fig. 5 regenerator.
//!
//! (a) Accuracy on a Video-MME-short-like workload as a function of how
//!     many frames are retained in the memory database (uniform
//!     retention, Top-16 retrieval) — redundancy degrades accuracy.
//! (b) Frame-wise similarity scores for one case-study query over 256
//!     uniformly sampled frames, with the Top-16 picks marked — greedy
//!     selection concentrates on adjacent timestamps.
//! (c) Span coverage of the Top-16 picks vs the sampling-based picks for
//!     the same query.

use venus::baselines::frame_scores;
use venus::cloud::{VlmClient, VlmPersonality};
use venus::config::{CloudConfig, VenusConfig};
use venus::eval::build_synth;
use venus::util::bench::{note, section};
use venus::util::rng::Pcg64;
use venus::util::stats::Table;
use venus::video::workload::{DatasetPreset, QueryType, WorkloadGen};

fn main() {
    let cfg = VenusConfig::default();
    let _ = &cfg;
    let synth = build_synth(DatasetPreset::VideoMmeShort, 3100).expect("synth");
    let script = synth.script();
    let total = synth.total_frames();
    let queries = WorkloadGen::new(31, DatasetPreset::VideoMmeShort).generate(script, 150);

    // ---------------- (a) accuracy vs retained DB size ----------------
    section("Fig. 5(a) — accuracy vs number of frames retained in the DB");
    let cloud = CloudConfig { vlm: VlmPersonality::Qwen2Vl7b.name().into(), ..Default::default() };
    let mut table = Table::new(vec!["retained frames", "accuracy %", "mean redundancy"]);
    for retained in [16usize, 32, 64, 128, 256, 512] {
        let kept: Vec<u64> = venus::baselines::uniform::select(total, retained);
        let mut vlm = VlmClient::new(cloud.clone(), 5);
        let mut correct = 0usize;
        let mut redundancy = 0.0f64;
        for q in &queries {
            let scores = frame_scores(script, q, total, 11);
            // greedy Top-16 over the retained subset (the naive §III DB)
            let mut order: Vec<u64> = kept.clone();
            order.sort_by(|&a, &b| {
                scores[b as usize].partial_cmp(&scores[a as usize]).unwrap()
            });
            let mut sel: Vec<u64> = order.into_iter().take(16).collect();
            sel.sort_unstable();
            let st = venus::cloud::SelectionStats::compute(q, script, &sel, 4);
            redundancy += st.redundancy;
            let (ok, _) = vlm.judge(q, script, &sel);
            correct += ok as usize;
        }
        table.row(vec![
            retained.to_string(),
            format!("{:.1}", 100.0 * correct as f64 / queries.len() as f64),
            format!("{:.2}", redundancy / queries.len() as f64),
        ]);
    }
    print!("{table}");
    note("paper shape: peak around 64 retained frames; denser DBs add near-duplicates");

    // ---------------- (b) similarity curve case study ----------------
    section("Fig. 5(b) — frame-wise similarity, 256 uniform samples, Top-16 marked");
    let q = queries
        .iter()
        .find(|q| q.qtype == QueryType::Dispersed && q.evidence.len() >= 2)
        .unwrap_or(&queries[0]);
    let sampled: Vec<u64> = venus::baselines::uniform::select(total, 256);
    let scores = frame_scores(script, q, total, 11);
    let series: Vec<f32> = sampled.iter().map(|&f| scores[f as usize]).collect();
    let mut top: Vec<usize> = (0..series.len()).collect();
    top.sort_by(|&a, &b| series[b].partial_cmp(&series[a]).unwrap());
    let top16: std::collections::HashSet<usize> = top.into_iter().take(16).collect();

    // ASCII sparkline rows of 64
    println!("query: \"{}\" | evidence spans: {:?}", q.text, q.evidence);
    for row in 0..4 {
        let mut curve = String::new();
        let mut marks = String::new();
        for i in row * 64..(row + 1) * 64 {
            let s = series[i];
            curve.push(match () {
                _ if s > 0.7 => '#',
                _ if s > 0.45 => '+',
                _ if s > 0.2 => '-',
                _ => '.',
            });
            marks.push(if top16.contains(&i) { '^' } else { ' ' });
        }
        println!("  [{:>3}..{:>3}] {curve}", row * 64, (row + 1) * 64 - 1);
        println!("            {marks}");
    }
    let picked: Vec<usize> = (0..series.len()).filter(|i| top16.contains(i)).collect();
    let spread = picked.last().unwrap() - picked.first().unwrap();
    note(&format!(
        "Top-16 sample indices: {picked:?} (spread {spread} of 256)"
    ));

    // ---------------- (c) coverage: Top-K vs sampling -----------------
    section("Fig. 5(c) — evidence-span coverage: greedy Top-16 vs sampling-16");
    let mut rng = Pcg64::seeded(17);
    // greedy over all frames
    let mut order: Vec<u64> = (0..total).collect();
    order.sort_by(|&a, &b| scores[b as usize].partial_cmp(&scores[a as usize]).unwrap());
    let mut greedy: Vec<u64> = order.into_iter().take(16).collect();
    greedy.sort_unstable();
    // sampling via softmax over the same scores
    let probs = venus::retrieval::softmax_probs(&scores, 0.07);
    let mut cdf = Vec::with_capacity(probs.len());
    let mut acc = 0.0f32;
    for &p in &probs {
        acc += p;
        cdf.push(acc);
    }
    let mut sampled16: Vec<u64> = (0..16)
        .map(|_| cdf.partition_point(|&c| c < rng.f32() * acc) as u64)
        .collect();
    sampled16.sort_unstable();
    sampled16.dedup();

    let mut t = Table::new(vec!["selector", "spans covered", "of", "selected frames"]);
    for (name, sel) in [("Top-16 (greedy)", &greedy), ("Sampling-16", &sampled16)] {
        let st = venus::cloud::SelectionStats::compute(q, script, sel, 4);
        t.row(vec![
            name.to_string(),
            st.covered_spans.to_string(),
            st.n_spans.to_string(),
            sel.len().to_string(),
        ]);
    }
    print!("{t}");
    note("paper shape: greedy fixates on one segment; sampling covers more options");
}
