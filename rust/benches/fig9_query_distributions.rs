//! Fig. 9 regenerator: different query types yield different probability
//! distributions over the memory index.
//!
//! Runs the REAL system: a short clip is ingested through the pipeline,
//! then one localized and one dispersed query are embedded (PJRT text
//! tower) and scored against the index; the Eq. 5 distributions are
//! printed, showing the concentrated vs spread shapes that motivate AKR.

use std::sync::Arc;

use venus::config::VenusConfig;
use venus::coordinator::query::QueryEngine;
use venus::embed::EmbedEngine;
use venus::eval::prepare_case;
use venus::retrieval::softmax_probs;
use venus::util::bench::{note, section};
use venus::video::workload::{DatasetPreset, QueryType};

fn main() {
    section("Fig. 9 — query type vs probability distribution over indexed frames");
    let cfg = VenusConfig::default();
    // medium preset: long enough that concepts recur across scenes, so the
    // workload contains genuinely dispersed queries
    let case =
        prepare_case(DatasetPreset::VideoMmeMedium, &cfg, 60, 4100).expect("prepare");
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(true).unwrap(),
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        9,
    );

    // pick the most-localized and most-dispersed queries by evidence-span
    // count (the workload mix varies per seed)
    let localized = case
        .queries
        .iter()
        .min_by_key(|q| q.evidence.len())
        .expect("queries");
    let dispersed = case
        .queries
        .iter()
        .max_by_key(|q| q.evidence.len())
        .expect("queries");
    let _ = QueryType::Localized; // (type referenced for doc purposes)

    for (label, q) in [("localized", localized), ("dispersed", dispersed)] {
        let scores = qe.score_query(&q.text).expect("score");
        // same distribution the retrieval path samples from (Eq. 5 over
        // the scored shortlist)
        let masked =
            venus::retrieval::shortlist_mask(&scores, cfg.retrieval.shortlist);
        let probs = softmax_probs(&masked, cfg.retrieval.tau);
        let mut top: Vec<(usize, f32)> =
            probs.iter().cloned().enumerate().collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

        println!();
        println!(
            "{label} query: \"{}\" ({} evidence spans)",
            q.text,
            q.evidence.len()
        );
        // distribution shape statistics
        let entropy: f64 = probs
            .iter()
            .filter(|&&p| p > 0.0)
            .map(|&p| -(p as f64) * (p as f64).ln())
            .sum();
        let top1 = top[0].1;
        let top5: f32 = top.iter().take(5).map(|t| t.1).sum();
        println!(
            "  top-1 mass {:.2} | top-5 mass {:.2} | entropy {:.2} nats over {} indexed vectors",
            top1, top5, entropy, probs.len()
        );
        // bar chart of the top 12
        for &(i, p) in top.iter().take(12) {
            let bar = "█".repeat(((p * 120.0).round() as usize).max(1).min(60));
            let scene = case
                .memory
                .read()
                .unwrap()
                .record(i)
                .map(|r| r.scene_id)
                .expect("scored index has a record");
            println!("  idx {:>4} (scene {:>3}) p={:.3} {bar}", i, scene, p);
        }
    }
    note("paper shape: localized → concentrated mass (few samples suffice);");
    note("             dispersed → spread mass (more samples needed) — AKR's premise");
}
