//! Hot-path microbenchmarks (the §Perf instrument): vector search, Eq. 1
//! scene features, incremental clustering, sampling/AKR, and the backend
//! embedding entry points.  Run `cargo bench --bench hotpath_micro`;
//! results are recorded in EXPERIMENTS.md §Perf.

use std::time::Duration;

use venus::backend::{self, EmbedBackend};
use venus::config::MemoryConfig;
use venus::embed::EmbedEngine;
use venus::features::frame_features;
use venus::ingest::PartitionClusterer;
use venus::memory::{
    ClusterRecord, FlatIndex, Hierarchy, InMemoryRaw, IvfIndex, Metric, StreamId, VectorIndex,
};
use venus::retrieval::{akr_retrieve, sample_retrieve};
use venus::util::bench::{note, section, Bench};
use venus::util::rng::Pcg64;
use venus::video::frame::Frame;

fn unit_vecs(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| {
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            venus::util::l2_normalize(&mut v);
            v
        })
        .collect()
}

fn main() {
    let mut b = Bench::new(Duration::from_millis(100), Duration::from_millis(600));

    section("vectordb: score_all + top-k (d=64)");
    for n in [1_000usize, 10_000, 100_000] {
        let vs = unit_vecs(n, 64, 1);
        let mut flat = FlatIndex::new(64, Metric::Cosine);
        for v in &vs {
            flat.insert(v).unwrap();
        }
        let q = vs[n / 2].clone();
        let mut out = Vec::new();
        b.run(&format!("flat score_all n={n}"), || {
            flat.score_all(&q, &mut out);
            out.len()
        });
        b.run(&format!("flat search top-32 n={n}"), || flat.search(&q, 32).len());
    }
    {
        let n = 100_000;
        let vs = unit_vecs(n, 64, 2);
        let mut ivf = IvfIndex::new(64, Metric::Cosine, 256, 16);
        for v in &vs {
            ivf.insert(v).unwrap();
        }
        let q = vs[7].clone();
        b.run("ivf search top-32 n=100000 probe=16", || ivf.search(&q, 32).len());
    }

    section("perception: Eq.1 features + clustering (64×64 frames)");
    let mut rng = Pcg64::seeded(3);
    let mut frame = Frame::new(64);
    for v in frame.data_mut() {
        *v = rng.f32();
    }
    b.run("frame_features (HSL+Sobel+pool)", || frame_features(&frame).len());
    let frames: Vec<Frame> = (0..64)
        .map(|i| {
            let mut f = frame.clone();
            for v in f.data_mut().iter_mut().take(512) {
                *v = (*v + i as f32 * 0.001).fract();
            }
            f
        })
        .collect();
    b.run("clusterer push ×64 frames", || {
        let mut c = PartitionClusterer::new(0.085);
        for (i, f) in frames.iter().enumerate() {
            c.push(i as u64, f);
        }
        c.n_clusters()
    });

    section("retrieval: sampling + AKR over 4096-cluster memory");
    let mut mem = Hierarchy::new(&MemoryConfig::default(), 64, Box::new(InMemoryRaw::new(8)))
        .unwrap();
    let n_clusters = 4096;
    for i in 0..(n_clusters as u64 * 4) {
        mem.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
    }
    let vs = unit_vecs(n_clusters, 64, 4);
    for (c, v) in vs.iter().enumerate() {
        mem.insert(
            v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: c,
                centroid_frame: c as u64 * 4,
                members: (c as u64 * 4..c as u64 * 4 + 4).collect(),
            },
        )
        .unwrap();
    }
    let scores: Vec<f32> = {
        let mut s = Vec::new();
        mem.score_all(&vs[100], &mut s).unwrap();
        s
    };
    let mut rng = Pcg64::seeded(5);
    b.run("sample_retrieve budget=32", || {
        sample_retrieve(&mem, &scores, 0.07, 32, &mut rng).frames.len()
    });
    b.run("akr_retrieve θ=0.9 n_max=32", || {
        akr_retrieve(&mem, &scores, 0.07, 0.9, 4.0, 32, &mut rng).draws
    });

    section("MEM entry points (default backend)");
    let mut engine = EmbedEngine::default_backend(true).expect("engine");
    let f1 = Frame::filled(64, [0.3, 0.5, 0.7]);
    for batch in [1usize, 8, 32] {
        let refs: Vec<&Frame> = std::iter::repeat(&f1).take(batch).collect();
        engine.embed_index_frames(&refs).unwrap(); // compile warm-up
        b.run(&format!("embed_image batch={batch}"), || {
            engine.embed_index_frames(&refs).unwrap().len()
        });
    }
    b.run("embed_text (query path)", || {
        engine.embed_query("when did concept05 appear").unwrap().len()
    });
    {
        let be2 = backend::shared_default().unwrap();
        let m = be2.model().clone();
        let rows = m.sim_rows;
        let idx = unit_vecs(rows, m.d_embed, 6).concat();
        let q = unit_vecs(1, m.d_embed, 7).pop().unwrap();
        be2.similarity(&q, &idx, rows, 0.07).unwrap(); // warm-up
        b.run("similarity_n1024 (fused kernel)", || {
            be2.similarity(&q, &idx, rows, 0.07).unwrap().0.len()
        });
    }

    note("record before/after in EXPERIMENTS.md §Perf");
}
