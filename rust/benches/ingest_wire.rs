//! ingest_wire — live camera ingest over TCP × concurrent query load.
//!
//! Starts a hub-enabled gateway over an EMPTY fabric, pushes two paced
//! `Camera` clients through it (the real wire envelopes, not in-process
//! calls), and drives query traffic against the same gateway while the
//! frames land: a steady phase for the headline numbers and an overload
//! burst that queues the Interactive lane so the admission controller's
//! backpressure verdicts show up in the camera reports.
//!
//! Headline: sustained ingest FPS × served QPS, query p95 under live
//! ingest, and capture→queryable freshness p50/p95 — persisted via
//! `BENCH_JSON_DIR` as flat metrics alongside the printed tables.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use venus::config::VenusConfig;
use venus::memory::{InMemoryRaw, MemoryFabric, RawStore};
use venus::net::wire::{Camera, CameraReport, Gateway, IngestHub, LoadGen};
use venus::server::Service;
use venus::util::bench::{note, persist_metric, section};
use venus::util::stats::fmt_duration;
use venus::video::synth::{SynthConfig, VideoSynth};

const STREAMS: usize = 2;
/// Pacing rate the cameras declare and enforce — 4× the synth's native
/// 8 fps so the run finishes in seconds while staying genuinely paced.
const CAMERA_FPS: f64 = 32.0;
/// Stream time at the synth's native rate (240 frames per camera).
const DURATION_S: f64 = 30.0;

fn main() {
    section("ingest_wire — live camera ingest over TCP × concurrent query load");
    let be = venus::backend::shared_default().expect("backend");
    let synth = Arc::new(VideoSynth::new(
        SynthConfig { duration_s: DURATION_S, seed: 5, ..Default::default() },
        be.concept_codes().expect("concept codes"),
        be.model().patch,
    ));
    let frames = synth.total_frames();

    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();
    // one partition per second of stream time: freshness samples appear
    // continuously instead of only at drain
    cfg.ingest.max_partition_s = 1.0;

    let raws: Vec<Box<dyn RawStore>> = (0..STREAMS)
        .map(|_| Box::new(InMemoryRaw::new(synth.config().frame_size)) as Box<dyn RawStore>)
        .collect();
    let fabric =
        Arc::new(MemoryFabric::new(&cfg.memory, be.model().d_embed, raws).expect("fabric"));
    let service = Arc::new(Service::start(&cfg, Arc::clone(&fabric), 0x1f).expect("service"));
    let hub = Arc::new(
        IngestHub::new(&cfg, Arc::clone(&fabric), Arc::clone(&service.metrics), STREAMS)
            .expect("hub"),
    );
    let gateway = Gateway::start_with(&cfg.wire, Arc::clone(&service), Some(Arc::clone(&hub)))
        .expect("gateway");
    let addr = gateway.local_addr();
    note(&format!(
        "gateway on {addr}: {STREAMS} cameras × {frames} frames at {CAMERA_FPS} fps declared"
    ));

    let t0 = Instant::now();
    let cams: Vec<thread::JoinHandle<CameraReport>> = (0..STREAMS)
        .map(|sid| {
            let synth = Arc::clone(&synth);
            let wire = cfg.wire.clone();
            let addr = addr.to_string();
            thread::spawn(move || {
                let mut cam = Camera::new(addr, sid as u16, synth);
                cam.fps = CAMERA_FPS;
                cam.wire = wire;
                cam.run().expect("camera run")
            })
        })
        .collect();

    // let the fabric fill before measuring queries against it
    thread::sleep(Duration::from_secs(2));
    let texts: Vec<String> =
        (0..8).map(|i| format!("what happened with concept0{} variant {i}", i % 4)).collect();

    // --- steady phase: the headline coexistence numbers ---
    let mut lg = LoadGen::new(addr.to_string(), texts.clone());
    lg.clients = 4;
    lg.rate_qps = 48.0;
    lg.duration = Duration::from_secs(3);
    lg.wire = cfg.wire.clone();
    let steady = lg.run().expect("steady load");
    assert!(steady.completed > 0, "no query completed under live ingest");
    assert_eq!(steady.transport_errors, 0, "gateway dropped connections under load");

    // --- overload burst: queue the Interactive lane so the admission
    // controller yields ingest (SlowDown verdicts under the default
    // policy) while the cameras are still pushing ---
    let mut lg = LoadGen::new(addr.to_string(), texts);
    lg.clients = 8;
    lg.rate_qps = 400.0;
    lg.duration = Duration::from_secs(2);
    lg.wire = cfg.wire.clone();
    let burst = lg.run().expect("burst load");

    let reports: Vec<CameraReport> =
        cams.into_iter().map(|h| h.join().expect("camera thread")).collect();
    let wall = t0.elapsed().as_secs_f64();
    for r in &reports {
        note(&r.render());
    }
    let accepted: u64 = reports.iter().map(|r| r.accepted).sum();
    let slowed: u64 = reports.iter().map(|r| r.slowed_batches).sum();
    let dropped: u64 = reports.iter().map(|r| r.dropped).sum();
    assert_eq!(
        accepted,
        STREAMS as u64 * frames,
        "the default slowdown policy must land every frame"
    );
    assert_eq!(dropped, 0);
    // the staleness bound held: a camera may run behind its paced
    // schedule (burst slowdowns are the point), but never further than
    // the admission controller's starvation guard allows
    let schedule_s = frames as f64 / CAMERA_FPS;
    let bound_s = cfg.ingest.staleness_bound_ms as f64 / 1000.0;
    for r in &reports {
        assert!(
            r.wall_s < schedule_s + bound_s,
            "camera s{} starved past the staleness bound: {:.1}s wall vs {schedule_s:.1}s \
             schedule + {bound_s:.1}s bound",
            r.stream,
            r.wall_s,
        );
    }

    // wait out the embed pool so the freshness tails cover the whole run
    let mut snap = hub.snapshot();
    let deadline = Instant::now() + Duration::from_secs(60);
    while snap.pool_queue_depth > 0 {
        assert!(Instant::now() < deadline, "embed pool never drained");
        thread::sleep(Duration::from_millis(50));
        snap = hub.snapshot();
    }
    note(&snap.render());
    let p50s: Vec<f64> = snap.streams.iter().filter_map(|s| s.freshness_p50_ms).collect();
    let p95s: Vec<f64> = snap.streams.iter().filter_map(|s| s.freshness_p95_ms).collect();
    assert_eq!(p50s.len(), STREAMS, "every stream must become queryable during the run");
    let fresh_p50 = p50s.iter().fold(f64::MIN, |a, &b| a.max(b));
    let fresh_p95 = p95s.iter().fold(f64::MIN, |a, &b| a.max(b));

    let ingest_fps = accepted as f64 / wall;
    note(&format!(
        "headline: {ingest_fps:.1} fps ingested × {:.1} q/s served; query p95 {} under live \
         ingest; freshness p50 {fresh_p50:.0} ms / p95 {fresh_p95:.0} ms (worst stream); \
         burst: {} ok / {} rejected / {} shed, {slowed} slowed batches",
        steady.qps(),
        fmt_duration(steady.latency.percentile(95.0)),
        burst.completed,
        burst.rejected,
        burst.shed,
    ));
    persist_metric("ingest_sustained_fps", ingest_fps, "fps");
    persist_metric("steady_query_qps", steady.qps(), "qps");
    persist_metric("query_p95_under_ingest_s", steady.latency.percentile(95.0), "s");
    persist_metric("freshness_p50_ms", fresh_p50, "ms");
    persist_metric("freshness_p95_ms", fresh_p95, "ms");
    persist_metric("overload_slowed_batches", slowed as f64, "count");

    // durability-safe teardown order: wire, then the hub drain, then lanes
    let wire = gateway.shutdown();
    note(&wire.render());
    for (sid, stats) in hub.finish_all().expect("ingest drain") {
        note(&format!(
            "stream {sid}: {} frames -> {} index vectors across {} partitions",
            stats.frames, stats.clusters, stats.partitions
        ));
        assert_eq!(stats.frames, frames);
    }
    drop(hub); // joins the embed pool workers
    let service = Arc::try_unwrap(service).ok().expect("gateway released the service");
    let snap = service.shutdown();
    note(&snap.render());
    assert_eq!(snap.queued(), 0, "lanes drained");
}
