//! Memory-lifecycle bench: what the durable tiered memory costs.
//!
//! Phase 1 — sustained ingest of N clusters into (a) the legacy pure-RAM
//! shard, (b) a durable shard with an unbounded hot tier (WAL + sealing
//! overhead only), and (c) a durable shard with a hot budget ~17% of the
//! working set (sealing + steady eviction).  Reported as inserts/s: the
//! eviction overhead on ingest throughput.
//!
//! Phase 2 — query latency p50/p95 of the Eq. 4–5 score+sample path over
//! the all-hot shard vs the mostly-cold shard (per-segment scans through
//! the LRU block cache), plus the cold-tier hit rate.
//!
//! Run: `cargo bench --bench memory_lifecycle`

use std::path::PathBuf;
use std::time::Instant;

use venus::config::MemoryConfig;
use venus::memory::{ClusterRecord, Hierarchy, InMemoryRaw, StreamId};
use venus::retrieval::{sample_retrieve, shortlist_mask};
use venus::util::bench::{persist_metric, Bench};
use venus::util::rng::Pcg64;
use venus::util::scorer::ScorePool;
use venus::util::stats::{fmt_bytes, Samples};
use venus::video::frame::Frame;

const N: u64 = 3_000;
const D: usize = 64;
const FRAME: usize = 16;

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "venus-lifecycle-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).unwrap();
        Self(path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn unit(rng: &mut Pcg64) -> Vec<f32> {
    let mut v: Vec<f32> = (0..D).map(|_| rng.normal()).collect();
    venus::util::l2_normalize(&mut v);
    v
}

/// Sustained ingest: N two-frame clusters; returns inserts/s.
fn ingest(h: &mut Hierarchy, seed: u64) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    let t0 = Instant::now();
    for c in 0..N {
        for f in c * 2..(c + 1) * 2 {
            h.archive_frame(f, &Frame::filled(FRAME, [0.5; 3])).unwrap();
        }
        let v = unit(&mut rng);
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: c as usize,
                centroid_frame: c * 2,
                members: vec![c * 2, c * 2 + 1],
            },
        )
        .unwrap();
    }
    N as f64 / t0.elapsed().as_secs_f64()
}

/// p50/p95 of the score+sample query stage over a shard.  With a pool,
/// cold segments + the hot index score as parallel disjoint-slice tasks
/// (bit-identical output — see DESIGN.md §Parallel-Query).
fn query_latency(h: &Hierarchy, pool: Option<&ScorePool>, queries: usize, seed: u64) -> (f64, f64) {
    let mut rng = Pcg64::seeded(seed);
    let mut lat = Samples::default();
    let mut scores = Vec::new();
    for _ in 0..queries {
        let q = unit(&mut rng);
        let t0 = Instant::now();
        match pool {
            Some(p) => h.score_all_pooled(p, &q, &mut scores).unwrap(),
            None => h.score_all(&q, &mut scores).unwrap(),
        }
        let masked = shortlist_mask(&scores, 128);
        let sel = sample_retrieve(h, &masked, 0.12, 16, &mut rng);
        std::hint::black_box(sel.frames.len());
        lat.push(t0.elapsed().as_secs_f64());
    }
    (lat.p50(), lat.p95())
}

fn main() {
    let tmp = TempDir::new("bench");
    let budget =
        500 * (D * 4 + std::mem::size_of::<ClusterRecord>() + 2 * 8);
    let base = MemoryConfig { segment_records: 256, cold_cache_segments: 4, ..Default::default() };

    println!("# memory_lifecycle — durable tiered memory costs");
    println!("# {N} clusters, d={D}, segment_records={}, hot budget {}", base.segment_records, fmt_bytes(budget));
    println!();

    // (a) pure RAM (legacy unbounded shard)
    let mut ram =
        Hierarchy::new(&base, D, Box::new(InMemoryRaw::new(FRAME))).unwrap();
    let ram_fps = ingest(&mut ram, 1);

    // (b) durable, unbounded hot tier: WAL + sealing overhead only
    let mut hot =
        Hierarchy::durable(&base, D, StreamId(0), &tmp.0.join("hot"), FRAME).unwrap();
    let hot_fps = ingest(&mut hot, 1);

    // (c) durable, bounded hot tier: sealing + steady eviction
    let bounded_cfg = MemoryConfig { hot_budget_bytes: budget, ..base.clone() };
    let mut cold =
        Hierarchy::durable(&bounded_cfg, D, StreamId(0), &tmp.0.join("cold"), FRAME)
            .unwrap();
    let cold_fps = ingest(&mut cold, 1);

    println!("ingest throughput (inserts/s):");
    println!("  pure-RAM shard          {ram_fps:>10.0}");
    println!(
        "  durable, unbounded hot  {hot_fps:>10.0}  ({:.1}% of RAM)",
        100.0 * hot_fps / ram_fps
    );
    println!(
        "  durable, {:>9} hot  {cold_fps:>10.0}  ({:.1}% of RAM — eviction overhead)",
        fmt_bytes(budget),
        100.0 * cold_fps / ram_fps
    );
    println!();

    let ts = cold.tier_stats();
    println!(
        "bounded shard after ingest: hot {} ({} rec) / cold {} segments ({} rec), {} demotions",
        fmt_bytes(ts.hot_bytes),
        ts.hot_records,
        ts.cold_segments,
        ts.cold_records,
        ts.evictions
    );
    assert!(ts.hot_bytes <= budget, "hot tier exceeded its budget");
    println!();

    let (hp50, hp95) = query_latency(&hot, None, 100, 9);
    let (cp50, cp95) = query_latency(&cold, None, 100, 9);
    let ts = cold.tier_stats();
    println!("query score+sample latency over {N} records:");
    println!("  all-hot     p50 {:>9.1} µs   p95 {:>9.1} µs", hp50 * 1e6, hp95 * 1e6);
    println!(
        "  mostly-cold p50 {:>9.1} µs   p95 {:>9.1} µs   (cold-hit rate {})",
        cp50 * 1e6,
        cp95 * 1e6,
        ts.cold_hit_rate()
            .map(|r| format!("{:.0}%", r * 100.0))
            .unwrap_or_else(|| "n/a".into())
    );
    persist_metric("cold_query_p50_us_serial", cp50 * 1e6, "us");
    persist_metric("cold_query_p95_us_serial", cp95 * 1e6, "us");

    // the same mostly-cold shard through the scoring pool: segment scans
    // fan out as disjoint-slice tasks and the next block prefetches
    // while the current one scores
    for workers in [2usize, 4] {
        let pool = ScorePool::new(workers);
        let (pp50, pp95) = query_latency(&cold, Some(&pool), 100, 9);
        println!(
            "  mostly-cold p50 {:>9.1} µs   p95 {:>9.1} µs   ({workers}-worker pool, {:.2}× p50, {} pool tasks)",
            pp50 * 1e6,
            pp95 * 1e6,
            cp50 / pp50.max(1e-12),
            pool.gauges().tasks_total,
        );
        persist_metric(&format!("cold_query_p50_us_{workers}w"), pp50 * 1e6, "us");
        persist_metric(&format!("cold_query_p95_us_{workers}w"), pp95 * 1e6, "us");
    }

    // machine-readable trajectory (BENCH_memory_lifecycle.json under
    // BENCH_JSON_DIR): the score+sample query stage per tier shape
    println!();
    let mut b = Bench::quick();
    let mut rng = Pcg64::seeded(17);
    let q = unit(&mut rng);
    let mut scores = Vec::new();
    b.run("score+sample all-hot", || {
        hot.score_all(&q, &mut scores).unwrap();
        let masked = shortlist_mask(&scores, 128);
        sample_retrieve(&hot, &masked, 0.12, 16, &mut rng).frames.len()
    });
    b.run("score+sample mostly-cold", || {
        cold.score_all(&q, &mut scores).unwrap();
        let masked = shortlist_mask(&scores, 128);
        sample_retrieve(&cold, &masked, 0.12, 16, &mut rng).frames.len()
    });
}
