//! Table I regenerator: accuracy vs query-irrelevant baselines across
//! datasets, VLMs, and frame budgets (N = 16 / 32).
//!
//! Venus rows run the REAL system: full pipeline ingestion (PJRT
//! embeddings in the memory index) + sampling-based retrieval.  Baselines
//! select over the same clips; all methods share one answer model.
//!
//! Paper shape to reproduce: Venus highest in every cell; uniform
//! degrades on long videos; MDF ≤ uniform; Video-RAG ≈ uniform.

use venus::baselines::Method;
use venus::cloud::VlmPersonality;
use venus::config::VenusConfig;
use venus::eval::{eval_baseline, eval_venus, prepare_case, CellOutcome, VenusMode};
use venus::util::bench::{note, section};
use venus::util::stats::Table;
use venus::video::workload::DatasetPreset;

const QUERIES_PER_VIDEO: usize = 100;
const VIDEOS_PER_PRESET: usize = 2;

fn main() {
    section("Table I — comparison with query-irrelevant baselines");
    note("accuracy (%) on synthetic Video-MME/EgoSchema-like workloads; see DESIGN.md §1");

    let cfg = VenusConfig::default();
    let presets = [
        DatasetPreset::VideoMmeShort,
        DatasetPreset::VideoMmeMedium,
        DatasetPreset::VideoMmeLong,
        DatasetPreset::EgoSchema,
    ];

    // ingest every case once; reuse across budgets and VLMs
    let cases: Vec<_> = presets
        .iter()
        .flat_map(|&p| (0..VIDEOS_PER_PRESET).map(move |v| (p, 1000 + v as u64)))
        .map(|(p, seed)| {
            eprintln!("  ingesting {} (seed {seed})...", p.name());
            prepare_case(p, &cfg, QUERIES_PER_VIDEO, seed).expect("prepare case")
        })
        .collect();

    for personality in [VlmPersonality::LlavaOv7b, VlmPersonality::Qwen2Vl7b] {
        for budget in [16usize, 32] {
            println!();
            println!("--- model {} | N = {budget} ---", personality.name());
            let mut table = Table::new(vec![
                "Method", "VM-Short", "VM-Medium", "VM-Long", "VM-Overall", "EgoSchema",
            ]);
            for method in [Method::Uniform, Method::Mdf, Method::VideoRag, Method::Venus] {
                let mut per_preset = std::collections::HashMap::new();
                for case in &cases {
                    let out = if method == Method::Venus {
                        eval_venus(
                            case,
                            VenusMode::FixedSampling(budget),
                            &cfg,
                            personality,
                            42,
                        )
                        .expect("venus eval")
                    } else {
                        eval_baseline(case, method, budget, personality, 42)
                    };
                    per_preset
                        .entry(case.preset)
                        .or_insert_with(CellOutcome::default)
                        .merge(&out);
                }
                let acc =
                    |p: DatasetPreset| format!("{:.1}", per_preset[&p].accuracy() * 100.0);
                let overall = {
                    let mut o = CellOutcome::default();
                    for p in [
                        DatasetPreset::VideoMmeShort,
                        DatasetPreset::VideoMmeMedium,
                        DatasetPreset::VideoMmeLong,
                    ] {
                        o.merge(&per_preset[&p]);
                    }
                    format!("{:.1}", o.accuracy() * 100.0)
                };
                table.row(vec![
                    method.name().to_string(),
                    acc(DatasetPreset::VideoMmeShort),
                    acc(DatasetPreset::VideoMmeMedium),
                    acc(DatasetPreset::VideoMmeLong),
                    overall,
                    acc(DatasetPreset::EgoSchema),
                ]);
            }
            print!("{table}");
        }
    }
    note("paper: Venus highest in every cell; uniform collapses on long clips");
}
