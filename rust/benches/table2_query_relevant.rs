//! Table II regenerator: accuracy AND response latency vs query-relevant
//! baselines (AKS, BOLT × Cloud-Only / Edge-Cloud, Vanilla) with budget
//! fixed at 32 frames and Venus AKR disabled — the paper's headline
//! 15×–131× speedup table.
//!
//! Accuracy: real Venus retrieval vs oracle-driven baselines, one shared
//! answer model.  Latency: deployment models (net + device + VLM)
//! anchored to measured Venus edge compute on this host.

use venus::baselines::Method;
use venus::cloud::{VlmClient, VlmPersonality};
use venus::config::{CloudConfig, NetConfig, VenusConfig};
use venus::edge::AGX_ORIN;
use venus::eval::{
    eval_baseline, eval_venus, measure_venus_edge_latency, prepare_case, CellOutcome,
    Deployment, LatencyModel, VenusMode,
};
use venus::net::Link;
use venus::util::bench::{note, section};
use venus::util::stats::{fmt_duration, Table};
use venus::video::workload::DatasetPreset;

const BUDGET: usize = 32;
const QUERIES_PER_VIDEO: usize = 100;

fn main() {
    section("Table II — comparison with query-relevant baselines (budget 32, AKR off)");

    let cfg = VenusConfig::default();
    let presets = [
        DatasetPreset::VideoMmeShort,
        DatasetPreset::VideoMmeMedium,
        DatasetPreset::VideoMmeLong,
        DatasetPreset::EgoSchema,
    ];

    let cases: Vec<_> = presets
        .iter()
        .map(|&p| {
            eprintln!("  ingesting {}...", p.name());
            prepare_case(p, &cfg, QUERIES_PER_VIDEO, 2000).expect("prepare case")
        })
        .collect();

    let lat = LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0);

    for personality in [VlmPersonality::LlavaOv7b, VlmPersonality::Qwen2Vl7b] {
        println!();
        println!("--- model {} ---", personality.name());
        let mut table = Table::new(vec![
            "Method", "VM-S acc", "VM-S lat", "VM-M acc", "VM-M lat",
            "VM-L acc", "VM-L lat", "Ego acc", "Ego lat",
        ]);
        let cloud_cfg =
            CloudConfig { vlm: personality.name().into(), ..Default::default() };
        let vlm = VlmClient::new(cloud_cfg, 7);

        let rows: Vec<(String, Option<(Method, Deployment)>)> = vec![
            ("AKS (Cloud-Only)".into(), Some((Method::Aks, Deployment::CloudOnly))),
            ("AKS (Edge-Cloud)".into(), Some((Method::Aks, Deployment::EdgeCloud))),
            ("BOLT (Cloud-Only)".into(), Some((Method::Bolt, Deployment::CloudOnly))),
            ("BOLT (Edge-Cloud)".into(), Some((Method::Bolt, Deployment::EdgeCloud))),
            ("Vanilla".into(), Some((Method::Vanilla, Deployment::EdgeCloud))),
            ("Venus".into(), None),
        ];

        let mut venus_total = vec![0.0f64; cases.len()];
        let mut cloud_only = vec![Vec::new(); cases.len()];
        let mut edge_cloud = vec![Vec::new(); cases.len()];
        for (label, spec) in rows {
            let mut cells = Vec::new();
            for (ci, case) in cases.iter().enumerate() {
                let clip_s = case.preset.duration_s();
                let (out, parts): (CellOutcome, _) = match spec {
                    Some((method, dep)) => {
                        let out = eval_baseline(case, method, BUDGET, personality, 77);
                        let parts =
                            lat.baseline_parts(method, dep, clip_s, BUDGET, &vlm);
                        match dep {
                            Deployment::CloudOnly => cloud_only[ci].push(parts.total_s()),
                            Deployment::EdgeCloud => edge_cloud[ci].push(parts.total_s()),
                        }
                        (out, parts)
                    }
                    None => {
                        let out = eval_venus(
                            case,
                            VenusMode::FixedSampling(BUDGET),
                            &cfg,
                            personality,
                            77,
                        )
                        .expect("venus eval");
                        let measured =
                            measure_venus_edge_latency(case, &cfg, BUDGET, 5).ok();
                        let parts = lat.venus_parts(BUDGET, &vlm, measured);
                        venus_total[ci] = parts.total_s();
                        (out, parts)
                    }
                };
                cells.push(format!("{:.1}", out.accuracy() * 100.0));
                cells.push(fmt_duration(parts.total_s()));
            }
            let mut row = vec![label];
            row.extend(cells);
            table.row(row);
        }
        print!("{table}");

        // headline speedup bands (paper: up to 9.9× vs Cloud-Only on
        // short, up to 126× on long; 15×–131× across the Fig. 12 set)
        let band = |per_case: &[Vec<f64>]| -> (f64, f64) {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for (ci, xs) in per_case.iter().enumerate() {
                for &x in xs {
                    let s = x / venus_total[ci];
                    lo = lo.min(s);
                    hi = hi.max(s);
                }
            }
            (lo, hi)
        };
        let (clo, chi) = band(&cloud_only);
        let (elo, ehi) = band(&edge_cloud);
        note(&format!(
            "speedup vs Cloud-Only baselines: {clo:.0}×–{chi:.0}× (paper ≈ 10×–126×)"
        ));
        note(&format!(
            "speedup vs Edge-Cloud baselines: {elo:.0}×–{ehi:.0}× (paper Table II implies ≈ 90×–2500×)"
        ));
    }
}
