//! wire_throughput — sustained QPS and end-to-end wire-latency tails of
//! the TCP gateway.
//!
//! Ingests a real stream, exposes it through a gateway on an ephemeral
//! port, and drives it with the open-loop load generator at several
//! client counts (latency measured from the *scheduled* arrival — the
//! coordinated-omission-corrected number).  A second, single-flight pass
//! compares cold wire queries with cache-hit repeats end to end.
//! Acceptance targets: sustained QPS + p50/p95/p99 at ≥ 3 client counts,
//! and cache-hit wire p50 under cold wire p50.

use std::sync::Arc;
use std::time::{Duration, Instant};

use venus::api::QueryRequest;
use venus::config::VenusConfig;
use venus::eval::prepare_case;
use venus::net::wire::{Gateway, LoadGen, WireClient};
use venus::server::Service;
use venus::util::bench::{note, persist_metric, section};
use venus::util::stats::{fmt_duration, Samples, Table};
use venus::video::workload::DatasetPreset;

const QUERIES: usize = 16;
const CLIENT_COUNTS: [usize; 3] = [2, 4, 8];
const PER_CLIENT_QPS: f64 = 24.0;
const RUN_SECS: f64 = 2.0;
const CACHE_ROUNDS: usize = 3;

fn main() {
    section("wire_throughput — TCP gateway: sustained QPS and wire-latency tails");
    let mut cfg = VenusConfig::default();
    cfg.wire.listen = "127.0.0.1:0".into();

    eprintln!("  ingesting the stream...");
    let case =
        prepare_case(DatasetPreset::VideoMmeShort, &cfg, QUERIES, 0x31e1).expect("prepare case");
    cfg.api.fps = case.synth.config().fps;
    let service =
        Arc::new(Service::start(&cfg, Arc::clone(&case.fabric), 0x7ea).expect("service"));
    let gateway = Gateway::start(&cfg.wire, Arc::clone(&service)).expect("gateway");
    let addr = gateway.local_addr();
    note(&format!(
        "gateway on {addr}: {} workers, {} conns max, {} distinct query texts",
        cfg.server.workers,
        cfg.wire.max_conns,
        QUERIES
    ));

    let mut texts: Vec<String> = case.queries.iter().map(|q| q.text.clone()).collect();
    texts.sort();
    texts.dedup();

    // --- open-loop sweep over client counts ---
    let mut table = Table::new(vec![
        "clients",
        "target q/s",
        "sustained q/s",
        "p50",
        "p95",
        "p99",
        "ok",
        "rejected",
        "shed",
    ]);
    for &clients in &CLIENT_COUNTS {
        let mut lg = LoadGen::new(addr.to_string(), texts.clone());
        lg.clients = clients;
        lg.rate_qps = clients as f64 * PER_CLIENT_QPS;
        lg.duration = Duration::from_secs_f64(RUN_SECS);
        lg.wire = cfg.wire.clone();
        let report = lg.run().expect("load run");
        assert!(report.completed > 0, "{clients} clients completed nothing");
        assert_eq!(report.transport_errors, 0, "gateway dropped connections under load");
        persist_metric(&format!("sustained_qps_c{clients}"), report.qps(), "qps");
        persist_metric(
            &format!("wire_p95_c{clients}_s"),
            report.latency.percentile(95.0),
            "s",
        );
        table.row(vec![
            clients.to_string(),
            format!("{:.0}", report.target_qps),
            format!("{:.1}", report.qps()),
            fmt_duration(report.latency.percentile(50.0)),
            fmt_duration(report.latency.percentile(95.0)),
            fmt_duration(report.latency.percentile(99.0)),
            report.completed.to_string(),
            report.rejected.to_string(),
            report.shed.to_string(),
        ]);
    }
    print!("{table}");

    // --- cold vs cache-hit, end to end over the wire (single flight) ---
    let mut client = WireClient::connect_with(addr, &cfg.wire).expect("client");
    let mut cold = Samples::default();
    let mut hit = Samples::default();
    for round in 0..CACHE_ROUNDS {
        for (i, text) in texts.iter().enumerate() {
            // fresh phrasing per round; only status-confirmed misses and
            // hits are sampled, so semantic-tier near-matches of earlier
            // rounds can't pollute either side
            let fresh = format!("{text} cold round {round} {i}");
            let t0 = Instant::now();
            let response = client.query(QueryRequest::new(fresh.clone())).unwrap().unwrap();
            if !response.cache.is_hit() {
                cold.push(t0.elapsed().as_secs_f64());
            }
            let t0 = Instant::now();
            let response = client.query(QueryRequest::new(fresh)).unwrap().unwrap();
            if response.cache.is_hit() {
                hit.push(t0.elapsed().as_secs_f64());
            }
        }
    }
    assert!(!cold.is_empty() && !hit.is_empty(), "need both cold and hit samples");
    let speedup = cold.p50() / hit.p50().max(1e-12);
    note(&format!(
        "wire cache: cold p50 {} ({} samples) vs hit p50 {} ({} samples) — {speedup:.1}× lower",
        fmt_duration(cold.p50()),
        cold.len(),
        fmt_duration(hit.p50()),
        hit.len(),
    ));
    assert!(
        hit.p50() < cold.p50(),
        "cache-hit wire p50 ({}) must undercut cold wire p50 ({})",
        fmt_duration(hit.p50()),
        fmt_duration(cold.p50()),
    );
    persist_metric("cold_wire_p50_s", cold.p50(), "s");
    persist_metric("cache_hit_wire_p50_s", hit.p50(), "s");

    // durability-safe teardown order: wire first, then the lanes
    let wire = gateway.shutdown();
    note(&wire.render());
    let service = Arc::try_unwrap(service).ok().expect("gateway released the service");
    let snap = service.shutdown();
    note(&snap.render());
    assert_eq!(snap.queued(), 0, "lanes drained");
}
