//! Fabric-wide semantic query cache — the paper's query-indexing stage
//! (§IV: "indexes incoming queries from memory") applied to serving.
//!
//! Online video-understanding traffic is highly repetitive (the same
//! "what happened with X" phrasing recurs across users and turns), so
//! the cache indexes *query text embeddings* next to their finished
//! selections.  Two tiers:
//!
//!  * **exact** — a hash of the normalized query text.  Hits skip the
//!    whole edge hot path: no text embed, no scatter-gather scoring, no
//!    selection, no raw-frame fetch.
//!  * **semantic** — cosine similarity of the query embedding against
//!    cached embeddings.  A near-duplicate above the configured
//!    threshold reuses the cached selection, skipping scoring/selection
//!    (the embed was already paid to compute the similarity key).
//!
//! Freshness: every entry snapshots the ingest watermark of each shard
//! the query touched.  A lookup revalidates those watermarks; once any
//! touched shard advanced past the staleness bound the entry is dropped
//! (new evidence may exist that the cached selection cannot cite).
//! Entries are LRU-evicted beyond the configured capacity.

use std::fmt;

use crate::coordinator::query::RetrievalMode;
use crate::memory::{StreamId, StreamScope};
use crate::retrieval::Selection;
use crate::util::sync::{ranks, OrderedMutex};

/// How the cache participated in answering one query.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CacheStatus {
    /// No cache configured for this call.
    #[default]
    Bypass,
    /// Looked up, not found (or stale): the full edge path ran and the
    /// result was inserted.
    Miss,
    /// Normalized-text hit: the entire edge path (embed included) was
    /// skipped.
    HitExact,
    /// Embedding-similarity hit: scoring + selection were skipped.
    HitSemantic,
}

impl CacheStatus {
    pub fn is_hit(self) -> bool {
        matches!(self, CacheStatus::HitExact | CacheStatus::HitSemantic)
    }

    pub fn name(self) -> &'static str {
        match self {
            CacheStatus::Bypass => "bypass",
            CacheStatus::Miss => "miss",
            CacheStatus::HitExact => "hit_exact",
            CacheStatus::HitSemantic => "hit_semantic",
        }
    }
}

impl fmt::Display for CacheStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The cached payload: everything needed to rebuild a response without
/// touching the memory fabric.
#[derive(Clone, Debug)]
pub struct CachedQuery {
    pub selection: Selection,
    /// Eq. 4–5 score per selected frame, parallel to `selection.frames`.
    pub frame_scores: Vec<f32>,
    pub draws: usize,
}

struct Entry {
    text_key: u64,
    qvec: Vec<f32>,
    scope: StreamScope,
    mode: RetrievalMode,
    /// Effective AKR draw cap the selection ran under.  Part of the key:
    /// FixedSampling/TopK budgets live inside `mode`, but an AKR budget
    /// override only caps `n_max` — without this, an AKR query capped at
    /// 2 draws and an uncapped one would alias the same entry.
    n_max: usize,
    /// (stream, ingest watermark) per touched shard, at selection time.
    watermarks: Vec<(StreamId, u64)>,
    cached: CachedQuery,
    last_used: u64,
}

#[derive(Clone, Copy, Debug, Default)]
struct StatsInner {
    hits_exact: u64,
    hits_semantic: u64,
    misses: u64,
    invalidated: u64,
    evicted: u64,
}

/// Immutable cache-stats snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub entries: usize,
    pub hits_exact: u64,
    pub hits_semantic: u64,
    /// queries that fell through BOTH tiers (counted once per query, by
    /// the semantic tier — the last one to run)
    pub misses: u64,
    /// entries dropped because a touched shard's watermark advanced past
    /// the staleness bound
    pub invalidated: u64,
    /// entries dropped by LRU capacity pressure
    pub evicted: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits_exact + self.hits_semantic
    }

    pub fn render(&self) -> String {
        format!(
            "query cache: {} entries | {} exact + {} semantic hits / {} misses | {} invalidated, {} evicted",
            self.entries,
            self.hits_exact,
            self.hits_semantic,
            self.misses,
            self.invalidated,
            self.evicted,
        )
    }
}

struct Inner {
    entries: Vec<Entry>,
    tick: u64,
    stats: StatsInner,
}

/// Thread-safe semantic query cache, shared by every serving worker
/// (and usable standalone next to a bare [`crate::coordinator::query::QueryEngine`]).
pub struct QueryCache {
    inner: OrderedMutex<Inner>,
    capacity: usize,
    threshold: f32,
    max_stale: u64,
}

impl QueryCache {
    /// `capacity` in entries (0 disables the cache entirely), `threshold`
    /// the semantic-tier cosine bound, `max_stale` the per-shard ingest
    /// watermark advance beyond which an entry is invalid.
    pub fn new(capacity: usize, threshold: f32, max_stale: u64) -> Self {
        Self {
            inner: OrderedMutex::new(
                ranks::QUERY_CACHE,
                Inner { entries: Vec::new(), tick: 0, stats: StatsInner::default() },
            ),
            capacity,
            threshold,
            max_stale,
        }
    }

    /// Build from the `[api]` config section.
    pub fn from_config(cfg: &crate::config::ApiConfig) -> Self {
        Self::new(cfg.cache_entries, cfg.cache_threshold as f32, cfg.cache_max_stale)
    }

    /// A zero-capacity cache never stores or returns anything.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// FNV-1a over the normalized query text (lowercased, whitespace
    /// collapsed) — the exact-tier key.
    pub fn text_key(text: &str) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut first = true;
        for word in text.split_whitespace() {
            if !first {
                h = fnv_step(h, b' ');
            }
            first = false;
            for b in word.as_bytes() {
                h = fnv_step(h, b.to_ascii_lowercase());
            }
        }
        h
    }

    /// Exact-tier lookup.  `current` must be the fabric's watermarks for
    /// `scope` (same shard order as at insert time); `n_max` the
    /// effective AKR draw cap of this request.  A miss here is not yet a
    /// cache miss — the semantic tier still runs, and counts it.
    pub fn lookup_exact(
        &self,
        text_key: u64,
        scope: StreamScope,
        mode: RetrievalMode,
        n_max: usize,
        current: &[(StreamId, u64)],
    ) -> Option<CachedQuery> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock();
        inner.tick += 1;
        let tick = inner.tick;
        let pos = inner.entries.iter().position(|e| {
            e.text_key == text_key && e.scope == scope && e.mode == mode && e.n_max == n_max
        });
        match pos {
            Some(i) if fresh(&inner.entries[i].watermarks, current, self.max_stale) => {
                inner.entries[i].last_used = tick;
                inner.stats.hits_exact += 1;
                Some(inner.entries[i].cached.clone())
            }
            Some(i) => {
                inner.entries.swap_remove(i);
                inner.stats.invalidated += 1;
                None
            }
            None => None,
        }
    }

    /// Semantic-tier lookup: best cosine over cached entries with the
    /// same scope + mode + AKR cap.  Stale candidates above the threshold
    /// are dropped; a fresh candidate at or above the threshold is a hit.
    /// This tier runs last, so it is the one that counts a query's miss.
    pub fn lookup_semantic(
        &self,
        qvec: &[f32],
        scope: StreamScope,
        mode: RetrievalMode,
        n_max: usize,
        current: &[(StreamId, u64)],
    ) -> Option<CachedQuery> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock();
        inner.tick += 1;
        let tick = inner.tick;
        // one pass under the shared mutex: each candidate's cosine is
        // computed exactly once; stale candidates at/above the threshold
        // are collected for removal, fresh ones compete for best
        let mut best: Option<(usize, f32)> = None;
        let mut stale: Vec<usize> = Vec::new();
        for (i, e) in inner.entries.iter().enumerate() {
            if e.scope != scope || e.mode != mode || e.n_max != n_max || e.qvec.len() != qvec.len()
            {
                continue;
            }
            let sim = crate::util::dot(&e.qvec, qvec);
            if sim < self.threshold {
                continue;
            }
            if !fresh(&e.watermarks, current, self.max_stale) {
                stale.push(i);
            } else {
                let better = match best {
                    Some((_, s)) => sim > s,
                    None => true,
                };
                if better {
                    best = Some((i, sim));
                }
            }
        }
        // ascending `stale` removed back-to-front keeps lower indices
        // valid; `best` is fresh (disjoint from `stale`) and only shifts
        // down past removals above it
        for &r in stale.iter().rev() {
            inner.entries.remove(r);
            if let Some((ref mut b, _)) = best {
                if *b > r {
                    *b -= 1;
                }
            }
        }
        inner.stats.invalidated += stale.len() as u64;
        match best {
            Some((i, _)) => {
                inner.entries[i].last_used = tick;
                inner.stats.hits_semantic += 1;
                Some(inner.entries[i].cached.clone())
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) an entry.  `qvec` must be the unit-norm query
    /// embedding; `watermarks` the touched shards' watermarks captured
    /// under the same read guards the selection ran under.
    pub fn insert(
        &self,
        text_key: u64,
        qvec: Vec<f32>,
        scope: StreamScope,
        mode: RetrievalMode,
        n_max: usize,
        watermarks: Vec<(StreamId, u64)>,
        cached: CachedQuery,
    ) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.entries.iter_mut().find(|e| {
            e.text_key == text_key && e.scope == scope && e.mode == mode && e.n_max == n_max
        }) {
            e.qvec = qvec;
            e.watermarks = watermarks;
            e.cached = cached;
            e.last_used = tick;
            return;
        }
        inner.entries.push(Entry {
            text_key,
            qvec,
            scope,
            mode,
            n_max,
            watermarks,
            cached,
            last_used: tick,
        });
        while inner.entries.len() > self.capacity {
            let Some(lru) = inner
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
            else {
                break;
            };
            inner.entries.swap_remove(lru);
            inner.stats.evicted += 1;
        }
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock();
        CacheStats {
            entries: inner.entries.len(),
            hits_exact: inner.stats.hits_exact,
            hits_semantic: inner.stats.hits_semantic,
            misses: inner.stats.misses,
            invalidated: inner.stats.invalidated,
            evicted: inner.stats.evicted,
        }
    }

    /// Drop every entry (stats are kept).
    pub fn clear(&self) {
        self.inner.lock().entries.clear();
    }
}

fn fnv_step(h: u64, b: u8) -> u64 {
    (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
}

/// Entry watermarks vs current: fresh iff the same shard set, every
/// watermark monotone, and no shard advanced past `max_stale` inserts.
fn fresh(entry: &[(StreamId, u64)], current: &[(StreamId, u64)], max_stale: u64) -> bool {
    entry.len() == current.len()
        && entry.iter().zip(current).all(|(a, b)| {
            a.0 == b.0 && b.1.checked_sub(a.1).is_some_and(|adv| adv <= max_stale)
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::FrameId;

    fn sel(stream: u16, idx: u64) -> CachedQuery {
        CachedQuery {
            selection: Selection {
                frames: vec![FrameId::new(StreamId(stream), idx)],
                ..Default::default()
            },
            frame_scores: vec![0.5],
            draws: 4,
        }
    }

    fn wm(w: u64) -> Vec<(StreamId, u64)> {
        vec![(StreamId(0), w)]
    }

    const MODE: RetrievalMode = RetrievalMode::FixedSampling(8);
    const N: usize = 32;

    #[test]
    fn text_key_normalizes_case_and_whitespace() {
        let a = QueryCache::text_key("What   Happened with concept01");
        let b = QueryCache::text_key("what happened  with CONCEPT01 ");
        let c = QueryCache::text_key("what happened with concept02");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn exact_tier_hits_and_respects_scope_and_mode() {
        let c = QueryCache::new(8, 0.9, 10);
        let key = QueryCache::text_key("q one");
        c.insert(key, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(0), sel(0, 1));
        assert!(c.lookup_exact(key, StreamScope::All, MODE, N, &wm(0)).is_some());
        // different scope or mode: no entry matches
        assert!(c
            .lookup_exact(key, StreamScope::One(StreamId(0)), MODE, N, &wm(0))
            .is_none());
        assert!(c
            .lookup_exact(key, StreamScope::All, RetrievalMode::Akr, N, &wm(0))
            .is_none());
        let s = c.stats();
        assert_eq!(s.hits_exact, 1);
        // the exact tier never counts misses — the semantic tier (the
        // last to run per query) owns that stat
        assert_eq!(s.misses, 0);
    }

    #[test]
    fn akr_budget_cap_is_part_of_the_key() {
        // an AKR selection capped at 2 draws must never be replayed for
        // an uncapped AKR request with the same text (and vice versa)
        let c = QueryCache::new(8, 0.9, 10);
        let key = QueryCache::text_key("q");
        c.insert(key, vec![1.0, 0.0], StreamScope::All, RetrievalMode::Akr, 2, wm(0), sel(0, 1));
        assert!(c
            .lookup_exact(key, StreamScope::All, RetrievalMode::Akr, 32, &wm(0))
            .is_none());
        assert!(c
            .lookup_semantic(&[1.0, 0.0], StreamScope::All, RetrievalMode::Akr, 32, &wm(0))
            .is_none());
        assert!(c
            .lookup_exact(key, StreamScope::All, RetrievalMode::Akr, 2, &wm(0))
            .is_some());
        // both caps coexist as distinct entries
        c.insert(key, vec![1.0, 0.0], StreamScope::All, RetrievalMode::Akr, 32, wm(0), sel(0, 9));
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn semantic_tier_hits_near_duplicates_only() {
        let c = QueryCache::new(8, 0.95, 10);
        c.insert(
            QueryCache::text_key("q"),
            vec![1.0, 0.0],
            StreamScope::All,
            MODE,
            N,
            wm(0),
            sel(0, 7),
        );
        // cos = 0.999 -> hit
        let near = vec![0.999, 0.0447];
        let hit = c.lookup_semantic(&near, StreamScope::All, MODE, N, &wm(0)).unwrap();
        assert_eq!(hit.selection.frames, vec![FrameId::new(StreamId(0), 7)]);
        // orthogonal -> miss (counted here, once per query)
        assert!(c
            .lookup_semantic(&[0.0, 1.0], StreamScope::All, MODE, N, &wm(0))
            .is_none());
        let s = c.stats();
        assert_eq!(s.hits_semantic, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn watermark_advance_past_bound_invalidates() {
        let c = QueryCache::new(8, 0.9, 2);
        let key = QueryCache::text_key("q");
        c.insert(key, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(5), sel(0, 1));
        // advanced by exactly the bound: still fresh
        assert!(c.lookup_exact(key, StreamScope::All, MODE, N, &wm(7)).is_some());
        // past the bound: entry dropped
        assert!(c.lookup_exact(key, StreamScope::All, MODE, N, &wm(8)).is_none());
        assert_eq!(c.stats().invalidated, 1);
        assert_eq!(c.stats().entries, 0);
        // a watermark that went backwards (shard replaced) is also stale
        c.insert(key, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(5), sel(0, 1));
        assert!(c.lookup_exact(key, StreamScope::All, MODE, N, &wm(4)).is_none());
        assert_eq!(c.stats().invalidated, 2);
    }

    #[test]
    fn semantic_lookup_drops_stale_candidates() {
        let c = QueryCache::new(8, 0.9, 1);
        c.insert(
            QueryCache::text_key("q"),
            vec![1.0, 0.0],
            StreamScope::All,
            MODE,
            N,
            wm(0),
            sel(0, 1),
        );
        assert!(c
            .lookup_semantic(&[1.0, 0.0], StreamScope::All, MODE, N, &wm(5))
            .is_none());
        let s = c.stats();
        assert_eq!(s.invalidated, 1);
        assert_eq!(s.entries, 0);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = QueryCache::new(2, 0.9, 100);
        let (ka, kb, kc) =
            (QueryCache::text_key("a"), QueryCache::text_key("b"), QueryCache::text_key("c"));
        c.insert(ka, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(0), sel(0, 1));
        c.insert(kb, vec![0.0, 1.0], StreamScope::All, MODE, N, wm(0), sel(0, 2));
        // touch a so b becomes LRU
        assert!(c.lookup_exact(ka, StreamScope::All, MODE, N, &wm(0)).is_some());
        c.insert(kc, vec![0.6, 0.8], StreamScope::All, MODE, N, wm(0), sel(0, 3));
        assert_eq!(c.stats().entries, 2);
        assert_eq!(c.stats().evicted, 1);
        assert!(c.lookup_exact(ka, StreamScope::All, MODE, N, &wm(0)).is_some());
        assert!(c.lookup_exact(kb, StreamScope::All, MODE, N, &wm(0)).is_none());
        assert!(c.lookup_exact(kc, StreamScope::All, MODE, N, &wm(0)).is_some());
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let c = QueryCache::new(8, 0.9, 1);
        let key = QueryCache::text_key("q");
        c.insert(key, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(0), sel(0, 1));
        c.insert(key, vec![1.0, 0.0], StreamScope::All, MODE, N, wm(10), sel(0, 9));
        assert_eq!(c.stats().entries, 1);
        let hit = c.lookup_exact(key, StreamScope::All, MODE, N, &wm(10)).unwrap();
        assert_eq!(hit.selection.frames, vec![FrameId::new(StreamId(0), 9)]);
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let c = QueryCache::new(0, 0.9, 10);
        assert!(!c.enabled());
        let key = QueryCache::text_key("q");
        c.insert(key, vec![1.0], StreamScope::All, MODE, N, wm(0), sel(0, 1));
        assert!(c.lookup_exact(key, StreamScope::All, MODE, N, &wm(0)).is_none());
        assert!(c
            .lookup_semantic(&[1.0], StreamScope::All, MODE, N, &wm(0))
            .is_none());
        assert_eq!(c.stats().entries, 0);
        assert_eq!(c.stats().misses, 0, "disabled cache records no traffic");
    }
}
