//! Serving API v1 — the typed query protocol over the Venus serving
//! loop.
//!
//! The paper's querying stage "indexes incoming queries from memory"
//! (§IV); this layer is that idea turned into a serving surface:
//!
//!  * [`types`] — the wire protocol: a [`QueryRequest`] builder (text,
//!    stream scope, retrieval mode, per-query sampling budget, priority
//!    lane, deadline), a structured [`QueryResponse`] (per-frame
//!    [`Evidence`] with stream, timestamp, and Eq. 4–5 score, plus the
//!    full latency breakdown), and the [`ApiError`] taxonomy.  All
//!    JSON round-trippable through the in-tree writer/parser.
//!  * [`cache`] — the fabric-wide semantic query cache: query-text
//!    embeddings are indexed next to finished selections; exact text
//!    repeats skip the whole edge hot path, cosine-near duplicates skip
//!    scoring + selection, and per-shard ingest watermarks bound how
//!    stale a reused selection may be.
//!  * [`session`] — [`Client`]/[`Session`] handles with per-session
//!    query history over one shared service.
//!
//! Entry points: [`crate::server::Service::submit_request`] /
//! [`crate::server::Service::call`] (one-shot), or a [`Session`] for
//! multi-turn use.  See `examples/quickstart.rs` and DESIGN.md
//! §Serving-API.

pub mod cache;
pub mod session;
pub mod types;

pub use cache::{CacheStats, CacheStatus, QueryCache};
pub use session::{Client, Session, SessionTurn};
pub use types::{ApiError, Evidence, Priority, QueryRequest, QueryResponse};
