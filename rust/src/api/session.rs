//! Client/session handles over the query [`Service`]: multi-turn query
//! history per session, one shared semantic query cache per service.
//!
//! A [`Client`] is a cheap facade over a running service; each
//! [`Session`] models one user's conversation — every turn (request +
//! typed response or error) is recorded, so callers can inspect what a
//! user asked, how fast it was answered, and how often the fabric-wide
//! query cache absorbed their repeats.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::server::Service;

use super::cache::CacheStats;
use super::types::{ApiError, QueryRequest, QueryResponse};

/// One recorded session turn.
#[derive(Clone, Debug)]
pub struct SessionTurn {
    pub request: QueryRequest,
    pub response: Result<QueryResponse, ApiError>,
}

/// Typed-API client over a running [`Service`].
pub struct Client<'a> {
    service: &'a Service,
    next_session: AtomicU64,
}

impl<'a> Client<'a> {
    pub fn new(service: &'a Service) -> Self {
        Self { service, next_session: AtomicU64::new(0) }
    }

    /// Open a new session (fresh history, shared service + cache).
    pub fn session(&self) -> Session<'a> {
        Session {
            id: self.next_session.fetch_add(1, Ordering::Relaxed),
            service: self.service,
            history: Vec::new(),
        }
    }

    /// One-shot query without session history.
    pub fn call(&self, request: QueryRequest) -> Result<QueryResponse, ApiError> {
        self.service.call(request)
    }

    /// The service-wide semantic query-cache statistics.
    pub fn cache_stats(&self) -> CacheStats {
        self.service.cache.stats()
    }
}

/// A multi-turn query session.
pub struct Session<'a> {
    id: u64,
    service: &'a Service,
    history: Vec<SessionTurn>,
}

impl Session<'_> {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Submit a request and block for its typed response; the turn is
    /// recorded in the session history either way.
    pub fn ask(&mut self, request: QueryRequest) -> Result<QueryResponse, ApiError> {
        let response = self.service.call(request.clone());
        self.history.push(SessionTurn { request, response: response.clone() });
        response
    }

    /// Every turn this session has run, in order.
    pub fn history(&self) -> &[SessionTurn] {
        &self.history
    }

    /// Completed turns that were served from the semantic query cache.
    pub fn cache_hits(&self) -> usize {
        self.history
            .iter()
            .filter(|t| t.response.as_ref().is_ok_and(|r| r.cache.is_hit()))
            .count()
    }

    /// Turns that ended in a typed error (shed, rejected, ...).
    pub fn errors(&self) -> usize {
        self.history.iter().filter(|t| t.response.is_err()).count()
    }
}
