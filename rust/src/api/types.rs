//! Protocol types for Serving API v1: the typed query request (builder),
//! the structured response with per-frame evidence, and the error
//! taxonomy that subsumes the old stringly `SubmitError`.
//!
//! Wire format: every type serializes to/from JSON through the in-tree
//! [`crate::util::json`] writer/parser (serde is unavailable offline),
//! so requests and responses survive a real transport unchanged.  The
//! encoding is stable and round-trip tested.

use std::fmt;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::query::{EdgeTimings, RetrievalMode};
use crate::memory::{FrameId, StreamId, StreamScope};
use crate::obs::TraceId;
use crate::util::json::Json;

use super::cache::CacheStatus;

/// Upper bound on a wire deadline (30 days in ms) — far beyond any real
/// query budget, but finite so decoding can never panic.
const MAX_DEADLINE_MS: f64 = 30.0 * 86_400.0 * 1e3;

/// Decode a stream id, rejecting values that don't fit a `StreamId`
/// instead of silently truncating (65537 must not alias stream 1).
fn stream_id_from(v: &Json) -> Result<StreamId> {
    let id = v.as_usize()?;
    if id > u16::MAX as usize {
        bail!("stream id {id} exceeds the fabric's StreamId range (<= {})", u16::MAX);
    }
    Ok(StreamId(id as u16))
}

/// Scheduling class of a query: which admission lane it enters and how
/// the worker pool orders it relative to other pending queries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// A human is waiting: dequeued before any batch query.
    #[default]
    Interactive,
    /// Offline/analytics traffic: served only when the interactive lane
    /// is empty.
    Batch,
}

impl Priority {
    /// Lane-array index (interactive first — it is popped first).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed query request (builder-style).
///
/// ```
/// use std::time::Duration;
/// use venus::api::{Priority, QueryRequest};
/// use venus::memory::{StreamId, StreamScope};
///
/// let req = QueryRequest::new("what happened with concept03")
///     .scope(StreamScope::One(StreamId(1)))
///     .budget(16)
///     .priority(Priority::Interactive)
///     .deadline(Duration::from_secs(5));
/// assert_eq!(req.budget, Some(16));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct QueryRequest {
    /// Natural-language query text.
    pub text: String,
    /// Which camera streams the query sees.
    pub scope: StreamScope,
    /// Retrieval-mode override (None = the engine's configured default).
    pub mode: Option<RetrievalMode>,
    /// Sampling-budget override: replaces the fixed budget / Top-K size,
    /// and caps AKR's `n_max` for this query only.
    pub budget: Option<usize>,
    /// Admission lane.
    pub priority: Priority,
    /// Time budget from submission; a query still queued past its
    /// deadline is shed at dequeue time (never executed).
    pub deadline: Option<Duration>,
}

impl QueryRequest {
    pub fn new(text: impl Into<String>) -> Self {
        Self {
            text: text.into(),
            scope: StreamScope::All,
            mode: None,
            budget: None,
            priority: Priority::Interactive,
            deadline: None,
        }
    }

    pub fn scope(mut self, scope: StreamScope) -> Self {
        self.scope = scope;
        self
    }

    pub fn mode(mut self, mode: RetrievalMode) -> Self {
        self.mode = Some(mode);
        self
    }

    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = Some(budget);
        self
    }

    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Estimated VLM prompt tokens for this query's text — the one shared
    /// estimate used by the serving worker loop, the coordinator, and the
    /// eval latency model (formerly an inline `words * 2` magic formula).
    pub fn approx_tokens(&self) -> usize {
        Self::approx_tokens_for(&self.text)
    }

    /// Token estimate for raw query text (≈2 tokens per whitespace word,
    /// minimum 1 — a query never prompts zero tokens).
    pub fn approx_tokens_for(text: &str) -> usize {
        (text.split_whitespace().count() * 2).max(1)
    }

    /// Serialize to the wire JSON encoding.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("text".into(), Json::Str(self.text.clone()));
        m.insert("scope".into(), scope_to_json(self.scope));
        if let Some(mode) = self.mode {
            m.insert("mode".into(), mode_to_json(mode));
        }
        if let Some(b) = self.budget {
            m.insert("budget".into(), Json::Num(b as f64));
        }
        m.insert("priority".into(), Json::Str(self.priority.name().into()));
        if let Some(d) = self.deadline {
            m.insert("deadline_ms".into(), Json::Num(d.as_secs_f64() * 1e3));
        }
        Json::Obj(m)
    }

    /// Parse the wire JSON encoding (missing optional fields default).
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut req = Self::new(v.get("text")?.as_str()?);
        req.scope = scope_from_json(v.get("scope")?)?;
        if let Some(mode) = v.opt("mode") {
            req.mode = Some(mode_from_json(mode)?);
        }
        if let Some(b) = v.opt("budget") {
            req.budget = Some(b.as_usize()?);
        }
        if let Some(p) = v.opt("priority") {
            req.priority = priority_from_json(p)?;
        }
        if let Some(d) = v.opt("deadline_ms") {
            // wire input is untrusted: Duration::from_secs_f64 panics on
            // negative/NaN/huge values, so bound-check first
            let ms = d.as_f64()?;
            if !ms.is_finite() || !(0.0..=MAX_DEADLINE_MS).contains(&ms) {
                bail!("deadline_ms must be a finite value in [0, {MAX_DEADLINE_MS}], got {ms}");
            }
            req.deadline = Some(Duration::from_secs_f64(ms / 1e3));
        }
        Ok(req)
    }

    /// Parse from JSON text.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }
}

/// One retrieved evidence frame: fabric-global address, wall-clock
/// position in its stream, and the Eq. 4–5 retrieval score that drew it
/// (softmax probability for sampling/AKR, raw cosine for Top-K).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Evidence {
    pub frame: FrameId,
    pub time_s: f64,
    pub score: f32,
}

impl Evidence {
    /// The camera stream this evidence frame came from.
    pub fn stream(&self) -> StreamId {
        self.frame.stream
    }
}

/// A completed query: structured evidence plus the full latency
/// breakdown (queue wait, measured edge stages, simulated upload + VLM).
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub id: u64,
    pub priority: Priority,
    /// How the semantic query cache participated.
    pub cache: CacheStatus,
    /// Selected evidence frames, stream-major ascending.
    pub evidence: Vec<Evidence>,
    /// Retrieval draws used (== budget when AKR is off).
    pub draws: usize,
    pub queue_wait_s: f64,
    /// Measured edge-side stage timings (zero stages on a cache hit).
    pub edge: EdgeTimings,
    pub upload_s: f64,
    pub vlm_s: f64,
    /// Trace id of this query's span tree when the service head-sampled
    /// it — fetch the per-stage breakdown through the `trace` wire
    /// envelope (`venus query --connect --trace`).  `None` when tracing
    /// is disabled, the request was not sampled, or the reply came from
    /// an older server that predates tracing.
    pub trace_id: Option<TraceId>,
}

impl QueryResponse {
    pub fn total_s(&self) -> f64 {
        self.queue_wait_s + self.edge.total_s() + self.upload_s + self.vlm_s
    }

    /// Stream-local frame indices, in evidence order (the single-stream
    /// view the answer model judges against).
    pub fn frame_indices(&self) -> Vec<u64> {
        self.evidence.iter().map(|e| e.frame.idx).collect()
    }

    /// Distinct streams cited, ascending.
    pub fn streams(&self) -> Vec<StreamId> {
        let mut out: Vec<StreamId> = self.evidence.iter().map(|e| e.frame.stream).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Serialize to the wire JSON encoding.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert("priority".into(), Json::Str(self.priority.name().into()));
        m.insert("cache".into(), Json::Str(self.cache.name().into()));
        m.insert(
            "evidence".into(),
            Json::Arr(
                self.evidence
                    .iter()
                    .map(|e| {
                        let mut em = std::collections::BTreeMap::new();
                        em.insert("stream".into(), Json::Num(e.frame.stream.0 as f64));
                        em.insert("frame".into(), Json::Num(e.frame.idx as f64));
                        em.insert("time_s".into(), Json::Num(e.time_s));
                        em.insert("score".into(), Json::Num(e.score as f64));
                        Json::Obj(em)
                    })
                    .collect(),
            ),
        );
        m.insert("draws".into(), Json::Num(self.draws as f64));
        let mut lat = std::collections::BTreeMap::new();
        lat.insert("queue_wait_s".into(), Json::Num(self.queue_wait_s));
        lat.insert("embed_query_s".into(), Json::Num(self.edge.embed_query_s));
        lat.insert("search_s".into(), Json::Num(self.edge.search_s));
        lat.insert("select_s".into(), Json::Num(self.edge.select_s));
        lat.insert("fetch_s".into(), Json::Num(self.edge.fetch_s));
        lat.insert("upload_s".into(), Json::Num(self.upload_s));
        lat.insert("vlm_s".into(), Json::Num(self.vlm_s));
        // finer-grained gauges (PR: query tracing) — decoders treat them
        // as optional so replies interoperate across versions
        lat.insert("cache_probe_ms".into(), Json::Num(self.edge.cache_probe_s * 1e3));
        lat.insert("score_ms".into(), Json::Num(self.edge.score_s * 1e3));
        m.insert("latency".into(), Json::Obj(lat));
        if let Some(id) = self.trace_id {
            m.insert("trace_id".into(), Json::Str(id.to_string()));
        }
        Json::Obj(m)
    }

    /// Parse the wire JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        let lat = v.get("latency")?;
        let evidence = v
            .get("evidence")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(Evidence {
                    frame: FrameId::new(
                        stream_id_from(e.get("stream")?)?,
                        e.get("frame")?.as_usize()? as u64,
                    ),
                    time_s: e.get("time_s")?.as_f64()?,
                    score: e.get("score")?.as_f64()? as f32,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            id: v.get("id")?.as_usize()? as u64,
            priority: priority_from_json(v.get("priority")?)?,
            cache: cache_from_json(v.get("cache")?)?,
            evidence,
            draws: v.get("draws")?.as_usize()?,
            queue_wait_s: lat.get("queue_wait_s")?.as_f64()?,
            edge: EdgeTimings {
                embed_query_s: lat.get("embed_query_s")?.as_f64()?,
                search_s: lat.get("search_s")?.as_f64()?,
                select_s: lat.get("select_s")?.as_f64()?,
                fetch_s: lat.get("fetch_s")?.as_f64()?,
                // absent on replies from pre-tracing servers: default 0
                cache_probe_s: lat
                    .opt("cache_probe_ms")
                    .map(|x| x.as_f64())
                    .transpose()?
                    .unwrap_or(0.0)
                    / 1e3,
                score_s: lat.opt("score_ms").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0)
                    / 1e3,
            },
            upload_s: lat.get("upload_s")?.as_f64()?,
            vlm_s: lat.get("vlm_s")?.as_f64()?,
            trace_id: v
                .opt("trace_id")
                .map(|x| x.as_str())
                .transpose()?
                .and_then(TraceId::parse),
        })
    }

    /// Parse from JSON text.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }
}

/// Why a query produced no answer — the typed taxonomy subsuming the old
/// `SubmitError` (admission) and adding execution-time failures.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ApiError {
    /// Admission control: the request's lane is full.  The service is
    /// healthy, just saturated — retry later or shed load.
    Rejected { lane: Priority },
    /// The query sat queued past its deadline and was shed at dequeue
    /// time without executing.
    DeadlineExceeded,
    /// The service is shutting down (or its workers are gone).  Don't
    /// retry.
    Shutdown,
    /// The query engine failed while executing the request.
    Engine(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::Rejected { lane } => {
                write!(f, "{lane} lane full: query rejected by admission control")
            }
            ApiError::DeadlineExceeded => write!(f, "deadline exceeded while queued"),
            ApiError::Shutdown => write!(f, "service shutting down"),
            ApiError::Engine(msg) => write!(f, "query engine error: {msg}"),
        }
    }
}

impl ApiError {
    /// Serialize to the wire JSON encoding (the gateway's error frames).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            ApiError::Rejected { lane } => {
                m.insert("kind".into(), Json::Str("rejected".into()));
                m.insert("lane".into(), Json::Str(lane.name().into()));
            }
            ApiError::DeadlineExceeded => {
                m.insert("kind".into(), Json::Str("deadline_exceeded".into()));
            }
            ApiError::Shutdown => {
                m.insert("kind".into(), Json::Str("shutdown".into()));
            }
            ApiError::Engine(msg) => {
                m.insert("kind".into(), Json::Str("engine".into()));
                m.insert("message".into(), Json::Str(msg.clone()));
            }
        }
        Json::Obj(m)
    }

    /// Parse the wire JSON encoding.
    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "rejected" => Ok(ApiError::Rejected { lane: priority_from_json(v.get("lane")?)? }),
            "deadline_exceeded" => Ok(ApiError::DeadlineExceeded),
            "shutdown" => Ok(ApiError::Shutdown),
            "engine" => Ok(ApiError::Engine(v.get("message")?.as_str()?.to_string())),
            other => bail!("unknown api error kind '{other}'"),
        }
    }
}

impl std::error::Error for ApiError {}

// --- JSON helpers for the enum fields ---

fn scope_to_json(scope: StreamScope) -> Json {
    match scope {
        StreamScope::All => Json::Str("all".into()),
        StreamScope::One(s) => {
            let mut m = std::collections::BTreeMap::new();
            m.insert("one".into(), Json::Num(s.0 as f64));
            Json::Obj(m)
        }
    }
}

fn scope_from_json(v: &Json) -> Result<StreamScope> {
    match v {
        Json::Str(s) if s == "all" => Ok(StreamScope::All),
        Json::Obj(_) => Ok(StreamScope::One(stream_id_from(v.get("one")?)?)),
        other => bail!("bad scope encoding: {other:?}"),
    }
}

fn mode_to_json(mode: RetrievalMode) -> Json {
    let mut m = std::collections::BTreeMap::new();
    match mode {
        RetrievalMode::Akr => return Json::Str("akr".into()),
        RetrievalMode::FixedSampling(n) => {
            m.insert("fixed_sampling".into(), Json::Num(n as f64));
        }
        RetrievalMode::TopK(k) => {
            m.insert("top_k".into(), Json::Num(k as f64));
        }
    }
    Json::Obj(m)
}

fn mode_from_json(v: &Json) -> Result<RetrievalMode> {
    match v {
        Json::Str(s) if s == "akr" => Ok(RetrievalMode::Akr),
        Json::Obj(m) => {
            if let Some(n) = m.get("fixed_sampling") {
                Ok(RetrievalMode::FixedSampling(n.as_usize()?))
            } else if let Some(k) = m.get("top_k") {
                Ok(RetrievalMode::TopK(k.as_usize()?))
            } else {
                bail!("bad mode encoding: {v:?}")
            }
        }
        other => bail!("bad mode encoding: {other:?}"),
    }
}

fn priority_from_json(v: &Json) -> Result<Priority> {
    match v.as_str()? {
        "interactive" => Ok(Priority::Interactive),
        "batch" => Ok(Priority::Batch),
        other => bail!("unknown priority '{other}'"),
    }
}

fn cache_from_json(v: &Json) -> Result<CacheStatus> {
    match v.as_str()? {
        "bypass" => Ok(CacheStatus::Bypass),
        "miss" => Ok(CacheStatus::Miss),
        "hit_exact" => Ok(CacheStatus::HitExact),
        "hit_semantic" => Ok(CacheStatus::HitSemantic),
        other => bail!("unknown cache status '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> QueryRequest {
        QueryRequest::new("where did the red car go")
            .scope(StreamScope::One(StreamId(2)))
            .mode(RetrievalMode::FixedSampling(16))
            .budget(12)
            .priority(Priority::Batch)
            .deadline(Duration::from_millis(2500))
    }

    #[test]
    fn builder_defaults() {
        let req = QueryRequest::new("q");
        assert_eq!(req.scope, StreamScope::All);
        assert_eq!(req.mode, None);
        assert_eq!(req.budget, None);
        assert_eq!(req.priority, Priority::Interactive);
        assert_eq!(req.deadline, None);
    }

    #[test]
    fn approx_tokens_is_two_per_word_with_floor() {
        assert_eq!(QueryRequest::approx_tokens_for("one two three"), 6);
        assert_eq!(QueryRequest::approx_tokens_for("   "), 1);
        assert_eq!(QueryRequest::new("a b").approx_tokens(), 4);
    }

    #[test]
    fn request_round_trips_through_json() {
        let req = sample_request();
        let back = QueryRequest::from_json_str(&req.to_json().to_string()).unwrap();
        assert_eq!(back, req);
        // optional fields absent -> defaults
        let min = QueryRequest::new("hello world").to_json().to_string();
        let back = QueryRequest::from_json_str(&min).unwrap();
        assert_eq!(back, QueryRequest::new("hello world"));
    }

    #[test]
    fn mode_and_scope_encodings_round_trip() {
        for mode in [
            RetrievalMode::Akr,
            RetrievalMode::FixedSampling(7),
            RetrievalMode::TopK(3),
        ] {
            assert_eq!(mode_from_json(&mode_to_json(mode)).unwrap(), mode);
        }
        for scope in [StreamScope::All, StreamScope::One(StreamId(9))] {
            assert_eq!(scope_from_json(&scope_to_json(scope)).unwrap(), scope);
        }
        assert!(mode_from_json(&Json::Str("nope".into())).is_err());
        assert!(scope_from_json(&Json::Num(1.0)).is_err());
    }

    #[test]
    fn response_round_trips_through_json() {
        let resp = QueryResponse {
            id: 41,
            priority: Priority::Interactive,
            cache: CacheStatus::HitSemantic,
            evidence: vec![
                Evidence { frame: FrameId::new(StreamId(0), 12), time_s: 1.5, score: 0.25 },
                Evidence { frame: FrameId::new(StreamId(3), 7), time_s: 0.875, score: 0.125 },
            ],
            draws: 9,
            queue_wait_s: 0.001,
            edge: EdgeTimings {
                embed_query_s: 0.002,
                search_s: 0.003,
                select_s: 0.004,
                fetch_s: 0.005,
                cache_probe_s: 0.000125,
                score_s: 0.0025,
            },
            upload_s: 0.5,
            vlm_s: 1.25,
            trace_id: Some(TraceId(0xabcd_1234)),
        };
        let back = QueryResponse::from_json_str(&resp.to_json().to_string()).unwrap();
        assert_eq!(back.id, resp.id);
        assert_eq!(back.cache, resp.cache);
        assert_eq!(back.evidence, resp.evidence);
        assert_eq!(back.draws, resp.draws);
        assert_eq!(back.total_s(), resp.total_s());
        assert_eq!(back.frame_indices(), vec![12, 7]);
        assert_eq!(back.streams(), vec![StreamId(0), StreamId(3)]);
        assert_eq!(back.trace_id, resp.trace_id);
        assert!((back.edge.cache_probe_s - resp.edge.cache_probe_s).abs() < 1e-12);
        assert!((back.edge.score_s - resp.edge.score_s).abs() < 1e-12);
    }

    /// Interop across versions: a reply written by a server that predates
    /// tracing (no `trace_id`, no `score_ms` / `cache_probe_ms` latency
    /// keys) still decodes, with the new fields at their defaults.
    #[test]
    fn legacy_responses_without_trace_fields_still_decode() {
        let mut v = QueryResponse {
            id: 7,
            priority: Priority::Batch,
            cache: CacheStatus::Miss,
            evidence: vec![],
            draws: 1,
            queue_wait_s: 0.0,
            edge: EdgeTimings::default(),
            upload_s: 0.1,
            vlm_s: 0.2,
            trace_id: Some(TraceId(9)),
        }
        .to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("trace_id");
            let Some(Json::Obj(lat)) = m.get_mut("latency") else {
                panic!("latency must be an object")
            };
            lat.remove("score_ms");
            lat.remove("cache_probe_ms");
        }
        let back = QueryResponse::from_json_str(&v.to_string()).unwrap();
        assert_eq!(back.trace_id, None);
        assert_eq!(back.edge.score_s, 0.0);
        assert_eq!(back.edge.cache_probe_s, 0.0);
        // and an unparseable trace id degrades to None, not an error
        if let Json::Obj(m) = &mut v {
            m.insert("trace_id".into(), Json::Str("not-hex".into()));
        }
        let back = QueryResponse::from_json_str(&v.to_string()).unwrap();
        assert_eq!(back.trace_id, None);
    }

    #[test]
    fn malformed_wire_input_errs_instead_of_panicking() {
        // negative / huge / NaN-ish deadlines must be Err, not a panic
        // inside Duration::from_secs_f64
        for bad in ["-5", "1e300"] {
            let wire = format!(r#"{{"text":"q","scope":"all","deadline_ms":{bad}}}"#);
            assert!(QueryRequest::from_json_str(&wire).is_err(), "deadline_ms {bad}");
        }
        // out-of-range stream ids are rejected, never truncated to u16
        let wire = r#"{"text":"q","scope":{"one":65537}}"#;
        assert!(QueryRequest::from_json_str(wire).is_err());
        // in-range boundary still works
        let wire = r#"{"text":"q","scope":{"one":65535},"deadline_ms":1000}"#;
        let req = QueryRequest::from_json_str(wire).unwrap();
        assert_eq!(req.scope, StreamScope::One(StreamId(65535)));
        assert_eq!(req.deadline, Some(Duration::from_secs(1)));
    }

    #[test]
    fn api_error_displays_and_converts() {
        let e = ApiError::Rejected { lane: Priority::Batch };
        assert!(e.to_string().contains("batch lane full"));
        let any: anyhow::Error = ApiError::DeadlineExceeded.into();
        assert!(any.to_string().contains("deadline"));
    }

    #[test]
    fn api_error_round_trips_through_json() {
        for e in [
            ApiError::Rejected { lane: Priority::Batch },
            ApiError::Rejected { lane: Priority::Interactive },
            ApiError::DeadlineExceeded,
            ApiError::Shutdown,
            ApiError::Engine("index poisoned".into()),
        ] {
            let wire = e.to_json().to_string();
            let back = ApiError::from_json(&Json::parse(&wire).unwrap()).unwrap();
            assert_eq!(back, e, "{wire}");
        }
        assert!(ApiError::from_json(&Json::parse(r#"{"kind":"nope"}"#).unwrap()).is_err());
    }
}
