//! Pluggable compute backends for the MEM (multimodal embedding model).
//!
//! Everything above this layer — ingestion pipeline, coordinator, query
//! engine, server workers, eval harness, benches — talks to the model
//! through the [`EmbedBackend`] trait, which covers the five runtime entry
//! points the paper's edge node needs (image tower, text tower, fused
//! ingestion tower, Eq. 1 scene features, Eq. 4–5 similarity scoring) plus
//! the model metadata and concept side-data.
//!
//! Two implementations:
//!   * [`native::NativeBackend`] (default) — a pure-Rust mirror of the
//!     reference dual-encoder forward in `python/compile/model.py`, with
//!     weights generated deterministically from the model seed.  No
//!     artifact files, no FFI: the request path is self-contained on
//!     commodity hardware, which is the paper's core deployment claim.
//!   * `runtime::Runtime` (behind the off-by-default `pjrt` cargo
//!     feature) — executes the AOT-compiled XLA artifacts produced by
//!     `make artifacts` on the CPU PJRT client.
//!
//! See DESIGN.md §Backends for the trait contract and the parity story
//! between the two.

pub mod native;

use std::sync::Arc;

use anyhow::Result;

pub use native::{NativeBackend, NativeConfig};

/// Model hyperparameters every backend must agree on with its callers
/// (tokenizer layout, embedding dim, watermark geometry, fusion weights).
/// For the PJRT backend these are read from the artifact manifest; the
/// native backend derives them from its [`NativeConfig`].
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub img_size: usize,
    pub patch: usize,
    pub d_embed: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_concepts: usize,
    pub concept_token_base: usize,
    pub sim_rows: usize,
    pub scene_feat_dim: usize,
    pub sem_weight: f32,
    pub content_weight: f32,
    pub aux_weight: f32,
}

/// The compute-backend contract: the five MEM entry points + metadata.
///
/// Shape conventions (identical to the AOT artifact entry points):
///   * frames are `batch × (img_size · img_size · 3)` row-major pixels in
///     [0, 1], channel-interleaved (`Frame`'s memory layout);
///   * token windows are `seq_len` i32 ids per sequence;
///   * all embeddings come back L2-normalized, `d_embed` wide.
///
/// `Send + Sync` is part of the contract: one backend instance is
/// constructed per process and shared (`Arc<dyn EmbedBackend>`) by every
/// ingestion pipeline, pool worker, and query worker.  All entry points
/// take `&self`, so an implementation must either be immutable plain data
/// (the native backend: weights are read-only after construction) or
/// guard its interior mutability with a lock (the PJRT runtime's compiled
/// executable cache).
pub trait EmbedBackend: Send + Sync {
    /// Short backend identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// The model hyperparameters this backend was built with.
    fn model(&self) -> &ModelMeta;

    /// Image-tower batch sizes this backend serves, ascending.  The embed
    /// engine chunks ingestion batches to these sizes.
    fn image_batches(&self) -> Vec<usize>;

    /// Whether the fused (image + aux-prompt, Eq. 2–3) entry exists for
    /// the given batch size.
    fn has_fused(&self, batch: usize) -> bool;

    /// Eagerly prepare the named entry points (AOT backends compile here;
    /// the native backend is ready at construction).  Serving systems call
    /// this before the stream starts so the hot path never pays setup.
    fn warmup(&self, entries: &[&str]) -> Result<()>;

    /// Image tower: `batch` frames -> `batch` unit-norm embeddings.
    fn embed_image(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>>;

    /// Text tower (query path): one token window -> one unit-norm embedding.
    fn embed_text(&self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Fused ingestion entry: frames + per-frame aux-prompt token windows
    /// (Eq. 2–3 fusion with weight `aux_weight`).
    fn embed_fused(
        &self,
        frames: &[f32],
        aux_tokens: &[i32],
        batch: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// Eq. 1 scene features: `batch` frames -> `batch` × `scene_feat_dim`
    /// pooled (H, S, L, Sobel-energy) vectors.
    fn scene_features(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>>;

    /// Eq. 4–5 fused retrieval scoring over a padded index matrix.
    /// `index` must hold exactly `sim_rows × d_embed` values (pad with
    /// zero rows); returns `(scores, probs)` truncated to `n_valid`.
    fn similarity(
        &self,
        query: &[f32],
        index: &[f32],
        n_valid: usize,
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Concept pixel codes `[n_concepts][patch·patch·3]` — the watermark
    /// blocks the synthetic generator plants (shared with the towers).
    fn concept_codes(&self) -> Result<Vec<Vec<f32>>>;

    /// Concept embedding directions `[n_concepts][d_embed]`
    /// (`U[c] = w_r^T (codes[c] − 0.5)`).
    fn concept_dirs(&self) -> Result<Vec<Vec<f32>>>;
}

/// Build a fresh default backend for this process.
///
/// Selection order:
///   1. `VENUS_BACKEND=native` forces the native backend;
///   2. with the `pjrt` feature compiled in, an artifact directory (see
///      `Runtime::load_default`) selects the PJRT backend —
///      `VENUS_BACKEND=pjrt` makes a missing artifact set a hard error
///      instead of a fallback;
///   3. otherwise the self-contained native backend.
///
/// Construction is expensive (the native backend generates the full
/// weight set; the PJRT backend creates a client).  Request-path code
/// should go through [`shared_default`] so the process builds exactly one
/// backend and every engine shares it.
pub fn load_default() -> Result<Arc<dyn EmbedBackend>> {
    let choice = std::env::var("VENUS_BACKEND").unwrap_or_default();
    #[cfg(feature = "pjrt")]
    {
        if choice != "native" {
            match crate::runtime::Runtime::load_default() {
                Ok(rt) => return Ok(Arc::new(rt)),
                Err(e) if choice == "pjrt" => return Err(e),
                Err(_) => {} // no artifacts: fall back to native
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        if choice == "pjrt" {
            anyhow::bail!(
                "VENUS_BACKEND=pjrt, but this build has no PJRT backend \
                 (rebuild with `--features pjrt`)"
            );
        }
    }
    Ok(Arc::new(NativeBackend::new(NativeConfig::default())))
}

/// Process-wide shared default backend: constructed once (behind a lock,
/// so racing threads never build it twice), then handed out as `Arc`
/// clones.  Construction errors are not cached — a later call retries.
pub fn shared_default() -> Result<Arc<dyn EmbedBackend>> {
    use crate::util::sync::{ranks, OrderedMutex};
    static SHARED: OrderedMutex<Option<Arc<dyn EmbedBackend>>> =
        OrderedMutex::new(ranks::BACKEND_SHARED, None);
    let mut slot = SHARED.lock();
    if let Some(be) = slot.as_ref() {
        return Ok(Arc::clone(be));
    }
    let be = load_default()?;
    *slot = Some(Arc::clone(&be));
    Ok(be)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_loads_and_reports_model() {
        let b = load_default().unwrap();
        let m = b.model();
        assert!(m.d_embed > 0 && m.img_size > 0);
        assert!(!b.image_batches().is_empty());
    }

    #[test]
    fn native_backend_constructs_directly() {
        // (Deliberately does NOT exercise the VENUS_BACKEND env override:
        // std::env::set_var races getenv in parallel tests and is UB on
        // glibc.  The override is a thin string match in load_default.)
        let b: Box<dyn EmbedBackend> = Box::new(NativeBackend::new(NativeConfig::default()));
        assert_eq!(b.name(), "native");
        assert_eq!(b.model().d_embed, 64);
    }

    #[test]
    fn shared_default_hands_out_one_instance() {
        let a = shared_default().unwrap();
        let b = shared_default().unwrap();
        assert!(
            Arc::ptr_eq(&a, &b),
            "shared_default must construct the backend once per process"
        );
    }
}
