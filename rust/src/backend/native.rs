//! Native pure-Rust compute backend: the reference dual-encoder forward
//! pass of `python/compile/model.py` / `python/compile/kernels/ref.py`,
//! reimplemented on plain slices so the request path runs self-contained —
//! no artifact files, no FFI, no Python.
//!
//! ## Weights
//!
//! Parameters are generated deterministically from `NativeConfig::seed`
//! with the same *scheme* as `python/compile/params.py`: one independent
//! random stream per tensor (here: a [`Pcg64`] stream keyed by the tensor's
//! label), the same shapes, and the same initialization scales — including
//! the semantic-projection scaling `std(w_r) = sqrt(12 / (patch_dim ·
//! d_embed))` that puts concept readouts at unit norm.  Because the Python
//! side uses jax.random (threefry) and this side uses PCG64, the two
//! backends' weights are *statistically* identical but not bit-identical;
//! cross-backend parity is therefore checked at the level that matters for
//! the system (kernel-exact scene features / similarity, and cross-modal
//! ranking behavior) in `rust/tests/native_vs_artifact.rs`.
//!
//! ## Model recap (see DESIGN.md §1)
//!
//! Both towers combine a *semantic* path (watermark concept readout through
//! the shared projection `w_r`, which is what gives a randomly-initialized
//! encoder trained-model cross-modal alignment by construction) with a
//! *content* path (a small pre-LN transformer), weighted `sem_weight` :
//! `content_weight`, then L2-normalize.

use anyhow::{bail, ensure, Result};

use super::{EmbedBackend, ModelMeta};
use crate::util::rng::Pcg64;
use crate::util::{dot, l2_normalize, softmax_temp};
use crate::video::frame::Frame;

/// Hyperparameters of the native MEM; defaults mirror
/// `python/compile/config.py::MemConfig` exactly.
#[derive(Clone, Debug)]
pub struct NativeConfig {
    // --- image tower ---
    pub img_size: usize,
    pub patch: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks_img: usize,
    pub d_mlp: usize,
    // --- text tower ---
    pub vocab: usize,
    pub seq_len: usize,
    pub n_blocks_txt: usize,
    // --- shared embedding space ---
    pub d_embed: usize,
    // --- semantic projection ---
    pub n_concepts: usize,
    pub concept_token_base: usize,
    pub sem_weight: f32,
    pub content_weight: f32,
    pub aux_weight: f32,
    // --- misc ---
    pub sim_rows: usize,
    pub seed: u64,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            img_size: 64,
            patch: 8,
            d_model: 128,
            n_heads: 4,
            n_blocks_img: 2,
            d_mlp: 512,
            vocab: 512,
            seq_len: 16,
            n_blocks_txt: 1,
            d_embed: 64,
            n_concepts: 32,
            concept_token_base: 2,
            sem_weight: 4.0,
            content_weight: 1.0,
            aux_weight: 0.5,
            sim_rows: 1024,
            seed: 20250710,
        }
    }
}

impl NativeConfig {
    pub fn n_patches(&self) -> usize {
        (self.img_size / self.patch) * (self.img_size / self.patch)
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * 3
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// One pre-LN transformer block's parameters (row-major `[in, out]`).
struct Block {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

/// The native backend: all weights resident, ready at construction.
pub struct NativeBackend {
    cfg: NativeConfig,
    meta: ModelMeta,
    // image tower
    patch_proj: Vec<f32>,    // [patch_dim, d_model]
    patch_bias: Vec<f32>,    // [d_model]
    img_pos: Vec<f32>,       // [n_patches, d_model]
    img_content_proj: Vec<f32>, // [d_model, d_embed]
    img_blocks: Vec<Block>,
    // text tower
    txt_embed: Vec<f32>,     // [vocab, d_model]
    txt_pos: Vec<f32>,       // [seq_len, d_model]
    txt_content_proj: Vec<f32>, // [d_model, d_embed]
    txt_blocks: Vec<Block>,
    // semantic projection
    w_r: Vec<f32>,           // [patch_dim, d_embed]
    codes: Vec<f32>,         // [n_concepts, patch_dim], values in [0, 1)
    dirs: Vec<f32>,          // [n_concepts, d_embed]: (codes − 0.5) @ w_r
}

/// FNV-1a 64-bit: stable label → RNG-stream mapping (independent of tensor
/// generation order, so adding tensors never perturbs existing weights).
fn label_stream(label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

fn normal_tensor(seed: u64, label: &str, n: usize, std: f32) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, label_stream(label));
    (0..n).map(|_| rng.normal() * std).collect()
}

fn uniform_tensor(seed: u64, label: &str, n: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, label_stream(label));
    (0..n).map(|_| rng.f32()).collect()
}

fn block_params(seed: u64, prefix: &str, d_model: usize, d_mlp: usize) -> Block {
    let sd = (d_model as f32).powf(-0.5);
    Block {
        ln1_g: vec![1.0; d_model],
        ln1_b: vec![0.0; d_model],
        wq: normal_tensor(seed, &format!("{prefix}.wq"), d_model * d_model, sd),
        wk: normal_tensor(seed, &format!("{prefix}.wk"), d_model * d_model, sd),
        wv: normal_tensor(seed, &format!("{prefix}.wv"), d_model * d_model, sd),
        wo: normal_tensor(seed, &format!("{prefix}.wo"), d_model * d_model, sd),
        ln2_g: vec![1.0; d_model],
        ln2_b: vec![0.0; d_model],
        w1: normal_tensor(seed, &format!("{prefix}.w1"), d_model * d_mlp, sd),
        b1: vec![0.0; d_mlp],
        w2: normal_tensor(
            seed,
            &format!("{prefix}.w2"),
            d_mlp * d_model,
            (d_mlp as f32).powf(-0.5),
        ),
        b2: vec![0.0; d_model],
    }
}

// ---------------------------------------------------------------------
// Dense math helpers (naive but cache-ordered; the MEM is small enough —
// d_model 128 — that this sustains ingestion-rate embedding on a host CPU)
// ---------------------------------------------------------------------

/// `out[t, j] += x[t, k] · w[k, j]` for row-major x `[t, din]`, w `[din, dout]`.
fn matmul_acc(x: &[f32], w: &[f32], t: usize, din: usize, dout: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), t * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(out.len(), t * dout);
    for r in 0..t {
        let xr = &x[r * din..(r + 1) * din];
        let or = &mut out[r * dout..(r + 1) * dout];
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[k * dout..(k + 1) * dout];
            for (o, &wv) in or.iter_mut().zip(wr) {
                *o += xv * wv;
            }
        }
    }
}

/// LayerNorm one row (population variance, eps 1e-6), writing into `out`.
fn layer_norm_row(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let d = x.len();
    let mu = x.iter().sum::<f32>() / d as f32;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
    let inv = 1.0 / (var + 1e-6).sqrt();
    for i in 0..d {
        out[i] = (x[i] - mu) * inv * g[i] + b[i];
    }
}

/// GELU, tanh approximation (jax.nn.gelu(approximate=True)).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

impl NativeBackend {
    pub fn new(cfg: NativeConfig) -> Self {
        assert!(cfg.img_size % cfg.patch == 0, "patch must divide img_size");
        assert!(cfg.d_model % cfg.n_heads == 0, "heads must divide d_model");
        let (pd, dm, de) = (cfg.patch_dim(), cfg.d_model, cfg.d_embed);
        let seed = cfg.seed;

        let img_blocks = (0..cfg.n_blocks_img)
            .map(|i| block_params(seed, &format!("img.block{i}"), dm, cfg.d_mlp))
            .collect();
        let txt_blocks = (0..cfg.n_blocks_txt)
            .map(|i| block_params(seed, &format!("txt.block{i}"), dm, cfg.d_mlp))
            .collect();

        // w_r scaled so ||w_r^T (code − 0.5)|| ≈ 1 for uniform codes
        // (per-coord var 1/12 ⇒ std = sqrt(12 / (patch_dim · d_embed)));
        // same derivation as params.py.
        let wr_std = (12.0 / (pd * de) as f32).sqrt();
        let w_r = normal_tensor(seed, "sem.w_r", pd * de, wr_std);
        let codes = uniform_tensor(seed, "sem.codes", cfg.n_concepts * pd);
        let mut dirs = vec![0.0f32; cfg.n_concepts * de];
        for c in 0..cfg.n_concepts {
            let code = &codes[c * pd..(c + 1) * pd];
            let out = &mut dirs[c * de..(c + 1) * de];
            for (k, &cv) in code.iter().enumerate() {
                let x = cv - 0.5;
                let wr = &w_r[k * de..(k + 1) * de];
                for (o, &wv) in out.iter_mut().zip(wr) {
                    *o += x * wv;
                }
            }
        }

        let meta = ModelMeta {
            img_size: cfg.img_size,
            patch: cfg.patch,
            d_embed: de,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
            n_concepts: cfg.n_concepts,
            concept_token_base: cfg.concept_token_base,
            sim_rows: cfg.sim_rows,
            scene_feat_dim: crate::features::FEAT_DIM,
            sem_weight: cfg.sem_weight,
            content_weight: cfg.content_weight,
            aux_weight: cfg.aux_weight,
        };

        Self {
            patch_proj: normal_tensor(
                seed,
                "img.patch_proj",
                pd * dm,
                (pd as f32).powf(-0.5),
            ),
            patch_bias: vec![0.0; dm],
            img_pos: normal_tensor(seed, "img.pos", cfg.n_patches() * dm, 0.02),
            img_content_proj: normal_tensor(
                seed,
                "img.content_proj",
                dm * de,
                (dm as f32).powf(-0.5),
            ),
            img_blocks,
            txt_embed: normal_tensor(seed, "txt.embed", cfg.vocab * dm, 0.5),
            txt_pos: normal_tensor(seed, "txt.pos", cfg.seq_len * dm, 0.02),
            txt_content_proj: normal_tensor(
                seed,
                "txt.content_proj",
                dm * de,
                (dm as f32).powf(-0.5),
            ),
            txt_blocks,
            w_r,
            codes,
            dirs,
            meta,
            cfg,
        }
    }

    pub fn config(&self) -> &NativeConfig {
        &self.cfg
    }

    /// One pre-LN transformer block over `x: [t, d_model]`, in place.
    fn transformer_block(&self, x: &mut [f32], t: usize, blk: &Block) {
        let d = self.cfg.d_model;
        let heads = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        let scale = 1.0 / (dh as f32).sqrt();

        // --- attention sublayer ---
        let mut xn = vec![0.0f32; t * d];
        for r in 0..t {
            layer_norm_row(
                &x[r * d..(r + 1) * d],
                &blk.ln1_g,
                &blk.ln1_b,
                &mut xn[r * d..(r + 1) * d],
            );
        }
        let mut q = vec![0.0f32; t * d];
        let mut k = vec![0.0f32; t * d];
        let mut v = vec![0.0f32; t * d];
        matmul_acc(&xn, &blk.wq, t, d, d, &mut q);
        matmul_acc(&xn, &blk.wk, t, d, d, &mut k);
        matmul_acc(&xn, &blk.wv, t, d, d, &mut v);

        let mut attn = vec![0.0f32; t * d];
        let mut logits = vec![0.0f32; t];
        for h in 0..heads {
            let off = h * dh;
            for i in 0..t {
                let qi = &q[i * d + off..i * d + off + dh];
                for (j, l) in logits.iter_mut().enumerate() {
                    *l = dot(qi, &k[j * d + off..j * d + off + dh]) * scale;
                }
                let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for l in logits.iter_mut() {
                    *l = (*l - m).exp();
                    sum += *l;
                }
                let inv = 1.0 / sum;
                let ai = &mut attn[i * d + off..i * d + off + dh];
                for j in 0..t {
                    let p = logits[j] * inv;
                    let vj = &v[j * d + off..j * d + off + dh];
                    for (a, &vv) in ai.iter_mut().zip(vj) {
                        *a += p * vv;
                    }
                }
            }
        }
        // residual: h = x + attn @ wo
        let mut proj = vec![0.0f32; t * d];
        matmul_acc(&attn, &blk.wo, t, d, d, &mut proj);
        for (xv, pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }

        // --- MLP sublayer ---
        let dm = self.cfg.d_mlp;
        let mut z = vec![0.0f32; t * d];
        for r in 0..t {
            layer_norm_row(
                &x[r * d..(r + 1) * d],
                &blk.ln2_g,
                &blk.ln2_b,
                &mut z[r * d..(r + 1) * d],
            );
        }
        let mut m1 = vec![0.0f32; t * dm];
        matmul_acc(&z, &blk.w1, t, d, dm, &mut m1);
        for r in 0..t {
            for (mv, &bv) in m1[r * dm..(r + 1) * dm].iter_mut().zip(&blk.b1) {
                *mv = gelu(*mv + bv);
            }
        }
        let mut m2 = vec![0.0f32; t * d];
        matmul_acc(&m1, &blk.w2, t, dm, d, &mut m2);
        for r in 0..t {
            for (i, (xv, &mv)) in x[r * d..(r + 1) * d]
                .iter_mut()
                .zip(&m2[r * d..(r + 1) * d])
                .enumerate()
            {
                *xv += mv + blk.b2[i];
            }
        }
    }

    /// `(patch − 0.5) @ w_r`, accumulated into `out` with weight `scale`.
    fn semantic_readout(&self, patch: &[f32], scale: f32, out: &mut [f32]) {
        let de = self.cfg.d_embed;
        for (k, &pv) in patch.iter().enumerate() {
            let x = (pv - 0.5) * scale;
            let wr = &self.w_r[k * de..(k + 1) * de];
            for (o, &wv) in out.iter_mut().zip(wr) {
                *o += x * wv;
            }
        }
    }

    /// Concept-count readout of a token window (model.py::_text_semantic):
    /// sum of concept directions for each concept token present, counted
    /// with multiplicity and normalized by the total count.
    fn text_semantic(&self, tokens: &[i32], out: &mut [f32]) {
        let base = self.cfg.concept_token_base as i32;
        let top = base + self.cfg.n_concepts as i32;
        let mut counts = vec![0.0f32; self.cfg.n_concepts];
        let mut total = 0.0f32;
        for &t in tokens {
            if (base..top).contains(&t) {
                counts[(t - base) as usize] += 1.0;
                total += 1.0;
            }
        }
        let inv = 1.0 / total.max(1.0);
        let de = self.cfg.d_embed;
        for (c, &n) in counts.iter().enumerate() {
            if n == 0.0 {
                continue;
            }
            let w = n * inv;
            let u = &self.dirs[c * de..(c + 1) * de];
            for (o, &uv) in out.iter_mut().zip(u) {
                *o += w * uv;
            }
        }
    }

    /// Image tower over one frame (optionally with an aux-prompt window).
    fn embed_one_image(&self, frame: &[f32], aux_tokens: Option<&[i32]>) -> Vec<f32> {
        let cfg = &self.cfg;
        let (s, p) = (cfg.img_size, cfg.patch);
        let g = s / p;
        let (t, pd, dm, de) = (cfg.n_patches(), cfg.patch_dim(), cfg.d_model, cfg.d_embed);

        // patchify: [t, pd], row-major patches, row-major pixels per patch
        let mut patches = vec![0.0f32; t * pd];
        for gy in 0..g {
            for gx in 0..g {
                let pi = gy * g + gx;
                for dy in 0..p {
                    let src = ((gy * p + dy) * s + gx * p) * 3;
                    let dst = pi * pd + dy * p * 3;
                    patches[dst..dst + p * 3].copy_from_slice(&frame[src..src + p * 3]);
                }
            }
        }

        // semantic path: watermark readout of patch 0 (top-left) and patch
        // g−1 (top-right), as in model.py::watermark_patches
        let mut sem = vec![0.0f32; de];
        self.semantic_readout(&patches[0..pd], 1.0, &mut sem);
        let w1 = g - 1;
        self.semantic_readout(&patches[w1 * pd..(w1 + 1) * pd], 1.0, &mut sem);
        if let Some(toks) = aux_tokens {
            let mut aux = vec![0.0f32; de];
            self.text_semantic(toks, &mut aux);
            for (s_, a) in sem.iter_mut().zip(&aux) {
                *s_ += cfg.aux_weight * a;
            }
        }

        // content path: transformer over projected patch embeddings
        let mut x = vec![0.0f32; t * dm];
        matmul_acc(&patches, &self.patch_proj, t, pd, dm, &mut x);
        for r in 0..t {
            for (i, xv) in x[r * dm..(r + 1) * dm].iter_mut().enumerate() {
                *xv += self.patch_bias[i] + self.img_pos[r * dm + i];
            }
        }
        for blk in &self.img_blocks {
            self.transformer_block(&mut x, t, blk);
        }
        let mut pooled = vec![0.0f32; dm];
        for r in 0..t {
            for (pv, &xv) in pooled.iter_mut().zip(&x[r * dm..(r + 1) * dm]) {
                *pv += xv;
            }
        }
        let inv_t = 1.0 / t as f32;
        for pv in pooled.iter_mut() {
            *pv *= inv_t;
        }
        let mut content = vec![0.0f32; de];
        matmul_acc(&pooled, &self.img_content_proj, 1, dm, de, &mut content);
        l2_normalize(&mut content);

        let mut out = vec![0.0f32; de];
        for i in 0..de {
            out[i] = cfg.sem_weight * sem[i] + cfg.content_weight * content[i];
        }
        l2_normalize(&mut out);
        out
    }

    fn check_frames(&self, frames: &[f32], batch: usize) -> Result<usize> {
        ensure!(batch > 0, "embed: batch must be positive");
        let px = self.cfg.img_size * self.cfg.img_size * 3;
        ensure!(
            frames.len() == batch * px,
            "embed: {} pixel values for batch {batch} (expected {})",
            frames.len(),
            batch * px
        );
        Ok(px)
    }
}

impl EmbedBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn model(&self) -> &ModelMeta {
        &self.meta
    }

    fn image_batches(&self) -> Vec<usize> {
        // Mirror the AOT export set so the embed engine's chunking policy is
        // backend-independent (the native tower has no real batch limit).
        vec![1, 8, 32]
    }

    fn has_fused(&self, _batch: usize) -> bool {
        true
    }

    fn warmup(&self, _entries: &[&str]) -> Result<()> {
        Ok(()) // weights are resident from construction
    }

    fn embed_image(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        let px = self.check_frames(frames, batch)?;
        Ok((0..batch)
            .map(|b| self.embed_one_image(&frames[b * px..(b + 1) * px], None))
            .collect())
    }

    fn embed_text(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        ensure!(
            tokens.len() == cfg.seq_len,
            "embed_text: {} tokens, expected {}",
            tokens.len(),
            cfg.seq_len
        );
        let (t, dm, de) = (cfg.seq_len, cfg.d_model, cfg.d_embed);
        let mut x = vec![0.0f32; t * dm];
        for (r, &tok) in tokens.iter().enumerate() {
            ensure!(
                (0..cfg.vocab as i32).contains(&tok),
                "embed_text: token id {tok} outside vocab {}",
                cfg.vocab
            );
            let emb = &self.txt_embed[tok as usize * dm..(tok as usize + 1) * dm];
            let pos = &self.txt_pos[r * dm..(r + 1) * dm];
            for (i, xv) in x[r * dm..(r + 1) * dm].iter_mut().enumerate() {
                *xv = emb[i] + pos[i];
            }
        }
        for blk in &self.txt_blocks {
            self.transformer_block(&mut x, t, blk);
        }
        let mut pooled = vec![0.0f32; dm];
        for r in 0..t {
            for (pv, &xv) in pooled.iter_mut().zip(&x[r * dm..(r + 1) * dm]) {
                *pv += xv;
            }
        }
        let inv_t = 1.0 / t as f32;
        for pv in pooled.iter_mut() {
            *pv *= inv_t;
        }
        let mut content = vec![0.0f32; de];
        matmul_acc(&pooled, &self.txt_content_proj, 1, dm, de, &mut content);
        l2_normalize(&mut content);

        let mut sem = vec![0.0f32; de];
        self.text_semantic(tokens, &mut sem);

        let mut out = vec![0.0f32; de];
        for i in 0..de {
            out[i] = cfg.sem_weight * sem[i] + cfg.content_weight * content[i];
        }
        l2_normalize(&mut out);
        Ok(out)
    }

    fn embed_fused(
        &self,
        frames: &[f32],
        aux_tokens: &[i32],
        batch: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let px = self.check_frames(frames, batch)?;
        let seq = self.cfg.seq_len;
        ensure!(
            aux_tokens.len() == batch * seq,
            "embed_fused: {} aux tokens for batch {batch} (expected {})",
            aux_tokens.len(),
            batch * seq
        );
        Ok((0..batch)
            .map(|b| {
                self.embed_one_image(
                    &frames[b * px..(b + 1) * px],
                    Some(&aux_tokens[b * seq..(b + 1) * seq]),
                )
            })
            .collect())
    }

    fn scene_features(&self, frames: &[f32], batch: usize) -> Result<Vec<Vec<f32>>> {
        let px = self.check_frames(frames, batch)?;
        Ok((0..batch)
            .map(|b| {
                let f = Frame::from_data(
                    self.cfg.img_size,
                    frames[b * px..(b + 1) * px].to_vec(),
                );
                crate::features::frame_features(&f)
            })
            .collect())
    }

    fn similarity(
        &self,
        query: &[f32],
        index: &[f32],
        n_valid: usize,
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.meta;
        if query.len() != m.d_embed {
            bail!("similarity: query dim {}", query.len());
        }
        if index.len() != m.sim_rows * m.d_embed {
            bail!(
                "similarity: index has {} values, expected {}",
                index.len(),
                m.sim_rows * m.d_embed
            );
        }
        if n_valid > m.sim_rows {
            bail!("similarity: n_valid {} > padded rows {}", n_valid, m.sim_rows);
        }
        let mut scores = vec![0.0f32; n_valid];
        for (r, s) in scores.iter_mut().enumerate() {
            *s = dot(query, &index[r * m.d_embed..(r + 1) * m.d_embed]);
        }
        let mut probs = vec![0.0f32; n_valid];
        softmax_temp(&scores, tau, &mut probs);
        Ok((scores, probs))
    }

    fn concept_codes(&self) -> Result<Vec<Vec<f32>>> {
        let pd = self.cfg.patch_dim();
        Ok(self.codes.chunks_exact(pd).map(|c| c.to_vec()).collect())
    }

    fn concept_dirs(&self) -> Result<Vec<Vec<f32>>> {
        let de = self.cfg.d_embed;
        Ok(self.dirs.chunks_exact(de).map(|c| c.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::Tokenizer;
    use crate::util::rng::Pcg64;

    fn backend() -> NativeBackend {
        NativeBackend::new(NativeConfig::default())
    }

    fn noisy_frame(seed: u64, size: usize) -> Frame {
        let mut rng = Pcg64::seeded(seed);
        let mut f = Frame::new(size);
        for v in f.data_mut() {
            *v = rng.f32();
        }
        f
    }

    #[test]
    fn deterministic_across_instances() {
        let a = backend();
        let b = backend();
        let f = noisy_frame(1, 64);
        let ea = a.embed_image(f.data(), 1).unwrap();
        let eb = b.embed_image(f.data(), 1).unwrap();
        assert_eq!(ea, eb, "same seed must give bit-identical embeddings");
    }

    #[test]
    fn embeddings_unit_norm() {
        let be = backend();
        let f = noisy_frame(2, 64);
        let e = be.embed_image(f.data(), 1).unwrap();
        let norm = e[0].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4, "norm {norm}");
        let tok = Tokenizer::from_model(be.model());
        let q = be.embed_text(&tok.tokenize("what happened near the stove")).unwrap();
        let norm = q.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4, "norm {norm}");
    }

    #[test]
    fn batch_rows_match_single_frame_calls() {
        let be = backend();
        let frames: Vec<Frame> = (0..3).map(|i| noisy_frame(10 + i, 64)).collect();
        let mut flat = Vec::new();
        for f in &frames {
            flat.extend_from_slice(f.data());
        }
        let batched = be.embed_image(&flat, 3).unwrap();
        for (f, want) in frames.iter().zip(&batched) {
            let one = be.embed_image(f.data(), 1).unwrap();
            assert_eq!(&one[0], want);
        }
    }

    #[test]
    fn planted_concept_aligns_image_with_text() {
        let be = backend();
        let codes = be.concept_codes().unwrap();
        let patch = be.model().patch;
        let tok = Tokenizer::from_model(be.model());

        let mut with_c3 = noisy_frame(21, 64);
        with_c3.blend_block(0, 0, patch, &codes[3], 0.85);
        let mut with_c9 = noisy_frame(22, 64);
        with_c9.blend_block(0, 0, patch, &codes[9], 0.85);

        let e3 = be.embed_image(with_c3.data(), 1).unwrap().remove(0);
        let e9 = be.embed_image(with_c9.data(), 1).unwrap().remove(0);
        let q = be
            .embed_text(&tok.tokenize("what happened with concept03"))
            .unwrap();
        let (s3, s9) = (dot(&q, &e3), dot(&q, &e9));
        assert!(
            s3 > s9 + 0.2,
            "query must align with the planted concept: match {s3} vs other {s9}"
        );
    }

    #[test]
    fn aux_prompt_sharpens_planted_concept() {
        let be = backend();
        let codes = be.concept_codes().unwrap();
        let patch = be.model().patch;
        let seq = be.model().seq_len;

        let mut f = noisy_frame(31, 64);
        f.blend_block(0, 0, patch, &codes[5], 0.85);
        let mut aux = vec![0i32; seq];
        aux[0] = (be.model().concept_token_base + 5) as i32;

        let plain = be.embed_image(f.data(), 1).unwrap().remove(0);
        let fused = be.embed_fused(f.data(), &aux, 1).unwrap().remove(0);
        let dirs = be.concept_dirs().unwrap();
        let mut u = dirs[5].clone();
        l2_normalize(&mut u);
        assert!(
            dot(&fused, &u) > dot(&plain, &u),
            "aux prompt should raise concept-5 alignment"
        );
    }

    #[test]
    fn similarity_matches_native_softmax() {
        let be = backend();
        let m = be.model();
        let mut rng = Pcg64::seeded(41);
        let n_valid = 300;
        let mut index = vec![0.0f32; m.sim_rows * m.d_embed];
        for r in 0..n_valid {
            let row = &mut index[r * m.d_embed..(r + 1) * m.d_embed];
            for x in row.iter_mut() {
                *x = rng.normal();
            }
            l2_normalize(row);
        }
        let q = index[7 * m.d_embed..8 * m.d_embed].to_vec();
        let (scores, probs) = be.similarity(&q, &index, n_valid, 0.1).unwrap();
        assert_eq!(scores.len(), n_valid);
        let mut want = vec![0.0f32; n_valid];
        softmax_temp(&scores, 0.1, &mut want);
        for (a, b) in probs.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
        let argmax = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 7, "exact-match row must dominate");
    }

    #[test]
    fn scene_features_match_native_frontend() {
        let be = backend();
        let f = noisy_frame(51, 64);
        let got = be.scene_features(f.data(), 1).unwrap();
        let want = crate::features::frame_features(&f);
        assert_eq!(got[0], want);
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let be = backend();
        assert!(be.embed_image(&[0.0; 10], 1).is_err());
        assert!(be.embed_text(&[0i32; 3]).is_err());
        assert!(be.embed_text(&vec![9999i32; 16]).is_err());
        let m = be.model();
        let idx = vec![0.0f32; m.sim_rows * m.d_embed];
        assert!(be.similarity(&vec![0.0; 3], &idx, 1, 0.1).is_err());
        assert!(be
            .similarity(&vec![0.0; m.d_embed], &idx, m.sim_rows + 1, 0.1)
            .is_err());
    }

    #[test]
    fn concept_side_data_consistent() {
        let be = backend();
        let m = be.model();
        let codes = be.concept_codes().unwrap();
        let dirs = be.concept_dirs().unwrap();
        assert_eq!(codes.len(), m.n_concepts);
        assert_eq!(dirs.len(), m.n_concepts);
        assert_eq!(codes[0].len(), m.patch * m.patch * 3);
        assert_eq!(dirs[0].len(), m.d_embed);
        for row in &codes {
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
        // the w_r scaling puts concept directions near unit norm
        let mean_norm: f32 = dirs
            .iter()
            .map(|d| d.iter().map(|x| x * x).sum::<f32>().sqrt())
            .sum::<f32>()
            / dirs.len() as f32;
        assert!(
            (0.5..2.0).contains(&mean_norm),
            "mean ||u_c|| = {mean_norm}, expected ≈ 1"
        );
    }
}
