//! AKS — Adaptive Keyframe Sampling [Tang et al., CVPR'25].
//!
//! Query-relevant selection balancing *relevance* (frame-query similarity)
//! and *coverage* (spread over the timeline).  Reproduced as the paper
//! describes it: recursive binary timeline splitting — if a segment's
//! top-scoring frames are judged sufficient (high relevance mass), take
//! the best frames; otherwise split the segment and recurse, which
//! guarantees every temporal region is examined (their "comprehensive
//! coverage" objective).

/// Select `budget` frames from per-frame scores.
pub fn select(scores: &[f32], budget: usize) -> Vec<u64> {
    let n = scores.len();
    if n == 0 || budget == 0 {
        return Vec::new();
    }
    let budget = budget.min(n);
    let mut out = Vec::with_capacity(budget);
    split(scores, 0, n, budget, &mut out);
    out.sort_unstable();
    out.dedup();
    // numerical safety: if dedup lost slots, top up with best remaining
    if out.len() < budget {
        let chosen: std::collections::HashSet<u64> = out.iter().cloned().collect();
        let mut rest: Vec<u64> = (0..n as u64).filter(|f| !chosen.contains(f)).collect();
        rest.sort_by(|&a, &b| {
            scores[b as usize].partial_cmp(&scores[a as usize]).unwrap()
        });
        out.extend(rest.into_iter().take(budget - out.len()));
        out.sort_unstable();
    }
    out
}

/// Recursive budget allocation over [lo, hi).
fn split(scores: &[f32], lo: usize, hi: usize, budget: usize, out: &mut Vec<u64>) {
    if budget == 0 || lo >= hi {
        return;
    }
    let len = hi - lo;
    if budget == 1 || len <= 2 {
        // take the argmax of the segment
        let best = (lo..hi)
            .max_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        out.push(best as u64);
        return;
    }
    // relevance dominance test: if the segment's top-`budget` scores are
    // tightly clustered in time, trust relevance; otherwise split evenly
    let mid = lo + len / 2;
    let left_mass: f32 = (lo..mid).map(|i| positive(scores[i])).sum();
    let right_mass: f32 = (mid..hi).map(|i| positive(scores[i])).sum();
    let total = left_mass + right_mass;
    if total <= f32::EPSILON {
        // no relevance signal anywhere: pure coverage — even split
        let lb = budget / 2;
        split(scores, lo, mid, lb, out);
        split(scores, mid, hi, budget - lb, out);
        return;
    }
    // allocate budget proportionally to relevance mass, but guarantee ≥1
    // per half when any budget ≥ 2 remains (the coverage guarantee)
    let mut lb = ((budget as f32) * left_mass / total).round() as usize;
    lb = lb.clamp(usize::from(budget >= 2), budget - usize::from(budget >= 2));
    split(scores, lo, mid, lb, out);
    split(scores, mid, hi, budget - lb, out);
}

#[inline]
fn positive(s: f32) -> f32 {
    (s - 0.2).max(0.0) // scores below the noise floor carry no relevance
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_and_bounds() {
        let scores = vec![0.1f32; 100];
        let sel = select(&scores, 16);
        assert_eq!(sel.len(), 16);
        assert!(sel.iter().all(|&f| f < 100));
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn relevance_attracts_budget() {
        let mut scores = vec![0.1f32; 200];
        for i in 150..170 {
            scores[i] = 0.9;
        }
        let sel = select(&scores, 8);
        let hot = sel.iter().filter(|&&f| (150..170).contains(&(f as usize))).count();
        assert!(hot >= 4, "{hot}/8 in the relevant region ({sel:?})");
    }

    #[test]
    fn coverage_guaranteed_with_flat_scores() {
        let scores = vec![0.5f32; 128];
        let sel = select(&scores, 8);
        // every quarter of the timeline is touched
        for q in 0..4 {
            let lo = q * 32;
            let hi = lo + 32;
            assert!(
                sel.iter().any(|&f| (lo..hi).contains(&(f as usize))),
                "quarter {q} uncovered: {sel:?}"
            );
        }
    }

    #[test]
    fn two_hot_regions_both_covered() {
        let mut scores = vec![0.05f32; 400];
        for i in 40..60 {
            scores[i] = 0.85;
        }
        for i in 330..350 {
            scores[i] = 0.85;
        }
        let sel = select(&scores, 8);
        assert!(sel.iter().any(|&f| (40..60).contains(&(f as usize))));
        assert!(sel.iter().any(|&f| (330..350).contains(&(f as usize))));
    }

    #[test]
    fn degenerate_inputs() {
        assert!(select(&[], 8).is_empty());
        assert!(select(&[0.5], 0).is_empty());
        assert_eq!(select(&[0.5], 4), vec![0]);
    }
}
