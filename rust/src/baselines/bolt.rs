//! BOLT [Liu et al., CVPR'25] — training-free frame selection via
//! *inverse transform sampling* over the frame-query similarity
//! distribution.
//!
//! As published: per-frame similarities are normalized into a probability
//! distribution (after subtracting the noise floor and applying a
//! sharpening exponent); N frames are drawn by inverse-transform sampling
//! of the empirical CDF at evenly-spaced quantiles — which concentrates
//! picks on high-similarity frames while retaining spread (their fix for
//! greedy Top-K's redundancy).

use crate::util::rng::Pcg64;

/// Sharpening exponent on the shifted similarity (BOLT's temperature).
const GAMMA: f32 = 3.0;

/// Select `budget` frames by inverse-transform sampling of the score CDF.
pub fn select(scores: &[f32], budget: usize, seed: u64) -> Vec<u64> {
    let n = scores.len();
    if n == 0 || budget == 0 {
        return Vec::new();
    }
    let budget = budget.min(n);
    let floor = percentile(scores, 0.5); // median as the noise floor
    let weights: Vec<f32> = scores
        .iter()
        .map(|&s| (s - floor).max(0.0).powf(GAMMA))
        .collect();
    let total: f32 = weights.iter().sum();
    if total <= f32::EPSILON {
        // no signal: fall back to uniform coverage
        return super::uniform::select(n as u64, budget);
    }
    // CDF
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0f32;
    for &w in &weights {
        acc += w;
        cdf.push(acc);
    }
    // evenly-spaced quantiles with a small deterministic jitter: the
    // stratified inverse-transform draw from the paper
    let mut rng = Pcg64::new(seed, 0xb017);
    let mut out: Vec<u64> = (0..budget)
        .map(|i| {
            let u = ((i as f32 + 0.2 + 0.6 * rng.f32()) / budget as f32) * total;
            cdf.partition_point(|&c| c < u).min(n - 1) as u64
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    // top up duplicates-removed slots with next-best unseen frames
    if out.len() < budget {
        let chosen: std::collections::HashSet<u64> = out.iter().cloned().collect();
        let mut rest: Vec<u64> = (0..n as u64).filter(|f| !chosen.contains(f)).collect();
        rest.sort_by(|&a, &b| {
            weights[b as usize].partial_cmp(&weights[a as usize]).unwrap()
        });
        out.extend(rest.into_iter().take(budget - out.len()));
        out.sort_unstable();
    }
    out
}

fn percentile(xs: &[f32], q: f32) -> f32 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted[((sorted.len() - 1) as f32 * q) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_and_sorted() {
        let mut scores = vec![0.1f32; 300];
        for i in 100..140 {
            scores[i] = 0.9;
        }
        let sel = select(&scores, 16, 7);
        assert_eq!(sel.len(), 16);
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn concentrates_on_relevant_mass() {
        let mut scores = vec![0.1f32; 300];
        for i in 100..140 {
            scores[i] = 0.9;
        }
        let sel = select(&scores, 16, 7);
        let hot = sel.iter().filter(|&&f| (100..140).contains(&(f as usize))).count();
        assert!(hot >= 12, "{hot}/16 in the hot region");
    }

    #[test]
    fn spreads_over_two_regions() {
        let mut scores = vec![0.05f32; 400];
        for i in 50..70 {
            scores[i] = 0.8;
        }
        for i in 300..320 {
            scores[i] = 0.8;
        }
        let sel = select(&scores, 10, 3);
        assert!(sel.iter().any(|&f| (50..70).contains(&(f as usize))));
        assert!(sel.iter().any(|&f| (300..320).contains(&(f as usize))));
    }

    #[test]
    fn flat_scores_fall_back_to_uniform() {
        let scores = vec![0.3f32; 200];
        let sel = select(&scores, 8, 1);
        assert_eq!(sel.len(), 8);
        // roughly even spacing
        let gaps: Vec<u64> = sel.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(gaps.iter().all(|&g| g >= 15 && g <= 35), "{gaps:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut scores = vec![0.1f32; 100];
        scores[50] = 0.9;
        assert_eq!(select(&scores, 8, 42), select(&scores, 8, 42));
    }
}
