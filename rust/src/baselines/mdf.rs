//! MDF (Most Dominant Frames) — query-irrelevant self-adaptive filtering
//! [Han et al., NAACL'24 Findings].
//!
//! Reproduced as published: draw a uniform candidate pool, compute visual
//! features per candidate (our Eq. 1 feature vectors — real pixels, not
//! the oracle), then greedily keep the `budget` most mutually-distinct
//! dominant frames (max-min farthest-point selection).  Query-agnostic by
//! construction; its Table I weakness is that dominance ≠ relevance.

use crate::features::frame_features;
use crate::baselines::SelectionContext;

/// Candidate pool size (MDF samples a pool before filtering).
const POOL: usize = 256;

pub fn select(ctx: &SelectionContext, budget: usize) -> Vec<u64> {
    if ctx.total == 0 || budget == 0 {
        return Vec::new();
    }
    let pool_ids = super::uniform::select(ctx.total, POOL.min(ctx.total as usize));
    let feats: Vec<Vec<f32>> = pool_ids
        .iter()
        .map(|&id| frame_features(&ctx.synth.frame(id)))
        .collect();

    let l1 = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    };

    // start from the pool's most "dominant" frame: the one closest to the
    // pool mean (most representative)
    let dim = feats[0].len();
    let mut mean = vec![0.0f32; dim];
    for f in &feats {
        for (m, x) in mean.iter_mut().zip(f) {
            *m += x;
        }
    }
    for m in &mut mean {
        *m /= feats.len() as f32;
    }
    let first = (0..feats.len())
        .min_by(|&a, &b| l1(&feats[a], &mean).partial_cmp(&l1(&feats[b], &mean)).unwrap())
        .unwrap();

    let mut chosen = vec![first];
    let mut min_dist: Vec<f32> = feats.iter().map(|f| l1(f, &feats[first])).collect();
    while chosen.len() < budget.min(feats.len()) {
        // farthest-point: maximize distance to the chosen set
        let next = (0..feats.len())
            .filter(|i| !chosen.contains(i))
            .max_by(|&a, &b| min_dist[a].partial_cmp(&min_dist[b]).unwrap())
            .unwrap();
        chosen.push(next);
        for (i, f) in feats.iter().enumerate() {
            min_dist[i] = min_dist[i].min(l1(f, &feats[next]));
        }
    }

    let mut out: Vec<u64> = chosen.into_iter().map(|i| pool_ids[i]).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::video::synth::{SynthConfig, VideoSynth};
    use crate::video::workload::{DatasetPreset, WorkloadGen};

    fn fixture() -> VideoSynth {
        let mut rng = Pcg64::seeded(66);
        let codes = (0..8).map(|_| (0..192).map(|_| rng.f32()).collect()).collect();
        VideoSynth::new(
            SynthConfig { duration_s: 45.0, seed: 23, ..Default::default() },
            codes,
            8,
        )
    }

    #[test]
    fn spreads_across_scenes() {
        let synth = fixture();
        let qs = WorkloadGen::new(1, DatasetPreset::VideoMmeShort)
            .generate(synth.script(), 1);
        let ctx = SelectionContext {
            synth: &synth,
            query: &qs[0],
            total: synth.total_frames(),
            scores: None,
            seed: 1,
        };
        let sel = select(&ctx, 12);
        assert_eq!(sel.len(), 12);
        // dominant-diverse frames should touch several scenes
        let scenes: std::collections::HashSet<usize> =
            sel.iter().map(|&f| synth.script().scene_at(f).id).collect();
        assert!(scenes.len() >= 3, "{} scenes", scenes.len());
    }

    #[test]
    fn deterministic() {
        let synth = fixture();
        let qs = WorkloadGen::new(1, DatasetPreset::VideoMmeShort)
            .generate(synth.script(), 1);
        let ctx = SelectionContext {
            synth: &synth,
            query: &qs[0],
            total: synth.total_frames(),
            scores: None,
            seed: 1,
        };
        assert_eq!(select(&ctx, 8), select(&ctx, 8));
    }
}
