//! Baseline frame-selection methods from the paper's evaluation (§V-A-3):
//! Uniform Sampling, MDF, Video-RAG (query-irrelevant); AKS, BOLT
//! (query-relevant); and the Vanilla disaggregated architecture.
//!
//! Each implements the published algorithm's selection logic over the
//! same synthetic workload Venus sees; deployment latency (Cloud-Only vs
//! Edge-Cloud) is modeled in [`eval::latency`](crate::eval).

pub mod aks;
pub mod bolt;
pub mod mdf;
pub mod oracle;
pub mod uniform;
pub mod video_rag;

pub use oracle::frame_scores;

use crate::video::synth::VideoSynth;
use crate::video::workload::Query;

/// Identification of every evaluated method.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Uniform,
    Mdf,
    VideoRag,
    Aks,
    Bolt,
    Vanilla,
    Venus,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Uniform => "Uniform Sampling",
            Method::Mdf => "MDF",
            Method::VideoRag => "Video-RAG",
            Method::Aks => "AKS",
            Method::Bolt => "BOLT",
            Method::Vanilla => "Vanilla",
            Method::Venus => "Venus",
        }
    }

    pub fn query_relevant(&self) -> bool {
        matches!(self, Method::Aks | Method::Bolt | Method::Vanilla | Method::Venus)
    }
}

/// Everything a baseline may look at when selecting frames.
pub struct SelectionContext<'a> {
    pub synth: &'a VideoSynth,
    pub query: &'a Query,
    /// frames available in the queried clip: `[0, total)`
    pub total: u64,
    /// per-frame CLIP-style scores (query-relevant methods only)
    pub scores: Option<&'a [f32]>,
    pub seed: u64,
}

/// Dispatch a baseline selection (Venus itself runs through the
/// coordinator, not through this table).
pub fn select(method: Method, ctx: &SelectionContext, budget: usize) -> Vec<u64> {
    match method {
        Method::Uniform => uniform::select(ctx.total, budget),
        Method::Mdf => mdf::select(ctx, budget),
        Method::VideoRag => video_rag::select(ctx, budget),
        Method::Aks => aks::select(
            ctx.scores.expect("AKS needs frame scores"),
            budget,
        ),
        Method::Bolt => bolt::select(
            ctx.scores.expect("BOLT needs frame scores"),
            budget,
            ctx.seed,
        ),
        Method::Vanilla => {
            // naive disaggregated architecture: greedy per-frame Top-K
            let scores = ctx.scores.expect("Vanilla needs frame scores");
            let mut order: Vec<u64> = (0..ctx.total).collect();
            order.sort_by(|&a, &b| {
                scores[b as usize]
                    .partial_cmp(&scores[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            let mut sel: Vec<u64> = order.into_iter().take(budget).collect();
            sel.sort_unstable();
            sel
        }
        Method::Venus => unreachable!("Venus runs through the coordinator"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::video::synth::SynthConfig;
    use crate::video::workload::{DatasetPreset, WorkloadGen};

    fn ctx_fixture() -> (VideoSynth, Vec<Query>) {
        let mut rng = Pcg64::seeded(55);
        let codes = (0..16).map(|_| (0..192).map(|_| rng.f32()).collect()).collect();
        let synth = VideoSynth::new(
            SynthConfig { duration_s: 60.0, seed: 19, ..Default::default() },
            codes,
            8,
        );
        let qs = WorkloadGen::new(4, DatasetPreset::VideoMmeShort)
            .generate(synth.script(), 5);
        (synth, qs)
    }

    #[test]
    fn all_methods_respect_budget_and_range() {
        let (synth, qs) = ctx_fixture();
        let q = &qs[0];
        let total = synth.total_frames();
        let scores = frame_scores(synth.script(), q, total, 3);
        let ctx = SelectionContext { synth: &synth, query: q, total, scores: Some(&scores), seed: 3 };
        for m in [Method::Uniform, Method::Mdf, Method::VideoRag, Method::Aks, Method::Bolt, Method::Vanilla] {
            let sel = select(m, &ctx, 16);
            assert!(sel.len() <= 16, "{}: {} frames", m.name(), sel.len());
            assert!(!sel.is_empty(), "{}", m.name());
            assert!(sel.iter().all(|&f| f < total), "{}", m.name());
            // sorted & unique
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "{}", m.name());
        }
    }

    #[test]
    fn vanilla_concentrates_on_top_scores() {
        let (synth, qs) = ctx_fixture();
        let q = &qs[0];
        let total = synth.total_frames();
        let scores = frame_scores(synth.script(), q, total, 3);
        let ctx = SelectionContext { synth: &synth, query: q, total, scores: Some(&scores), seed: 3 };
        let sel = select(Method::Vanilla, &ctx, 8);
        // all selected frames are evidence frames (greedy on the oracle)
        let inside = sel.iter().filter(|&&f| q.covers(f)).count();
        assert!(inside >= 7, "{inside}/8 greedy picks inside evidence");
    }
}
