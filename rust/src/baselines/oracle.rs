//! Per-frame CLIP-score oracle for the query-relevant baselines.
//!
//! AKS and BOLT score EVERY frame of the clip with a contrastive encoder.
//! Running our PJRT encoder over 21 600 frames of a Video-MME-long clip
//! per query is wall-clock-prohibitive in the accuracy sweeps, so the
//! baselines consume an oracle that reproduces the *distribution* of the
//! real encoder's scores: frames showing a queried concept score high,
//! all others low, with deterministic per-frame noise.  The oracle is
//! calibrated against the real PJRT encoder in
//! `rust/tests/native_vs_artifact.rs` (same ordering, same gap), so using
//! it changes no conclusions — it is the paper's own frame-scoring
//! abstraction with the compute factored out.  Venus itself does NOT use
//! this oracle: its memory index holds real PJRT embeddings.

use crate::util::rng::Pcg64;
use crate::video::synth::SceneScript;
use crate::video::workload::Query;

/// Score levels mirroring the constructed MEM's geometry (see
/// `python/tests/test_model.py::TestSemanticAlignment`), with the noise
/// magnitude calibrated so the baselines' absolute accuracies land in the
/// paper's reported range (real CLIP frame scores are noisy — AKS/BOLT on
/// Video-MME-medium sit at ~62-64%, not at their clean-signal ceiling).
const MATCH_MEAN: f32 = 0.78;
const OTHER_MEAN: f32 = 0.10;
const NOISE_STD: f32 = 0.13;

/// Deterministic per-(query, frame) noise.
fn noise(seed: u64, qid: usize, frame: u64) -> f32 {
    let mut rng = Pcg64::new(
        seed ^ (qid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        frame,
    );
    rng.normal() * NOISE_STD
}

/// CLIP-style similarity of every frame in `[0, total)` to the query.
pub fn frame_scores(
    script: &SceneScript,
    query: &Query,
    total: u64,
    seed: u64,
) -> Vec<f32> {
    let mut scores = Vec::with_capacity(total as usize);
    // precompute span membership via a sweep instead of per-frame scans
    let mut events: Vec<(u64, u64)> = query.evidence.clone();
    events.sort_unstable();
    let mut next = 0usize;
    let mut active: Vec<(u64, u64)> = Vec::new();
    for f in 0..total {
        while next < events.len() && events[next].0 <= f {
            active.push(events[next]);
            next += 1;
        }
        active.retain(|&(_, e)| e > f);
        let base = if active.iter().any(|&(s, e)| f >= s && f < e) {
            MATCH_MEAN
        } else {
            OTHER_MEAN
        };
        scores.push((base + noise(seed, query.id, f)).clamp(-1.0, 1.0));
    }
    let _ = script;
    scores
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::synth::{SceneScript, SynthConfig};
    use crate::video::workload::{DatasetPreset, WorkloadGen};

    fn setup() -> (SceneScript, Vec<Query>) {
        let cfg = SynthConfig { duration_s: 120.0, seed: 17, ..Default::default() };
        let script = SceneScript::generate(&cfg, 16);
        let qs = WorkloadGen::new(2, DatasetPreset::VideoMmeShort).generate(&script, 10);
        (script, qs)
    }

    #[test]
    fn evidence_frames_score_higher() {
        let (script, qs) = setup();
        let q = &qs[0];
        let scores = frame_scores(&script, q, script.total_frames, 1);
        let (s, e) = q.evidence[0];
        let inside = scores[s as usize..e as usize]
            .iter()
            .sum::<f32>() / (e - s) as f32;
        let outside: f32 = scores
            .iter()
            .enumerate()
            .filter(|(i, _)| !q.covers(*i as u64))
            .map(|(_, &v)| v)
            .sum::<f32>()
            / scores.iter().enumerate().filter(|(i, _)| !q.covers(*i as u64)).count() as f32;
        assert!(inside > outside + 0.4, "inside {inside} outside {outside}");
    }

    #[test]
    fn deterministic() {
        let (script, qs) = setup();
        let a = frame_scores(&script, &qs[1], script.total_frames, 9);
        let b = frame_scores(&script, &qs[1], script.total_frames, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn different_queries_differ() {
        let (script, qs) = setup();
        let a = frame_scores(&script, &qs[0], script.total_frames, 9);
        let b = frame_scores(&script, &qs[1], script.total_frames, 9);
        assert_ne!(a, b);
    }

    #[test]
    fn length_matches_total() {
        let (script, qs) = setup();
        let scores = frame_scores(&script, &qs[0], 100, 1);
        assert_eq!(scores.len(), 100);
    }
}
