//! Uniform sampling: N frames at a fixed stride — the simplest
//! query-irrelevant baseline (and the sampler inside Video-RAG/LLaVA-OV
//! pipelines).

/// Evenly-spaced selection of `budget` frames from `[0, total)`.
pub fn select(total: u64, budget: usize) -> Vec<u64> {
    if total == 0 || budget == 0 {
        return Vec::new();
    }
    let n = (budget as u64).min(total);
    // midpoints of n equal bins — avoids biasing toward frame 0
    (0..n).map(|i| (2 * i + 1) * total / (2 * n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_count_and_order() {
        let sel = select(800, 32);
        assert_eq!(sel.len(), 32);
        assert!(sel.windows(2).all(|w| w[0] < w[1]));
        assert!(*sel.last().unwrap() < 800);
    }

    #[test]
    fn stride_is_even() {
        let sel = select(100, 4);
        assert_eq!(sel, vec![12, 37, 62, 87]);
    }

    #[test]
    fn budget_exceeding_total() {
        let sel = select(5, 32);
        assert_eq!(sel, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(select(0, 8).is_empty());
        assert!(select(10, 0).is_empty());
    }
}
