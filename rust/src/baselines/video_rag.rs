//! Video-RAG [Luo et al., 2024] — uniform visual sampling augmented with
//! a retrieval database of auxiliary texts (OCR/object tags).
//!
//! Reproduced at the selection level: frames are uniformly sampled, then
//! the auxiliary-text database (our simulated OCR/YOLO detections over a
//! candidate pool — real pixel inspection) is queried with the question;
//! candidates whose aux tags match query concepts replace the uniform
//! picks with the lowest information value.  This yields Table I's
//! behavior: ≈ uniform accuracy, with small gains when aux text happens
//! to hit the queried concept.

use crate::baselines::SelectionContext;
use crate::embed::auxmodels::AuxModels;

/// Aux-database pool size (frames actually OCR'd/detected).
const AUX_POOL: usize = 192;
/// Max uniform picks that aux retrieval may replace.
const MAX_SWAPS: usize = 8;

pub fn select(ctx: &SelectionContext, budget: usize) -> Vec<u64> {
    let mut picks = super::uniform::select(ctx.total, budget);
    if picks.is_empty() {
        return picks;
    }

    // build the aux database over a uniform candidate pool
    let codes = ctx.synth.codes().to_vec();
    let patch = ctx.synth.patch();
    let aux = AuxModels::new(codes, patch);
    let pool = super::uniform::select(ctx.total, AUX_POOL.min(ctx.total as usize));

    // retrieve pool frames whose aux tags mention a queried concept
    let mut matches: Vec<u64> = pool
        .into_iter()
        .filter(|&f| {
            aux.detect_concepts(&ctx.synth.frame(f))
                .iter()
                .any(|c| ctx.query.concepts.contains(c))
        })
        .collect();
    matches.retain(|f| !picks.contains(f));
    matches.truncate(MAX_SWAPS);

    // swap them in for the uniform picks nearest to other picks (least
    // marginal coverage)
    for m in matches {
        // find the pick whose removal least hurts temporal coverage:
        // the one with the smallest gap to its neighbor
        let mut worst = 0usize;
        let mut worst_gap = u64::MAX;
        for i in 0..picks.len() {
            let prev = if i == 0 { None } else { Some(picks[i - 1]) };
            let next = picks.get(i + 1).copied();
            let gap = match (prev, next) {
                (Some(p), Some(n)) => n - p,
                (None, Some(n)) => n,
                (Some(p), None) => ctx.total - p,
                (None, None) => u64::MAX,
            };
            if gap < worst_gap {
                worst_gap = gap;
                worst = i;
            }
        }
        picks[worst] = m;
        picks.sort_unstable();
    }
    picks.dedup();
    picks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::video::synth::{SynthConfig, VideoSynth};
    use crate::video::workload::{DatasetPreset, WorkloadGen};

    fn fixture(seed: u64) -> VideoSynth {
        let mut rng = Pcg64::seeded(13);
        let codes = (0..16).map(|_| (0..192).map(|_| rng.f32()).collect()).collect();
        VideoSynth::new(
            SynthConfig { duration_s: 60.0, seed, ..Default::default() },
            codes,
            8,
        )
    }

    #[test]
    fn budget_respected_and_sorted() {
        let synth = fixture(29);
        let qs = WorkloadGen::new(2, DatasetPreset::VideoMmeShort)
            .generate(synth.script(), 3);
        for q in &qs {
            let ctx = SelectionContext {
                synth: &synth,
                query: q,
                total: synth.total_frames(),
                scores: None,
                seed: 2,
            };
            let sel = select(&ctx, 16);
            assert!(sel.len() <= 16 && !sel.is_empty());
            assert!(sel.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn aux_retrieval_can_pull_in_evidence() {
        // across a batch of queries, Video-RAG should cover at least as
        // many evidence spans as plain uniform (the aux swaps only help)
        let synth = fixture(31);
        let qs = WorkloadGen::new(3, DatasetPreset::VideoMmeShort)
            .generate(synth.script(), 12);
        let mut rag_hits = 0usize;
        let mut uni_hits = 0usize;
        for q in &qs {
            let ctx = SelectionContext {
                synth: &synth,
                query: q,
                total: synth.total_frames(),
                scores: None,
                seed: 4,
            };
            let rag = select(&ctx, 16);
            let uni = super::super::uniform::select(ctx.total, 16);
            rag_hits += rag.iter().filter(|&&f| q.covers(f)).count();
            uni_hits += uni.iter().filter(|&&f| q.covers(f)).count();
        }
        assert!(
            rag_hits >= uni_hits,
            "aux retrieval should not lose evidence: rag {rag_hits} vs uniform {uni_hits}"
        );
    }
}
