//! Tiny argument parser: `--name value`, `--name=value`, boolean
//! switches, defaults, and generated `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declarative flag specification for one subcommand.
pub struct ArgSpec {
    name: String,
    flags: Vec<FlagDef>,
}

struct FlagDef {
    name: String,
    help: String,
    default: Option<String>,
    boolean: bool,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), flags: Vec::new() }
    }

    /// A `--name <value>` flag; `default: None` makes it required.
    pub fn flag(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.flags.push(FlagDef {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(String::from),
            boolean: false,
        });
        self
    }

    /// A boolean `--name` switch (default false).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagDef {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            boolean: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("USAGE: {} [flags]\n\nFLAGS:\n", self.name);
        for f in &self.flags {
            let kind = if f.boolean { "" } else { " <value>" };
            let def = match &f.default {
                Some(d) => format!(" (default: {d})"),
                None if !f.boolean => " (required)".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{kind:<10} {}{def}\n", f.name, f.help));
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let def = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown flag '--{name}'\n{}", self.usage())
                    })?;
                if def.boolean {
                    if inline.is_some() {
                        bail!("switch '--{name}' takes no value");
                    }
                    out.switches.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("flag '--{name}' needs a value"))?
                        }
                    };
                    out.values.insert(name, value);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        // defaults + required check
        for f in &self.flags {
            if f.boolean {
                out.switches.entry(f.name.clone()).or_insert(false);
            } else if !out.values.contains_key(&f.name) {
                match &f.default {
                    Some(d) => {
                        out.values.insert(f.name.clone(), d.clone());
                    }
                    None => bail!("missing required flag '--{}'\n{}", f.name, self.usage()),
                }
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self
            .values
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("flag '--{name}' not set"))?;
        Ok(v.parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self
            .values
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("flag '--{name}' not set"))?;
        Ok(v.parse()?)
    }

    pub fn on(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let spec = ArgSpec::new("t")
            .flag("a", "", Some("1"))
            .flag("b", "", None)
            .switch("v", "");
        let args = spec.parse(&argv(&["--b", "x", "--v"])).unwrap();
        assert_eq!(args.get("a"), Some("1"));
        assert_eq!(args.get("b"), Some("x"));
        assert!(args.on("v"));
    }

    #[test]
    fn equals_syntax() {
        let spec = ArgSpec::new("t").flag("n", "", None);
        let args = spec.parse(&argv(&["--n=42"])).unwrap();
        assert_eq!(args.get_usize("n").unwrap(), 42);
    }

    #[test]
    fn missing_required_fails() {
        let spec = ArgSpec::new("t").flag("b", "", None);
        assert!(spec.parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        let spec = ArgSpec::new("t");
        assert!(spec.parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let spec = ArgSpec::new("t").flag("a", "", Some("1"));
        let args = spec.parse(&argv(&["x", "--a", "2", "y"])).unwrap();
        assert_eq!(args.positional, vec!["x", "y"]);
    }
}
