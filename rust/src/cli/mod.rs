//! Zero-dependency CLI argument parser + the `venus` binary's subcommands.
//! (clap is unavailable offline; this covers subcommands, `--flag value`,
//! `--flag=value`, boolean switches, and `--help` generation.)

mod args;

pub use args::{ArgSpec, Args};

use std::sync::Arc;

use anyhow::Result;

use crate::api::{ApiError, Priority, QueryRequest};
use crate::backend::EmbedBackend;
use crate::config::VenusConfig;
use crate::util::stats::fmt_duration;
use crate::video::workload::DatasetPreset;

/// Binary entry: parse argv and dispatch.
pub fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&argv[1..]),
        "demo" => demo(&argv[1..]),
        "serve" => serve(&argv[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "venus — edge memory-and-retrieval for VLM-based online video understanding\n\
         \n\
         USAGE: venus <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS:\n\
           info     print artifact + runtime information\n\
           demo     ingest a synthetic stream and answer one query\n\
           serve    run the online query service over an ingested stream\n\
           help     this message\n\
         \n\
         Paper tables/figures: `cargo bench` (see DESIGN.md §4).\n"
    );
}

fn load_config(args: &Args) -> Result<VenusConfig> {
    match args.get("config") {
        Some(path) if !path.is_empty() => VenusConfig::from_file(path),
        _ => Ok(VenusConfig::default()),
    }
}

fn info(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus info")
        .flag("artifacts", "artifact directory (pjrt builds only)", Some(""));
    let parsed = spec.parse(args)?;

    // explicit artifact inspection (PJRT backend)
    if let Some(dir) = parsed.get("artifacts") {
        if !dir.is_empty() {
            #[cfg(feature = "pjrt")]
            {
                let rt = crate::runtime::Runtime::load(dir)?;
                let m = rt.manifest();
                println!("backend     : pjrt");
                println!("config hash : {}", m.config_hash);
                println!("d_embed     : {}", m.model.d_embed);
                println!("img size    : {}", m.model.img_size);
                println!("concepts    : {}", m.model.n_concepts);
                println!("entries     :");
                for (name, e) in &m.entries {
                    println!("  {name:24} {}", e.file);
                }
                return Ok(());
            }
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!(
                "--artifacts requires a build with `--features pjrt` \
                 (this build embeds with the native backend)"
            );
        }
    }

    // default: whatever backend this process would serve with
    let be = crate::backend::shared_default()?;
    let m = be.model();
    println!("backend     : {}", be.name());
    println!("d_embed     : {}", m.d_embed);
    println!("img size    : {}", m.img_size);
    println!("seq len     : {}", m.seq_len);
    println!("vocab       : {}", m.vocab);
    println!("concepts    : {}", m.n_concepts);
    println!("sim rows    : {}", m.sim_rows);
    println!("batches     : {:?}", be.image_batches());
    Ok(())
}

fn demo(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus demo")
        .flag("config", "TOML config file", Some(""))
        .flag("preset", "dataset preset", Some("videomme-short"))
        .flag("seed", "stream seed", Some("42"))
        .flag("query", "natural-language query (default: generated)", Some(""));
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;

    let synth = crate::eval::build_synth(preset, seed)?;
    let raw = Box::new(crate::memory::SynthBackedRaw::new(Arc::clone(&synth)));
    let mut venus = crate::coordinator::Venus::new(cfg, raw, seed)?;
    eprintln!("ingesting {} frames...", synth.total_frames());
    let stats = venus.ingest_stream(&synth, u64::MAX)?;
    eprintln!(
        "ingested {} frames -> {} index vectors in {}",
        stats.frames,
        stats.embedded,
        fmt_duration(stats.wall_s)
    );

    let text = match parsed.get("query") {
        Some(q) if !q.is_empty() => q.to_string(),
        _ => {
            let q = crate::video::workload::WorkloadGen::new(1, preset)
                .generate(synth.script(), 1)
                .remove(0);
            q.text
        }
    };
    println!("query: {text}");
    let (outcome, breakdown) = venus.query(&text)?;
    println!(
        "selected {} frames in {} edge / {} total: {:?}",
        outcome.selection.frames.len(),
        fmt_duration(breakdown.edge.total_s()),
        fmt_duration(breakdown.total_s()),
        outcome.selection.frames
    );
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus serve")
        .flag("config", "TOML config file", Some(""))
        .flag("preset", "dataset preset", Some("videomme-short"))
        .flag("seed", "stream seed", Some("42"))
        .flag("queries", "number of synthetic queries to replay", Some("32"))
        .flag(
            "streams",
            "camera streams (memory shards); 0 = from config [fabric]",
            Some("0"),
        )
        .flag(
            "repeat",
            "replay the query mix this many times (>1 exercises the query cache)",
            Some("1"),
        )
        .flag(
            "deadline-ms",
            "per-query deadline in milliseconds (0 = none)",
            Some("0"),
        )
        .flag(
            "data-dir",
            "durable memory root: first run ingests + persists, later runs recover from disk",
            Some(""),
        );
    let parsed = spec.parse(args)?;
    let mut cfg = load_config(&parsed)?;
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;
    let n_queries = parsed.get_usize("queries")?;
    let repeat = parsed.get_usize("repeat")?.max(1);
    let deadline_ms = parsed.get_usize("deadline-ms")?;
    let streams = match parsed.get_usize("streams")? {
        0 => cfg.fabric.streams,
        n => n,
    };
    let data_dir = parsed
        .get("data-dir")
        .filter(|p| !p.is_empty())
        .map(std::path::PathBuf::from);

    // build the typed request mix: alternating priorities (even slots are
    // a waiting human, odd slots are batch analytics), optional deadline
    let build_request = |i: usize, text: &str| {
        let mut req = QueryRequest::new(text).priority(if i % 2 == 0 {
            Priority::Interactive
        } else {
            Priority::Batch
        });
        if deadline_ms > 0 {
            req = req.deadline(std::time::Duration::from_millis(deadline_ms as u64));
        }
        req
    };

    let texts: Vec<String>;
    let service;
    let fabric;
    if streams <= 1 {
        // single-camera deployment: the paper's serving loop
        let case =
            crate::eval::prepare_case_at(preset, &cfg, n_queries, seed, data_dir.as_deref())?;
        if case.ingest_stats.frames == 0 && case.memory.read().unwrap().len() > 0 {
            eprintln!(
                "memory recovered from {}: {} index vectors over {} frames (ingest skipped)",
                data_dir.as_deref().unwrap_or_else(|| std::path::Path::new("?")).display(),
                case.memory.read().unwrap().len(),
                case.memory.read().unwrap().frames_ingested()
            );
        } else {
            eprintln!(
                "memory ready: {} index vectors over {} frames",
                case.memory.read().unwrap().len(),
                case.ingest_stats.frames
            );
        }
        texts = case.queries.iter().map(|q| q.text.clone()).collect();
        // evidence timestamps follow the stream's real frame rate
        cfg.api.fps = case.synth.config().fps;
        service = crate::server::Service::start(&cfg, Arc::clone(&case.fabric), seed)?;
        fabric = case.fabric;
    } else {
        // multi-camera fabric: K streams ingested concurrently through one
        // shared embed pool, then the query mix replays with All scope
        // (cross-camera answers) — `One` per-stream scoping is exercised
        // by `examples/multi_camera.rs`.
        let per_stream = ((n_queries + streams - 1) / streams).max(1);
        let case = crate::eval::prepare_multi_case_at(
            preset,
            &cfg,
            streams,
            per_stream,
            seed,
            data_dir.as_deref(),
        )?;
        let recovered = case.ingest_stats.iter().all(|s| s.frames == 0)
            && case.fabric.total_indexed() > 0;
        eprintln!(
            "fabric {}: {} streams, {} index vectors over {} frames",
            if recovered { "recovered from disk" } else { "ready" },
            case.fabric.n_streams(),
            case.fabric.total_indexed(),
            case.fabric.total_frames()
        );
        texts = case.queries.iter().map(|(_, q)| q.text.clone()).collect();
        cfg.api.fps = case.synths[0].config().fps;
        service = crate::server::Service::start(&cfg, Arc::clone(&case.fabric), seed)?;
        fabric = case.fabric;
    }

    let mut shed = 0usize;
    for round in 0..repeat {
        let mut receivers = Vec::new();
        for (i, text) in texts.iter().enumerate() {
            if let Ok(rx) = service.submit_request(build_request(i, text)) {
                receivers.push(rx);
            }
        }
        for rx in receivers {
            match rx.recv()? {
                Ok(_) => {}
                Err(ApiError::DeadlineExceeded) => shed += 1,
                Err(e) => eprintln!("query failed: {e}"),
            }
        }
        if repeat > 1 {
            eprintln!("round {}/{repeat}: {}", round + 1, service.cache.stats().render());
        }
    }
    if shed > 0 {
        eprintln!("{shed} queries shed at dequeue (deadline {deadline_ms} ms)");
    }
    println!("{}", service.cache.stats().render());
    let snap = service.shutdown();
    println!("{}", snap.render());
    if fabric.is_durable() {
        // clean shutdown: flush the WAL tails so the next `--data-dir`
        // run recovers everything, not just the sealed segments
        fabric.flush()?;
        eprintln!(
            "memory persisted to {} — rerun with the same --data-dir to serve without re-ingesting",
            fabric.data_dir().unwrap().display()
        );
    }
    Ok(())
}
