//! Zero-dependency CLI argument parser + the `venus` binary's subcommands.
//! (clap is unavailable offline; this covers subcommands, `--flag value`,
//! `--flag=value`, boolean switches, and `--help` generation.)

mod args;

pub use args::{ArgSpec, Args};

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::api::{ApiError, Priority, QueryRequest};
use crate::backend::EmbedBackend;
use crate::config::VenusConfig;
use crate::coordinator::query::RetrievalMode;
use crate::memory::{StreamId, StreamScope};
use crate::net::wire::{Camera, Gateway, IngestHub, LoadGen, WireClient};
use crate::util::stats::fmt_duration;
use crate::video::workload::DatasetPreset;

/// Binary entry: parse argv and dispatch.
pub fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&argv[1..]),
        "demo" => demo(&argv[1..]),
        "serve" => serve(&argv[1..]),
        "query" => query(&argv[1..]),
        "stats" => stats(&argv[1..]),
        "top" => top(&argv[1..]),
        "loadgen" => loadgen(&argv[1..]),
        "camera" => camera(&argv[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "venus — edge memory-and-retrieval for VLM-based online video understanding\n\
         \n\
         USAGE: venus <subcommand> [flags]\n\
         \n\
         SUBCOMMANDS:\n\
           info     print artifact + runtime information\n\
           demo     ingest a synthetic stream and answer one query\n\
           serve    run the online query service (--listen ADDR opens the TCP gateway)\n\
           query    send one query to a running gateway (venus query --connect ADDR \"...\")\n\
           stats    fetch a running gateway's metrics (--prom for Prometheus text format)\n\
           top      periodically poll a gateway's stats and recent query traces\n\
           loadgen  drive a running gateway with open-loop concurrent load\n\
           camera   push live frames into a running gateway (venus camera --connect ADDR)\n\
           help     this message\n\
         \n\
         Paper tables/figures: `cargo bench` (see DESIGN.md §4).\n"
    );
}

fn load_config(args: &Args) -> Result<VenusConfig> {
    match args.get("config") {
        Some(path) if !path.is_empty() => VenusConfig::from_file(path),
        _ => Ok(VenusConfig::default()),
    }
}

fn info(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus info")
        .flag("artifacts", "artifact directory (pjrt builds only)", Some(""));
    let parsed = spec.parse(args)?;

    // explicit artifact inspection (PJRT backend)
    if let Some(dir) = parsed.get("artifacts") {
        if !dir.is_empty() {
            #[cfg(feature = "pjrt")]
            {
                let rt = crate::runtime::Runtime::load(dir)?;
                let m = rt.manifest();
                println!("backend     : pjrt");
                println!("config hash : {}", m.config_hash);
                println!("d_embed     : {}", m.model.d_embed);
                println!("img size    : {}", m.model.img_size);
                println!("concepts    : {}", m.model.n_concepts);
                println!("entries     :");
                for (name, e) in &m.entries {
                    println!("  {name:24} {}", e.file);
                }
                return Ok(());
            }
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!(
                "--artifacts requires a build with `--features pjrt` \
                 (this build embeds with the native backend)"
            );
        }
    }

    // default: whatever backend this process would serve with
    let be = crate::backend::shared_default()?;
    let m = be.model();
    println!("backend     : {}", be.name());
    println!("d_embed     : {}", m.d_embed);
    println!("img size    : {}", m.img_size);
    println!("seq len     : {}", m.seq_len);
    println!("vocab       : {}", m.vocab);
    println!("concepts    : {}", m.n_concepts);
    println!("sim rows    : {}", m.sim_rows);
    println!("batches     : {:?}", be.image_batches());
    Ok(())
}

fn demo(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus demo")
        .flag("config", "TOML config file", Some(""))
        .flag("preset", "dataset preset", Some("videomme-short"))
        .flag("seed", "stream seed", Some("42"))
        .flag("query", "natural-language query (default: generated)", Some(""));
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;

    let synth = crate::eval::build_synth(preset, seed)?;
    let raw = Box::new(crate::memory::SynthBackedRaw::new(Arc::clone(&synth)));
    let mut venus = crate::coordinator::Venus::new(cfg, raw, seed)?;
    eprintln!("ingesting {} frames...", synth.total_frames());
    let stats = venus.ingest_stream(&synth, u64::MAX)?;
    eprintln!(
        "ingested {} frames -> {} index vectors in {}",
        stats.frames,
        stats.embedded,
        fmt_duration(stats.wall_s)
    );

    let text = match parsed.get("query") {
        Some(q) if !q.is_empty() => q.to_string(),
        _ => {
            let q = crate::video::workload::WorkloadGen::new(1, preset)
                .generate(synth.script(), 1)
                .remove(0);
            q.text
        }
    };
    println!("query: {text}");
    let (outcome, breakdown) = venus.query(&text)?;
    println!(
        "selected {} frames in {} edge / {} total: {:?}",
        outcome.selection.frames.len(),
        fmt_duration(breakdown.edge.total_s()),
        fmt_duration(breakdown.total_s()),
        outcome.selection.frames
    );
    Ok(())
}

fn serve(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus serve")
        .flag("config", "TOML config file", Some(""))
        .flag("preset", "dataset preset", Some("videomme-short"))
        .flag("seed", "stream seed", Some("42"))
        .flag("queries", "number of synthetic queries to replay", Some("32"))
        .flag(
            "streams",
            "camera streams (memory shards); 0 = from config [fabric]",
            Some("0"),
        )
        .flag(
            "repeat",
            "replay the query mix this many times (>1 exercises the query cache)",
            Some("1"),
        )
        .flag(
            "deadline-ms",
            "per-query deadline in milliseconds (0 = none)",
            Some("0"),
        )
        .flag(
            "data-dir",
            "durable memory root: first run ingests + persists, later runs recover from disk",
            Some(""),
        )
        .flag(
            "listen",
            "expose the typed query protocol over TCP on this address (port 0 = ephemeral); \
             the replay flags (--queries/--repeat/--deadline-ms) drive the closed loop only",
            Some(""),
        );
    let parsed = spec.parse(args)?;
    let mut cfg = load_config(&parsed)?;
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;
    let n_queries = parsed.get_usize("queries")?;
    let repeat = parsed.get_usize("repeat")?.max(1);
    let deadline_ms = parsed.get_usize("deadline-ms")?;
    let streams = match parsed.get_usize("streams")? {
        0 => cfg.fabric.streams,
        n => n,
    };
    let data_dir = parsed
        .get("data-dir")
        .filter(|p| !p.is_empty())
        .map(std::path::PathBuf::from);
    let listen = parsed.get("listen").filter(|a| !a.is_empty()).map(String::from);

    // build the typed request mix: alternating priorities (even slots are
    // a waiting human, odd slots are batch analytics), optional deadline
    let build_request = |i: usize, text: &str| {
        let mut req = QueryRequest::new(text).priority(if i % 2 == 0 {
            Priority::Interactive
        } else {
            Priority::Batch
        });
        if deadline_ms > 0 {
            req = req.deadline(std::time::Duration::from_millis(deadline_ms as u64));
        }
        req
    };

    let texts: Vec<String>;
    let service;
    let fabric;
    if streams <= 1 {
        // single-camera deployment: the paper's serving loop
        let case =
            crate::eval::prepare_case_at(preset, &cfg, n_queries, seed, data_dir.as_deref())?;
        if case.ingest_stats.frames == 0 && case.memory.read().len() > 0 {
            eprintln!(
                "memory recovered from {}: {} index vectors over {} frames (ingest skipped)",
                data_dir.as_deref().unwrap_or_else(|| std::path::Path::new("?")).display(),
                case.memory.read().len(),
                case.memory.read().frames_ingested()
            );
        } else {
            eprintln!(
                "memory ready: {} index vectors over {} frames",
                case.memory.read().len(),
                case.ingest_stats.frames
            );
        }
        texts = case.queries.iter().map(|q| q.text.clone()).collect();
        // evidence timestamps follow the stream's real frame rate
        cfg.api.fps = case.synth.config().fps;
        service = crate::server::Service::start(&cfg, Arc::clone(&case.fabric), seed)?;
        fabric = case.fabric;
    } else {
        // multi-camera fabric: K streams ingested concurrently through one
        // shared embed pool, then the query mix replays with All scope
        // (cross-camera answers) — `One` per-stream scoping is exercised
        // by `examples/multi_camera.rs`.
        let per_stream = ((n_queries + streams - 1) / streams).max(1);
        let case = crate::eval::prepare_multi_case_at(
            preset,
            &cfg,
            streams,
            per_stream,
            seed,
            data_dir.as_deref(),
        )?;
        let recovered = case.ingest_stats.iter().all(|s| s.frames == 0)
            && case.fabric.total_indexed() > 0;
        eprintln!(
            "fabric {}: {} streams, {} index vectors over {} frames",
            if recovered { "recovered from disk" } else { "ready" },
            case.fabric.n_streams(),
            case.fabric.total_indexed(),
            case.fabric.total_frames()
        );
        texts = case.queries.iter().map(|(_, q)| q.text.clone()).collect();
        cfg.api.fps = case.synths[0].config().fps;
        service = crate::server::Service::start(&cfg, Arc::clone(&case.fabric), seed)?;
        fabric = case.fabric;
    }

    if let Some(addr) = listen {
        // wire mode: remote clients drive the service; the replay mix is
        // not fired
        cfg.wire.listen = addr;
        return serve_wire(&cfg, service, &fabric);
    }

    let mut shed = 0usize;
    for round in 0..repeat {
        let mut receivers = Vec::new();
        for (i, text) in texts.iter().enumerate() {
            if let Ok(rx) = service.submit_request(build_request(i, text)) {
                receivers.push(rx);
            }
        }
        for rx in receivers {
            match rx.recv()? {
                Ok(_) => {}
                Err(ApiError::DeadlineExceeded) => shed += 1,
                Err(e) => eprintln!("query failed: {e}"),
            }
        }
        if repeat > 1 {
            eprintln!("round {}/{repeat}: {}", round + 1, service.cache.stats().render());
        }
    }
    if shed > 0 {
        eprintln!("{shed} queries shed at dequeue (deadline {deadline_ms} ms)");
    }
    finish_serving(service, &fabric)
}

/// Wire mode: run the TCP gateway over the prepared service until a
/// shutdown request arrives (a remote `Shutdown` message, or 'quit' on
/// an interactive stdin), then tear everything down in durability-safe
/// order.
fn serve_wire(
    cfg: &VenusConfig,
    service: crate::server::Service,
    fabric: &Arc<crate::memory::MemoryFabric>,
) -> Result<()> {
    use std::io::BufRead;

    let service = Arc::new(service);
    // the ingest hub shares the serving metrics (its admission controller
    // reads the Interactive lane's live queue depth) and the fabric the
    // queries run over — a camera's frames become queryable in place
    let hub = Arc::new(
        IngestHub::new(cfg, Arc::clone(fabric), Arc::clone(&service.metrics), 2)?
            .with_tracer(Arc::clone(&service.tracer)),
    );
    let gateway = Gateway::start_with(&cfg.wire, Arc::clone(&service), Some(Arc::clone(&hub)))?;
    let bound = gateway.local_addr();
    println!(
        "wire gateway listening on {bound} (protocol v{}, {} conns max)",
        crate::net::wire::PROTOCOL_VERSION,
        cfg.wire.max_conns
    );
    eprintln!("  venus query --connect {bound} \"what happened with concept01\"");
    eprintln!("  venus loadgen --connect {bound} --clients 8 --rate 64");
    eprintln!("  venus camera --connect {bound} --stream 0   # live push ingest");
    eprintln!("  venus query --connect {bound} --shutdown   # graceful stop");
    if std::io::IsTerminal::is_terminal(&std::io::stdin()) {
        eprintln!("  (or type 'quit' here)");
        let handle = gateway.shutdown_handle();
        std::thread::spawn(move || {
            for line in std::io::stdin().lock().lines() {
                match line {
                    Ok(l) if l.trim() == "quit" => break,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
            handle.request();
        });
    }
    gateway.wait_for_shutdown_request();
    eprintln!("shutdown requested: gateway, then ingest drain, then lane drain, then flush");
    // ordering is load-bearing for durability: stop accepting and join
    // every wire handler FIRST (no new work can arrive), THEN finish the
    // ingest pipelines (flush open partitions through the embed pool),
    // THEN drain the lanes, and only then flush the fabric — so the WAL
    // tail written at flush time covers every acknowledged frame
    let wire = gateway.shutdown();
    eprintln!("{}", wire.render());
    eprintln!("{}", hub.snapshot().render());
    match hub.finish_all() {
        Ok(finished) => {
            for (id, st) in &finished {
                eprintln!(
                    "ingest stream {id}: {} frames -> {} index vectors across {} partitions",
                    st.frames, st.embedded, st.partitions
                );
            }
        }
        Err(e) => eprintln!("ingest drain failed: {e:#}"),
    }
    drop(hub);
    let service = match Arc::try_unwrap(service) {
        Ok(s) => s,
        Err(arc) => {
            // should be unreachable — gateway.shutdown() joined every
            // thread holding a service handle, and ShutdownHandle holds
            // only the signal.  Degrade gracefully rather than skipping
            // the flush: whoever drops the last handle drains the lanes
            // (Service::drop closes and joins the workers), and the
            // flush below is safe either way — serving never ingests.
            eprintln!("warning: service handle still shared after gateway shutdown");
            println!("{}", arc.cache.stats().render());
            println!("{}", arc.tracer.render());
            println!("{}", arc.snapshot().render());
            drop(arc);
            if fabric.is_durable() {
                fabric.flush()?;
            }
            return Ok(());
        }
    };
    finish_serving(service, fabric)
}

/// `venus query --connect ADDR "..."` — one wire client, one session.
fn query(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus query")
        .flag("connect", "gateway address (host:port)", None)
        .flag("config", "TOML config file (client timeouts come from [wire])", Some(""))
        .flag("text", "query text (or pass it positionally)", Some(""))
        .flag("stream", "restrict to one camera stream id (default: all streams)", Some(""))
        .flag("mode", "retrieval mode override: akr | topk:K | sample:N", Some(""))
        .flag("budget", "per-query sampling budget override (0 = engine default)", Some("0"))
        .flag("priority", "admission lane: interactive | batch", Some("interactive"))
        .flag("deadline-ms", "per-query deadline in milliseconds (0 = none)", Some("0"))
        .flag("repeat", "send the query this many times (repeats exercise the cache)", Some("1"))
        .switch("stats", "print the server's metrics snapshot instead of querying")
        .switch("ping", "liveness probe instead of querying")
        .switch("shutdown", "ask the server to shut down gracefully")
        .switch("trace", "fetch and print this query's per-stage span tree")
        .switch("json", "print raw wire JSON instead of a summary");
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let addr = parsed.get("connect").unwrap().to_string();
    let mut client = WireClient::connect_with(addr.as_str(), &cfg.wire)?;
    eprintln!(
        "connected to {addr}: session {} over {} stream(s)",
        client.session_id(),
        client.streams()
    );

    if parsed.on("ping") {
        client.ping()?;
        println!("pong");
        return Ok(());
    }
    if parsed.on("stats") {
        let snap = client.stats()?;
        if parsed.on("json") {
            println!("{}", snap.to_json());
        } else {
            println!("{}", snap.render());
        }
        return Ok(());
    }
    if parsed.on("shutdown") {
        client.shutdown_server()?;
        println!("server acknowledged shutdown");
        return Ok(());
    }

    let text = match parsed.get("text") {
        Some(t) if !t.is_empty() => t.to_string(),
        _ => parsed.positional.join(" "),
    };
    if text.is_empty() {
        anyhow::bail!("no query text (use --text or a positional argument)");
    }
    let mut request = QueryRequest::new(text);
    if let Some(s) = parsed.get("stream").filter(|s| !s.is_empty()) {
        let id: usize = s.parse()?;
        if id >= client.streams() {
            anyhow::bail!(
                "stream {id} out of range: the server's fabric has {} stream(s)",
                client.streams()
            );
        }
        request = request.scope(StreamScope::One(StreamId(id as u16)));
    }
    if let Some(mode) = parse_mode(parsed.get("mode").unwrap())? {
        request = request.mode(mode);
    }
    let budget = parsed.get_usize("budget")?;
    if budget > 0 {
        request = request.budget(budget);
    }
    request = request.priority(parse_priority(parsed.get("priority").unwrap())?);
    let deadline_ms = parsed.get_usize("deadline-ms")?;
    if deadline_ms > 0 {
        request = request.deadline(Duration::from_millis(deadline_ms as u64));
    }

    let repeat = parsed.get_usize("repeat")?.max(1);
    let mut typed_errors: Vec<ApiError> = Vec::new();
    for _ in 0..repeat {
        match client.query(request.clone())? {
            Ok(resp) => {
                if parsed.on("json") {
                    println!("{}", resp.to_json());
                } else {
                    println!(
                        "#{} [{}] {} frames in {} (cache {}) — {} draws",
                        resp.id,
                        resp.priority,
                        resp.evidence.len(),
                        fmt_duration(resp.total_s()),
                        resp.cache,
                        resp.draws,
                    );
                    for e in &resp.evidence {
                        println!(
                            "  stream {} frame {:>6} t={:>8} score {:.4}",
                            e.frame.stream.0,
                            e.frame.idx,
                            fmt_duration(e.time_s),
                            e.score,
                        );
                    }
                }
                if parsed.on("trace") {
                    match resp.trace_id {
                        Some(id) => match client.trace(id)? {
                            Some(t) => println!("{}", t.render()),
                            None => eprintln!("trace {id} already evicted from the server's ring"),
                        },
                        None => eprintln!(
                            "server did not sample this query (tracing disabled, \
                             not sampled under [obs] trace_sample_n, or an older server)"
                        ),
                    }
                }
            }
            Err(api) => {
                eprintln!("typed error: {api}");
                typed_errors.push(api);
            }
        }
    }
    // scripted callers must see failure as failure: a run where any
    // query was refused/shed/failed exits non-zero
    if let Some(last) = typed_errors.last() {
        anyhow::bail!("{} of {repeat} queries failed (last: {last})", typed_errors.len());
    }
    Ok(())
}

/// `venus stats --connect ADDR` — one metrics fetch from a running
/// gateway, as a human summary, raw wire JSON, or Prometheus text.
fn stats(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus stats")
        .flag("connect", "gateway address (host:port)", None)
        .flag("config", "TOML config file (client timeouts come from [wire])", Some(""))
        .switch("prom", "Prometheus text exposition format (the metrics_text envelope)")
        .switch("json", "print raw wire JSON instead of a summary");
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let addr = parsed.get("connect").unwrap().to_string();
    let mut client = WireClient::connect_with(addr.as_str(), &cfg.wire)?;
    if parsed.on("prom") {
        print!("{}", client.metrics_text()?);
        return Ok(());
    }
    let snap = client.stats()?;
    if parsed.on("json") {
        println!("{}", snap.to_json());
    } else {
        println!("{}", snap.render());
        println!("lifetime {:.1} q/s over {:.1}s up", snap.derived_qps(), snap.uptime_s);
    }
    Ok(())
}

/// `venus top --connect ADDR` — periodically poll a gateway's metrics
/// snapshot and its most recent (or slowest) query traces.
fn top(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus top")
        .flag("connect", "gateway address (host:port)", None)
        .flag("config", "TOML config file (client timeouts come from [wire])", Some(""))
        .flag("interval-ms", "refresh interval in milliseconds", Some("1000"))
        .flag("iterations", "refreshes before exiting (0 = until interrupted)", Some("0"))
        .flag("traces", "traces listed per refresh", Some("5"))
        .switch("slow", "list the slow-query ring instead of the most recent traces")
        .switch("tree", "print each listed trace's full span tree");
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let addr = parsed.get("connect").unwrap().to_string();
    let interval = Duration::from_millis(parsed.get_usize("interval-ms")?.max(1) as u64);
    let iterations = parsed.get_usize("iterations")?;
    let n_traces = parsed.get_usize("traces")?;
    let slow = parsed.on("slow");
    let mut client = WireClient::connect_with(addr.as_str(), &cfg.wire)?;
    let mut round = 0usize;
    loop {
        let snap = client.stats()?;
        println!("{}", snap.render());
        println!("lifetime {:.1} q/s over {:.1}s up", snap.derived_qps(), snap.uptime_s);
        if n_traces > 0 {
            let traces = client.recent_traces(n_traces, slow)?;
            if traces.is_empty() {
                println!("  no {} traces yet", if slow { "slow" } else { "recent" });
            }
            for t in &traces {
                if parsed.on("tree") {
                    print!("{}", t.render());
                } else {
                    println!(
                        "  {} {} {:>9} \"{}\"",
                        t.id,
                        t.kind,
                        fmt_duration(t.total_us as f64 / 1e6),
                        t.label,
                    );
                }
            }
        }
        round += 1;
        if iterations > 0 && round >= iterations {
            return Ok(());
        }
        println!();
        std::thread::sleep(interval);
    }
}

/// `venus loadgen --connect ADDR` — open-loop concurrent load against a
/// running gateway; queries come from the same synthetic workload
/// generator the server was seeded with.
fn loadgen(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus loadgen")
        .flag("connect", "gateway address (host:port)", None)
        .flag("config", "TOML config file (client timeouts come from [wire])", Some(""))
        .flag("clients", "concurrent client connections", Some("8"))
        .flag("rate", "aggregate arrival rate, queries/second (open-loop)", Some("64"))
        .flag("duration-secs", "run length in seconds", Some("5"))
        .flag(
            "preset",
            "dataset preset the server was seeded with (drives the query generator)",
            Some("videomme-short"),
        )
        .flag("seed", "workload seed (match the server's for in-distribution queries)", Some("42"))
        .flag("queries", "distinct query texts to rotate through", Some("16"))
        .flag("interactive-share", "fraction of arrivals on the interactive lane", Some("0.5"))
        .flag("deadline-ms", "per-query deadline in milliseconds (0 = none)", Some("0"))
        .switch("shutdown", "gracefully stop the server after the run");
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let addr = parsed.get("connect").unwrap().to_string();
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;
    let n_texts = parsed.get_usize("queries")?.max(1);

    let synth = crate::eval::build_synth(preset, seed)?;
    let texts: Vec<String> = crate::video::workload::WorkloadGen::new(seed, preset)
        .generate(synth.script(), n_texts)
        .into_iter()
        .map(|q| q.text)
        .collect();

    let mut lg = LoadGen::new(addr.clone(), texts);
    lg.clients = parsed.get_usize("clients")?.max(1);
    lg.rate_qps = parsed.get_f64("rate")?;
    let duration_secs = parsed.get_f64("duration-secs")?;
    anyhow::ensure!(
        duration_secs > 0.0 && duration_secs.is_finite(),
        "duration-secs must be a positive number"
    );
    lg.duration = Duration::from_secs_f64(duration_secs);
    lg.interactive_share = parsed.get_f64("interactive-share")?;
    let deadline_ms = parsed.get_usize("deadline-ms")?;
    if deadline_ms > 0 {
        lg.deadline = Some(Duration::from_millis(deadline_ms as u64));
    }
    lg.wire = cfg.wire.clone();
    eprintln!(
        "driving {addr}: {} clients at {:.1} q/s for {:.1}s over {} texts",
        lg.clients,
        lg.rate_qps,
        lg.duration.as_secs_f64(),
        lg.texts.len()
    );
    let report = lg.run()?;
    println!("{}", report.render());
    // server-side parallel efficiency next to the client-side QPS: pull
    // the scoring-pool gauges over the same `stats` envelope operators
    // use (absent when driving an older server — from_json tolerates it)
    match WireClient::connect_with(addr.as_str(), &cfg.wire).and_then(|mut c| c.stats()) {
        Ok(snap) => {
            if let Some(sc) = &snap.scoring {
                println!("server {}", sc.render());
            }
        }
        Err(e) => eprintln!("stats fetch failed: {e:#}"),
    }
    if parsed.on("shutdown") {
        let mut client = WireClient::connect_with(addr.as_str(), &cfg.wire)?;
        client.shutdown_server()?;
        eprintln!("server acknowledged shutdown");
    }
    Ok(())
}

/// `venus camera --connect ADDR --stream N` — one paced push-ingest
/// client: frames from the synthetic preset, typed backpressure obeyed,
/// reconnect-with-resume on transport failures.
fn camera(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("venus camera")
        .flag("connect", "gateway address (host:port)", None)
        .flag("config", "TOML config file (client timeouts come from [wire])", Some(""))
        .flag("stream", "fabric stream id to claim", Some("0"))
        .flag("preset", "dataset preset generating the frames", Some("videomme-short"))
        .flag("seed", "stream seed", Some("42"))
        .flag("fps", "capture rate override (0 = preset rate)", Some("0"))
        .flag(
            "frames",
            "frames to push on top of the stream's current watermark (0 = one preset pass; \
             the synth loops)",
            Some("0"),
        )
        .flag("batch", "frames per ingest_frames envelope", Some("8"))
        .flag("reconnects", "transport-failure budget before giving up", Some("5"));
    let parsed = spec.parse(args)?;
    let cfg = load_config(&parsed)?;
    let addr = parsed.get("connect").unwrap().to_string();
    let preset = DatasetPreset::parse(parsed.get("preset").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    let seed: u64 = parsed.get("seed").unwrap().parse()?;
    let stream = parsed.get_usize("stream")?;
    anyhow::ensure!(stream <= u16::MAX as usize, "stream id {stream} out of range");

    let synth = crate::eval::build_synth(preset, seed)?;
    let mut cam = Camera::new(addr, stream as u16, synth);
    cam.wire = cfg.wire.clone();
    let fps = parsed.get_f64("fps")?;
    if fps > 0.0 {
        cam.fps = fps;
    }
    let frames = parsed.get_usize("frames")?;
    if frames > 0 {
        cam.frames = frames as u64;
    }
    cam.batch_frames = parsed.get_usize("batch")?.max(1);
    cam.max_reconnects = parsed.get_usize("reconnects")?;
    eprintln!(
        "pushing {} frames at {:.1} fps to {} as stream {} ({}-frame batches)",
        cam.frames, cam.fps, cam.addr, cam.stream, cam.batch_frames
    );
    let report = cam.run()?;
    println!("{}", report.render());
    Ok(())
}

fn parse_mode(s: &str) -> Result<Option<RetrievalMode>> {
    if s.is_empty() {
        return Ok(None);
    }
    if s == "akr" {
        return Ok(Some(RetrievalMode::Akr));
    }
    if let Some(k) = s.strip_prefix("topk:") {
        return Ok(Some(RetrievalMode::TopK(k.parse()?)));
    }
    if let Some(n) = s.strip_prefix("sample:") {
        return Ok(Some(RetrievalMode::FixedSampling(n.parse()?)));
    }
    anyhow::bail!("unknown mode '{s}' (use akr | topk:K | sample:N)")
}

fn parse_priority(s: &str) -> Result<Priority> {
    match s {
        "interactive" => Ok(Priority::Interactive),
        "batch" => Ok(Priority::Batch),
        other => anyhow::bail!("unknown priority '{other}' (use interactive | batch)"),
    }
}

/// Shared tail of every serve mode: print cache + serving stats, drain
/// the worker lanes, and flush durable memory only after everything
/// drained (clean exits leave no torn WAL tails behind).
fn finish_serving(
    service: crate::server::Service,
    fabric: &Arc<crate::memory::MemoryFabric>,
) -> Result<()> {
    println!("{}", service.cache.stats().render());
    println!("{}", service.tracer.render());
    for t in service.tracer.slow_recent(3) {
        println!(
            "  slow {} {} \"{}\"",
            t.id,
            fmt_duration(t.total_us as f64 / 1e6),
            t.label
        );
    }
    let snap = service.shutdown();
    println!("{}", snap.render());
    println!("lifetime {:.1} q/s over {:.1}s up", snap.derived_qps(), snap.uptime_s);
    if fabric.is_durable() {
        // clean shutdown: flush the WAL tails so the next `--data-dir`
        // run recovers everything, not just the sealed segments
        fabric.flush()?;
        eprintln!(
            "memory persisted to {} — rerun with the same --data-dir to serve without re-ingesting",
            fabric.data_dir().unwrap().display()
        );
    }
    Ok(())
}
