//! Simulated cloud VLM service (stands in for LLaVA-OV-7B / Qwen2-VL-7B
//! on an L40S, which are unavailable here).
//!
//! Two calibrated models, shared by EVERY method under evaluation (no
//! per-method constants — accuracy differences in the tables emerge from
//! each method's actual frame selection):
//!
//!  * **latency**: `prefill(n_frames · tokens_per_frame + q_tokens) +
//!    decode(answer_tokens) + overhead` — linear in uploaded frames, which
//!    is what makes frame-budget reduction (AKR, Fig. 11) pay off;
//!  * **answer**: P(correct) as a function of ground-truth evidence
//!    coverage, span diversity, near-duplicate redundancy, and context
//!    overflow (DESIGN.md §6), Bernoulli-sampled per query.

pub mod vlm;

pub use vlm::{AnswerModel, SelectionStats, VlmClient, VlmPersonality};
