//! VLM latency + answer models.

use crate::config::CloudConfig;
use crate::util::rng::Pcg64;
use crate::video::synth::SceneScript;
use crate::video::workload::Query;

/// Cloud VLM personality: base reasoning skill differs between the two
/// paper models (Qwen2-VL-7B outperforms LLaVA-OV-7B across Table I/II).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VlmPersonality {
    LlavaOv7b,
    Qwen2Vl7b,
}

impl VlmPersonality {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "llava-ov-7b" => Some(Self::LlavaOv7b),
            "qwen2-vl-7b" => Some(Self::Qwen2Vl7b),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::LlavaOv7b => "llava-ov-7b",
            Self::Qwen2Vl7b => "qwen2-vl-7b",
        }
    }

    /// Base P(correct) with zero visual evidence beyond chance priors
    /// (VLMs answer many MCQs from context/language priors alone).
    fn base_skill(&self) -> f64 {
        match self {
            Self::LlavaOv7b => 0.40,
            Self::Qwen2Vl7b => 0.44,
        }
    }
}

/// Evidence statistics of a frame selection w.r.t. one query's ground
/// truth.  Computed once, consumed by the answer model.
#[derive(Clone, Copy, Debug, Default)]
pub struct SelectionStats {
    /// fraction of evidence spans covered by ≥1 selected frame
    pub coverage: f64,
    /// number of distinct covered spans
    pub covered_spans: usize,
    /// total evidence spans
    pub n_spans: usize,
    /// fraction of selected frames that are temporal near-duplicates
    pub redundancy: f64,
    /// selected frames showing a distractor-option concept
    pub distractor_frac: f64,
    pub n_frames: usize,
}

impl SelectionStats {
    /// Compute stats for `frames` (global frame ids) against a query.
    /// `near_dup_gap`: frames closer than this count as duplicates.
    pub fn compute(
        query: &Query,
        script: &SceneScript,
        frames: &[u64],
        near_dup_gap: u64,
    ) -> Self {
        let n_spans = query.evidence.len();
        let covered_spans = query
            .evidence
            .iter()
            .filter(|&&(s, e)| frames.iter().any(|&f| f >= s && f < e))
            .count();
        let coverage = if n_spans == 0 {
            0.0
        } else {
            covered_spans as f64 / n_spans as f64
        };

        // temporal near-duplicates
        let mut sorted: Vec<u64> = frames.to_vec();
        sorted.sort_unstable();
        let dups = sorted
            .windows(2)
            .filter(|w| w[1] - w[0] < near_dup_gap)
            .count();
        let redundancy = if frames.len() <= 1 {
            0.0
        } else {
            dups as f64 / (frames.len() - 1) as f64
        };

        // frames showing distractor concepts (can mislead the VLM)
        let distractor_hits = frames
            .iter()
            .filter(|&&f| {
                script
                    .concepts_at(f)
                    .iter()
                    .any(|(c, _)| query.distractor_concepts.contains(c))
            })
            .count();
        let distractor_frac = if frames.is_empty() {
            0.0
        } else {
            distractor_hits as f64 / frames.len() as f64
        };

        Self {
            coverage,
            covered_spans,
            n_spans,
            redundancy,
            distractor_frac,
            n_frames: frames.len(),
        }
    }
}

/// The answer model: maps selection stats to P(correct).
#[derive(Clone, Debug)]
pub struct AnswerModel {
    personality: VlmPersonality,
    /// weight of evidence coverage
    pub alpha: f64,
    /// bonus for multi-span diversity (dispersed queries)
    pub gamma: f64,
    /// penalty for near-duplicate frames (they displace useful context)
    pub delta: f64,
    /// penalty per frame beyond the sweet spot (context dilution, Fig. 5a)
    pub eta: f64,
    pub sweet_spot: usize,
    /// penalty for distractor-concept frames
    pub rho: f64,
}

impl AnswerModel {
    pub fn new(personality: VlmPersonality) -> Self {
        Self {
            personality,
            alpha: 0.30,
            gamma: 0.06,
            delta: 0.08,
            eta: 0.0012,
            sweet_spot: 48,
            rho: 0.05,
        }
    }

    /// Probability of a correct answer for a query given selection stats.
    pub fn p_correct(&self, query: &Query, st: &SelectionStats) -> f64 {
        let chance = 1.0 / query.n_options as f64;
        let diversity = if st.n_spans > 1 {
            self.gamma * (st.covered_spans.saturating_sub(1)) as f64
                / (st.n_spans - 1) as f64
        } else {
            0.0
        };
        let overflow =
            self.eta * (st.n_frames.saturating_sub(self.sweet_spot)) as f64;
        let p = self.personality.base_skill() + self.alpha * st.coverage + diversity
            - self.delta * st.redundancy
            - self.rho * st.distractor_frac
            - overflow;
        p.clamp(chance, 0.97)
    }

    pub fn personality(&self) -> VlmPersonality {
        self.personality
    }
}

/// The full simulated cloud service: latency + sampled answers.
#[derive(Clone, Debug)]
pub struct VlmClient {
    cfg: CloudConfig,
    answer: AnswerModel,
    rng: Pcg64,
    /// near-duplicate gap in frames for redundancy stats (0.5 s @ 8 FPS)
    pub near_dup_gap: u64,
}

impl VlmClient {
    pub fn new(cfg: CloudConfig, seed: u64) -> Self {
        let personality =
            VlmPersonality::parse(&cfg.vlm).unwrap_or(VlmPersonality::Qwen2Vl7b);
        Self {
            cfg,
            answer: AnswerModel::new(personality),
            rng: Pcg64::new(seed, 0xc10d),
            near_dup_gap: 4,
        }
    }

    pub fn config(&self) -> &CloudConfig {
        &self.cfg
    }

    pub fn answer_model(&self) -> &AnswerModel {
        &self.answer
    }

    /// Inference latency for a request with `n_frames` visual inputs.
    pub fn infer_latency_s(&self, n_frames: usize, query_tokens: usize) -> f64 {
        let prefill_tokens =
            (n_frames * self.cfg.tokens_per_frame + query_tokens) as f64;
        prefill_tokens / self.cfg.prefill_tps
            + self.cfg.answer_tokens as f64 / self.cfg.decode_tps
            + self.cfg.overhead_s
    }

    /// Judge a query given the selected frames; returns (correct?, p).
    pub fn judge(
        &mut self,
        query: &Query,
        script: &SceneScript,
        frames: &[u64],
    ) -> (bool, f64) {
        let st = SelectionStats::compute(query, script, frames, self.near_dup_gap);
        let p = self.answer.p_correct(query, &st);
        (self.rng.chance(p), p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::synth::{SceneScript, SynthConfig};
    use crate::video::workload::{DatasetPreset, WorkloadGen};

    fn setup() -> (SceneScript, Vec<Query>) {
        let cfg = SynthConfig { duration_s: 200.0, seed: 5, ..Default::default() };
        let script = SceneScript::generate(&cfg, 16);
        let qs = WorkloadGen::new(1, DatasetPreset::VideoMmeShort).generate(&script, 20);
        (script, qs)
    }

    #[test]
    fn stats_full_coverage_when_frames_inside_spans() {
        let (script, qs) = setup();
        let q = &qs[0];
        let frames: Vec<u64> = q.evidence.iter().map(|&(s, _)| s).collect();
        let st = SelectionStats::compute(q, &script, &frames, 4);
        assert_eq!(st.coverage, 1.0);
        assert_eq!(st.covered_spans, st.n_spans);
    }

    #[test]
    fn stats_zero_coverage_when_frames_outside() {
        let (script, qs) = setup();
        let q = qs
            .iter()
            .find(|q| q.evidence[0].0 > 10)
            .expect("query with late evidence");
        let frames = vec![0u64, 1, 2];
        let st = SelectionStats::compute(q, &script, &frames, 4);
        assert_eq!(st.coverage, 0.0);
        // adjacent frames are redundant
        assert!(st.redundancy > 0.9);
    }

    #[test]
    fn coverage_raises_p_correct() {
        let (_, qs) = setup();
        let q = &qs[0];
        let m = AnswerModel::new(VlmPersonality::Qwen2Vl7b);
        let none = SelectionStats { coverage: 0.0, n_spans: 1, n_frames: 8, ..Default::default() };
        let full = SelectionStats {
            coverage: 1.0,
            covered_spans: 1,
            n_spans: 1,
            n_frames: 8,
            ..Default::default()
        };
        assert!(m.p_correct(q, &full) > m.p_correct(q, &none) + 0.2);
    }

    #[test]
    fn redundancy_and_overflow_lower_p() {
        let (_, qs) = setup();
        let q = &qs[0];
        let m = AnswerModel::new(VlmPersonality::LlavaOv7b);
        let clean = SelectionStats {
            coverage: 1.0, covered_spans: 1, n_spans: 1, n_frames: 16,
            ..Default::default()
        };
        let redundant = SelectionStats { redundancy: 0.8, ..clean };
        let bloated = SelectionStats { n_frames: 256, ..clean };
        assert!(m.p_correct(q, &redundant) < m.p_correct(q, &clean));
        assert!(m.p_correct(q, &bloated) < m.p_correct(q, &clean));
    }

    #[test]
    fn p_correct_bounded_by_chance_and_cap() {
        let (_, qs) = setup();
        let q = &qs[0];
        let m = AnswerModel::new(VlmPersonality::LlavaOv7b);
        let terrible = SelectionStats {
            redundancy: 1.0,
            distractor_frac: 1.0,
            n_frames: 1000,
            n_spans: 1,
            ..Default::default()
        };
        let p = m.p_correct(q, &terrible);
        assert!((p - 1.0 / q.n_options as f64).abs() < 1e-9);
    }

    #[test]
    fn qwen_outranks_llava() {
        let (_, qs) = setup();
        let q = &qs[0];
        let st = SelectionStats {
            coverage: 0.8, covered_spans: 1, n_spans: 1, n_frames: 16,
            ..Default::default()
        };
        let llava = AnswerModel::new(VlmPersonality::LlavaOv7b).p_correct(q, &st);
        let qwen = AnswerModel::new(VlmPersonality::Qwen2Vl7b).p_correct(q, &st);
        assert!(qwen > llava);
    }

    #[test]
    fn latency_linear_in_frames() {
        let c = VlmClient::new(CloudConfig::default(), 0);
        let t16 = c.infer_latency_s(16, 30);
        let t32 = c.infer_latency_s(32, 30);
        let t64 = c.infer_latency_s(64, 30);
        // doubling the frame delta doubles the latency delta
        assert!(((t64 - t32) - 2.0 * (t32 - t16)).abs() < 1e-9);
        assert!(t32 > t16);
    }

    #[test]
    fn judge_is_deterministic_per_seed() {
        let (script, qs) = setup();
        let frames: Vec<u64> = (0..32).map(|i| i * 10).collect();
        let mut a = VlmClient::new(CloudConfig::default(), 7);
        let mut b = VlmClient::new(CloudConfig::default(), 7);
        for q in &qs {
            assert_eq!(a.judge(q, &script, &frames), b.judge(q, &script, &frames));
        }
    }
}
