//! Typed configuration for the Venus system, loadable from TOML files
//! (see `configs/` for examples) with validated defaults matching the
//! paper's settings (§V-A): 8 FPS streams, 100 Mbps edge-cloud link,
//! AGX-Orin-class edge device, τ-softmax retrieval with AKR θ = 0.9.

pub mod toml;

use anyhow::{bail, Context, Result};

pub use toml::{TomlDoc, TomlValue};

/// Ingestion-stage parameters (scene segmentation + clustering + embed).
#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// Eq. 1 scene-boundary threshold on the tracking score φ.
    pub scene_threshold: f32,
    /// Minimum temporal partition length in seconds (fixed-view fallback).
    pub max_partition_s: f64,
    /// Minimum frames between detected boundaries (debounce).
    pub min_scene_frames: u64,
    /// Incremental-clustering L2 distance threshold.
    pub cluster_threshold: f32,
    /// Embedding batch size (must match an exported artifact batch).
    pub embed_batch: usize,
    /// Bounded channel capacity between pipeline stages (backpressure).
    pub queue_capacity: usize,
    /// Enable auxiliary models (simulated OCR/YOLO) for index prompts.
    pub aux_models: bool,
    /// Wire-ingest overload policy: "slowdown" paces cameras down with
    /// `SlowDown{delay_ms}` replies (no frame is lost); "drop" sheds
    /// whole batches with `Dropped{from_seq,count}` (fresher at the cost
    /// of archive holes).  See DESIGN.md §Ingest-Wire.
    pub drop_policy: String,
    /// Admission-controller staleness bound in milliseconds: once any
    /// ingest stream's capture→queryable lag exceeds this, its batches
    /// are admitted even while interactive queries are queued (ingest
    /// yields under load but is never starved past the bound).
    pub staleness_bound_ms: u64,
    /// Delay carried in `SlowDown` replies (and the pause a yielding
    /// camera is asked to take), milliseconds.
    pub slowdown_ms: u64,
    /// Largest accepted `ingest_frames` batch; bigger batches are a
    /// protocol error (bounds per-batch decode work next to the wire's
    /// byte-level `max_frame_bytes`).
    pub max_batch_frames: usize,
    /// Interactive-lane queue depth above which ingest yields (the
    /// admission controller's contention signal).
    pub yield_queue_depth: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        Self {
            scene_threshold: 0.055,
            max_partition_s: 12.0,
            min_scene_frames: 8,
            cluster_threshold: 0.085,
            embed_batch: 8,
            queue_capacity: 256,
            aux_models: true,
            drop_policy: "slowdown".into(),
            staleness_bound_ms: 5_000,
            slowdown_ms: 250,
            max_batch_frames: 64,
            yield_queue_depth: 2,
        }
    }
}

/// Query-stage retrieval parameters (Eq. 4–7).
#[derive(Clone, Debug)]
pub struct RetrievalConfig {
    /// Softmax temperature τ (Eq. 5).
    pub tau: f32,
    /// Fixed sampling budget N when AKR is disabled.
    pub budget: usize,
    /// AKR enabled?
    pub akr: bool,
    /// AKR cumulative-probability threshold θ (Eq. 6).
    pub theta: f64,
    /// AKR lower-bound scale β (Eq. 7).
    pub beta: f64,
    /// AKR upper bound on sampled frames (transmission-delay cap).
    pub n_max: usize,
    /// Softmax candidate shortlist: sampling considers only the top-M
    /// scored index vectors (0 = all).  Keeps the relevance-diversity
    /// trade-off invariant to index size on hour-long streams.
    pub shortlist: usize,
}

impl Default for RetrievalConfig {
    fn default() -> Self {
        // τ tuned on the relevance-diversity trade-off (DESIGN.md §Perf);
        // stratified within-cluster expansion keeps near-duplicate
        // redundancy low even at this sharper τ, and θ=0.9 (the paper's
        // operating point) terminates AKR early on concentrated
        // distributions (akr_tuning sweeps the surface).
        Self {
            tau: 0.12,
            budget: 32,
            akr: true,
            theta: 0.90,
            beta: 4.0,
            n_max: 32,
            shortlist: 128,
        }
    }
}

/// Hierarchical memory parameters.
#[derive(Clone, Debug)]
pub struct MemoryConfig {
    /// Vector index kind: "flat" or "ivf".
    pub index: String,
    /// IVF cell count (0 = auto: √n heuristic).
    pub ivf_nlist: usize,
    /// IVF probe count at query time.
    pub ivf_nprobe: usize,
    /// Raw-layer segment size (frames per on-disk frame-log chunk file).
    pub segment_frames: usize,
    /// Index-layer segment size: the WAL seals an immutable segment file
    /// once this many inserts accumulate (durable fabrics only).
    pub segment_records: usize,
    /// Hot-tier budget in bytes (in-RAM index vectors + their cluster
    /// records).  0 = unbounded (the pure-RAM legacy behavior).  A
    /// non-zero budget requires a durable fabric (`MemoryFabric::open`):
    /// eviction demotes the oldest sealed segments to the cold tier.
    pub hot_budget_bytes: usize,
    /// Cold-tier block cache: how many sealed segments' vector blocks may
    /// stay resident at once (LRU).
    pub cold_cache_segments: usize,
    /// Sealed-segment scan quantization: "none" (exact f32, the default)
    /// or "sq8" (per-dimension scalar u8 codes written at seal time and
    /// scored asymmetrically — ~4× more vectors per cache slot, bounded
    /// approximation gated by the recall@k ≥ 0.95 test).
    pub quantization: String,
    /// Coarse-probe budget for cold queries: fully scan only the
    /// top-`coarse_nprobe` sealed segments by centroid score (segments
    /// without centroids always scan).  0 = scan all (exact).
    pub coarse_nprobe: usize,
    /// K-means centroids trained per sealed segment at seal time (the
    /// coarse index `coarse_nprobe` routes on).  0 = none.
    pub coarse_centroids_per_segment: usize,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        Self {
            index: "flat".into(),
            ivf_nlist: 0,
            ivf_nprobe: 8,
            segment_frames: 512,
            segment_records: 256,
            hot_budget_bytes: 0,
            cold_cache_segments: 4,
            quantization: "none".into(),
            coarse_nprobe: 0,
            coarse_centroids_per_segment: 0,
        }
    }
}

/// Edge-cloud network model (paper: 100 Mbps typical edge uplink).
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
    /// Modeled size of one transmitted camera frame (1080p JPEG).
    pub frame_kb: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // frame_kb calibrated so baseline clip-upload latencies land on the
        // paper's Table II scale (1080p high-quality JPEG per frame).
        Self { bandwidth_mbps: 100.0, rtt_ms: 20.0, frame_kb: 450.0 }
    }
}

/// Cloud VLM service model.
#[derive(Clone, Debug)]
pub struct CloudConfig {
    /// "llava-ov-7b" or "qwen2-vl-7b" personality.
    pub vlm: String,
    /// Visual tokens per frame (LLaVA-OV uses 196).
    pub tokens_per_frame: usize,
    /// Prefill throughput, visual tokens/s (L40S-class, 7B model;
    /// calibrated so a 32-frame request ≈ the paper's ~3.4 s inference).
    pub prefill_tps: f64,
    /// Decode throughput, tokens/s.
    pub decode_tps: f64,
    /// Answer length in tokens (MCQ answers are short).
    pub answer_tokens: usize,
    /// Fixed service overhead (queueing, scheduling), seconds.
    pub overhead_s: f64,
}

impl Default for CloudConfig {
    fn default() -> Self {
        Self {
            vlm: "qwen2-vl-7b".into(),
            tokens_per_frame: 196,
            prefill_tps: 2200.0,
            decode_tps: 60.0,
            answer_tokens: 24,
            overhead_s: 0.15,
        }
    }
}

/// Serving loop parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Legacy single-queue admission depth: the default for BOTH priority
    /// lanes unless `[api] interactive_depth` / `batch_depth` override it
    /// (see [`VenusConfig::lane_depths`]).
    pub queue_depth: usize,
    /// Query worker threads.
    pub workers: usize,
    /// Scoring-pool threads shared by every query worker
    /// (DESIGN.md §Parallel-Query).  `0` (the default) resolves to the
    /// host's available parallelism — see
    /// [`ServerConfig::resolved_score_workers`].
    pub score_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { queue_depth: 64, workers: 2, score_workers: 0 }
    }
}

impl ServerConfig {
    /// Resolve `score_workers = 0` to the auto heuristic: one scoring
    /// thread per core.  Scoring tasks are compute-bound row scans, so
    /// unlike the embed pool there is no per-stream cap — an All-scope
    /// query over few shards still fans out per cold segment.
    pub fn resolved_score_workers(&self) -> usize {
        if self.score_workers > 0 {
            return self.score_workers;
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
    }
}

/// Serving-API parameters: priority lanes + semantic query cache
/// (the typed query protocol of DESIGN.md §Serving-API).
#[derive(Clone, Debug)]
pub struct ApiConfig {
    /// Semantic query-cache capacity in entries (0 disables the cache).
    pub cache_entries: usize,
    /// Cosine-similarity threshold for a semantic cache hit: a new query
    /// whose text embedding is at least this close to a cached one reuses
    /// the cached selection.  1.0 restricts reuse to (near-)identical
    /// embeddings; exact text repeats hit regardless of this threshold.
    pub cache_threshold: f64,
    /// Staleness bound: a cached selection is dropped once any touched
    /// shard's ingest watermark advanced by more than this many inserts
    /// since the entry was cached.
    pub cache_max_stale: u64,
    /// Interactive-lane queue depth (admission control per lane).
    /// `None` inherits the legacy `server.queue_depth` — see
    /// [`VenusConfig::lane_depths`].
    pub interactive_depth: Option<usize>,
    /// Batch-lane queue depth (`None` inherits `server.queue_depth`).
    pub batch_depth: Option<usize>,
    /// Camera frame rate used to render evidence timestamps.  Defaults
    /// to the paper's 8 FPS evaluation rate; deployments whose streams
    /// run at a different rate must set it to the real camera rate (the
    /// CLI and examples copy it from the stream config before serving),
    /// or reported `Evidence::time_s` values will be scaled wrong.
    pub fps: f64,
}

impl Default for ApiConfig {
    fn default() -> Self {
        Self {
            cache_entries: 256,
            cache_threshold: 0.92,
            cache_max_stale: 8,
            interactive_depth: None,
            batch_depth: None,
            fps: 8.0,
        }
    }
}

/// Wire-serving parameters: the TCP gateway that exposes the typed query
/// protocol to remote clients (DESIGN.md §Wire-Protocol).
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Default listen address for `venus serve --listen` (the CLI flag
    /// overrides it; port 0 binds an ephemeral port).
    pub listen: String,
    /// Bounded connection budget: accepts beyond this are answered with a
    /// typed capacity error and closed, never queued.
    pub max_conns: usize,
    /// Per-FRAME read budget in milliseconds: a frame that has not fully
    /// arrived within this window fails its connection.  The budget
    /// spans the whole frame (not each recv), so even a byte-trickling
    /// peer cannot hold a handler or a connection slot forever.
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout in milliseconds.
    pub write_timeout_ms: u64,
    /// Largest accepted/emitted frame payload in bytes; an oversized
    /// length prefix fails that one connection before allocating.
    pub max_frame_bytes: usize,
}

impl Default for WireConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7661".into(),
            max_conns: 64,
            read_timeout_ms: 30_000,
            write_timeout_ms: 10_000,
            max_frame_bytes: 1 << 20,
        }
    }
}

/// Observability parameters: per-query span tracing + slow-query log
/// (DESIGN.md §Observability).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Head-sampling rate: trace every Nth query/ingest batch (1 = every
    /// request, the default — spans are cheap `Instant` pairs).  0
    /// disables tracing entirely; the disabled path allocates nothing.
    pub trace_sample_n: usize,
    /// Queries whose total latency meets or exceeds this many
    /// milliseconds have their span tree retained in the slow-query ring
    /// (0 disables the slow log).
    pub slow_query_ms: u64,
    /// Bounded capacity of the completed-trace ring (oldest evicted).
    pub trace_ring: usize,
    /// Bounded capacity of the slow-query ring (oldest evicted).
    pub slow_ring: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self { trace_sample_n: 1, slow_query_ms: 500, trace_ring: 256, slow_ring: 64 }
    }
}

/// Multi-camera memory-fabric parameters.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Camera streams (= memory shards).  1 reproduces the paper's
    /// single-camera deployment.
    pub streams: usize,
    /// Shared embed-pool worker threads; 0 = auto
    /// (`min(streams, available cores)`).
    pub pool_workers: usize,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self { streams: 1, pool_workers: 0 }
    }
}

impl FabricConfig {
    /// Resolve `pool_workers = 0` to the auto heuristic: one worker per
    /// stream, capped at the host's cores — more workers than streams
    /// can't help (each stream produces one partition at a time), more
    /// than cores just contend.
    pub fn resolved_pool_workers(&self) -> usize {
        if self.pool_workers > 0 {
            return self.pool_workers;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2);
        self.streams.min(cores).max(1)
    }
}

/// Top-level Venus configuration.
#[derive(Clone, Debug, Default)]
pub struct VenusConfig {
    pub ingest: IngestConfig,
    pub retrieval: RetrievalConfig,
    pub memory: MemoryConfig,
    pub net: NetConfig,
    pub cloud: CloudConfig,
    pub server: ServerConfig,
    pub api: ApiConfig,
    pub wire: WireConfig,
    pub obs: ObsConfig,
    pub fabric: FabricConfig,
    /// Edge device profile name (see `edge::DeviceProfile`).
    pub device: String,
}

impl VenusConfig {
    /// Parse from TOML text; unknown keys are rejected (typo safety).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = Self::default();

        for key in doc.keys() {
            if !KNOWN_KEYS.contains(&key) {
                bail!("unknown config key '{key}'");
            }
        }

        let d = &doc;
        cfg.ingest.scene_threshold = d.f64_or("ingest.scene_threshold", cfg.ingest.scene_threshold as f64)? as f32;
        cfg.ingest.max_partition_s = d.f64_or("ingest.max_partition_s", cfg.ingest.max_partition_s)?;
        cfg.ingest.min_scene_frames = d.usize_or("ingest.min_scene_frames", cfg.ingest.min_scene_frames as usize)? as u64;
        cfg.ingest.cluster_threshold = d.f64_or("ingest.cluster_threshold", cfg.ingest.cluster_threshold as f64)? as f32;
        cfg.ingest.embed_batch = d.usize_or("ingest.embed_batch", cfg.ingest.embed_batch)?;
        cfg.ingest.queue_capacity = d.usize_or("ingest.queue_capacity", cfg.ingest.queue_capacity)?;
        cfg.ingest.aux_models = d.bool_or("ingest.aux_models", cfg.ingest.aux_models)?;
        cfg.ingest.drop_policy = d.str_or("ingest.drop_policy", &cfg.ingest.drop_policy)?;
        cfg.ingest.staleness_bound_ms =
            d.usize_or("ingest.staleness_bound_ms", cfg.ingest.staleness_bound_ms as usize)? as u64;
        cfg.ingest.slowdown_ms =
            d.usize_or("ingest.slowdown_ms", cfg.ingest.slowdown_ms as usize)? as u64;
        cfg.ingest.max_batch_frames =
            d.usize_or("ingest.max_batch_frames", cfg.ingest.max_batch_frames)?;
        cfg.ingest.yield_queue_depth =
            d.usize_or("ingest.yield_queue_depth", cfg.ingest.yield_queue_depth)?;

        cfg.retrieval.tau = d.f64_or("retrieval.tau", cfg.retrieval.tau as f64)? as f32;
        cfg.retrieval.budget = d.usize_or("retrieval.budget", cfg.retrieval.budget)?;
        cfg.retrieval.akr = d.bool_or("retrieval.akr", cfg.retrieval.akr)?;
        cfg.retrieval.theta = d.f64_or("retrieval.theta", cfg.retrieval.theta)?;
        cfg.retrieval.beta = d.f64_or("retrieval.beta", cfg.retrieval.beta)?;
        cfg.retrieval.n_max = d.usize_or("retrieval.n_max", cfg.retrieval.n_max)?;
        cfg.retrieval.shortlist = d.usize_or("retrieval.shortlist", cfg.retrieval.shortlist)?;

        cfg.memory.index = d.str_or("memory.index", &cfg.memory.index)?;
        cfg.memory.ivf_nlist = d.usize_or("memory.ivf_nlist", cfg.memory.ivf_nlist)?;
        cfg.memory.ivf_nprobe = d.usize_or("memory.ivf_nprobe", cfg.memory.ivf_nprobe)?;
        cfg.memory.segment_frames = d.usize_or("memory.segment_frames", cfg.memory.segment_frames)?;
        cfg.memory.segment_records =
            d.usize_or("memory.segment_records", cfg.memory.segment_records)?;
        cfg.memory.hot_budget_bytes =
            d.usize_or("memory.hot_budget_bytes", cfg.memory.hot_budget_bytes)?;
        cfg.memory.cold_cache_segments =
            d.usize_or("memory.cold_cache_segments", cfg.memory.cold_cache_segments)?;
        cfg.memory.quantization = d.str_or("memory.quantization", &cfg.memory.quantization)?;
        cfg.memory.coarse_nprobe = d.usize_or("memory.coarse_nprobe", cfg.memory.coarse_nprobe)?;
        cfg.memory.coarse_centroids_per_segment = d.usize_or(
            "memory.coarse_centroids_per_segment",
            cfg.memory.coarse_centroids_per_segment,
        )?;

        cfg.net.bandwidth_mbps = d.f64_or("net.bandwidth_mbps", cfg.net.bandwidth_mbps)?;
        cfg.net.rtt_ms = d.f64_or("net.rtt_ms", cfg.net.rtt_ms)?;
        cfg.net.frame_kb = d.f64_or("net.frame_kb", cfg.net.frame_kb)?;

        cfg.cloud.vlm = d.str_or("cloud.vlm", &cfg.cloud.vlm)?;
        cfg.cloud.tokens_per_frame = d.usize_or("cloud.tokens_per_frame", cfg.cloud.tokens_per_frame)?;
        cfg.cloud.prefill_tps = d.f64_or("cloud.prefill_tps", cfg.cloud.prefill_tps)?;
        cfg.cloud.decode_tps = d.f64_or("cloud.decode_tps", cfg.cloud.decode_tps)?;
        cfg.cloud.answer_tokens = d.usize_or("cloud.answer_tokens", cfg.cloud.answer_tokens)?;
        cfg.cloud.overhead_s = d.f64_or("cloud.overhead_s", cfg.cloud.overhead_s)?;

        cfg.server.queue_depth = d.usize_or("server.queue_depth", cfg.server.queue_depth)?;
        cfg.server.workers = d.usize_or("server.workers", cfg.server.workers)?;
        cfg.server.score_workers =
            d.usize_or("server.score_workers", cfg.server.score_workers)?;

        cfg.api.cache_entries = d.usize_or("api.cache_entries", cfg.api.cache_entries)?;
        cfg.api.cache_threshold = d.f64_or("api.cache_threshold", cfg.api.cache_threshold)?;
        cfg.api.cache_max_stale =
            d.usize_or("api.cache_max_stale", cfg.api.cache_max_stale as usize)? as u64;
        // lane depths stay None unless explicitly set — resolution against
        // the legacy `server.queue_depth` happens in `lane_depths`, so it
        // applies to programmatically built configs too
        if d.get("api.interactive_depth").is_some() {
            cfg.api.interactive_depth = Some(d.usize_or("api.interactive_depth", 0)?);
        }
        if d.get("api.batch_depth").is_some() {
            cfg.api.batch_depth = Some(d.usize_or("api.batch_depth", 0)?);
        }
        cfg.api.fps = d.f64_or("api.fps", cfg.api.fps)?;

        cfg.wire.listen = d.str_or("wire.listen", &cfg.wire.listen)?;
        cfg.wire.max_conns = d.usize_or("wire.max_conns", cfg.wire.max_conns)?;
        cfg.wire.read_timeout_ms =
            d.usize_or("wire.read_timeout_ms", cfg.wire.read_timeout_ms as usize)? as u64;
        cfg.wire.write_timeout_ms =
            d.usize_or("wire.write_timeout_ms", cfg.wire.write_timeout_ms as usize)? as u64;
        cfg.wire.max_frame_bytes =
            d.usize_or("wire.max_frame_bytes", cfg.wire.max_frame_bytes)?;

        cfg.obs.trace_sample_n =
            d.usize_or("obs.trace_sample_n", cfg.obs.trace_sample_n)?;
        cfg.obs.slow_query_ms =
            d.usize_or("obs.slow_query_ms", cfg.obs.slow_query_ms as usize)? as u64;
        cfg.obs.trace_ring = d.usize_or("obs.trace_ring", cfg.obs.trace_ring)?;
        cfg.obs.slow_ring = d.usize_or("obs.slow_ring", cfg.obs.slow_ring)?;

        cfg.fabric.streams = d.usize_or("fabric.streams", cfg.fabric.streams)?;
        cfg.fabric.pool_workers =
            d.usize_or("fabric.pool_workers", cfg.fabric.pool_workers)?;

        cfg.device = d.str_or("device", &Self::default().device_or_default())?;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Resolved (interactive, batch) admission-lane depths: an explicit
    /// `[api]` depth wins; otherwise the legacy single-queue
    /// `server.queue_depth` applies — including for configs built in
    /// code, not just ones parsed from TOML.
    pub fn lane_depths(&self) -> (usize, usize) {
        (
            self.api.interactive_depth.unwrap_or(self.server.queue_depth),
            self.api.batch_depth.unwrap_or(self.server.queue_depth),
        )
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    fn device_or_default(&self) -> String {
        if self.device.is_empty() {
            "agx-orin".to_string()
        } else {
            self.device.clone()
        }
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&(self.ingest.scene_threshold as f64)) {
            bail!("ingest.scene_threshold must be in (0,1)");
        }
        if self.ingest.cluster_threshold <= 0.0 {
            bail!("ingest.cluster_threshold must be positive");
        }
        if self.ingest.drop_policy != "slowdown" && self.ingest.drop_policy != "drop" {
            bail!("ingest.drop_policy must be 'slowdown' or 'drop'");
        }
        if self.ingest.staleness_bound_ms == 0 {
            bail!("ingest.staleness_bound_ms must be >= 1");
        }
        if self.ingest.slowdown_ms == 0 {
            bail!("ingest.slowdown_ms must be >= 1");
        }
        if self.ingest.max_batch_frames == 0 {
            bail!("ingest.max_batch_frames must be >= 1");
        }
        if self.retrieval.tau <= 0.0 {
            bail!("retrieval.tau must be positive");
        }
        if !(0.0..=1.0).contains(&self.retrieval.theta) {
            bail!("retrieval.theta must be in [0,1]");
        }
        if self.retrieval.beta < 1.0 {
            bail!("retrieval.beta must be >= 1");
        }
        if self.retrieval.budget == 0 || self.retrieval.n_max == 0 {
            bail!("retrieval budget / n_max must be positive");
        }
        if self.memory.index != "flat" && self.memory.index != "ivf" {
            bail!("memory.index must be 'flat' or 'ivf'");
        }
        if self.memory.segment_records == 0 || self.memory.segment_frames == 0 {
            bail!("memory.segment_records / segment_frames must be >= 1");
        }
        if self.memory.cold_cache_segments == 0 {
            bail!("memory.cold_cache_segments must be >= 1");
        }
        if self.memory.quantization != "none" && self.memory.quantization != "sq8" {
            bail!("memory.quantization must be 'none' or 'sq8'");
        }
        if self.memory.coarse_nprobe > 0 && self.memory.coarse_centroids_per_segment == 0 {
            bail!(
                "memory.coarse_nprobe > 0 needs memory.coarse_centroids_per_segment >= 1 \
                 (segments sealed without centroids are never pruned)"
            );
        }
        if self.net.bandwidth_mbps <= 0.0 || self.net.frame_kb <= 0.0 {
            bail!("net parameters must be positive");
        }
        if self.cloud.prefill_tps <= 0.0 || self.cloud.decode_tps <= 0.0 {
            bail!("cloud throughputs must be positive");
        }
        if self.server.workers == 0 {
            bail!("server.workers must be >= 1");
        }
        if !(-1.0..=1.0).contains(&self.api.cache_threshold) {
            bail!("api.cache_threshold must be a cosine similarity in [-1,1]");
        }
        let (interactive, batch) = self.lane_depths();
        if interactive == 0 || batch == 0 {
            bail!("lane depths (api.*_depth / server.queue_depth) must be >= 1");
        }
        if self.api.fps <= 0.0 {
            bail!("api.fps must be positive");
        }
        if self.wire.listen.is_empty() {
            bail!("wire.listen must be a host:port address");
        }
        if self.wire.max_conns == 0 {
            bail!("wire.max_conns must be >= 1");
        }
        if self.wire.read_timeout_ms == 0 || self.wire.write_timeout_ms == 0 {
            bail!("wire read/write timeouts must be >= 1 ms");
        }
        if self.wire.max_frame_bytes < 1024 {
            bail!("wire.max_frame_bytes must be >= 1024 (a QueryRequest must fit)");
        }
        if self.obs.trace_sample_n > 0 && (self.obs.trace_ring == 0 || self.obs.slow_ring == 0) {
            bail!("obs.trace_ring / obs.slow_ring must be >= 1 while tracing is enabled");
        }
        if self.fabric.streams == 0 {
            bail!("fabric.streams must be >= 1");
        }
        if self.fabric.streams > u16::MAX as usize {
            bail!("fabric.streams must fit a StreamId (<= {})", u16::MAX);
        }
        Ok(())
    }
}

/// Accepted config keys (typo guard).
const KNOWN_KEYS: &[&str] = &[
    "ingest.scene_threshold",
    "ingest.max_partition_s",
    "ingest.min_scene_frames",
    "ingest.cluster_threshold",
    "ingest.embed_batch",
    "ingest.queue_capacity",
    "ingest.aux_models",
    "ingest.drop_policy",
    "ingest.staleness_bound_ms",
    "ingest.slowdown_ms",
    "ingest.max_batch_frames",
    "ingest.yield_queue_depth",
    "retrieval.tau",
    "retrieval.budget",
    "retrieval.akr",
    "retrieval.theta",
    "retrieval.beta",
    "retrieval.n_max",
    "retrieval.shortlist",
    "memory.index",
    "memory.ivf_nlist",
    "memory.ivf_nprobe",
    "memory.segment_frames",
    "memory.segment_records",
    "memory.hot_budget_bytes",
    "memory.cold_cache_segments",
    "memory.quantization",
    "memory.coarse_nprobe",
    "memory.coarse_centroids_per_segment",
    "net.bandwidth_mbps",
    "net.rtt_ms",
    "net.frame_kb",
    "cloud.vlm",
    "cloud.tokens_per_frame",
    "cloud.prefill_tps",
    "cloud.decode_tps",
    "cloud.answer_tokens",
    "cloud.overhead_s",
    "server.queue_depth",
    "server.score_workers",
    "server.workers",
    "api.cache_entries",
    "api.cache_threshold",
    "api.cache_max_stale",
    "api.interactive_depth",
    "api.batch_depth",
    "api.fps",
    "wire.listen",
    "wire.max_conns",
    "wire.read_timeout_ms",
    "wire.write_timeout_ms",
    "wire.max_frame_bytes",
    "obs.trace_sample_n",
    "obs.slow_query_ms",
    "obs.trace_ring",
    "obs.slow_ring",
    "fabric.streams",
    "fabric.pool_workers",
    "device",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        let mut cfg = VenusConfig::default();
        cfg.device = "agx-orin".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let cfg = VenusConfig::from_toml(
            r#"
            device = "jetson-tx2"
            [retrieval]
            tau = 0.1
            akr = false
            budget = 16
            [net]
            bandwidth_mbps = 50.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.device, "jetson-tx2");
        assert_eq!(cfg.retrieval.tau, 0.1);
        assert!(!cfg.retrieval.akr);
        assert_eq!(cfg.retrieval.budget, 16);
        assert_eq!(cfg.net.bandwidth_mbps, 50.0);
        // untouched defaults survive
        assert_eq!(cfg.cloud.tokens_per_frame, 196);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(VenusConfig::from_toml("[retrieval]\ntypo_key = 1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(VenusConfig::from_toml("[retrieval]\ntau = -1.0").is_err());
        assert!(VenusConfig::from_toml("[retrieval]\ntheta = 1.5").is_err());
        assert!(VenusConfig::from_toml("[memory]\nindex = \"hnsw\"").is_err());
        assert!(VenusConfig::from_toml("[server]\nworkers = 0").is_err());
        assert!(VenusConfig::from_toml("[fabric]\nstreams = 0").is_err());
    }

    #[test]
    fn api_keys_parse_validate_and_inherit_queue_depth() {
        let cfg = VenusConfig::from_toml(
            "[api]\ncache_entries = 16\ncache_threshold = 0.8\ncache_max_stale = 3\nfps = 4.0",
        )
        .unwrap();
        assert_eq!(cfg.api.cache_entries, 16);
        assert_eq!(cfg.api.cache_threshold, 0.8);
        assert_eq!(cfg.api.cache_max_stale, 3);
        assert_eq!(cfg.api.fps, 4.0);
        // lane depths inherit the legacy single-queue depth unless set
        let cfg = VenusConfig::from_toml("[server]\nqueue_depth = 5").unwrap();
        assert_eq!(cfg.lane_depths(), (5, 5));
        let cfg =
            VenusConfig::from_toml("[server]\nqueue_depth = 5\n[api]\nbatch_depth = 9").unwrap();
        assert_eq!(cfg.lane_depths(), (5, 9));
        // ...and the inheritance works for configs built in code too
        let mut cfg = VenusConfig::default();
        cfg.server.queue_depth = 2;
        assert_eq!(cfg.lane_depths(), (2, 2));
        cfg.api.interactive_depth = Some(7);
        assert_eq!(cfg.lane_depths(), (7, 2));
        // invalid values rejected
        assert!(VenusConfig::from_toml("[api]\ncache_threshold = 1.5").is_err());
        assert!(VenusConfig::from_toml("[api]\ninteractive_depth = 0").is_err());
        assert!(VenusConfig::from_toml("[server]\nqueue_depth = 0").is_err());
        assert!(VenusConfig::from_toml("[api]\nfps = 0.0").is_err());
    }

    #[test]
    fn score_workers_parses_and_resolves() {
        // explicit value is used verbatim
        let cfg = VenusConfig::from_toml("[server]\nscore_workers = 3").unwrap();
        assert_eq!(cfg.server.score_workers, 3);
        assert_eq!(cfg.server.resolved_score_workers(), 3);
        // default 0 = auto: resolves to the host's parallelism, >= 1
        let cfg = VenusConfig::default();
        assert_eq!(cfg.server.score_workers, 0);
        assert!(cfg.server.resolved_score_workers() >= 1);
        // an unknown sibling key is still caught by the typo guard
        assert!(VenusConfig::from_toml("[server]\nscore_wrokers = 3").is_err());
    }

    #[test]
    fn memory_tier_keys_parse_and_validate() {
        let cfg = VenusConfig::from_toml(
            "[memory]\nsegment_records = 64\nhot_budget_bytes = 1048576\ncold_cache_segments = 2",
        )
        .unwrap();
        assert_eq!(cfg.memory.segment_records, 64);
        assert_eq!(cfg.memory.hot_budget_bytes, 1_048_576);
        assert_eq!(cfg.memory.cold_cache_segments, 2);
        // defaults: unbounded hot tier, 256-record segments
        let cfg = VenusConfig::default();
        assert_eq!(cfg.memory.hot_budget_bytes, 0);
        assert_eq!(cfg.memory.segment_records, 256);
        // invalid values rejected
        assert!(VenusConfig::from_toml("[memory]\nsegment_records = 0").is_err());
        assert!(VenusConfig::from_toml("[memory]\ncold_cache_segments = 0").is_err());
        assert!(VenusConfig::from_toml("[memory]\nsegment_frames = 0").is_err());
    }

    #[test]
    fn quantization_and_coarse_keys_parse_and_validate() {
        let cfg = VenusConfig::from_toml(
            "[memory]\nquantization = \"sq8\"\ncoarse_nprobe = 4\ncoarse_centroids_per_segment = 8",
        )
        .unwrap();
        assert_eq!(cfg.memory.quantization, "sq8");
        assert_eq!(cfg.memory.coarse_nprobe, 4);
        assert_eq!(cfg.memory.coarse_centroids_per_segment, 8);
        // defaults: exact mode, no coarse index
        let cfg = VenusConfig::default();
        assert_eq!(cfg.memory.quantization, "none");
        assert_eq!(cfg.memory.coarse_nprobe, 0);
        assert_eq!(cfg.memory.coarse_centroids_per_segment, 0);
        // invalid: unknown scheme, probing without centroids
        assert!(VenusConfig::from_toml("[memory]\nquantization = \"pq\"").is_err());
        assert!(VenusConfig::from_toml("[memory]\ncoarse_nprobe = 2").is_err());
    }

    #[test]
    fn wire_keys_parse_and_validate() {
        let cfg = VenusConfig::from_toml(
            "[wire]\nlisten = \"0.0.0.0:9000\"\nmax_conns = 8\nread_timeout_ms = 5000\nmax_frame_bytes = 4096",
        )
        .unwrap();
        assert_eq!(cfg.wire.listen, "0.0.0.0:9000");
        assert_eq!(cfg.wire.max_conns, 8);
        assert_eq!(cfg.wire.read_timeout_ms, 5000);
        assert_eq!(cfg.wire.max_frame_bytes, 4096);
        // untouched defaults survive
        assert_eq!(cfg.wire.write_timeout_ms, 10_000);
        // invalid values rejected
        assert!(VenusConfig::from_toml("[wire]\nmax_conns = 0").is_err());
        assert!(VenusConfig::from_toml("[wire]\nread_timeout_ms = 0").is_err());
        assert!(VenusConfig::from_toml("[wire]\nmax_frame_bytes = 16").is_err());
        assert!(VenusConfig::from_toml("[wire]\nlisten = \"\"").is_err());
    }

    #[test]
    fn ingest_wire_keys_parse_and_validate() {
        let cfg = VenusConfig::from_toml(
            "[ingest]\ndrop_policy = \"drop\"\nstaleness_bound_ms = 1500\nslowdown_ms = 40\n\
             max_batch_frames = 16\nyield_queue_depth = 4",
        )
        .unwrap();
        assert_eq!(cfg.ingest.drop_policy, "drop");
        assert_eq!(cfg.ingest.staleness_bound_ms, 1500);
        assert_eq!(cfg.ingest.slowdown_ms, 40);
        assert_eq!(cfg.ingest.max_batch_frames, 16);
        assert_eq!(cfg.ingest.yield_queue_depth, 4);
        // defaults: pace down rather than shed, generous bound
        let cfg = VenusConfig::default();
        assert_eq!(cfg.ingest.drop_policy, "slowdown");
        assert_eq!(cfg.ingest.staleness_bound_ms, 5_000);
        // invalid values rejected
        assert!(VenusConfig::from_toml("[ingest]\ndrop_policy = \"panic\"").is_err());
        assert!(VenusConfig::from_toml("[ingest]\nstaleness_bound_ms = 0").is_err());
        assert!(VenusConfig::from_toml("[ingest]\nslowdown_ms = 0").is_err());
        assert!(VenusConfig::from_toml("[ingest]\nmax_batch_frames = 0").is_err());
    }

    #[test]
    fn obs_keys_parse_and_validate() {
        let cfg = VenusConfig::from_toml(
            "[obs]\ntrace_sample_n = 4\nslow_query_ms = 250\ntrace_ring = 32\nslow_ring = 8",
        )
        .unwrap();
        assert_eq!(cfg.obs.trace_sample_n, 4);
        assert_eq!(cfg.obs.slow_query_ms, 250);
        assert_eq!(cfg.obs.trace_ring, 32);
        assert_eq!(cfg.obs.slow_ring, 8);
        // defaults: trace everything, 500 ms slow bar
        let cfg = VenusConfig::default();
        assert_eq!(cfg.obs.trace_sample_n, 1);
        assert_eq!(cfg.obs.slow_query_ms, 500);
        // sampling off is valid even with zero rings; on requires capacity
        assert!(VenusConfig::from_toml("[obs]\ntrace_sample_n = 0\ntrace_ring = 0").is_ok());
        assert!(VenusConfig::from_toml("[obs]\ntrace_ring = 0").is_err());
        assert!(VenusConfig::from_toml("[obs]\nslow_ring = 0").is_err());
    }

    #[test]
    fn fabric_keys_parse_and_resolve() {
        let cfg = VenusConfig::from_toml("[fabric]\nstreams = 4\npool_workers = 3").unwrap();
        assert_eq!(cfg.fabric.streams, 4);
        assert_eq!(cfg.fabric.resolved_pool_workers(), 3);
        // auto sizing never exceeds the stream count and never hits zero
        let auto = FabricConfig { streams: 4, pool_workers: 0 };
        let n = auto.resolved_pool_workers();
        assert!((1..=4).contains(&n), "auto pool workers {n}");
        assert_eq!(FabricConfig::default().resolved_pool_workers(), 1);
    }
}
