//! Minimal TOML parser (serde/toml crates unavailable offline).
//!
//! Supports the subset used by Venus config files: `[section]` and
//! `[section.sub]` tables, `key = value` with strings, integers, floats,
//! booleans, and homogeneous inline arrays, plus `#` comments.  Keys are
//! flattened to dotted paths (`section.sub.key`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed TOML scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(x) => Ok(*x),
            TomlValue::Int(x) => Ok(*x as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(x) => Ok(*x),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        if v < 0 {
            bail!("expected non-negative integer, got {v}");
        }
        Ok(v as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flattened TOML document: dotted path → value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = Self::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}: '{}'", lineno + 1, raw.trim());
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .with_context(|| format!("unterminated table header, {}", ctx()))?
                    .trim();
                if name.is_empty() {
                    bail!("empty table name, {}", ctx());
                }
                section = name.to_string();
            } else {
                let (key, value) = line
                    .split_once('=')
                    .with_context(|| format!("expected key = value, {}", ctx()))?;
                let key = key.trim();
                if key.is_empty() {
                    bail!("empty key, {}", ctx());
                }
                let path = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                let parsed = parse_value(value.trim())
                    .with_context(|| format!("bad value, {}", ctx()))?;
                if doc.values.insert(path.clone(), parsed).is_some() {
                    bail!("duplicate key '{path}', {}", ctx());
                }
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    /// Typed getters with defaults.
    pub fn f64_or(&self, path: &str, default: f64) -> Result<f64> {
        self.values.get(path).map_or(Ok(default), |v| {
            v.as_f64().with_context(|| format!("key '{path}'"))
        })
    }

    pub fn usize_or(&self, path: &str, default: usize) -> Result<usize> {
        self.values.get(path).map_or(Ok(default), |v| {
            v.as_usize().with_context(|| format!("key '{path}'"))
        })
    }

    pub fn bool_or(&self, path: &str, default: bool) -> Result<bool> {
        self.values.get(path).map_or(Ok(default), |v| {
            v.as_bool().with_context(|| format!("key '{path}'"))
        })
    }

    pub fn str_or(&self, path: &str, default: &str) -> Result<String> {
        self.values.get(path).map_or(Ok(default.to_string()), |v| {
            Ok(v.as_str().with_context(|| format!("key '{path}'"))?.to_string())
        })
    }

    /// All keys under a dotted prefix (for unknown-key validation).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .context("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("bad escape '\\{other:?}'"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_array(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(v) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value '{s}'")
}

fn split_array(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        parts.push(&s[start..]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            top = 1
            [server]
            port = 8080          # comment
            name = "edge-cam #1"
            debug = true
            [retrieval.akr]
            theta = 0.9
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_i64().unwrap(), 1);
        assert_eq!(doc.get("server.port").unwrap().as_usize().unwrap(), 8080);
        assert_eq!(doc.get("server.name").unwrap().as_str().unwrap(), "edge-cam #1");
        assert!(doc.get("server.debug").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("retrieval.akr.theta").unwrap().as_f64().unwrap(), 0.9);
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse("w = [1.0, 2.0, 3.5]\nids = [1, 2]").unwrap();
        match doc.get("w").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn defaults_and_typed_getters() {
        let doc = TomlDoc::parse("x = 2").unwrap();
        assert_eq!(doc.f64_or("x", 0.0).unwrap(), 2.0);
        assert_eq!(doc.f64_or("missing", 7.5).unwrap(), 7.5);
        assert!(doc.usize_or("x", 0).unwrap() == 2);
    }

    #[test]
    fn rejects_errors() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("key").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("a = \"unterminated").is_err());
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let doc = TomlDoc::parse("a = -5\nb = 1_000\nc = -2.5e3").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64().unwrap(), -5);
        assert_eq!(doc.get("b").unwrap().as_i64().unwrap(), 1000);
        assert_eq!(doc.get("c").unwrap().as_f64().unwrap(), -2500.0);
    }
}
