//! The Venus coordinator: composes ingestion, the sharded memory fabric,
//! retrieval, the network model, and the cloud VLM client into the
//! deployable two-stage system of Fig. 6.

pub mod query;

pub use query::{EdgeTimings, QueryEngine, QueryOutcome};

use std::sync::Arc;

use anyhow::Result;

use crate::backend::{self, EmbedBackend};
use crate::cloud::VlmClient;
use crate::config::VenusConfig;
use crate::embed::EmbedEngine;
use crate::ingest::{IngestStats, Pipeline};
use crate::memory::raw::RawStore;
use crate::memory::{FrameId, Hierarchy, MemoryFabric};
use crate::net::{Link, Payload};
use crate::util::sync::OrderedRwLock;
use crate::video::frame::Frame;
use crate::video::synth::VideoSynth;

/// End-to-end latency breakdown for one query (Fig. 12's bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyBreakdown {
    /// measured on this host
    pub edge: EdgeTimings,
    /// simulated uplink transfer of the selected frames
    pub upload_s: f64,
    /// simulated cloud VLM inference
    pub vlm_s: f64,
}

impl LatencyBreakdown {
    pub fn total_s(&self) -> f64 {
        self.edge.total_s() + self.upload_s + self.vlm_s
    }
}

/// A fully-assembled Venus instance (single edge node).
pub struct Venus {
    pub cfg: VenusConfig,
    pub fabric: Arc<MemoryFabric>,
    query: QueryEngine,
    pub link: Link,
    pub vlm: VlmClient,
}

impl Venus {
    /// Build a single-stream instance from config + a raw-layer backend.
    pub fn new(cfg: VenusConfig, raw: Box<dyn RawStore>, seed: u64) -> Result<Self> {
        Self::with_raws(cfg, vec![raw], seed)
    }

    /// Build a multi-camera instance: one raw store per stream.  The one
    /// process-shared embed backend serves the d_embed probe, the query
    /// engine, and (via [`Venus::ingest_stream`]) every pipeline — native
    /// construction generates the full weight set, so it must happen once.
    pub fn with_raws(
        cfg: VenusConfig,
        raws: Vec<Box<dyn RawStore>>,
        seed: u64,
    ) -> Result<Self> {
        let be = backend::shared_default()?;
        let d_embed = be.model().d_embed;
        let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d_embed, raws)?);
        let query_engine = QueryEngine::new(
            EmbedEngine::new(be, cfg.ingest.aux_models)?,
            Arc::clone(&fabric),
            cfg.retrieval.clone(),
            seed,
        );
        let link = Link::new(cfg.net.clone());
        let vlm = VlmClient::new(cfg.cloud.clone(), seed ^ 0xc1);
        Ok(Self { cfg, fabric, query: query_engine, link, vlm })
    }

    /// Stream 0's shard — the whole memory in single-camera deployments.
    pub fn memory(&self) -> &Arc<OrderedRwLock<Hierarchy>> {
        &self.fabric.shards()[0]
    }

    /// Ingest an entire synthetic stream into stream 0's shard
    /// (offline/catch-up mode: frames processed as fast as the pipeline
    /// allows).  Returns pipeline stats.
    pub fn ingest_stream(&self, synth: &VideoSynth, upto: u64) -> Result<IngestStats> {
        let engine = EmbedEngine::default_backend(self.cfg.ingest.aux_models)?;
        let mut pipe = Pipeline::new(
            &self.cfg.ingest,
            synth.config().fps,
            engine,
            Arc::clone(self.memory()),
        )?;
        let n = upto.min(synth.total_frames());
        for i in 0..n {
            let frame = synth.frame(i);
            pipe.push_frame(i, &frame)?;
        }
        pipe.finish()
    }

    /// Answer a query end-to-end: edge retrieval (measured) + upload and
    /// VLM inference (simulated models).
    pub fn query(&mut self, text: &str) -> Result<(QueryOutcome, LatencyBreakdown)> {
        let outcome = self.query.retrieve(text)?;
        let upload_s = self.link.round_trip_s(Payload::Frames(outcome.selection.frames.len()));
        let vlm_s = self.vlm.infer_latency_s(
            outcome.selection.frames.len(),
            crate::api::QueryRequest::approx_tokens_for(text),
        );
        let breakdown =
            LatencyBreakdown { edge: outcome.timings, upload_s, vlm_s };
        Ok((outcome, breakdown))
    }

    /// Direct access to the query engine (server workers build their own).
    pub fn query_engine(&mut self) -> &mut QueryEngine {
        &mut self.query
    }

    /// Fetch the selected frames from the raw layer (the payload bytes
    /// that would be shipped).  Missing frames propagate as errors.
    pub fn fetch_frames(&self, ids: &[FrameId]) -> Result<Vec<Frame>> {
        self.fabric.fetch_frames(ids)
    }
}
