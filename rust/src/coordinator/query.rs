//! The query-stage engine (Fig. 6, steps ⑤–⑥): embed the query text,
//! score it against the memory index, and select keyframes via
//! sampling-based retrieval or AKR.  All timings here are *measured*
//! wall-clock on the local host (the honest edge-compute numbers that
//! anchor the paper-scale simulation).
//!
//! Locking: the shared memory is an `RwLock` — the query path is
//! read-only, so concurrent query workers score/select in parallel and
//! ingestion (the lone writer) is only excluded for the narrow windows
//! below.  Query embedding runs before any lock; score+select share ONE
//! read guard (selection must see the same index the scores were computed
//! over, or `scores.len() != memory.len()` races with inserts); the
//! raw-frame fetch takes a fresh guard, since selected frames are already
//! archived and the raw layer is append-only — ingestion can interleave
//! between the two.

use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::Result;

use crate::config::RetrievalConfig;
use crate::embed::EmbedEngine;
use crate::memory::Hierarchy;
use crate::retrieval::{akr_retrieve, sample_retrieve, topk_retrieve, Selection};
use crate::util::rng::Pcg64;

/// Measured edge-side latencies for one query.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeTimings {
    pub embed_query_s: f64,
    pub search_s: f64,
    pub select_s: f64,
    pub fetch_s: f64,
}

impl EdgeTimings {
    pub fn total_s(&self) -> f64 {
        self.embed_query_s + self.search_s + self.select_s + self.fetch_s
    }
}

/// Result of the edge-side query stage.
#[derive(Clone, Debug, Default)]
pub struct QueryOutcome {
    pub selection: Selection,
    pub timings: EdgeTimings,
    /// AKR draws actually used (== selection budget when AKR is off)
    pub draws: usize,
}

/// Retrieval mode (the ablation axis of Fig. 10 / Fig. 11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetrievalMode {
    /// AKR progressive sampling (Eq. 6–7)
    Akr,
    /// fixed-budget sampling (Eq. 5)
    FixedSampling(usize),
    /// greedy Top-K over indexed frames (Vanilla)
    TopK(usize),
}

/// The query engine: owns an embed engine + shares the memory.
pub struct QueryEngine {
    engine: EmbedEngine,
    memory: Arc<RwLock<Hierarchy>>,
    cfg: RetrievalConfig,
    rng: Pcg64,
    scores_buf: Vec<f32>,
}

impl QueryEngine {
    pub fn new(
        engine: EmbedEngine,
        memory: Arc<RwLock<Hierarchy>>,
        cfg: RetrievalConfig,
        seed: u64,
    ) -> Self {
        Self {
            engine,
            memory,
            cfg,
            rng: Pcg64::new(seed, 0x9e4),
            scores_buf: Vec::new(),
        }
    }

    pub fn config(&self) -> &RetrievalConfig {
        &self.cfg
    }

    pub fn set_config(&mut self, cfg: RetrievalConfig) {
        self.cfg = cfg;
    }

    /// Default mode from config.
    fn default_mode(&self) -> RetrievalMode {
        if self.cfg.akr {
            RetrievalMode::Akr
        } else {
            RetrievalMode::FixedSampling(self.cfg.budget)
        }
    }

    /// Run the full query stage with the configured mode.
    pub fn retrieve(&mut self, text: &str) -> Result<QueryOutcome> {
        self.retrieve_with(text, self.default_mode())
    }

    /// Run the query stage with an explicit retrieval mode.
    pub fn retrieve_with(&mut self, text: &str, mode: RetrievalMode) -> Result<QueryOutcome> {
        let mut t = EdgeTimings::default();

        // query embedding: pure compute, no lock held
        let t0 = Instant::now();
        let qvec = self.engine.embed_query(text)?;
        t.embed_query_s = t0.elapsed().as_secs_f64();

        // score + select under ONE read guard: the sampler needs scores
        // consistent with the index it expands clusters from
        let (selection, draws) = {
            let mem = self.memory.read().unwrap();
            let t0 = Instant::now();
            mem.score_all(&qvec, &mut self.scores_buf);
            t.search_s = t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            // bound the sampling distribution to the scored shortlist so the
            // Eq. 5 trade-off is invariant to how long the stream has run
            let masked =
                crate::retrieval::shortlist_mask(&self.scores_buf, self.cfg.shortlist);
            let (selection, draws) = match mode {
                RetrievalMode::Akr => {
                    let out = akr_retrieve(
                        &mem,
                        &masked,
                        self.cfg.tau,
                        self.cfg.theta,
                        self.cfg.beta,
                        self.cfg.n_max,
                        &mut self.rng,
                    );
                    (out.selection, out.draws)
                }
                RetrievalMode::FixedSampling(n) => {
                    let sel = sample_retrieve(&mem, &masked, self.cfg.tau, n, &mut self.rng);
                    (sel, n)
                }
                RetrievalMode::TopK(k) => (topk_retrieve(&mem, &self.scores_buf, k), k),
            };
            t.select_s = t0.elapsed().as_secs_f64();
            (selection, draws)
        };

        // fetch (decode) the selected raw frames — part of the edge path.
        // Fresh guard: the ids are already archived, so the ingestion
        // writer may interleave between selection and fetch.
        let t0 = Instant::now();
        {
            let mem = self.memory.read().unwrap();
            for &f in &selection.frames {
                std::hint::black_box(mem.fetch_frame(f));
            }
        }
        t.fetch_s = t0.elapsed().as_secs_f64();

        Ok(QueryOutcome { selection, timings: t, draws })
    }

    /// Raw similarity scores for the given query (diagnostics / benches).
    pub fn score_query(&mut self, text: &str) -> Result<Vec<f32>> {
        let qvec = self.engine.embed_query(text)?;
        let mem = self.memory.read().unwrap();
        let mut scores = Vec::new();
        mem.score_all(&qvec, &mut scores);
        Ok(scores)
    }

    /// Measured mean text-embedding latency so far.
    pub fn measured_text_embed_s(&self) -> f64 {
        self.engine.measured_text_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::{ClusterRecord, InMemoryRaw};
    use crate::video::frame::Frame;

    /// Ingest-while-query smoke test for the RwLock'd memory: a writer
    /// thread keeps archiving + inserting while this thread runs the full
    /// query stage.  Every retrieval must succeed, reference only archived
    /// frames, and never deadlock.
    #[test]
    fn queries_run_while_writer_inserts() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let memory = Arc::new(RwLock::new(
            Hierarchy::new(&MemoryConfig::default(), d, Box::new(InMemoryRaw::new(8)))
                .unwrap(),
        ));

        let writer_mem = Arc::clone(&memory);
        let writer = std::thread::spawn(move || {
            let mut rng = Pcg64::seeded(7);
            for c in 0..60u64 {
                let mut mem = writer_mem.write().unwrap();
                for f in c * 4..(c + 1) * 4 {
                    mem.archive_frame(f, &Frame::filled(8, [0.5; 3]));
                }
                let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                crate::util::l2_normalize(&mut v);
                mem.insert(
                    &v,
                    ClusterRecord {
                        scene_id: c as usize,
                        centroid_frame: c * 4,
                        members: (c * 4..(c + 1) * 4).collect(),
                    },
                )
                .unwrap();
                drop(mem);
                std::thread::yield_now();
            }
        });

        let mut qe = QueryEngine::new(
            EmbedEngine::default_backend(false).unwrap(),
            Arc::clone(&memory),
            RetrievalConfig::default(),
            3,
        );
        for i in 0..20 {
            let mode = if i % 2 == 0 {
                RetrievalMode::Akr
            } else {
                RetrievalMode::FixedSampling(4)
            };
            let out = qe
                .retrieve_with("what happened with concept01", mode)
                .unwrap();
            let archived = memory.read().unwrap().frames_ingested();
            assert!(
                out.selection.frames.iter().all(|&f| f < archived),
                "selection referenced an unarchived frame"
            );
        }
        writer.join().unwrap();
        memory.read().unwrap().check_invariants().unwrap();
        // with the writer drained, the index is fully visible to queries
        let out = qe
            .retrieve_with("what happened with concept01", RetrievalMode::FixedSampling(8))
            .unwrap();
        assert!(
            !out.selection.frames.is_empty(),
            "query after ingest must select from the 60-cluster index"
        );
    }
}
