//! The query-stage engine (Fig. 6, steps ⑤–⑥): embed the query text,
//! score it against the memory index, and select keyframes via
//! sampling-based retrieval or AKR.  All timings here are *measured*
//! wall-clock on the local host (the honest edge-compute numbers that
//! anchor the paper-scale simulation).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::RetrievalConfig;
use crate::embed::EmbedEngine;
use crate::memory::Hierarchy;
use crate::retrieval::{akr_retrieve, sample_retrieve, topk_retrieve, Selection};
use crate::util::rng::Pcg64;

/// Measured edge-side latencies for one query.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeTimings {
    pub embed_query_s: f64,
    pub search_s: f64,
    pub select_s: f64,
    pub fetch_s: f64,
}

impl EdgeTimings {
    pub fn total_s(&self) -> f64 {
        self.embed_query_s + self.search_s + self.select_s + self.fetch_s
    }
}

/// Result of the edge-side query stage.
#[derive(Clone, Debug, Default)]
pub struct QueryOutcome {
    pub selection: Selection,
    pub timings: EdgeTimings,
    /// AKR draws actually used (== selection budget when AKR is off)
    pub draws: usize,
}

/// Retrieval mode (the ablation axis of Fig. 10 / Fig. 11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetrievalMode {
    /// AKR progressive sampling (Eq. 6–7)
    Akr,
    /// fixed-budget sampling (Eq. 5)
    FixedSampling(usize),
    /// greedy Top-K over indexed frames (Vanilla)
    TopK(usize),
}

/// The query engine: owns a PJRT embed engine + shares the memory.
pub struct QueryEngine {
    engine: EmbedEngine,
    memory: Arc<Mutex<Hierarchy>>,
    cfg: RetrievalConfig,
    rng: Pcg64,
    scores_buf: Vec<f32>,
}

impl QueryEngine {
    pub fn new(
        engine: EmbedEngine,
        memory: Arc<Mutex<Hierarchy>>,
        cfg: RetrievalConfig,
        seed: u64,
    ) -> Self {
        Self {
            engine,
            memory,
            cfg,
            rng: Pcg64::new(seed, 0x9e4),
            scores_buf: Vec::new(),
        }
    }

    pub fn config(&self) -> &RetrievalConfig {
        &self.cfg
    }

    pub fn set_config(&mut self, cfg: RetrievalConfig) {
        self.cfg = cfg;
    }

    /// Default mode from config.
    fn default_mode(&self) -> RetrievalMode {
        if self.cfg.akr {
            RetrievalMode::Akr
        } else {
            RetrievalMode::FixedSampling(self.cfg.budget)
        }
    }

    /// Run the full query stage with the configured mode.
    pub fn retrieve(&mut self, text: &str) -> Result<QueryOutcome> {
        self.retrieve_with(text, self.default_mode())
    }

    /// Run the query stage with an explicit retrieval mode.
    pub fn retrieve_with(&mut self, text: &str, mode: RetrievalMode) -> Result<QueryOutcome> {
        let mut t = EdgeTimings::default();

        let t0 = Instant::now();
        let qvec = self.engine.embed_query(text)?;
        t.embed_query_s = t0.elapsed().as_secs_f64();

        let mem = self.memory.lock().unwrap();
        let t0 = Instant::now();
        mem.score_all(&qvec, &mut self.scores_buf);
        t.search_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        // bound the sampling distribution to the scored shortlist so the
        // Eq. 5 trade-off is invariant to how long the stream has run
        let masked =
            crate::retrieval::shortlist_mask(&self.scores_buf, self.cfg.shortlist);
        let (selection, draws) = match mode {
            RetrievalMode::Akr => {
                let out = akr_retrieve(
                    &mem,
                    &masked,
                    self.cfg.tau,
                    self.cfg.theta,
                    self.cfg.beta,
                    self.cfg.n_max,
                    &mut self.rng,
                );
                (out.selection, out.draws)
            }
            RetrievalMode::FixedSampling(n) => {
                let sel = sample_retrieve(&mem, &masked, self.cfg.tau, n, &mut self.rng);
                (sel, n)
            }
            RetrievalMode::TopK(k) => (topk_retrieve(&mem, &self.scores_buf, k), k),
        };
        t.select_s = t0.elapsed().as_secs_f64();

        // fetch (decode) the selected raw frames — part of the edge path
        let t0 = Instant::now();
        for &f in &selection.frames {
            std::hint::black_box(mem.fetch_frame(f));
        }
        t.fetch_s = t0.elapsed().as_secs_f64();
        drop(mem);

        Ok(QueryOutcome { selection, timings: t, draws })
    }

    /// Raw similarity scores for the given query (diagnostics / benches).
    pub fn score_query(&mut self, text: &str) -> Result<Vec<f32>> {
        let qvec = self.engine.embed_query(text)?;
        let mem = self.memory.lock().unwrap();
        let mut scores = Vec::new();
        mem.score_all(&qvec, &mut scores);
        Ok(scores)
    }

    /// Measured mean text-embedding latency so far.
    pub fn measured_text_embed_s(&self) -> f64 {
        self.engine.measured_text_s()
    }
}
