//! The query-stage engine (Fig. 6, steps ⑤–⑥): embed the query text,
//! score it against the memory fabric, and select keyframes via
//! sampling-based retrieval or AKR.  All timings here are *measured*
//! wall-clock on the local host (the honest edge-compute numbers that
//! anchor the paper-scale simulation).
//!
//! Stream scoping: a query runs against [`StreamScope::One`] shard or
//! scatter-gathers over [`StreamScope::All`].  The `All` path concatenates
//! every shard's Eq. 4 score vector (shard order), applies the shortlist
//! mask and the Eq. 5 softmax over the *merged* distribution, and runs
//! AKR/sampling over the merged record view — so one answer can cite
//! evidence frames from several cameras, and AKR's adaptive budget
//! reflects total cross-camera evidence concentration.
//!
//! Locking: each shard sits behind its own rank-ordered `OrderedRwLock`
//! (rank `ranks::shard(i)`, ascending by stream) — the query path is
//! read-only, so concurrent query workers score/select in parallel and a
//! stream's ingestion writer only excludes readers *of that stream* for
//! its narrow insert/archive sections.  Query embedding runs before any
//! lock; score+select hold the scoped shards' read guards together
//! (selection must see the same indices the scores were computed over, or
//! `scores.len() != records.len()` races with inserts) — guards are taken
//! in ascending stream order while writers hold at most one shard lock,
//! so no deadlock is possible; the raw-frame fetch takes fresh per-shard
//! guards, since selected frames are already archived and the raw layer
//! is append-only.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::api::cache::{CacheStatus, CachedQuery, QueryCache};
use crate::config::RetrievalConfig;
use crate::embed::EmbedEngine;
use crate::memory::{ClusterRecord, Hierarchy, MemoryFabric, StreamId, StreamScope};
use crate::obs::{stage, TraceCtx};
use crate::retrieval::{akr_retrieve, sample_retrieve, topk_retrieve, RecordSource, Selection};
use crate::util::rng::Pcg64;
use crate::util::scorer::ScorePool;
use crate::util::sync::{OrderedReadGuard, OrderedRwLock};

/// Measured edge-side latencies for one query.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeTimings {
    pub embed_query_s: f64,
    pub search_s: f64,
    pub select_s: f64,
    pub fetch_s: f64,
    /// Query-cache lookup time (exact + semantic tiers).  *Not* part of
    /// [`EdgeTimings::total_s`] — the probe runs before the edge stages
    /// and is reported separately (`latency.cache_probe_ms`).
    pub cache_probe_s: f64,
    /// Pure scoring time inside `search_s`: the pool-attributed hot +
    /// cold task milliseconds when a scoring pool ran the scan, else the
    /// serial scan wall time.  A subset of `search_s`, so also excluded
    /// from [`EdgeTimings::total_s`].
    pub score_s: f64,
}

impl EdgeTimings {
    pub fn total_s(&self) -> f64 {
        self.embed_query_s + self.search_s + self.select_s + self.fetch_s
    }
}

/// Result of the edge-side query stage.
#[derive(Clone, Debug, Default)]
pub struct QueryOutcome {
    pub selection: Selection,
    pub timings: EdgeTimings,
    /// AKR draws actually used (== selection budget when AKR is off)
    pub draws: usize,
    /// Eq. 4–5 score per selected frame, parallel to `selection.frames`
    /// (softmax probability for sampling/AKR, raw cosine for Top-K) —
    /// the structured evidence the serving API returns.
    pub frame_scores: Vec<f32>,
}

/// Retrieval mode (the ablation axis of Fig. 10 / Fig. 11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetrievalMode {
    /// AKR progressive sampling (Eq. 6–7)
    Akr,
    /// fixed-budget sampling (Eq. 5)
    FixedSampling(usize),
    /// greedy Top-K over indexed frames (Vanilla)
    TopK(usize),
}

/// The query engine: owns an embed engine + shares the memory fabric.
pub struct QueryEngine {
    engine: EmbedEngine,
    fabric: Arc<MemoryFabric>,
    cfg: RetrievalConfig,
    rng: Pcg64,
    scores_buf: Vec<f32>,
    /// Engine-owned merged score buffer for the All path — reused across
    /// queries (it grows to the fabric's total row count and stays
    /// there), replacing the per-query `Vec<f32>` allocation.
    merged_buf: Vec<f32>,
    /// Shared scoring pool.  `None` ⇒ serial scoring (embedded and
    /// legacy callers); the server attaches one pool to every worker's
    /// engine.  Output is bit-identical either way
    /// (DESIGN.md §Parallel-Query).
    pool: Option<Arc<ScorePool>>,
}

impl QueryEngine {
    pub fn new(
        engine: EmbedEngine,
        fabric: Arc<MemoryFabric>,
        cfg: RetrievalConfig,
        seed: u64,
    ) -> Self {
        Self {
            engine,
            fabric,
            cfg,
            rng: Pcg64::new(seed, 0x9e4),
            scores_buf: Vec::new(),
            merged_buf: Vec::new(),
            pool: None,
        }
    }

    /// Attach a shared scoring pool (builder style): scoring fans out as
    /// row-disjoint tasks across shards and cold segments, bit-identical
    /// to the serial path at any worker count.
    pub fn with_pool(mut self, pool: Arc<ScorePool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Convenience: a query engine over one bare shard (single-camera
    /// deployments, tests, benches).
    pub fn over_memory(
        engine: EmbedEngine,
        memory: Arc<OrderedRwLock<Hierarchy>>,
        cfg: RetrievalConfig,
        seed: u64,
    ) -> Self {
        Self::new(engine, Arc::new(MemoryFabric::single(memory)), cfg, seed)
    }

    pub fn config(&self) -> &RetrievalConfig {
        &self.cfg
    }

    pub fn set_config(&mut self, cfg: RetrievalConfig) {
        self.cfg = cfg;
    }

    pub fn fabric(&self) -> &Arc<MemoryFabric> {
        &self.fabric
    }

    /// Default mode from config.
    fn default_mode(&self) -> RetrievalMode {
        if self.cfg.akr {
            RetrievalMode::Akr
        } else {
            RetrievalMode::FixedSampling(self.cfg.budget)
        }
    }

    /// Run the full query stage with the configured mode over every
    /// stream.
    pub fn retrieve(&mut self, text: &str) -> Result<QueryOutcome> {
        self.retrieve_scoped_with(text, StreamScope::All, self.default_mode())
    }

    /// Configured mode, explicit stream scope.
    pub fn retrieve_scoped(&mut self, text: &str, scope: StreamScope) -> Result<QueryOutcome> {
        self.retrieve_scoped_with(text, scope, self.default_mode())
    }

    /// Explicit retrieval mode over every stream.
    pub fn retrieve_with(&mut self, text: &str, mode: RetrievalMode) -> Result<QueryOutcome> {
        self.retrieve_scoped_with(text, StreamScope::All, mode)
    }

    /// Run the query stage with an explicit mode and stream scope.
    pub fn retrieve_scoped_with(
        &mut self,
        text: &str,
        scope: StreamScope,
        mode: RetrievalMode,
    ) -> Result<QueryOutcome> {
        self.retrieve_request(text, scope, Some(mode), None, None)
            .map(|(outcome, _)| outcome)
    }

    /// Resolve the effective retrieval mode for a request: an explicit
    /// mode wins over the configured default, and a per-query sampling
    /// budget replaces the fixed budget / Top-K size.  (An AKR budget
    /// override instead caps `n_max` — see [`QueryEngine::retrieve_request`].)
    pub fn effective_mode(
        &self,
        mode: Option<RetrievalMode>,
        budget: Option<usize>,
    ) -> RetrievalMode {
        let base = mode.unwrap_or_else(|| self.default_mode());
        match (base, budget) {
            (RetrievalMode::FixedSampling(_), Some(b)) => RetrievalMode::FixedSampling(b),
            (RetrievalMode::TopK(_), Some(b)) => RetrievalMode::TopK(b),
            (m, _) => m,
        }
    }

    /// The serving API's retrieve path: explicit scope, optional mode and
    /// per-query budget override, and an optional semantic query cache.
    ///
    /// Cache protocol (the paper's query-indexing stage):
    ///  1. exact tier — normalized-text hit returns the cached selection
    ///     with zero edge stages (no embed, no scoring, no fetch);
    ///  2. on exact miss the query text is embedded, and a cached entry
    ///     whose embedding is cosine-close enough is reused (scoring +
    ///     selection + fetch skipped);
    ///  3. on a full miss the cold path runs and the selection is cached
    ///     together with the touched shards' ingest watermarks, captured
    ///     under the same read guards the selection ran under.
    pub fn retrieve_request(
        &mut self,
        text: &str,
        scope: StreamScope,
        mode: Option<RetrievalMode>,
        budget: Option<usize>,
        cache: Option<&QueryCache>,
    ) -> Result<(QueryOutcome, CacheStatus)> {
        self.retrieve_request_traced(text, scope, mode, budget, cache, None)
    }

    /// [`QueryEngine::retrieve_request`] with per-stage span capture: when
    /// a [`TraceCtx`] is supplied, every edge stage (cache probe, embed,
    /// score — with per-shard children, hot/cold split and probe gauges —
    /// select, fetch) records a span into it.  Tracing never perturbs the
    /// retrieval itself: spans carry only `Instant` timings and counters,
    /// no RNG is consumed and no FP evaluation order changes, so scored
    /// output stays bit-identical with tracing on or off.
    pub fn retrieve_request_traced(
        &mut self,
        text: &str,
        scope: StreamScope,
        mode: Option<RetrievalMode>,
        budget: Option<usize>,
        cache: Option<&QueryCache>,
        mut trace: Option<&mut TraceCtx>,
    ) -> Result<(QueryOutcome, CacheStatus)> {
        let mode = self.effective_mode(mode, budget);
        // AKR takes its budget from cfg.n_max: cap it for this query only
        let cfg = match (mode, budget) {
            (RetrievalMode::Akr, Some(b)) => {
                let mut c = self.cfg.clone();
                c.n_max = b.clamp(1, c.n_max.max(1));
                c
            }
            _ => self.cfg.clone(),
        };
        let mut t = EdgeTimings::default();
        let cache = cache.filter(|c| c.enabled());

        // cache tier 1: normalized-text key (skips even the text embed).
        // `cfg.n_max` is part of the key: it carries an AKR budget
        // override, which `mode` alone does not encode.
        let mut lookup_state = None;
        if let Some(c) = cache {
            let t0 = Instant::now();
            let wms = self.fabric.watermarks(scope)?;
            let key = QueryCache::text_key(text);
            let hit = c.lookup_exact(key, scope, mode, cfg.n_max, &wms);
            let d = t0.elapsed();
            t.cache_probe_s += d.as_secs_f64();
            if let Some(tc) = trace.as_deref_mut() {
                tc.record_counters(
                    stage::CACHE_PROBE,
                    t0,
                    d,
                    &[("tier", 1.0), ("hit", if hit.is_some() { 1.0 } else { 0.0 })],
                );
            }
            if let Some(hit) = hit {
                return Ok((outcome_from_cached(hit, t), CacheStatus::HitExact));
            }
            lookup_state = Some((key, wms));
        }

        // query embedding: pure compute, no lock held
        let t0 = Instant::now();
        let qvec = self.engine.embed_query(text)?;
        let embed_d = t0.elapsed();
        t.embed_query_s = embed_d.as_secs_f64();
        if let Some(tc) = trace.as_deref_mut() {
            tc.record(stage::EMBED, t0, embed_d);
        }

        // cache tier 2: embedding similarity (skips scoring + selection)
        if let (Some(c), Some((_, wms))) = (cache, lookup_state.as_ref()) {
            let t0 = Instant::now();
            let hit = c.lookup_semantic(&qvec, scope, mode, cfg.n_max, wms);
            let d = t0.elapsed();
            t.cache_probe_s += d.as_secs_f64();
            if let Some(tc) = trace.as_deref_mut() {
                tc.record_counters(
                    stage::CACHE_PROBE_SEMANTIC,
                    t0,
                    d,
                    &[("tier", 2.0), ("hit", if hit.is_some() { 1.0 } else { 0.0 })],
                );
            }
            if let Some(hit) = hit {
                return Ok((outcome_from_cached(hit, t), CacheStatus::HitSemantic));
            }
        }

        // score + select under the scoped shards' read guards: the sampler
        // needs scores consistent with the records it expands clusters
        // from, across every shard at once
        let shards = self.fabric.scoped(scope)?;
        let (selection, draws, frame_scores, touched) = {
            let guards: Vec<_> = shards.iter().map(|s| s.read()).collect();
            // watermarks captured under the same guards the selection
            // sees — exactly the index state a cached reuse would replay
            let touched: Vec<(StreamId, u64)> =
                guards.iter().map(|g| (g.stream(), g.watermark())).collect();

            if guards.len() == 1 {
                // single-shard fast path (One scope, or a single-camera
                // fabric): select straight off the shard — no merged
                // score copy, no per-record reference vec.  With a pool
                // attached, cold segments and the hot index still score
                // in parallel within the shard.
                let g = &guards[0];
                // probe gauges + pool hot/cold attribution are cumulative
                // process-wide counters: capture them around the scan so
                // the span carries this query's deltas (telemetry-grade —
                // a concurrent query on the same shard may bleed in).
                let ts0 = trace.as_deref_mut().map(|_| g.tier_stats());
                let g0 = self.pool.as_deref().map(|p| p.gauges());
                let t0 = Instant::now();
                match self.pool.as_deref() {
                    Some(pool) => g.score_all_pooled(pool, &qvec, &mut self.scores_buf)?,
                    None => g.score_all(&qvec, &mut self.scores_buf)?,
                }
                let search_d = t0.elapsed();
                t.search_s = search_d.as_secs_f64();
                let (hot_ms, cold_ms) = match (g0, self.pool.as_deref()) {
                    (Some(g0), Some(p)) => {
                        let g1 = p.gauges();
                        (g1.hot_score_ms - g0.hot_score_ms, g1.cold_score_ms - g0.cold_score_ms)
                    }
                    _ => (0.0, 0.0),
                };
                t.score_s = if self.pool.is_some() {
                    (hot_ms + cold_ms) / 1e3
                } else {
                    t.search_s
                };
                if let Some(tc) = trace.as_deref_mut() {
                    let ts1 = g.tier_stats();
                    let ts0 = ts0.unwrap_or(ts1);
                    let probed =
                        ts1.cold_probe_segments.saturating_sub(ts0.cold_probe_segments);
                    let candidates =
                        ts1.cold_probe_candidates.saturating_sub(ts0.cold_probe_candidates);
                    tc.record_counters(
                        stage::SCORE,
                        t0,
                        search_d,
                        &[
                            ("shards", 1.0),
                            ("rows", self.scores_buf.len() as f64),
                            ("hot_ms", hot_ms),
                            ("cold_ms", cold_ms),
                            ("probed_segments", probed as f64),
                            ("pruned_segments", candidates.saturating_sub(probed) as f64),
                        ],
                    );
                    tc.record_counters(
                        stage::SCORE_SHARD,
                        t0,
                        search_d,
                        &[
                            ("shard", g.stream().0 as f64),
                            ("rows", self.scores_buf.len() as f64),
                        ],
                    );
                }

                let t0 = Instant::now();
                let (sel, draws) =
                    select_over(&**g, &self.scores_buf, &cfg, &mut self.rng, mode);
                let fs = frame_scores_for(&**g, &sel, &self.scores_buf);
                let select_d = t0.elapsed();
                t.select_s = select_d.as_secs_f64();
                if let Some(tc) = trace.as_deref_mut() {
                    tc.record_counters(
                        stage::SELECT,
                        t0,
                        select_d,
                        &[("frames", sel.frames.len() as f64), ("draws", draws as f64)],
                    );
                }
                (sel, draws, fs, touched)
            } else {
                // All-scope scatter-gather into one engine-owned merged
                // buffer.  With a pool: one row-disjoint task per shard
                // × {cold segment, hot index} (+ readahead tasks), each
                // writing its pre-carved slice — concatenated
                // cold-then-hot, shard-ordered output is bit-identical
                // to the serial walk below.
                let g0 = self.pool.as_deref().map(|p| p.gauges());
                // (stream, rows, probed, pruned) per shard, filled from
                // the pooled path's plans — the serial path records
                // per-shard spans with real wall times instead
                let mut shard_plans: Vec<(StreamId, usize, usize, usize)> = Vec::new();
                let t0 = Instant::now();
                self.merged_buf.clear();
                match self.pool.as_deref() {
                    Some(pool) => {
                        let plans: Vec<_> =
                            guards.iter().map(|g| g.plan_score(&qvec)).collect();
                        if trace.is_some() {
                            for (g, plan) in guards.iter().zip(&plans) {
                                shard_plans.push((
                                    g.stream(),
                                    plan.rows(),
                                    plan.probed_segments(),
                                    plan.pruned_segments(),
                                ));
                            }
                        }
                        let total: usize = plans.iter().map(|p| p.rows()).sum();
                        self.merged_buf.resize(total, 0.0);
                        let mut tasks = Vec::new();
                        let mut rest = self.merged_buf.as_mut_slice();
                        for (g, plan) in guards.iter().zip(&plans) {
                            let (slice, r) = rest.split_at_mut(plan.rows());
                            rest = r;
                            g.push_score_tasks(plan, &qvec, slice, pool, &mut tasks);
                        }
                        pool.run_batch(tasks)?;
                    }
                    None => {
                        for g in &guards {
                            let ts0 = Instant::now();
                            g.score_all(&qvec, &mut self.scores_buf)?;
                            if let Some(tc) = trace.as_deref_mut() {
                                tc.record_counters(
                                    stage::SCORE_SHARD,
                                    ts0,
                                    ts0.elapsed(),
                                    &[
                                        ("shard", g.stream().0 as f64),
                                        ("rows", self.scores_buf.len() as f64),
                                    ],
                                );
                            }
                            self.merged_buf.extend_from_slice(&self.scores_buf);
                        }
                    }
                }
                let search_d = t0.elapsed();
                t.search_s = search_d.as_secs_f64();
                let (hot_ms, cold_ms) = match (g0, self.pool.as_deref()) {
                    (Some(g0), Some(p)) => {
                        let g1 = p.gauges();
                        (g1.hot_score_ms - g0.hot_score_ms, g1.cold_score_ms - g0.cold_score_ms)
                    }
                    _ => (0.0, 0.0),
                };
                t.score_s = if self.pool.is_some() {
                    (hot_ms + cold_ms) / 1e3
                } else {
                    t.search_s
                };
                if let Some(tc) = trace.as_deref_mut() {
                    // pooled shards scan concurrently, so their child
                    // spans carry counters only (no per-shard wall time)
                    for &(sid, rows, probed, pruned) in &shard_plans {
                        tc.record_counters(
                            stage::SCORE_SHARD,
                            t0,
                            Duration::ZERO,
                            &[
                                ("shard", sid.0 as f64),
                                ("rows", rows as f64),
                                ("probed_segments", probed as f64),
                                ("pruned_segments", pruned as f64),
                            ],
                        );
                    }
                    tc.record_counters(
                        stage::SCORE,
                        t0,
                        search_d,
                        &[
                            ("shards", guards.len() as f64),
                            ("rows", self.merged_buf.len() as f64),
                            ("hot_ms", hot_ms),
                            ("cold_ms", cold_ms),
                            (
                                "probed_segments",
                                shard_plans.iter().map(|p| p.2 as f64).sum(),
                            ),
                            (
                                "pruned_segments",
                                shard_plans.iter().map(|p| p.3 as f64).sum(),
                            ),
                        ],
                    );
                }

                let t0 = Instant::now();
                let view = MergedView::over(&guards);
                let (sel, draws) =
                    select_over(&view, &self.merged_buf, &cfg, &mut self.rng, mode);
                let fs = frame_scores_for(&view, &sel, &self.merged_buf);
                let select_d = t0.elapsed();
                t.select_s = select_d.as_secs_f64();
                if let Some(tc) = trace.as_deref_mut() {
                    tc.record_counters(
                        stage::SELECT,
                        t0,
                        select_d,
                        &[("frames", sel.frames.len() as f64), ("draws", draws as f64)],
                    );
                }
                (sel, draws, fs, touched)
            }
        };

        // fetch (decode) the selected raw frames — part of the edge path.
        // Fresh per-shard guards: the ids are already archived, so each
        // stream's ingestion writer may interleave between selection and
        // fetch.
        let t0 = Instant::now();
        for frame in self.fabric.fetch_frames(&selection.frames)? {
            std::hint::black_box(frame);
        }
        let fetch_d = t0.elapsed();
        t.fetch_s = fetch_d.as_secs_f64();
        if let Some(tc) = trace.as_deref_mut() {
            tc.record_counters(
                stage::FETCH,
                t0,
                fetch_d,
                &[("frames", selection.frames.len() as f64)],
            );
        }

        let status = if let (Some(c), Some((key, _))) = (cache, lookup_state) {
            c.insert(
                key,
                qvec,
                scope,
                mode,
                cfg.n_max,
                touched,
                CachedQuery {
                    selection: selection.clone(),
                    frame_scores: frame_scores.clone(),
                    draws,
                },
            );
            CacheStatus::Miss
        } else {
            CacheStatus::Bypass
        };

        Ok((QueryOutcome { selection, timings: t, draws, frame_scores }, status))
    }

    /// Raw similarity scores for the given query over the whole fabric
    /// (diagnostics / benches), in merged shard order.
    pub fn score_query(&mut self, text: &str) -> Result<Vec<f32>> {
        let qvec = self.engine.embed_query(text)?;
        let mut merged = Vec::new();
        for shard in self.fabric.shards() {
            let g = shard.read();
            g.score_all(&qvec, &mut self.scores_buf)?;
            merged.extend_from_slice(&self.scores_buf);
        }
        Ok(merged)
    }

    /// Measured mean text-embedding latency so far.
    pub fn measured_text_embed_s(&self) -> f64 {
        self.engine.measured_text_s()
    }
}

/// Rebuild a query outcome from a cache hit: the cached selection with
/// whatever edge stages were actually paid (all zero on an exact hit,
/// embed only on a semantic hit).
fn outcome_from_cached(hit: CachedQuery, timings: EdgeTimings) -> QueryOutcome {
    QueryOutcome {
        selection: hit.selection,
        timings,
        draws: hit.draws,
        frame_scores: hit.frame_scores,
    }
}

/// Zero-copy merged record view over the scoped shards' read guards:
/// per-shard record slices concatenated in shard order, addressed by the
/// same global offsets the merged score buffer uses.  Replaces the
/// per-record `Vec<&ClusterRecord>` the All path used to assemble on
/// every query (fabric-sized, rebuilt per request) with a per-shard
/// offset table.
struct MergedView<'a> {
    /// (shard, its first row's offset in the merged buffer), shard order
    shards: Vec<(&'a Hierarchy, usize)>,
    total: usize,
}

impl<'a> MergedView<'a> {
    fn over(guards: &'a [OrderedReadGuard<'a, Hierarchy>]) -> Self {
        let mut shards = Vec::with_capacity(guards.len());
        let mut off = 0usize;
        for g in guards {
            shards.push((&**g, off));
            off += Hierarchy::len(g);
        }
        Self { shards, total: off }
    }
}

impl RecordSource for MergedView<'_> {
    fn len(&self) -> usize {
        self.total
    }

    fn record(&self, id: usize) -> Option<&ClusterRecord> {
        if id >= self.total {
            return None;
        }
        let i = self.shards.partition_point(|&(_, off)| off <= id) - 1;
        let (shard, off) = self.shards[i];
        shard.record(id - off)
    }
}

/// Per-selected-frame retrieval score, parallel to `sel.frames`: the
/// Eq. 5 softmax probability of the drawn index whose cluster cites the
/// frame (sampling/AKR), falling back to the raw Eq. 4 score when the
/// selector produced no distribution (Top-K).
fn frame_scores_for<M: crate::retrieval::RecordSource + ?Sized>(
    memory: &M,
    sel: &Selection,
    raw_scores: &[f32],
) -> Vec<f32> {
    let mut drawn: Vec<usize> = sel.drawn_indices.clone();
    drawn.sort_unstable();
    drawn.dedup();
    let score_of = |idx: usize| -> f32 {
        if sel.probs.is_empty() {
            raw_scores.get(idx).copied().unwrap_or(0.0)
        } else {
            sel.probs[idx]
        }
    };
    sel.frames
        .iter()
        .map(|f| {
            drawn
                .iter()
                .filter(|&&i| {
                    // a stale drawn id (typed miss) simply contributes no
                    // score — the selection layer already skipped it
                    memory.record(i).is_some_and(|r| {
                        r.stream == f.stream && r.members.binary_search(&f.idx).is_ok()
                    })
                })
                .map(|&i| score_of(i))
                .max_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
                .unwrap_or(0.0)
        })
        .collect()
}

/// Shortlist-mask + mode dispatch over any record source — one shard
/// (fast path) or the merged cross-shard view.
fn select_over<M: crate::retrieval::RecordSource + ?Sized>(
    memory: &M,
    scores: &[f32],
    cfg: &RetrievalConfig,
    rng: &mut Pcg64,
    mode: RetrievalMode,
) -> (Selection, usize) {
    // bound the sampling distribution to the scored shortlist so the
    // Eq. 5 trade-off is invariant to how long (and how many) streams
    // have run
    let masked = crate::retrieval::shortlist_mask(scores, cfg.shortlist);
    match mode {
        RetrievalMode::Akr => {
            let out = akr_retrieve(
                memory,
                &masked,
                cfg.tau,
                cfg.theta,
                cfg.beta,
                cfg.n_max,
                rng,
            );
            (out.selection, out.draws)
        }
        RetrievalMode::FixedSampling(n) => {
            (sample_retrieve(memory, &masked, cfg.tau, n, rng), n)
        }
        RetrievalMode::TopK(k) => (topk_retrieve(memory, scores, k), k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::{ClusterRecord, InMemoryRaw, StreamId};
    use crate::util::sync::ranks;
    use crate::video::frame::Frame;

    /// Ingest-while-query smoke test for the RwLock'd memory: a writer
    /// thread keeps archiving + inserting while this thread runs the full
    /// query stage.  Every retrieval must succeed, reference only archived
    /// frames, and never deadlock.
    #[test]
    fn queries_run_while_writer_inserts() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let memory = Arc::new(OrderedRwLock::new(
            ranks::shard(0),
            Hierarchy::new(&MemoryConfig::default(), d, Box::new(InMemoryRaw::new(8)))
                .unwrap(),
        ));

        let writer_mem = Arc::clone(&memory);
        let writer = std::thread::spawn(move || {
            let mut rng = Pcg64::seeded(7);
            for c in 0..60u64 {
                let mut mem = writer_mem.write();
                for f in c * 4..(c + 1) * 4 {
                    mem.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
                }
                let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                crate::util::l2_normalize(&mut v);
                mem.insert(
                    &v,
                    ClusterRecord {
                        stream: StreamId(0),
                        scene_id: c as usize,
                        centroid_frame: c * 4,
                        members: (c * 4..(c + 1) * 4).collect(),
                    },
                )
                .unwrap();
                drop(mem);
                std::thread::yield_now();
            }
        });

        let mut qe = QueryEngine::over_memory(
            EmbedEngine::default_backend(false).unwrap(),
            Arc::clone(&memory),
            RetrievalConfig::default(),
            3,
        );
        for i in 0..20 {
            let mode = if i % 2 == 0 {
                RetrievalMode::Akr
            } else {
                RetrievalMode::FixedSampling(4)
            };
            let out = qe
                .retrieve_with("what happened with concept01", mode)
                .unwrap();
            let archived = memory.read().frames_ingested();
            assert!(
                out.selection.frames.iter().all(|f| f.idx < archived),
                "selection referenced an unarchived frame"
            );
        }
        writer.join().unwrap();
        memory.read().check_invariants().unwrap();
        // with the writer drained, the index is fully visible to queries
        let out = qe
            .retrieve_with("what happened with concept01", RetrievalMode::FixedSampling(8))
            .unwrap();
        assert!(
            !out.selection.frames.is_empty(),
            "query after ingest must select from the 60-cluster index"
        );
    }

    /// Deterministic single-shard memory for the API-path tests (random
    /// unit vectors, 4 frames per cluster).
    fn seeded_memory(d: usize, clusters: u64, seed: u64) -> Arc<OrderedRwLock<Hierarchy>> {
        let memory = Arc::new(OrderedRwLock::new(
            ranks::shard(0),
            Hierarchy::new(&MemoryConfig::default(), d, Box::new(InMemoryRaw::new(8)))
                .unwrap(),
        ));
        let mut rng = Pcg64::seeded(seed);
        let mut mem = memory.write();
        for c in 0..clusters {
            for f in c * 4..(c + 1) * 4 {
                mem.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
            }
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            crate::util::l2_normalize(&mut v);
            mem.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 4,
                    members: (c * 4..(c + 1) * 4).collect(),
                },
            )
            .unwrap();
        }
        drop(mem);
        memory
    }

    fn engine_over(memory: &Arc<OrderedRwLock<Hierarchy>>, seed: u64) -> QueryEngine {
        QueryEngine::over_memory(
            EmbedEngine::default_backend(false).unwrap(),
            Arc::clone(memory),
            RetrievalConfig::default(),
            seed,
        )
    }

    #[test]
    fn frame_scores_parallel_the_selection() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let memory = seeded_memory(engine.d_embed(), 12, 41);
        let mut qe = engine_over(&memory, 7);
        for mode in [
            RetrievalMode::FixedSampling(8),
            RetrievalMode::Akr,
            RetrievalMode::TopK(4),
        ] {
            let out = qe.retrieve_with("what happened with concept01", mode).unwrap();
            assert_eq!(
                out.frame_scores.len(),
                out.selection.frames.len(),
                "{mode:?}: scores must parallel frames"
            );
            if mode != RetrievalMode::TopK(4) {
                // every sampled frame came from a drawn cluster: its Eq. 5
                // probability is strictly positive
                assert!(
                    out.frame_scores.iter().all(|&s| s > 0.0),
                    "{mode:?}: {:?}",
                    out.frame_scores
                );
            }
        }
    }

    #[test]
    fn budget_override_rescopes_every_mode() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let memory = seeded_memory(engine.d_embed(), 16, 43);
        let mut qe = engine_over(&memory, 9);
        // fixed sampling: budget replaces the draw count exactly
        let (out, _) = qe
            .retrieve_request(
                "concept01",
                StreamScope::All,
                Some(RetrievalMode::FixedSampling(32)),
                Some(5),
                None,
            )
            .unwrap();
        assert_eq!(out.draws, 5);
        // top-k: budget replaces k
        let (out, _) = qe
            .retrieve_request(
                "concept01",
                StreamScope::All,
                Some(RetrievalMode::TopK(12)),
                Some(3),
                None,
            )
            .unwrap();
        assert_eq!(out.selection.frames.len(), 3);
        // AKR: budget caps n_max
        let (out, _) = qe
            .retrieve_request(
                "concept01",
                StreamScope::All,
                Some(RetrievalMode::Akr),
                Some(2),
                None,
            )
            .unwrap();
        assert!(out.draws <= 2, "AKR draws {} exceed the budget cap", out.draws);
        // no override: configured default mode applies
        let mode = qe.effective_mode(None, None);
        assert_eq!(mode, RetrievalMode::Akr, "default config enables AKR");
    }

    #[test]
    fn cache_tiers_exact_then_semantic_then_miss() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let memory = seeded_memory(engine.d_embed(), 10, 47);
        let mut qe = engine_over(&memory, 11);
        let cache = crate::api::cache::QueryCache::new(16, -1.0, 1_000);

        let (cold, status) = qe
            .retrieve_request(
                "what happened with concept01",
                StreamScope::All,
                Some(RetrievalMode::FixedSampling(8)),
                None,
                Some(&cache),
            )
            .unwrap();
        assert_eq!(status, CacheStatus::Miss);

        // exact tier: same text modulo case/whitespace, zero edge stages
        let (warm, status) = qe
            .retrieve_request(
                "  What HAPPENED with concept01 ",
                StreamScope::All,
                Some(RetrievalMode::FixedSampling(8)),
                None,
                Some(&cache),
            )
            .unwrap();
        assert_eq!(status, CacheStatus::HitExact);
        assert_eq!(warm.selection.frames, cold.selection.frames);
        assert_eq!(warm.frame_scores, cold.frame_scores);
        assert_eq!(warm.timings.total_s(), 0.0, "exact hit skips every edge stage");

        // semantic tier: different text, threshold -1 accepts any cosine
        let (sem, status) = qe
            .retrieve_request(
                "completely different wording",
                StreamScope::All,
                Some(RetrievalMode::FixedSampling(8)),
                None,
                Some(&cache),
            )
            .unwrap();
        assert_eq!(status, CacheStatus::HitSemantic);
        assert_eq!(sem.selection.frames, cold.selection.frames);
        assert!(sem.timings.embed_query_s > 0.0, "semantic hit still pays the embed");
        assert_eq!(sem.timings.search_s + sem.timings.select_s + sem.timings.fetch_s, 0.0);

        // no cache handle: bypass
        let (_, status) = qe
            .retrieve_request(
                "what happened with concept01",
                StreamScope::All,
                Some(RetrievalMode::FixedSampling(8)),
                None,
                None,
            )
            .unwrap();
        assert_eq!(status, CacheStatus::Bypass);
    }

    /// Scope semantics over a two-shard fabric with disjoint concepts:
    /// `One(s)` selections cite only stream `s`; `All` merges both.
    #[test]
    fn scoped_queries_respect_stream_boundaries() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let raws: Vec<Box<dyn crate::memory::RawStore>> = vec![
            Box::new(InMemoryRaw::new(8)),
            Box::new(InMemoryRaw::new(8)),
        ];
        let fabric =
            Arc::new(MemoryFabric::new(&MemoryConfig::default(), d, raws).unwrap());

        let mut rng = Pcg64::seeded(99);
        for sid in 0..2u16 {
            let shard = fabric.shard(StreamId(sid)).unwrap();
            let mut g = shard.write();
            for c in 0..8u64 {
                for f in c * 4..(c + 1) * 4 {
                    g.archive_frame(f, &Frame::filled(8, [0.5; 3])).unwrap();
                }
                let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                crate::util::l2_normalize(&mut v);
                g.insert(
                    &v,
                    ClusterRecord {
                        stream: StreamId(sid),
                        scene_id: c as usize,
                        centroid_frame: c * 4,
                        members: (c * 4..(c + 1) * 4).collect(),
                    },
                )
                .unwrap();
            }
        }

        let mut qe = QueryEngine::new(
            engine,
            Arc::clone(&fabric),
            RetrievalConfig::default(),
            5,
        );
        for sid in 0..2u16 {
            let out = qe
                .retrieve_scoped_with(
                    "what happened with concept01",
                    StreamScope::One(StreamId(sid)),
                    RetrievalMode::FixedSampling(8),
                )
                .unwrap();
            assert!(!out.selection.frames.is_empty());
            assert!(
                out.selection.frames.iter().all(|f| f.stream == StreamId(sid)),
                "One({sid}) leaked foreign frames: {:?}",
                out.selection.frames
            );
        }
        // flat random embeddings: an All-scope budget spread over 16
        // equally-plausible clusters lands in both shards w.h.p.
        let out = qe
            .retrieve_scoped_with(
                "what happened with concept01",
                StreamScope::All,
                RetrievalMode::FixedSampling(64),
            )
            .unwrap();
        assert!(!out.selection.frames.is_empty());
        // unknown stream is an error, not a panic
        assert!(qe
            .retrieve_scoped("anything", StreamScope::One(StreamId(9)))
            .is_err());
    }
}
