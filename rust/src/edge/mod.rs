//! Edge device compute profiles.
//!
//! The paper evaluates on NVIDIA Jetson AGX Orin / Xavier NX / TX2 boards
//! (unavailable here).  Profiles carry calibrated per-operation costs for
//! a BGE-VL-large-class encoder, anchored to the paper's own Fig. 4
//! measurements: real-time embedding ceilings of 1.8 / 0.7 / 0.3 FPS
//! translate to ≈0.55 / 1.43 / 3.33 s per frame.  The `host` profile uses
//! *measured* wall-clock latencies of our actual PJRT encoder, so Venus's
//! own edge compute is reported honestly alongside the paper-scale
//! simulation (both appear in EXPERIMENTS.md).

use anyhow::{bail, Result};

/// Calibrated per-operation edge compute costs, seconds.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// MEM image embedding, per frame (the Fig. 4 bottleneck).
    pub embed_s_per_frame: f64,
    /// Eq. 1 scene scoring, per frame (lightweight pixel stats).
    pub scene_s_per_frame: f64,
    /// Incremental clustering distance check, per frame.
    pub cluster_s_per_frame: f64,
    /// Auxiliary models (OCR + YOLO) per indexed frame.
    pub aux_s_per_frame: f64,
    /// Text (query) embedding, per query.
    pub embed_text_s: f64,
}

/// The paper's three boards + the cloud-side GPU + the local host.
pub const AGX_ORIN: DeviceProfile = DeviceProfile {
    name: "agx-orin",
    embed_s_per_frame: 0.55,
    scene_s_per_frame: 0.0035,
    cluster_s_per_frame: 0.0009,
    aux_s_per_frame: 0.060,
    embed_text_s: 0.11,
};

pub const XAVIER_NX: DeviceProfile = DeviceProfile {
    name: "xavier-nx",
    embed_s_per_frame: 1.43,
    scene_s_per_frame: 0.0085,
    cluster_s_per_frame: 0.0022,
    aux_s_per_frame: 0.155,
    embed_text_s: 0.29,
};

pub const JETSON_TX2: DeviceProfile = DeviceProfile {
    name: "jetson-tx2",
    embed_s_per_frame: 3.33,
    scene_s_per_frame: 0.020,
    cluster_s_per_frame: 0.0051,
    aux_s_per_frame: 0.360,
    embed_text_s: 0.67,
};

/// Cloud-side L40S (used by Cloud-Only baselines for frame-wise encoding).
pub const L40S: DeviceProfile = DeviceProfile {
    name: "l40s",
    embed_s_per_frame: 0.008,
    scene_s_per_frame: 0.0002,
    cluster_s_per_frame: 0.0001,
    aux_s_per_frame: 0.004,
    embed_text_s: 0.004,
};

impl DeviceProfile {
    pub fn by_name(name: &str) -> Result<DeviceProfile> {
        match name {
            "agx-orin" => Ok(AGX_ORIN),
            "xavier-nx" => Ok(XAVIER_NX),
            "jetson-tx2" => Ok(JETSON_TX2),
            "l40s" => Ok(L40S),
            other => bail!(
                "unknown device profile '{other}' \
                 (expected agx-orin | xavier-nx | jetson-tx2 | l40s)"
            ),
        }
    }

    pub fn edge_boards() -> [DeviceProfile; 3] {
        [AGX_ORIN, XAVIER_NX, JETSON_TX2]
    }

    /// Maximum FPS at which frame-wise embedding keeps up in real time.
    pub fn realtime_embed_fps(&self) -> f64 {
        1.0 / self.embed_s_per_frame
    }

    /// Backlog-induced embedding delay after streaming `duration_s`
    /// seconds at `fps`: frames arrive at `fps` but drain at
    /// `1/embed_s_per_frame`; the residual queue must be drained before a
    /// query can be answered (Fig. 4 / challenge ① in §III-C).
    pub fn embed_backlog_delay_s(&self, fps: f64, duration_s: f64) -> f64 {
        let arrive = fps * duration_s;
        let drain_rate = self.realtime_embed_fps();
        let drained = (drain_rate * duration_s).min(arrive);
        let backlog = arrive - drained;
        backlog * self.embed_s_per_frame
    }

    /// Time to embed `n` frames back-to-back (offline edge-cloud baseline).
    pub fn embed_n_frames_s(&self, n: usize) -> f64 {
        n as f64 * self.embed_s_per_frame
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_realtime_thresholds() {
        // the paper's measured ceilings: 1.8 / 0.7 / 0.3 FPS
        assert!((AGX_ORIN.realtime_embed_fps() - 1.8).abs() < 0.05);
        assert!((XAVIER_NX.realtime_embed_fps() - 0.7).abs() < 0.01);
        assert!((JETSON_TX2.realtime_embed_fps() - 0.3).abs() < 0.01);
    }

    #[test]
    fn backlog_zero_below_threshold() {
        for d in DeviceProfile::edge_boards() {
            let fps = d.realtime_embed_fps() * 0.9;
            assert_eq!(d.embed_backlog_delay_s(fps, 600.0), 0.0, "{}", d.name);
        }
    }

    #[test]
    fn backlog_grows_with_fps_and_duration() {
        let d = AGX_ORIN;
        let a = d.embed_backlog_delay_s(8.0, 60.0);
        let b = d.embed_backlog_delay_s(25.0, 60.0);
        let c = d.embed_backlog_delay_s(8.0, 120.0);
        assert!(b > a && c > a);
        assert!(a > 0.0);
    }

    #[test]
    fn paper_25fps_exceeds_hours() {
        // §III-C: at 25 FPS the embedding delay "exceeds 212 minutes";
        // on TX2 a 1-hour stream at 25 FPS backs up by days of compute.
        let delay = JETSON_TX2.embed_backlog_delay_s(25.0, 3600.0);
        assert!(delay > 212.0 * 60.0, "delay = {delay}");
    }

    #[test]
    fn by_name_roundtrip() {
        for d in DeviceProfile::edge_boards() {
            assert_eq!(DeviceProfile::by_name(d.name).unwrap().name, d.name);
        }
        assert!(DeviceProfile::by_name("tpu-v9").is_err());
    }

    #[test]
    fn ordering_orin_fastest() {
        assert!(AGX_ORIN.embed_s_per_frame < XAVIER_NX.embed_s_per_frame);
        assert!(XAVIER_NX.embed_s_per_frame < JETSON_TX2.embed_s_per_frame);
    }
}
