//! Simulated lightweight auxiliary models (the paper uses EasyOCR + YOLO).
//!
//! Honest pixel-level detectors: they inspect ONLY the frame's pixels —
//! the two watermark patches — and match them against the known concept
//! code book (nearest-code L2), exactly the way an OCR/detector recognizes
//! planted text/objects.  Detection is imperfect by construction: codes
//! are blended with scene content at plant time, so weakly-blended or
//! occluded marks fall below the match threshold and are missed.

use crate::video::frame::Frame;

/// A detected concept with a confidence score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Detection {
    pub concept: usize,
    /// 1 − normalized L2 distance to the matched code (higher = surer).
    pub confidence: f32,
}

/// The aux-model bank (simulated OCR + YOLO).
#[derive(Clone, Debug)]
pub struct AuxModels {
    codes: Vec<Vec<f32>>,
    patch: usize,
    /// max normalized L2 distance for a match
    pub threshold: f32,
}

impl AuxModels {
    pub fn new(codes: Vec<Vec<f32>>, patch: usize) -> Self {
        Self { codes, patch, threshold: 0.22 }
    }

    /// Extract the watermark patch at `slot` (0 = top-left, 1 = top-right).
    fn region(&self, frame: &Frame, slot: u8) -> Vec<f32> {
        let p = self.patch;
        let x0 = if slot == 0 { 0 } else { frame.size() - p };
        let mut out = Vec::with_capacity(p * p * 3);
        for y in 0..p {
            for x in 0..p {
                let (r, g, b) = frame.rgb(y, x0 + x);
                out.extend_from_slice(&[r, g, b]);
            }
        }
        out
    }

    /// Run the detectors over one frame.
    pub fn detect(&self, frame: &Frame) -> Vec<Detection> {
        let mut out = Vec::new();
        for slot in 0..2u8 {
            let region = self.region(frame, slot);
            let mut best: Option<Detection> = None;
            for (c, code) in self.codes.iter().enumerate() {
                let mut acc = 0.0f32;
                for (a, b) in region.iter().zip(code) {
                    let d = a - b;
                    acc += d * d;
                }
                let dist = (acc / region.len() as f32).sqrt();
                let conf = 1.0 - dist / self.threshold;
                if dist < self.threshold
                    && best.map_or(true, |b| conf > b.confidence)
                {
                    best = Some(Detection { concept: c, confidence: conf });
                }
            }
            if let Some(d) = best {
                if !out.iter().any(|o: &Detection| o.concept == d.concept) {
                    out.push(d);
                }
            }
        }
        out
    }

    /// Detected concept ids only (for prompt construction).
    pub fn detect_concepts(&self, frame: &Frame) -> Vec<usize> {
        self.detect(frame).into_iter().map(|d| d.concept).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn codes(n: usize, patch: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(21);
        (0..n)
            .map(|_| (0..patch * patch * 3).map(|_| rng.f32()).collect())
            .collect()
    }

    fn noisy_scene(seed: u64) -> Frame {
        let mut rng = Pcg64::seeded(seed);
        let mut f = Frame::new(64);
        for y in 0..64 {
            for x in 0..64 {
                f.set_rgb(y, x, [rng.f32(), rng.f32(), rng.f32()]);
            }
        }
        f
    }

    #[test]
    fn detects_planted_code() {
        let cs = codes(8, 8);
        let aux = AuxModels::new(cs.clone(), 8);
        let mut f = noisy_scene(1);
        f.blend_block(0, 0, 8, &cs[3], 0.85);
        let dets = aux.detect(&f);
        assert!(dets.iter().any(|d| d.concept == 3), "{dets:?}");
    }

    #[test]
    fn detects_both_slots() {
        let cs = codes(8, 8);
        let aux = AuxModels::new(cs.clone(), 8);
        let mut f = noisy_scene(2);
        f.blend_block(0, 0, 8, &cs[1], 0.9);
        f.blend_block(0, 56, 8, &cs[6], 0.9);
        let got = aux.detect_concepts(&f);
        assert!(got.contains(&1) && got.contains(&6), "{got:?}");
    }

    #[test]
    fn no_false_positive_on_plain_scene() {
        let cs = codes(8, 8);
        let aux = AuxModels::new(cs, 8);
        let f = noisy_scene(3);
        assert!(aux.detect(&f).is_empty());
    }

    #[test]
    fn misses_weak_blend() {
        // occluded / faint marks fall below threshold — detector is honest
        let cs = codes(8, 8);
        let aux = AuxModels::new(cs.clone(), 8);
        let mut f = noisy_scene(4);
        f.blend_block(0, 0, 8, &cs[2], 0.2);
        let dets = aux.detect(&f);
        assert!(!dets.iter().any(|d| d.concept == 2), "{dets:?}");
    }
}
