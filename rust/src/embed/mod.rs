//! Embedding engine: the bridge between L3 and the MEM compute backend.
//!
//! Owns a pluggable [`EmbedBackend`] (native pure-Rust by default; PJRT
//! artifacts behind the `pjrt` feature), the tokenizer, and the aux-model
//! bank, and exposes the two operations the coordinator needs:
//!   * `embed_index_frames` — ingestion path: batch of indexed frames
//!     (+ aux prompts, Eq. 2–3) → unit-norm vectors; pads the tail batch
//!     to the nearest served batch size;
//!   * `embed_query` — query path: text → unit-norm vector.
//!
//! The engine also tracks wall-clock embed timings so the §Perf report
//! and the `host` device profile use *measured* numbers.

pub mod auxmodels;
pub mod tokenizer;

pub use auxmodels::{AuxModels, Detection};
pub use tokenizer::Tokenizer;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::backend::EmbedBackend;
use crate::util::stats::Samples;
use crate::video::frame::Frame;

/// Embedding engine over a compute backend.
///
/// The backend is a shared `Arc`: engines are cheap per-thread front-ends
/// (tokenizer + aux bank + timing samples) over the one expensive backend
/// the process constructed.  `EmbedEngine` is therefore plainly `Send` —
/// no unsafe wrapper is needed to move one into a worker thread.
pub struct EmbedEngine {
    backend: Arc<dyn EmbedBackend>,
    tok: Tokenizer,
    aux: Option<AuxModels>,
    batches: Vec<usize>,
    /// measured per-call wall times (image batches, text singles)
    pub image_times: Samples,
    pub text_times: Samples,
}

impl EmbedEngine {
    /// Build from a shared backend; `use_aux` enables the aux-model bank.
    pub fn new(backend: Arc<dyn EmbedBackend>, use_aux: bool) -> Result<Self> {
        let tok = Tokenizer::from_model(backend.model());
        let aux = if use_aux {
            let codes = backend.concept_codes()?;
            let patch = backend.model().patch;
            Some(AuxModels::new(codes, patch))
        } else {
            None
        };
        let batches = backend.image_batches();
        anyhow::ensure!(!batches.is_empty(), "backend serves no image batches");
        Ok(Self {
            backend,
            tok,
            aux,
            batches,
            image_times: Samples::default(),
            text_times: Samples::default(),
        })
    }

    /// Convenience: build over the process-wide shared backend
    /// (see [`crate::backend::shared_default`]) — the default path, so
    /// every engine in the process shares one backend construction.
    pub fn default_backend(use_aux: bool) -> Result<Self> {
        Self::new(crate::backend::shared_default()?, use_aux)
    }

    pub fn backend(&self) -> &dyn EmbedBackend {
        self.backend.as_ref()
    }

    /// Clone of the shared backend handle (for building sibling engines).
    pub fn backend_arc(&self) -> Arc<dyn EmbedBackend> {
        Arc::clone(&self.backend)
    }

    /// Largest image-tower batch the backend serves (the embed pool's
    /// cross-stream coalescing target).
    pub fn max_image_batch(&self) -> usize {
        *self.batches.last().unwrap()
    }

    /// Eagerly prepare every entry this engine will execute (ingestion
    /// batches + text tower).  Serving systems warm up before the stream
    /// starts; on AOT backends the first partition would otherwise pay
    /// seconds of XLA compilation on the hot path (the native backend is
    /// ready at construction and returns immediately).
    pub fn warmup(&self) -> Result<()> {
        let mut names: Vec<String> = Vec::new();
        for &b in &self.batches {
            if self.aux.is_some() && self.backend.has_fused(b) {
                names.push(format!("embed_fused_b{b}"));
            } else {
                names.push(format!("embed_image_b{b}"));
            }
        }
        names.push("embed_text_b1".to_string());
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        self.backend.warmup(&refs)
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    pub fn d_embed(&self) -> usize {
        self.backend.model().d_embed
    }

    pub fn aux_enabled(&self) -> bool {
        self.aux.is_some()
    }

    /// Batch size for the next chunk of `n` pending frames.  Large sets
    /// chunk at batch-8 rather than batch-32: the measured per-frame cost
    /// on the CPU PJRT backend is 1.06 ms at b8 vs 1.35 ms at b32
    /// (§Perf — XLA's CPU matmul tiles saturate by b8, larger batches
    /// only grow the working set past L2).  Tail chunks use the smallest
    /// served batch that fits.
    fn pick_batch(&self, n: usize) -> usize {
        const PREFERRED: usize = 8;
        if n >= PREFERRED && self.batches.contains(&PREFERRED) {
            return PREFERRED;
        }
        for &b in &self.batches {
            if b >= n {
                return b;
            }
        }
        *self.batches.last().unwrap()
    }

    /// Embed a slice of frames (ingestion path).  Splits into backend-
    /// sized chunks, padding the tail with zero frames that are dropped
    /// from the result.  With aux models enabled, per-frame detections are
    /// folded in through the fused entry point.
    pub fn embed_index_frames(&mut self, frames: &[&Frame]) -> Result<Vec<Vec<f32>>> {
        let m = self.backend.model();
        let px = m.img_size * m.img_size * 3;
        let seq = m.seq_len;
        let mut out = Vec::with_capacity(frames.len());
        let mut i = 0;
        while i < frames.len() {
            let remaining = frames.len() - i;
            let b = self.pick_batch(remaining.min(*self.batches.last().unwrap()));
            let take = remaining.min(b);
            let chunk = &frames[i..i + take];

            let mut pixels = vec![0.0f32; b * px];
            for (j, f) in chunk.iter().enumerate() {
                pixels[j * px..(j + 1) * px].copy_from_slice(f.data());
            }

            let t0 = Instant::now();
            let embs = if let Some(aux) = &self.aux {
                let mut tokens = vec![0i32; b * seq];
                for (j, f) in chunk.iter().enumerate() {
                    let concepts = aux.detect_concepts(f);
                    let prompt = self.tok.aux_prompt(&concepts);
                    tokens[j * seq..(j + 1) * seq].copy_from_slice(&prompt);
                }
                // the fused entry exists per batch size on AOT backends;
                // fall back to image-only when absent
                if self.backend.has_fused(b) {
                    self.backend.embed_fused(&pixels, &tokens, b)?
                } else {
                    self.backend.embed_image(&pixels, b)?
                }
            } else {
                self.backend.embed_image(&pixels, b)?
            };
            self.image_times.push_duration(t0.elapsed());

            out.extend(embs.into_iter().take(take));
            i += take;
        }
        Ok(out)
    }

    /// Embed a natural-language query (query path).
    pub fn embed_query(&mut self, text: &str) -> Result<Vec<f32>> {
        let tokens = self.tok.tokenize(text);
        let t0 = Instant::now();
        let emb = self.backend.embed_text(&tokens)?;
        self.text_times.push_duration(t0.elapsed());
        Ok(emb)
    }

    /// Measured mean image-embed latency per *batch call* (seconds).
    pub fn measured_image_batch_s(&self) -> f64 {
        self.image_times.mean()
    }

    /// Measured mean text-embed latency (seconds).
    pub fn measured_text_s(&self) -> f64 {
        self.text_times.mean()
    }
}
