//! Query/prompt tokenizer — bit-for-bit mirror of
//! `python/compile/tokenizer.py` (verified against the golden token file).
//!
//! Vocabulary layout:
//!   0                              PAD
//!   1                              UNK (reserved)
//!   [base, base+C)                 concept tokens
//!   [base+C, vocab)                FNV-1a-hashed word ids

use crate::backend::ModelMeta;

const FNV_OFFSET: u32 = 0x811C_9DC5;
const FNV_PRIME: u32 = 0x0100_0193;

/// 32-bit FNV-1a hash (identical to the Python side).
pub fn fnv1a(data: &[u8]) -> u32 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Tokenizer configured from the artifact manifest.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    seq_len: usize,
    vocab: usize,
    base: usize,
    n_concepts: usize,
}

impl Tokenizer {
    pub fn from_model(m: &ModelMeta) -> Self {
        Self {
            seq_len: m.seq_len,
            vocab: m.vocab,
            base: m.concept_token_base,
            n_concepts: m.n_concepts,
        }
    }

    /// Token id of concept `c`.
    pub fn concept_token(&self, c: usize) -> i32 {
        assert!(c < self.n_concepts);
        (self.base + c) as i32
    }

    /// Lowercase whitespace tokenization into a PAD-padded fixed window.
    pub fn tokenize(&self, text: &str) -> Vec<i32> {
        let hash_base = self.base + self.n_concepts;
        let hash_range = (self.vocab - hash_base) as u32;
        let mut ids = Vec::with_capacity(self.seq_len);
        for word in text.to_lowercase().split_whitespace() {
            let word = word.trim_matches(|c| ".,?!\"'".contains(c));
            if word.is_empty() {
                continue;
            }
            if ids.len() == self.seq_len {
                break;
            }
            if let Some(rest) = word.strip_prefix("concept") {
                if !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()) {
                    if let Ok(c) = rest.parse::<usize>() {
                        if c < self.n_concepts {
                            ids.push((self.base + c) as i32);
                            continue;
                        }
                    }
                }
            }
            ids.push((hash_base as u32 + fnv1a(word.as_bytes()) % hash_range) as i32);
        }
        ids.resize(self.seq_len, 0);
        ids
    }

    /// Build an aux-prompt token window from detected concept ids
    /// (Eq. 2's textual template, reduced to its token effect).
    pub fn aux_prompt(&self, concepts: &[usize]) -> Vec<i32> {
        let mut ids: Vec<i32> = concepts
            .iter()
            .take(self.seq_len)
            .map(|&c| self.concept_token(c))
            .collect();
        ids.resize(self.seq_len, 0);
        ids
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            img_size: 64,
            patch: 8,
            d_embed: 64,
            seq_len: 16,
            vocab: 512,
            n_concepts: 32,
            concept_token_base: 2,
            sim_rows: 1024,
            scene_feat_dim: 64,
            sem_weight: 4.0,
            content_weight: 1.0,
            aux_weight: 0.5,
        }
    }

    #[test]
    fn fnv_reference_values() {
        // mirrored in python/tests/test_model.py::test_fnv_golden
        assert_eq!(fnv1a(b""), 0x811C_9DC5);
        assert_eq!(fnv1a(b"a"), 0xE40C_292C);
    }

    #[test]
    fn concept_words_map_to_concept_tokens() {
        let t = Tokenizer::from_model(&meta());
        let ids = t.tokenize("concept00 concept31");
        assert_eq!(ids[0], 2);
        assert_eq!(ids[1], 33);
    }

    #[test]
    fn hashed_words_in_range() {
        let t = Tokenizer::from_model(&meta());
        let ids = t.tokenize("kitchen stove window door");
        for &id in ids.iter().take(4) {
            assert!((34..512).contains(&(id as usize)), "id {id}");
        }
    }

    #[test]
    fn padding_and_truncation() {
        let t = Tokenizer::from_model(&meta());
        assert_eq!(t.tokenize(""), vec![0; 16]);
        let long: String = std::iter::repeat("word ").take(40).collect();
        assert_eq!(t.tokenize(&long).len(), 16);
    }

    #[test]
    fn case_and_punctuation_insensitive() {
        let t = Tokenizer::from_model(&meta());
        assert_eq!(t.tokenize("Kitchen, stove!"), t.tokenize("kitchen stove"));
    }

    #[test]
    fn aux_prompt_layout() {
        let t = Tokenizer::from_model(&meta());
        let ids = t.aux_prompt(&[4, 7]);
        assert_eq!(ids[0], 6);
        assert_eq!(ids[1], 9);
        assert_eq!(ids[2], 0);
    }

    #[test]
    fn invalid_concept_number_hashes_instead() {
        let t = Tokenizer::from_model(&meta());
        let ids = t.tokenize("concept99");
        assert!(ids[0] as usize >= 34, "out-of-range concept falls back to hash");
    }
}
