//! Deployment latency models (Table II, Fig. 2, Fig. 12).
//!
//! Composes the network link, cloud VLM, and edge device profiles into
//! per-method end-to-end response latencies, decomposed into the paper's
//! three bars: on-device, communication, cloud.  Venus's own edge terms
//! can be overridden with *measured* host numbers (EXPERIMENTS.md reports
//! both the paper-scale simulation and the measured variant).

use crate::api::QueryRequest;
use crate::baselines::Method;
use crate::cloud::VlmClient;
use crate::edge::DeviceProfile;
use crate::net::{Link, Payload};

/// Representative 16-word MCQ query the latency tables are computed for
/// (the VLM prompt-token estimate goes through the one shared
/// [`QueryRequest::approx_tokens_for`] used by the serving worker loop —
/// 32 tokens, matching the paper's short-question regime).
const REFERENCE_QUERY: &str = "in the video what happened with the highlighted concept \
                               between the first and the second scene";

/// Where the frame-selection algorithm runs (§V-A-3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Deployment {
    /// upload the whole clip; select + infer in the cloud
    CloudOnly,
    /// select on the edge (frame-wise encoder); upload only selections
    EdgeCloud,
}

impl Deployment {
    pub fn name(&self) -> &'static str {
        match self {
            Deployment::CloudOnly => "Cloud-Only",
            Deployment::EdgeCloud => "Edge-Cloud",
        }
    }
}

/// The Fig. 2 / Fig. 12 decomposition.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyParts {
    pub on_device_s: f64,
    pub comm_s: f64,
    pub cloud_s: f64,
}

impl LatencyParts {
    pub fn total_s(&self) -> f64 {
        self.on_device_s + self.comm_s + self.cloud_s
    }
}

/// Latency model for one testbed configuration.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    pub link: Link,
    pub edge: DeviceProfile,
    pub cloud_gpu: DeviceProfile,
    pub fps: f64,
}

impl LatencyModel {
    pub fn new(link: Link, edge: DeviceProfile, fps: f64) -> Self {
        Self { link, edge, cloud_gpu: crate::edge::L40S, fps }
    }

    /// Frames extracted from a clip at the evaluation rate.
    fn clip_frames(&self, clip_s: f64) -> usize {
        (clip_s * self.fps).round() as usize
    }

    /// Per-method selection compute on `device` for an n-frame clip.
    fn selection_compute_s(&self, method: Method, device: &DeviceProfile, clip_frames: usize) -> f64 {
        match method {
            // stride arithmetic — free
            Method::Uniform => 0.0,
            // feature extraction over the candidate pool
            Method::Mdf => 256.0_f64.min(clip_frames as f64) * device.scene_s_per_frame * 4.0,
            // uniform + aux models over the aux pool
            Method::VideoRag => 192.0_f64.min(clip_frames as f64) * device.aux_s_per_frame,
            // frame-wise encoder over the whole clip + light optimization
            Method::Aks => clip_frames as f64 * device.embed_s_per_frame + 0.4,
            Method::Bolt => clip_frames as f64 * device.embed_s_per_frame + 0.2,
            // naive disaggregation: frame-wise encoder into the vector DB
            Method::Vanilla => clip_frames as f64 * device.embed_s_per_frame,
            Method::Venus => unreachable!("use venus_parts"),
        }
    }

    /// Baseline end-to-end latency for a query over a `clip_s`-second clip
    /// with `n_selected` frames sent to the VLM.
    pub fn baseline_parts(
        &self,
        method: Method,
        deployment: Deployment,
        clip_s: f64,
        n_selected: usize,
        vlm: &VlmClient,
    ) -> LatencyParts {
        let frames = self.clip_frames(clip_s);
        let infer =
            vlm.infer_latency_s(n_selected, QueryRequest::approx_tokens_for(REFERENCE_QUERY));
        match deployment {
            Deployment::CloudOnly => LatencyParts {
                on_device_s: 0.0,
                comm_s: self
                    .link
                    .transfer_s(Payload::VideoClip { duration_s: clip_s, fps: self.fps }),
                cloud_s: self.selection_compute_s(method, &self.cloud_gpu, frames) + infer,
            },
            Deployment::EdgeCloud => LatencyParts {
                on_device_s: self.selection_compute_s(method, &self.edge, frames),
                comm_s: self.link.transfer_s(Payload::Frames(n_selected)),
                cloud_s: infer,
            },
        }
    }

    /// Venus end-to-end latency: ingestion is real-time (no backlog), so
    /// the query path is text embed + index search + sampling + upload of
    /// the selected frames + VLM inference.  `edge_query_s` overrides the
    /// profile-modeled edge time with a measured value when available.
    pub fn venus_parts(
        &self,
        n_selected: usize,
        vlm: &VlmClient,
        measured_edge_s: Option<f64>,
    ) -> LatencyParts {
        let on_device = measured_edge_s.unwrap_or(
            self.edge.embed_text_s + 0.02, // text embed + search/sample/fetch
        );
        LatencyParts {
            on_device_s: on_device,
            comm_s: self.link.transfer_s(Payload::Frames(n_selected)),
            cloud_s: vlm
                .infer_latency_s(n_selected, QueryRequest::approx_tokens_for(REFERENCE_QUERY)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CloudConfig, NetConfig};
    use crate::edge::AGX_ORIN;

    fn model() -> (LatencyModel, VlmClient) {
        (
            LatencyModel::new(Link::new(NetConfig::default()), AGX_ORIN, 8.0),
            VlmClient::new(CloudConfig::default(), 1),
        )
    }

    #[test]
    fn reference_query_keeps_the_calibrated_token_count() {
        // the latency tables were calibrated at 32 prompt tokens; the
        // shared estimator over the reference query must preserve that
        assert_eq!(QueryRequest::approx_tokens_for(REFERENCE_QUERY), 32);
    }

    #[test]
    fn venus_is_seconds_scale() {
        let (m, vlm) = model();
        let p = m.venus_parts(32, &vlm, None);
        assert!(p.total_s() > 1.0 && p.total_s() < 10.0, "{}", p.total_s());
    }

    #[test]
    fn cloud_only_dominated_by_communication_on_long_clips() {
        let (m, vlm) = model();
        let p = m.baseline_parts(Method::Aks, Deployment::CloudOnly, 2700.0, 32, &vlm);
        assert!(p.comm_s / p.total_s() > 0.6, "comm share {}", p.comm_s / p.total_s());
        // paper: ~11 min for Video-MME long
        assert!(p.total_s() > 8.0 * 60.0 && p.total_s() < 20.0 * 60.0);
    }

    #[test]
    fn edge_cloud_dominated_by_on_device_compute() {
        let (m, vlm) = model();
        let p = m.baseline_parts(Method::Bolt, Deployment::EdgeCloud, 180.0, 32, &vlm);
        assert!(p.on_device_s / p.total_s() > 0.8);
        // paper: ~900 s for EgoSchema edge-cloud
        assert!(p.total_s() > 600.0 && p.total_s() < 1200.0, "{}", p.total_s());
    }

    #[test]
    fn venus_speedup_matches_paper_band() {
        // paper headline: 15×–131× total-latency speedup
        let (m, vlm) = model();
        let venus = m.venus_parts(32, &vlm, None).total_s();
        for (clip_s, lo, hi) in [
            (90.0, 5.0, 40.0),     // short, cloud-only ≈ 30 s → ≥5×
            (2700.0, 100.0, 400.0) // long, cloud-only ≈ 13 min → ≥100×
        ] {
            let base = m
                .baseline_parts(Method::Aks, Deployment::CloudOnly, clip_s, 32, &vlm)
                .total_s();
            let speedup = base / venus;
            assert!(
                speedup > lo && speedup < hi,
                "clip {clip_s}s: speedup {speedup:.1} outside [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn vanilla_edge_embedding_is_the_bottleneck() {
        let (m, vlm) = model();
        let p = m.baseline_parts(Method::Vanilla, Deployment::EdgeCloud, 90.0, 32, &vlm);
        // 720 frames × 0.55 s ≈ 396 s (paper: 379 s)
        assert!(p.on_device_s > 300.0 && p.on_device_s < 500.0, "{}", p.on_device_s);
    }
}
