//! Evaluation harness: experiment runner (accuracy) + deployment latency
//! models.  Every `rust/benches/*` table/figure regenerator is a thin
//! driver over this module — see DESIGN.md §4 for the experiment index.

pub mod latency;
pub mod runner;

pub use latency::{Deployment, LatencyModel, LatencyParts};
pub use runner::{
    build_synth, eval_baseline, eval_venus, measure_venus_edge_latency, prepare_case,
    prepare_case_at, prepare_multi_case, prepare_multi_case_at, CellOutcome, FabricCase,
    VenusMode, VideoCase,
};
