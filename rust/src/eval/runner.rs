//! Experiment runner: prepares ingested video cases and evaluates every
//! method's reasoning accuracy against the shared VLM answer model.
//!
//! One [`VideoCase`] = one synthetic clip, fully ingested through the real
//! Venus pipeline (backend MEM embeddings in the memory index), plus its
//! query set with ground truth.  Baselines select over the same clip via
//! the frame-score oracle; Venus retrieves from its memory.  All methods
//! are judged by the SAME answer model, so accuracy differences come from
//! selection behavior only.
//!
//! [`prepare_multi_case`] is the multi-camera variant: K streams ingested
//! concurrently through one shared embed pool into a K-shard fabric —
//! the substrate for the fabric bench, the multi-stream serve path, and
//! the cross-stream property tests.

use std::sync::Arc;

use anyhow::Result;

use crate::backend::{self, EmbedBackend};
use crate::baselines::{self, frame_scores, Method, SelectionContext};
use crate::cloud::{VlmClient, VlmPersonality};
use crate::config::{CloudConfig, VenusConfig};
use crate::coordinator::query::{QueryEngine, RetrievalMode};
use crate::embed::EmbedEngine;
use crate::ingest::{EmbedPool, IngestStats, Pipeline};
use crate::memory::{
    Hierarchy, MemoryFabric, RawStore, StreamId, SynthBackedRaw,
};
use crate::util::sync::{ranks, OrderedRwLock};
use crate::video::synth::{SynthConfig, VideoSynth};
use crate::video::workload::{DatasetPreset, Query, WorkloadGen};

/// A prepared evaluation case: clip + ingested memory + queries.
pub struct VideoCase {
    pub synth: Arc<VideoSynth>,
    /// the single-stream fabric the query engines run against
    pub fabric: Arc<MemoryFabric>,
    /// stream 0's shard (== the whole memory for a single-stream case)
    pub memory: Arc<OrderedRwLock<Hierarchy>>,
    pub queries: Vec<Query>,
    pub ingest_stats: IngestStats,
    pub preset: DatasetPreset,
}

/// Build the synthetic stream for a preset (codes from the shared embed
/// backend so the MEM can read the watermarks).
pub fn build_synth(preset: DatasetPreset, seed: u64) -> Result<Arc<VideoSynth>> {
    let be = backend::shared_default()?;
    let codes = be.concept_codes()?;
    let patch = be.model().patch;
    let (lo, hi) = preset.scene_len_s();
    Ok(Arc::new(VideoSynth::new(
        SynthConfig {
            duration_s: preset.duration_s(),
            scene_len_s: (lo, hi),
            seed,
            ..Default::default()
        },
        codes,
        patch,
    )))
}

/// Ingest a full clip through the real pipeline and generate queries.
pub fn prepare_case(
    preset: DatasetPreset,
    cfg: &VenusConfig,
    n_queries: usize,
    seed: u64,
) -> Result<VideoCase> {
    prepare_case_at(preset, cfg, n_queries, seed, None)
}

/// Pin the workload a durable data dir was ingested with: the first run
/// writes a `WORKLOAD` marker (preset, seed, streams); later runs must
/// match it exactly, or recovery would silently serve the OLD stream's
/// memory against a different workload's queries — a typed error beats
/// evidence frames from the wrong video.
fn check_workload_marker(
    dir: &std::path::Path,
    preset: DatasetPreset,
    seed: u64,
    streams: usize,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("WORKLOAD");
    let desc = format!("preset {} seed {seed} streams {streams}\n", preset.name());
    match std::fs::read_to_string(&path) {
        Ok(existing) => anyhow::ensure!(
            existing == desc,
            "data dir {} was ingested with '{}' but this run asked for '{}' — \
             wipe the dir or match the original --preset/--seed/--streams",
            dir.display(),
            existing.trim(),
            desc.trim()
        ),
        Err(_) => std::fs::write(&path, desc)?,
    }
    Ok(())
}

/// [`prepare_case`] with an optional durable data dir.  With `Some(dir)`
/// the memory fabric opens on disk (`MemoryFabric::open`): the first run
/// ingests through the real pipeline and flushes; a later run over the
/// same dir *recovers* the memory instead of re-ingesting (its
/// `ingest_stats` are zero — the stream was never replayed), which is
/// the `venus serve --data-dir` restart path.  The dir is pinned to its
/// workload (preset/seed) via a `WORKLOAD` marker — reusing it with a
/// different workload is a typed error, not silently wrong evidence.
pub fn prepare_case_at(
    preset: DatasetPreset,
    cfg: &VenusConfig,
    n_queries: usize,
    seed: u64,
    data_dir: Option<&std::path::Path>,
) -> Result<VideoCase> {
    let synth = build_synth(preset, seed)?;
    // the one process-shared backend serves the d_embed probe and the
    // ingestion engine alike
    let be = backend::shared_default()?;
    let d_embed = be.model().d_embed;
    let (fabric, memory) = match data_dir {
        Some(dir) => {
            check_workload_marker(dir, preset, seed, 1)?;
            let frame_size = synth.config().frame_size;
            let fabric =
                Arc::new(MemoryFabric::open(&cfg.memory, d_embed, 1, frame_size, dir)?);
            let memory = Arc::clone(&fabric.shards()[0]);
            (fabric, memory)
        }
        None => {
            let memory = Arc::new(OrderedRwLock::new(
                ranks::shard(0),
                Hierarchy::new(
                    &cfg.memory,
                    d_embed,
                    Box::new(SynthBackedRaw::new(Arc::clone(&synth))),
                )?,
            ));
            let fabric = Arc::new(MemoryFabric::single(Arc::clone(&memory)));
            (fabric, memory)
        }
    };
    let recovered = memory.read().len() > 0;
    let ingest_stats = if recovered {
        // honesty check: a dir left by a run killed mid-ingest recovers
        // to a truncated memory — serve it (it is self-consistent), but
        // never silently pretend it covers the whole stream
        let frames = memory.read().frames_ingested();
        if frames < synth.total_frames() {
            eprintln!(
                "warning: recovered memory covers {frames}/{} frames of the configured \
                 stream (a previous run stopped mid-ingest); wipe the data dir to \
                 re-ingest from scratch",
                synth.total_frames()
            );
        }
        IngestStats::default()
    } else {
        let engine = EmbedEngine::new(be, cfg.ingest.aux_models)?;
        let mut pipe =
            Pipeline::new(&cfg.ingest, synth.config().fps, engine, Arc::clone(&memory))?;
        for i in 0..synth.total_frames() {
            pipe.push_frame(i, &synth.frame(i))?;
        }
        let stats = pipe.finish()?;
        fabric.flush()?; // durability point: no-op for pure-RAM fabrics
        stats
    };
    let queries = WorkloadGen::new(seed ^ 0x9, preset).generate(synth.script(), n_queries);
    Ok(VideoCase { synth, fabric, memory, queries, ingest_stats, preset })
}

/// A prepared multi-camera case: K streams, one fabric, per-stream
/// queries tagged with their ground-truth stream.
pub struct FabricCase {
    pub synths: Vec<Arc<VideoSynth>>,
    pub fabric: Arc<MemoryFabric>,
    /// (owning stream, query) — evidence spans are stream-local
    pub queries: Vec<(StreamId, Query)>,
    pub ingest_stats: Vec<IngestStats>,
}

/// Ingest K synthetic streams concurrently — one pipeline thread per
/// stream, all feeding one shared embed pool — into a K-shard fabric.
pub fn prepare_multi_case(
    preset: DatasetPreset,
    cfg: &VenusConfig,
    streams: usize,
    queries_per_stream: usize,
    seed: u64,
) -> Result<FabricCase> {
    prepare_multi_case_at(preset, cfg, streams, queries_per_stream, seed, None)
}

/// [`prepare_multi_case`] with an optional durable data dir: with
/// `Some(dir)` the K-shard fabric opens on disk and a non-empty recovery
/// skips re-ingesting (per-stream `ingest_stats` are zero).
pub fn prepare_multi_case_at(
    preset: DatasetPreset,
    cfg: &VenusConfig,
    streams: usize,
    queries_per_stream: usize,
    seed: u64,
    data_dir: Option<&std::path::Path>,
) -> Result<FabricCase> {
    anyhow::ensure!(streams >= 1, "need at least one stream");
    let be = backend::shared_default()?;
    let d_embed = be.model().d_embed;

    let synths: Vec<Arc<VideoSynth>> = (0..streams)
        .map(|s| build_synth(preset, seed.wrapping_add(s as u64 * 0x9e37)))
        .collect::<Result<_>>()?;
    let fabric = match data_dir {
        Some(dir) => {
            check_workload_marker(dir, preset, seed, streams)?;
            Arc::new(MemoryFabric::open(
                &cfg.memory,
                d_embed,
                streams,
                synths[0].config().frame_size,
                dir,
            )?)
        }
        None => {
            let raws: Vec<Box<dyn RawStore>> = synths
                .iter()
                .map(|s| Box::new(SynthBackedRaw::new(Arc::clone(s))) as Box<dyn RawStore>)
                .collect();
            Arc::new(MemoryFabric::new(&cfg.memory, d_embed, raws)?)
        }
    };

    let ingest_stats = if fabric.total_indexed() > 0 {
        // recovered from disk: the streams were already ingested by a
        // previous process — nothing to replay (but never silently
        // pretend a mid-ingest crash left complete coverage)
        for (i, synth) in synths.iter().enumerate() {
            let frames = fabric.shard(StreamId(i as u16))?.read().frames_ingested();
            if frames < synth.total_frames() {
                eprintln!(
                    "warning: stream {i} recovered {frames}/{} frames (a previous run \
                     stopped mid-ingest); wipe the data dir to re-ingest from scratch",
                    synth.total_frames()
                );
            }
        }
        vec![IngestStats::default(); streams]
    } else {
        // pool sized for THIS case's stream count (cfg.fabric.streams may
        // describe the deployment, not the experiment)
        let pool_cfg = crate::config::FabricConfig {
            streams,
            pool_workers: cfg.fabric.pool_workers,
        };
        let pool = EmbedPool::start(
            be,
            cfg.ingest.aux_models,
            pool_cfg.resolved_pool_workers(),
            cfg.ingest.queue_capacity,
        )?;

        // one ingestion thread per camera
        let mut handles = Vec::new();
        for (i, synth) in synths.iter().enumerate() {
            let shard = Arc::clone(fabric.shard(StreamId(i as u16))?);
            let mut pipe = Pipeline::attach(&cfg.ingest, synth.config().fps, &pool, shard)?;
            let synth = Arc::clone(synth);
            handles.push(std::thread::spawn(move || -> Result<IngestStats> {
                for f in 0..synth.total_frames() {
                    pipe.push_frame(f, &synth.frame(f))?;
                }
                pipe.finish()
            }));
        }
        let mut stats = Vec::new();
        for h in handles {
            stats.push(h.join().map_err(|_| anyhow::anyhow!("ingest thread panicked"))??);
        }
        pool.shutdown()?;
        fabric.flush()?; // durability point: no-op for pure-RAM fabrics
        stats
    };
    fabric.check_invariants()?;

    let mut queries = Vec::new();
    for (i, synth) in synths.iter().enumerate() {
        let qs = WorkloadGen::new(seed ^ 0x9 ^ i as u64, preset)
            .generate(synth.script(), queries_per_stream);
        queries.extend(qs.into_iter().map(|q| (StreamId(i as u16), q)));
    }
    Ok(FabricCase { synths, fabric, queries, ingest_stats })
}

/// Accuracy + selection-size outcome of one method on one case.
#[derive(Clone, Copy, Debug, Default)]
pub struct CellOutcome {
    pub correct: usize,
    pub total: usize,
    pub mean_frames: f64,
    /// mean AKR draws (Venus-AKR only; == budget otherwise)
    pub mean_draws: f64,
}

impl CellOutcome {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }

    pub fn merge(&mut self, other: &CellOutcome) {
        let frames_sum = self.mean_frames * self.total as f64
            + other.mean_frames * other.total as f64;
        let draws_sum =
            self.mean_draws * self.total as f64 + other.mean_draws * other.total as f64;
        self.correct += other.correct;
        self.total += other.total;
        if self.total > 0 {
            self.mean_frames = frames_sum / self.total as f64;
            self.mean_draws = draws_sum / self.total as f64;
        }
    }
}

/// Venus retrieval flavor under evaluation.
#[derive(Clone, Copy, Debug)]
pub enum VenusMode {
    FixedSampling(usize),
    Akr,
    TopK(usize),
}

/// Evaluate a *baseline* method over a case.
pub fn eval_baseline(
    case: &VideoCase,
    method: Method,
    budget: usize,
    personality: VlmPersonality,
    seed: u64,
) -> CellOutcome {
    let cloud_cfg = CloudConfig { vlm: personality.name().into(), ..Default::default() };
    let mut vlm = VlmClient::new(cloud_cfg, seed);
    let total = case.synth.total_frames();
    let mut out = CellOutcome { mean_draws: budget as f64, ..Default::default() };
    let mut frames_sum = 0usize;
    for q in &case.queries {
        let scores;
        let ctx = SelectionContext {
            synth: &case.synth,
            query: q,
            total,
            scores: if method.query_relevant() {
                scores = frame_scores(case.synth.script(), q, total, seed);
                Some(&scores)
            } else {
                None
            },
            seed,
        };
        let sel = baselines::select(method, &ctx, budget);
        frames_sum += sel.len();
        let (correct, _) = vlm.judge(q, case.synth.script(), &sel);
        out.correct += correct as usize;
        out.total += 1;
    }
    out.mean_frames = frames_sum as f64 / out.total.max(1) as f64;
    out
}

/// Evaluate Venus (real memory retrieval) over a case.
pub fn eval_venus(
    case: &VideoCase,
    mode: VenusMode,
    cfg: &VenusConfig,
    personality: VlmPersonality,
    seed: u64,
) -> Result<CellOutcome> {
    let cloud_cfg = CloudConfig { vlm: personality.name().into(), ..Default::default() };
    let mut vlm = VlmClient::new(cloud_cfg, seed);
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(cfg.ingest.aux_models)?,
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        seed,
    );
    let rmode = match mode {
        VenusMode::FixedSampling(n) => RetrievalMode::FixedSampling(n),
        VenusMode::Akr => RetrievalMode::Akr,
        VenusMode::TopK(k) => RetrievalMode::TopK(k),
    };
    let mut out = CellOutcome::default();
    let mut frames_sum = 0usize;
    let mut draws_sum = 0usize;
    for q in &case.queries {
        let res = qe.retrieve_with(&q.text, rmode)?;
        frames_sum += res.selection.frames.len();
        draws_sum += res.draws;
        let (correct, _) =
            vlm.judge(q, case.synth.script(), &res.selection.frame_indices());
        out.correct += correct as usize;
        out.total += 1;
    }
    out.mean_frames = frames_sum as f64 / out.total.max(1) as f64;
    out.mean_draws = draws_sum as f64 / out.total.max(1) as f64;
    Ok(out)
}

/// Mean measured edge-side query latency of Venus on a case (seconds).
pub fn measure_venus_edge_latency(
    case: &VideoCase,
    cfg: &VenusConfig,
    budget: usize,
    seed: u64,
) -> Result<f64> {
    let mut qe = QueryEngine::new(
        EmbedEngine::default_backend(cfg.ingest.aux_models)?,
        Arc::clone(&case.fabric),
        cfg.retrieval.clone(),
        seed,
    );
    let mut total = 0.0;
    let n = case.queries.len().min(16);
    for q in case.queries.iter().take(n) {
        let res = qe.retrieve_with(&q.text, RetrievalMode::FixedSampling(budget))?;
        total += res.timings.total_s();
    }
    Ok(total / n as f64)
}
