//! Native Eq. 1 perception features: HSL conversion, Sobel edge energy,
//! and pooled frame feature vectors.
//!
//! This is the hot perception front-end (runs on every captured frame at
//! stream rate), so it has a pure-Rust implementation; numerics mirror the
//! Pallas `scene_score` kernel / `ref.py` oracle bit-for-bit in structure
//! (cross-validated by `rust/tests/native_vs_artifact.rs`).  Per Eq. 1 the
//! scene score is a weighted L1 distance between consecutive frames'
//! pooled (H, S, L, E) maps.

use crate::video::frame::Frame;

/// Pooling grid per side (4 ⇒ 16 cells ⇒ 64-dim feature vector).
pub const POOL: usize = 4;
/// Feature vector length: 4 channels × POOL².
pub const FEAT_DIM: usize = 4 * POOL * POOL;

/// Per-channel Eq. 1 weights (hue, saturation, lightness, edge).
#[derive(Clone, Copy, Debug)]
pub struct ChannelWeights {
    pub hue: f32,
    pub saturation: f32,
    pub lightness: f32,
    pub edge: f32,
}

impl Default for ChannelWeights {
    fn default() -> Self {
        // edge map weighted up, as in content-aware shot detection practice
        Self { hue: 1.0, saturation: 1.0, lightness: 1.0, edge: 2.0 }
    }
}

/// RGB → (hue, saturation, lightness), all in [0, 1].
#[inline]
pub fn rgb_to_hsl(r: f32, g: f32, b: f32) -> (f32, f32, f32) {
    let mx = r.max(g).max(b);
    let mn = r.min(g).min(b);
    let c = mx - mn;
    let l = 0.5 * (mx + mn);
    if c < 1e-8 {
        return (0.0, 0.0, l);
    }
    let s = c / (1.0 - (2.0 * l - 1.0).abs() + 1e-8);
    let h = if mx == r {
        ((g - b) / c).rem_euclid(6.0)
    } else if mx == g {
        (b - r) / c + 2.0
    } else {
        (r - g) / c + 4.0
    };
    (h / 6.0, s, l)
}

/// Eq. 1 feature vector of a frame: pooled (H, S, L, SobelEnergy) means,
/// laid out `[h_cells..., s_cells..., l_cells..., e_cells...]` row-major —
/// identical to `ref.scene_features_one`.
pub fn frame_features(frame: &Frame) -> Vec<f32> {
    let size = frame.size();
    let cell = size / POOL;
    let mut h_plane = vec![0.0f32; size * size];
    let mut s_plane = vec![0.0f32; size * size];
    let mut l_plane = vec![0.0f32; size * size];

    for y in 0..size {
        for x in 0..size {
            let (r, g, b) = frame.rgb(y, x);
            let (h, s, l) = rgb_to_hsl(r, g, b);
            let i = y * size + x;
            h_plane[i] = h;
            s_plane[i] = s;
            l_plane[i] = l;
        }
    }

    // Sobel magnitude over lightness with edge-replicated padding
    let mut e_plane = vec![0.0f32; size * size];
    let at = |y: isize, x: isize| -> f32 {
        let yy = y.clamp(0, size as isize - 1) as usize;
        let xx = x.clamp(0, size as isize - 1) as usize;
        l_plane[yy * size + xx]
    };
    for y in 0..size as isize {
        for x in 0..size as isize {
            let (tl, tc, tr) = (at(y - 1, x - 1), at(y - 1, x), at(y - 1, x + 1));
            let (ml, mr) = (at(y, x - 1), at(y, x + 1));
            let (bl, bc, br) = (at(y + 1, x - 1), at(y + 1, x), at(y + 1, x + 1));
            let gx = (tr + 2.0 * mr + br) - (tl + 2.0 * ml + bl);
            let gy = (bl + 2.0 * bc + br) - (tl + 2.0 * tc + tr);
            e_plane[y as usize * size + x as usize] = (gx * gx + gy * gy + 1e-12).sqrt();
        }
    }

    let mut out = Vec::with_capacity(FEAT_DIM);
    for plane in [&h_plane, &s_plane, &l_plane, &e_plane] {
        for cy in 0..POOL {
            for cx in 0..POOL {
                let mut sum = 0.0f32;
                for y in cy * cell..(cy + 1) * cell {
                    for x in cx * cell..(cx + 1) * cell {
                        sum += plane[y * size + x];
                    }
                }
                out.push(sum / (cell * cell) as f32);
            }
        }
    }
    out
}

/// Eq. 1 scene-tracking score between two feature vectors.
pub fn scene_score(a: &[f32], b: &[f32], w: ChannelWeights) -> f32 {
    debug_assert_eq!(a.len(), FEAT_DIM);
    debug_assert_eq!(b.len(), FEAT_DIM);
    let p2 = POOL * POOL;
    let ws = [w.hue, w.saturation, w.lightness, w.edge];
    let mut num = 0.0f32;
    for (ch, &wc) in ws.iter().enumerate() {
        let mut acc = 0.0f32;
        for i in ch * p2..(ch + 1) * p2 {
            acc += (a[i] - b[i]).abs();
        }
        num += wc * acc;
    }
    // Eq. 1 normalizes by ||w||_1 over the full weight vector (each channel
    // weight repeated per cell), hence the p2 factor in the denominator.
    let denom: f32 = ws.iter().sum::<f32>() * p2 as f32;
    num / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::frame::Frame;

    #[test]
    fn hsl_primaries() {
        let (h, s, _) = rgb_to_hsl(1.0, 0.0, 0.0);
        assert!(h.abs() < 1e-6 && (s - 1.0).abs() < 1e-4);
        let (h, _, _) = rgb_to_hsl(0.0, 1.0, 0.0);
        assert!((h - 1.0 / 3.0).abs() < 1e-6);
        let (h, _, _) = rgb_to_hsl(0.0, 0.0, 1.0);
        assert!((h - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn hsl_gray_has_zero_saturation() {
        let (h, s, l) = rgb_to_hsl(0.5, 0.5, 0.5);
        assert_eq!((h, s), (0.0, 0.0));
        assert!((l - 0.5).abs() < 1e-6);
    }

    #[test]
    fn constant_frame_features() {
        let f = Frame::filled(64, [0.5, 0.5, 0.5]);
        let feat = frame_features(&f);
        let p2 = POOL * POOL;
        // hue 0, sat 0, light 0.5, edges ~0
        assert!(feat[..p2].iter().all(|&x| x == 0.0));
        assert!(feat[p2..2 * p2].iter().all(|&x| x == 0.0));
        assert!(feat[2 * p2..3 * p2].iter().all(|&x| (x - 0.5).abs() < 1e-6));
        assert!(feat[3 * p2..].iter().all(|&x| x < 1e-3));
    }

    #[test]
    fn vertical_edge_energy_in_middle_columns() {
        let mut f = Frame::filled(64, [0.0, 0.0, 0.0]);
        for y in 0..64 {
            for x in 32..64 {
                f.set_rgb(y, x, [1.0, 1.0, 1.0]);
            }
        }
        let feat = frame_features(&f);
        let p2 = POOL * POOL;
        let edges = &feat[3 * p2..];
        let mid: f32 = (0..POOL).map(|cy| edges[cy * POOL + 1] + edges[cy * POOL + 2]).sum();
        let border: f32 = (0..POOL).map(|cy| edges[cy * POOL]).sum();
        assert!(mid > 10.0 * border.max(1e-6));
    }

    #[test]
    fn scene_score_zero_for_identical() {
        let f = Frame::filled(64, [0.3, 0.6, 0.9]);
        let a = frame_features(&f);
        assert!(scene_score(&a, &a, ChannelWeights::default()).abs() < 1e-7);
    }

    #[test]
    fn scene_score_larger_for_bigger_change() {
        let a = frame_features(&Frame::filled(64, [0.2, 0.2, 0.2]));
        let b = frame_features(&Frame::filled(64, [0.25, 0.25, 0.25]));
        let c = frame_features(&Frame::filled(64, [0.9, 0.9, 0.9]));
        let w = ChannelWeights::default();
        assert!(scene_score(&a, &c, w) > scene_score(&a, &b, w));
    }
}
