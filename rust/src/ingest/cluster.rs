//! Incremental frame clustering within a scene partition (§IV-B-2).
//!
//! Leader clustering, as in the paper: the first frame seeds cluster c₁;
//! each subsequent frame joins the nearest existing cluster if its L2
//! pixel distance to that cluster's centroid is within the threshold,
//! otherwise it seeds a new cluster.  Centroid frames become the *indexed
//! frames* that get embedded into memory; members stay temporally
//! contiguous-ish by construction (clusters are per-partition).

use crate::video::frame::Frame;

/// One cluster of visually-similar frames inside a partition.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// global frame id of the centroid (leader) frame
    pub centroid_id: u64,
    /// the centroid pixels (kept for embedding)
    pub centroid: Frame,
    /// member frame ids (includes the centroid), insertion order
    pub members: Vec<u64>,
}

impl Cluster {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Incremental clusterer for one partition.
pub struct PartitionClusterer {
    threshold: f32,
    clusters: Vec<Cluster>,
}

impl PartitionClusterer {
    pub fn new(threshold: f32) -> Self {
        Self { threshold, clusters: Vec::new() }
    }

    /// Assign a frame to a cluster (creating one if needed); returns the
    /// cluster index it joined.
    pub fn push(&mut self, frame_id: u64, frame: &Frame) -> usize {
        let mut best: Option<(usize, f32)> = None;
        for (i, c) in self.clusters.iter().enumerate() {
            // bounded distance: abort as soon as this centroid can no
            // longer beat the running best (or the join threshold)
            let bound = best.map_or(self.threshold, |(_, bd)| bd.min(self.threshold));
            let d = frame.l2_distance_bounded(&c.centroid, bound);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((i, d));
            }
        }
        match best {
            Some((i, d)) if d <= self.threshold => {
                self.clusters[i].members.push(frame_id);
                i
            }
            _ => {
                self.clusters.push(Cluster {
                    centroid_id: frame_id,
                    centroid: frame.clone(),
                    members: vec![frame_id],
                });
                self.clusters.len() - 1
            }
        }
    }

    /// Finish the partition, yielding its clusters.
    pub fn finish(self) -> Vec<Cluster> {
        self.clusters
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::video::synth::{SynthConfig, VideoSynth};

    #[test]
    fn identical_frames_one_cluster() {
        let mut c = PartitionClusterer::new(0.05);
        let f = Frame::filled(64, [0.5; 3]);
        for i in 0..10 {
            c.push(i, &f);
        }
        let clusters = c.finish();
        assert_eq!(clusters.len(), 1);
        assert_eq!(clusters[0].members.len(), 10);
        assert_eq!(clusters[0].centroid_id, 0);
    }

    #[test]
    fn distinct_frames_new_clusters() {
        let mut c = PartitionClusterer::new(0.05);
        c.push(0, &Frame::filled(64, [0.1; 3]));
        c.push(1, &Frame::filled(64, [0.5; 3]));
        c.push(2, &Frame::filled(64, [0.9; 3]));
        assert_eq!(c.n_clusters(), 3);
    }

    #[test]
    fn joins_nearest_cluster() {
        let mut c = PartitionClusterer::new(0.15);
        c.push(0, &Frame::filled(64, [0.1; 3]));
        c.push(1, &Frame::filled(64, [0.9; 3]));
        let joined = c.push(2, &Frame::filled(64, [0.82; 3]));
        assert_eq!(joined, 1);
    }

    #[test]
    fn members_are_conserved() {
        // property: every pushed frame appears in exactly one cluster
        let mut rng = Pcg64::seeded(31);
        let codes = (0..8)
            .map(|_| (0..192).map(|_| rng.f32()).collect())
            .collect();
        let synth = VideoSynth::new(
            SynthConfig { duration_s: 20.0, seed: 4, ..Default::default() },
            codes,
            8,
        );
        let mut c = PartitionClusterer::new(0.085);
        let n = synth.total_frames().min(80);
        for i in 0..n {
            c.push(i, &synth.frame(i));
        }
        let clusters = c.finish();
        let mut all: Vec<u64> = clusters.iter().flat_map(|c| c.members.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
        // compression happened: fewer clusters than frames
        assert!(clusters.len() < n as usize / 2, "{} clusters", clusters.len());
    }

    #[test]
    fn centroid_is_first_member() {
        let mut c = PartitionClusterer::new(0.2);
        c.push(7, &Frame::filled(64, [0.3; 3]));
        c.push(8, &Frame::filled(64, [0.31; 3]));
        let clusters = c.finish();
        assert_eq!(clusters[0].centroid_id, 7);
        assert_eq!(clusters[0].members, vec![7, 8]);
    }
}
