//! Ingestion stage (§IV-B): streaming scene segmentation, incremental
//! clustering, and the threaded perception pipeline that feeds the
//! hierarchical memory in real time.

pub mod cluster;
pub mod pipeline;
pub mod scene;

pub use cluster::{Cluster, PartitionClusterer};
pub use pipeline::{IngestStats, Pipeline};
pub use scene::{Partition, SceneSegmenter};
