//! Ingestion stage (§IV-B): streaming scene segmentation, incremental
//! clustering, the per-stream perception pipelines, and the shared embed
//! worker pool that batches MEM compute across camera streams.

pub mod cluster;
pub mod pipeline;
pub mod pool;
pub mod scene;

pub use cluster::{Cluster, PartitionClusterer};
pub use pipeline::{IngestStats, Pipeline};
pub use pool::{EmbedPool, PoolGaugeSnapshot, PoolGauges};
pub use scene::{Partition, SceneSegmenter};
