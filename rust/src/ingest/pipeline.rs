//! Streaming ingestion pipeline (Fig. 6, ingestion stage) — the
//! per-stream front-end of the multi-camera fabric.
//!
//! The caller (camera driver) pushes frames; the pipeline:
//!   1. archives every frame to its stream's shard (raw layer),
//!   2. computes Eq. 1 features and runs scene segmentation,
//!   3. clusters frames incrementally within the open partition,
//!   4. hands completed partitions to the [`EmbedPool`] — the shared
//!      worker pool that coalesces partitions *across streams* into full
//!      MEM batches and inserts indexed vectors into each stream's shard.
//!
//! The pool channel is bounded: if embedding falls behind the stream,
//! `push_frame` blocks — the backpressure the paper's challenge ①
//! describes.  Because only sparse centroids are embedded, the pipeline
//! sustains far higher FPS than frame-wise embedding (Fig. 4 vs Venus).
//!
//! Single-camera deployments use [`Pipeline::new`], which owns a private
//! single-worker pool (same behavior as the historical dedicated embed
//! thread).  Multi-camera deployments build one [`EmbedPool`] and attach
//! N pipelines to it with [`Pipeline::attach`].

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::IngestConfig;
use crate::embed::EmbedEngine;
use crate::features::frame_features;
use crate::ingest::cluster::PartitionClusterer;
use crate::ingest::pool::{EmbedPool, PoolJob, PoolSender, StreamProgress};
use crate::ingest::scene::SceneSegmenter;
use crate::memory::{Hierarchy, StreamId};
use crate::util::sync::OrderedRwLock;
use crate::video::frame::Frame;

/// Ingestion statistics for the run.
#[derive(Clone, Debug, Default)]
pub struct IngestStats {
    pub frames: u64,
    pub partitions: usize,
    pub clusters: usize,
    pub embedded: usize,
    pub embed_batches: usize,
    /// mean wall time per embed batch call (seconds, measured; for
    /// pool-coalesced batches, this stream's cluster-share of the wall)
    pub mean_embed_batch_s: f64,
    /// mean wall time per embedded (indexed) frame
    pub mean_embed_frame_s: f64,
    /// total pipeline wall time
    pub wall_s: f64,
}

/// The streaming ingestion pipeline (one camera stream).
pub struct Pipeline {
    cfg: IngestConfig,
    stream: StreamId,
    shard: Arc<OrderedRwLock<Hierarchy>>,
    tx: Option<PoolSender>,
    owned_pool: Option<EmbedPool>,
    progress: Arc<StreamProgress>,
    /// pool liveness (worker count) — guards the drain wait in `finish`
    pool_alive: Arc<std::sync::atomic::AtomicUsize>,
    seg: SceneSegmenter,
    clusterer: PartitionClusterer,
    frames: u64,
    partitions: usize,
    started: Instant,
}

impl Pipeline {
    /// Single-stream pipeline owning a private single-worker pool that
    /// consumes `engine`; `memory` is shared with the query path.
    ///
    /// Fallible: backend warm-up runs here so a broken backend (missing /
    /// mismatched artifacts, corrupt entry) surfaces at construction with
    /// context, not as a confusing mid-stream embed error after frames are
    /// already flowing.
    pub fn new(
        cfg: &IngestConfig,
        fps: f64,
        engine: EmbedEngine,
        memory: Arc<OrderedRwLock<Hierarchy>>,
    ) -> Result<Self> {
        let pool = EmbedPool::with_engine(engine, cfg.queue_capacity)?;
        let mut pipe = Self::attach(cfg, fps, &pool, memory)?;
        pipe.owned_pool = Some(pool);
        Ok(pipe)
    }

    /// Attach a per-stream front-end to a shared [`EmbedPool`].  The
    /// stream identity comes from the shard (built via
    /// `Hierarchy::for_stream` / `MemoryFabric::new`).
    pub fn attach(
        cfg: &IngestConfig,
        fps: f64,
        pool: &EmbedPool,
        memory: Arc<OrderedRwLock<Hierarchy>>,
    ) -> Result<Self> {
        let stream = memory.read().stream();
        Ok(Self {
            cfg: cfg.clone(),
            stream,
            shard: memory,
            tx: Some(pool.sender()),
            owned_pool: None,
            progress: StreamProgress::new(),
            pool_alive: pool.alive_handle(),
            seg: SceneSegmenter::new(cfg, fps),
            clusterer: PartitionClusterer::new(cfg.cluster_threshold),
            frames: 0,
            partitions: 0,
            started: Instant::now(),
        })
    }

    /// The camera stream this pipeline feeds.
    pub fn stream(&self) -> StreamId {
        self.stream
    }

    fn submit_partition(&mut self, scene_id: usize) -> Result<()> {
        let done = std::mem::replace(
            &mut self.clusterer,
            PartitionClusterer::new(self.cfg.cluster_threshold),
        );
        self.partitions += 1;
        self.tx.as_ref().unwrap().send(PoolJob {
            stream: self.stream,
            scene_id,
            clusters: done.finish(),
            shard: Arc::clone(&self.shard),
            progress: Arc::clone(&self.progress),
        })
    }

    /// Feed the next captured frame (stream-local ids, dense ascending).
    pub fn push_frame(&mut self, id: u64, frame: &Frame) -> Result<()> {
        self.shard.write().archive_frame(id, frame)?;
        let feat = frame_features(frame);
        if let Some(part) = self.seg.push_features(feat) {
            self.submit_partition(part.id)?;
        }
        self.clusterer.push(id, frame);
        self.frames += 1;
        Ok(())
    }

    /// Close the stream: flush the open partition, wait for the pool to
    /// drain this stream's partitions, and return run statistics.
    pub fn finish(mut self) -> Result<IngestStats> {
        if let Some(part) = self.seg.finish() {
            self.submit_partition(part.id)?;
        }
        drop(self.tx.take()); // release our sender; an owned pool's queue closes
        let out = if let Some(pool) = self.owned_pool.take() {
            // private pool: join its worker, then read the final state —
            // never blocks on a dead worker
            pool.shutdown()?;
            let st = self.progress.snapshot();
            anyhow::ensure!(
                st.partitions_done >= self.partitions || st.error.is_some(),
                "embed worker died with partitions pending"
            );
            st
        } else {
            // shared pool: other streams keep it alive; wait for ours
            // (the alive counter turns a dead pool into an error, not a
            // hang)
            self.progress
                .wait_partitions(self.partitions, &self.pool_alive)
        };
        if let Some(e) = out.error {
            anyhow::bail!("embed stage failed: {e}");
        }
        Ok(IngestStats {
            frames: self.frames,
            partitions: self.partitions,
            clusters: out.clusters,
            embedded: out.embedded,
            embed_batches: out.batches,
            mean_embed_batch_s: if out.batches > 0 {
                out.batch_time_s / out.batches as f64
            } else {
                0.0
            },
            mean_embed_frame_s: if out.embedded > 0 {
                out.batch_time_s / out.embedded as f64
            } else {
                0.0
            },
            wall_s: self.started.elapsed().as_secs_f64(),
        })
    }

    pub fn frames_pushed(&self) -> u64 {
        self.frames
    }

    /// Partitions handed to the pool so far (the denominator the ingest
    /// hub polls [`progress`](Self::progress_snapshot) against).
    pub fn partitions_submitted(&self) -> usize {
        self.partitions
    }

    /// Completed-partition count from the pool side: how many of this
    /// stream's submitted partitions are embedded, inserted, and thus
    /// queryable.  The wire-ingest freshness metric is derived from the
    /// delta between submissions and this number.
    pub fn partitions_completed(&self) -> usize {
        self.progress.snapshot().partitions_done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{EmbedBackend, ModelMeta};
    use crate::config::MemoryConfig;
    use crate::memory::{InMemoryRaw, MemoryFabric, RawStore};
    use crate::util::sync::ranks;

    /// A backend whose warm-up fails — stands in for a broken artifact set.
    struct BrokenBackend(ModelMeta);

    impl BrokenBackend {
        fn shared() -> Arc<dyn EmbedBackend> {
            Arc::new(Self(ModelMeta {
                img_size: 16,
                patch: 8,
                d_embed: 8,
                seq_len: 16,
                vocab: 512,
                n_concepts: 4,
                concept_token_base: 2,
                sim_rows: 64,
                scene_feat_dim: 64,
                sem_weight: 4.0,
                content_weight: 1.0,
                aux_weight: 0.5,
            }))
        }
    }

    impl EmbedBackend for BrokenBackend {
        fn name(&self) -> &'static str {
            "broken"
        }
        fn model(&self) -> &ModelMeta {
            &self.0
        }
        fn image_batches(&self) -> Vec<usize> {
            vec![1]
        }
        fn has_fused(&self, _batch: usize) -> bool {
            false
        }
        fn warmup(&self, _entries: &[&str]) -> Result<()> {
            anyhow::bail!("artifact 'embed_image_b1' is corrupt")
        }
        fn embed_image(&self, _frames: &[f32], _batch: usize) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn embed_text(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
            anyhow::bail!("unreachable in this test")
        }
        fn embed_fused(
            &self,
            _frames: &[f32],
            _aux: &[i32],
            _batch: usize,
        ) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn scene_features(&self, _frames: &[f32], _batch: usize) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn similarity(
            &self,
            _q: &[f32],
            _i: &[f32],
            _n: usize,
            _tau: f32,
        ) -> Result<(Vec<f32>, Vec<f32>)> {
            anyhow::bail!("unreachable in this test")
        }
        fn concept_codes(&self) -> Result<Vec<Vec<f32>>> {
            Ok(vec![vec![0.5; 8 * 8 * 3]; 4])
        }
        fn concept_dirs(&self) -> Result<Vec<Vec<f32>>> {
            Ok(vec![vec![0.0; 8]; 4])
        }
    }

    #[test]
    fn broken_backend_fails_at_construction_not_mid_stream() {
        let engine = EmbedEngine::new(BrokenBackend::shared(), false).unwrap();
        let memory = Arc::new(OrderedRwLock::new(
            ranks::shard(0),
            Hierarchy::new(&MemoryConfig::default(), 8, Box::new(InMemoryRaw::new(16)))
                .unwrap(),
        ));
        let err = Pipeline::new(&IngestConfig::default(), 8.0, engine, memory)
            .err()
            .expect("warm-up failure must propagate from Pipeline::new");
        let msg = format!("{err:#}");
        assert!(msg.contains("warm-up"), "context missing: {msg}");
        assert!(msg.contains("corrupt"), "root cause missing: {msg}");
    }

    #[test]
    fn healthy_backend_constructs() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let memory = Arc::new(OrderedRwLock::new(
            ranks::shard(0),
            Hierarchy::new(&MemoryConfig::default(), d, Box::new(InMemoryRaw::new(64)))
                .unwrap(),
        ));
        let pipe = Pipeline::new(&IngestConfig::default(), 8.0, engine, memory).unwrap();
        assert_eq!(pipe.frames_pushed(), 0);
        pipe.finish().unwrap();
    }

    /// Two pipelines share one pool: partitions from both streams coalesce
    /// through the same workers, yet land in their own shards.
    #[test]
    fn shared_pool_routes_partitions_to_their_shards() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let backend = engine.backend_arc();
        drop(engine);

        let raws: Vec<Box<dyn RawStore>> = (0..2)
            .map(|_| Box::new(InMemoryRaw::new(64)) as Box<dyn RawStore>)
            .collect();
        let fabric =
            Arc::new(MemoryFabric::new(&MemoryConfig::default(), d, raws).unwrap());
        let pool = EmbedPool::start(backend, false, 2, 64).unwrap();

        let cfg = IngestConfig { max_partition_s: 1.0, ..Default::default() };
        let mut pipes: Vec<Pipeline> = fabric
            .shards()
            .iter()
            .map(|shard| Pipeline::attach(&cfg, 8.0, &pool, Arc::clone(shard)).unwrap())
            .collect();

        // distinct flat-color ramps per stream → every frame clusters
        for i in 0..64u64 {
            let shade = (i % 8) as f32 / 8.0;
            pipes[0].push_frame(i, &Frame::filled(64, [shade, 0.2, 0.2])).unwrap();
            pipes[1].push_frame(i, &Frame::filled(64, [0.2, shade, 0.2])).unwrap();
        }
        let mut embedded = 0;
        for pipe in pipes.drain(..) {
            let stats = pipe.finish().unwrap();
            assert_eq!(stats.frames, 64);
            assert!(stats.embedded > 0, "stream embedded nothing");
            embedded += stats.embedded;
        }
        pool.shutdown().unwrap();

        fabric.check_invariants().unwrap();
        assert_eq!(fabric.total_indexed(), embedded);
        for shard in fabric.shards() {
            let g = shard.read();
            assert!(!g.is_empty(), "each shard received its own partitions");
            assert_eq!(g.frames_ingested(), 64);
        }
    }
}
