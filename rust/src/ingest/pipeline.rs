//! Threaded streaming ingestion pipeline (Fig. 6, ingestion stage).
//!
//! The caller (camera driver) pushes frames; the pipeline:
//!   1. archives every frame to the raw layer,
//!   2. computes Eq. 1 features and runs scene segmentation,
//!   3. clusters frames incrementally within the open partition,
//!   4. hands completed partitions to a dedicated *embed thread* that
//!      owns the embed engine, batches centroid frames through the MEM,
//!      and inserts indexed vectors into the hierarchical memory.
//!
//! The partition channel is bounded: if embedding falls behind the
//! stream, `push_frame` blocks — the backpressure the paper's challenge ①
//! describes.  Because only sparse centroids are embedded, the pipeline
//! sustains far higher FPS than frame-wise embedding (Fig. 4 vs Venus).
//!
//! The shared memory is an `RwLock`: this pipeline is the only writer
//! (frame archival + index inserts); the query path takes read locks, so
//! concurrent queries never serialize against each other and only overlap
//! writers for the narrow insert/archive critical sections.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::IngestConfig;
use crate::embed::EmbedEngine;
use crate::features::frame_features;
use crate::ingest::cluster::{Cluster, PartitionClusterer};
use crate::ingest::scene::SceneSegmenter;
use crate::memory::{ClusterRecord, Hierarchy};
use crate::video::frame::Frame;

/// Ingestion statistics for the run.
#[derive(Clone, Debug, Default)]
pub struct IngestStats {
    pub frames: u64,
    pub partitions: usize,
    pub clusters: usize,
    pub embedded: usize,
    pub embed_batches: usize,
    /// mean wall time per embed batch call (seconds, measured)
    pub mean_embed_batch_s: f64,
    /// mean wall time per embedded (indexed) frame
    pub mean_embed_frame_s: f64,
    /// total pipeline wall time
    pub wall_s: f64,
}

enum WorkItem {
    Partition { scene_id: usize, clusters: Vec<Cluster> },
}

/// EmbedEngine may wrap PJRT raw pointers and is not auto-Send; we move it
/// into exactly one embed thread and never alias it.  The PJRT CPU client
/// is safe to drive from the single owning thread (the native backend is
/// plain data and trivially safe).
struct SendEngine(EmbedEngine);
unsafe impl Send for SendEngine {}

struct EmbedWorkerOut {
    clusters: usize,
    embedded: usize,
    batches: usize,
    mean_batch_s: f64,
}

/// The streaming ingestion pipeline.
pub struct Pipeline {
    cfg: IngestConfig,
    memory: Arc<RwLock<Hierarchy>>,
    tx: Option<SyncSender<WorkItem>>,
    worker: Option<JoinHandle<Result<EmbedWorkerOut>>>,
    seg: SceneSegmenter,
    clusterer: PartitionClusterer,
    frames: u64,
    partitions: usize,
    started: Instant,
}

impl Pipeline {
    /// `engine` is consumed by the embed thread; `memory` is shared with
    /// the query path.
    ///
    /// Fallible: backend warm-up runs here so a broken backend (missing /
    /// mismatched artifacts, corrupt entry) surfaces at construction with
    /// context, not as a confusing mid-stream embed error after frames are
    /// already flowing.
    pub fn new(
        cfg: &IngestConfig,
        fps: f64,
        engine: EmbedEngine,
        memory: Arc<RwLock<Hierarchy>>,
    ) -> Result<Self> {
        // precompile the embed entries so the first partition doesn't pay
        // backend compilation latency on the streaming path
        engine
            .warmup()
            .context("embed backend warm-up failed; refusing to start the pipeline")?;
        let (tx, rx) = sync_channel::<WorkItem>(cfg.queue_capacity);
        let mem2 = Arc::clone(&memory);
        let send_engine = SendEngine(engine);
        let worker =
            std::thread::spawn(move || embed_worker(send_engine, rx, mem2));
        Ok(Self {
            cfg: cfg.clone(),
            memory,
            tx: Some(tx),
            worker: Some(worker),
            seg: SceneSegmenter::new(cfg, fps),
            clusterer: PartitionClusterer::new(cfg.cluster_threshold),
            frames: 0,
            partitions: 0,
            started: Instant::now(),
        })
    }

    /// Feed the next captured frame (global ids must be dense ascending).
    pub fn push_frame(&mut self, id: u64, frame: &Frame) -> Result<()> {
        self.memory.write().unwrap().archive_frame(id, frame);
        let feat = frame_features(frame);
        if let Some(part) = self.seg.push_features(feat) {
            let done = std::mem::replace(
                &mut self.clusterer,
                PartitionClusterer::new(self.cfg.cluster_threshold),
            );
            self.partitions += 1;
            self.tx
                .as_ref()
                .unwrap()
                .send(WorkItem::Partition { scene_id: part.id, clusters: done.finish() })
                .context("embed worker died")?;
        }
        self.clusterer.push(id, frame);
        self.frames += 1;
        Ok(())
    }

    /// Close the stream: flush the open partition, join the embed thread,
    /// and return run statistics.
    pub fn finish(mut self) -> Result<IngestStats> {
        if let Some(part) = self.seg.finish() {
            let done = std::mem::replace(
                &mut self.clusterer,
                PartitionClusterer::new(self.cfg.cluster_threshold),
            );
            self.partitions += 1;
            self.tx
                .as_ref()
                .unwrap()
                .send(WorkItem::Partition { scene_id: part.id, clusters: done.finish() })
                .context("embed worker died")?;
        }
        drop(self.tx.take()); // close the channel; worker drains and exits
        let out = self
            .worker
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow::anyhow!("embed worker panicked"))??;
        Ok(IngestStats {
            frames: self.frames,
            partitions: self.partitions,
            clusters: out.clusters,
            embedded: out.embedded,
            embed_batches: out.batches,
            mean_embed_batch_s: out.mean_batch_s,
            mean_embed_frame_s: if out.embedded > 0 {
                out.mean_batch_s * out.batches as f64 / out.embedded as f64
            } else {
                0.0
            },
            wall_s: self.started.elapsed().as_secs_f64(),
        })
    }

    pub fn frames_pushed(&self) -> u64 {
        self.frames
    }
}

fn embed_worker(
    engine: SendEngine,
    rx: Receiver<WorkItem>,
    memory: Arc<RwLock<Hierarchy>>,
) -> Result<EmbedWorkerOut> {
    let mut engine = engine.0;
    let mut clusters = 0usize;
    let mut embedded = 0usize;
    while let Ok(WorkItem::Partition { scene_id, clusters: parts }) = rx.recv() {
        if parts.is_empty() {
            continue;
        }
        clusters += parts.len();
        let refs: Vec<&Frame> = parts.iter().map(|c| &c.centroid).collect();
        // embed OUTSIDE the lock — this is the slow stage; queries keep
        // reading the index while the MEM runs
        let embs = engine.embed_index_frames(&refs)?;
        embedded += embs.len();
        let mut mem = memory.write().unwrap();
        for (c, emb) in parts.iter().zip(embs) {
            mem.insert(
                &emb,
                ClusterRecord {
                    scene_id,
                    centroid_frame: c.centroid_id,
                    members: c.members.clone(),
                },
            )?;
        }
    }
    Ok(EmbedWorkerOut {
        clusters,
        embedded,
        batches: engine.image_times.len(),
        mean_batch_s: engine.measured_image_batch_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{EmbedBackend, ModelMeta};
    use crate::config::MemoryConfig;
    use crate::memory::InMemoryRaw;

    /// A backend whose warm-up fails — stands in for a broken artifact set.
    struct BrokenBackend(ModelMeta);

    impl BrokenBackend {
        fn boxed() -> Box<dyn EmbedBackend> {
            Box::new(Self(ModelMeta {
                img_size: 16,
                patch: 8,
                d_embed: 8,
                seq_len: 16,
                vocab: 512,
                n_concepts: 4,
                concept_token_base: 2,
                sim_rows: 64,
                scene_feat_dim: 64,
                sem_weight: 4.0,
                content_weight: 1.0,
                aux_weight: 0.5,
            }))
        }
    }

    impl EmbedBackend for BrokenBackend {
        fn name(&self) -> &'static str {
            "broken"
        }
        fn model(&self) -> &ModelMeta {
            &self.0
        }
        fn image_batches(&self) -> Vec<usize> {
            vec![1]
        }
        fn has_fused(&self, _batch: usize) -> bool {
            false
        }
        fn warmup(&self, _entries: &[&str]) -> Result<()> {
            anyhow::bail!("artifact 'embed_image_b1' is corrupt")
        }
        fn embed_image(&self, _frames: &[f32], _batch: usize) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn embed_text(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
            anyhow::bail!("unreachable in this test")
        }
        fn embed_fused(
            &self,
            _frames: &[f32],
            _aux: &[i32],
            _batch: usize,
        ) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn scene_features(&self, _frames: &[f32], _batch: usize) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!("unreachable in this test")
        }
        fn similarity(
            &self,
            _q: &[f32],
            _i: &[f32],
            _n: usize,
            _tau: f32,
        ) -> Result<(Vec<f32>, Vec<f32>)> {
            anyhow::bail!("unreachable in this test")
        }
        fn concept_codes(&self) -> Result<Vec<Vec<f32>>> {
            Ok(vec![vec![0.5; 8 * 8 * 3]; 4])
        }
        fn concept_dirs(&self) -> Result<Vec<Vec<f32>>> {
            Ok(vec![vec![0.0; 8]; 4])
        }
    }

    #[test]
    fn broken_backend_fails_at_construction_not_mid_stream() {
        let engine = EmbedEngine::new(BrokenBackend::boxed(), false).unwrap();
        let memory = Arc::new(RwLock::new(
            Hierarchy::new(&MemoryConfig::default(), 8, Box::new(InMemoryRaw::new(16)))
                .unwrap(),
        ));
        let err = Pipeline::new(&IngestConfig::default(), 8.0, engine, memory)
            .err()
            .expect("warm-up failure must propagate from Pipeline::new");
        let msg = format!("{err:#}");
        assert!(msg.contains("warm-up"), "context missing: {msg}");
        assert!(msg.contains("corrupt"), "root cause missing: {msg}");
    }

    #[test]
    fn healthy_backend_constructs() {
        let engine = EmbedEngine::default_backend(false).unwrap();
        let d = engine.d_embed();
        let memory = Arc::new(RwLock::new(
            Hierarchy::new(&MemoryConfig::default(), d, Box::new(InMemoryRaw::new(64)))
                .unwrap(),
        ));
        let pipe = Pipeline::new(&IngestConfig::default(), 8.0, engine, memory).unwrap();
        assert_eq!(pipe.frames_pushed(), 0);
        pipe.finish().unwrap();
    }
}
