//! Shared embed worker pool: the compute stage every per-stream
//! [`super::Pipeline`] front-end feeds.
//!
//! With one pipeline per camera, per-stream embed threads each see only
//! their own partition trickle and embed whatever tail batch they happen
//! to hold.  The pool fixes both waste axes at once:
//!
//!   * **one backend, N workers** — workers share the process-wide
//!     `Arc<dyn EmbedBackend>` through cheap per-worker [`EmbedEngine`]
//!     front-ends (no per-thread weight regeneration, no per-thread XLA
//!     compilation cache);
//!   * **cross-stream batch coalescing** — a worker that picks up a
//!     partition opportunistically drains further queued partitions (any
//!     stream) until it holds a full MEM batch, embeds them in one call,
//!     and scatters the resulting vectors into each partition's own
//!     shard.  Tail fragments from K cameras merge into full batches.
//!
//! Backpressure is preserved: the job channel is bounded, so pipelines
//! block in `push_frame` when embedding falls behind (the paper's
//! challenge ① applied fleet-wide).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::backend::EmbedBackend;
use crate::embed::EmbedEngine;
use crate::ingest::cluster::Cluster;
use crate::memory::{ClusterRecord, Hierarchy, StreamId};
use crate::util::sync::{ranks, OrderedCondvar, OrderedMutex, OrderedRwLock};

/// Live pool observability, shared lock-free between submitters, workers,
/// and the metrics snapshot path.  `queue_depth` counts submitted-but-not-
/// picked-up jobs (including a submitter currently blocked on the bounded
/// channel); the batch counters describe worker pickups — how well
/// cross-stream (and, over the wire, cross-connection) coalescing is
/// filling MEM batches.  The admission controller and the `ingest_wire`
/// bench both read these.
#[derive(Debug, Default)]
pub struct PoolGauges {
    queue_depth: AtomicUsize,
    pickups: AtomicUsize,
    picked_jobs: AtomicUsize,
    picked_clusters: AtomicUsize,
    max_pickup_clusters: AtomicUsize,
}

/// One point-in-time reading of [`PoolGauges`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolGaugeSnapshot {
    /// Jobs submitted but not yet picked up by a worker.
    pub queue_depth: usize,
    /// Worker pickups (each = one coalesced embed call).
    pub batches: usize,
    /// Mean partitions coalesced per pickup.
    pub mean_batch_jobs: f64,
    /// Mean clusters (index embeds) per pickup.
    pub mean_batch_clusters: f64,
    /// Largest single pickup, in clusters.
    pub max_batch_clusters: usize,
}

impl PoolGauges {
    /// Jobs submitted but not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Acquire)
    }

    fn on_pickup(&self, jobs: usize, clusters: usize) {
        // never underflows: every picked-up job was counted by its
        // sender before the channel send that delivered it here
        self.queue_depth.fetch_sub(jobs, Ordering::AcqRel);
        self.pickups.fetch_add(1, Ordering::AcqRel);
        self.picked_jobs.fetch_add(jobs, Ordering::AcqRel);
        self.picked_clusters.fetch_add(clusters, Ordering::AcqRel);
        self.max_pickup_clusters.fetch_max(clusters, Ordering::AcqRel);
    }

    pub fn snapshot(&self) -> PoolGaugeSnapshot {
        let batches = self.pickups.load(Ordering::Acquire);
        let denom = batches.max(1) as f64;
        PoolGaugeSnapshot {
            queue_depth: self.queue_depth(),
            batches,
            mean_batch_jobs: self.picked_jobs.load(Ordering::Acquire) as f64 / denom,
            mean_batch_clusters: self.picked_clusters.load(Ordering::Acquire) as f64 / denom,
            max_batch_clusters: self.max_pickup_clusters.load(Ordering::Acquire),
        }
    }
}

/// A pipeline's handle into the pool queue: a bounded sender plus the
/// shared gauges, so queue depth counts submissions at the source.
pub(crate) struct PoolSender {
    tx: SyncSender<PoolJob>,
    gauges: Arc<PoolGauges>,
}

impl PoolSender {
    /// Blocking submit (the bounded channel is the ingest backpressure).
    pub fn send(&self, job: PoolJob) -> Result<()> {
        // count before the potentially-blocking send: a submitter stuck
        // on a full queue IS queue pressure the admission controller
        // must see
        self.gauges.queue_depth.fetch_add(1, Ordering::AcqRel);
        if self.tx.send(job).is_err() {
            self.gauges.queue_depth.fetch_sub(1, Ordering::AcqRel);
            anyhow::bail!("embed pool died");
        }
        Ok(())
    }
}

/// One completed partition, routed to its stream's shard.
pub(crate) struct PoolJob {
    pub stream: StreamId,
    pub scene_id: usize,
    pub clusters: Vec<Cluster>,
    pub shard: Arc<OrderedRwLock<Hierarchy>>,
    pub progress: Arc<StreamProgress>,
}

/// Per-stream ingestion progress, updated by pool workers and awaited by
/// the stream's pipeline at `finish()`.
#[derive(Clone, Debug, Default)]
pub(crate) struct ProgressState {
    pub partitions_done: usize,
    pub clusters: usize,
    pub embedded: usize,
    /// backend batch calls this stream's frames rode in
    pub batches: usize,
    /// this stream's share of embed wall time (seconds); for batches that
    /// coalesced several streams the wall is split by cluster share, so
    /// per-stream means stay comparable to the dedicated-thread numbers
    pub batch_time_s: f64,
    pub error: Option<String>,
}

pub(crate) struct StreamProgress {
    state: OrderedMutex<ProgressState>,
    cv: OrderedCondvar,
}

impl StreamProgress {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            state: OrderedMutex::new(ranks::STREAM_PROGRESS, ProgressState::default()),
            cv: OrderedCondvar::new(),
        })
    }

    fn update(&self, f: impl FnOnce(&mut ProgressState)) {
        let mut st = self.state.lock();
        f(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    pub fn snapshot(&self) -> ProgressState {
        self.state.lock().clone()
    }

    /// Block until `n` partitions completed or an error was recorded —
    /// with a liveness guard: if every pool worker has exited (panic)
    /// while partitions are still pending, give up instead of waiting
    /// forever on a condvar nobody will signal.
    pub fn wait_partitions(&self, n: usize, workers_alive: &AtomicUsize) -> ProgressState {
        let mut st = self.state.lock();
        while st.partitions_done < n && st.error.is_none() {
            if workers_alive.load(Ordering::Acquire) == 0 {
                st.error
                    .get_or_insert_with(|| "embed pool workers died".to_string());
                break;
            }
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, std::time::Duration::from_millis(100));
            st = guard;
        }
        st.clone()
    }
}

/// Decrements the pool's alive-worker counter on thread exit — including
/// panic unwinds, so waiting pipelines never hang on a dead pool.
struct WorkerAliveGuard(Arc<AtomicUsize>);

impl Drop for WorkerAliveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// The shared embed worker pool.
pub struct EmbedPool {
    tx: Option<SyncSender<PoolJob>>,
    workers: Vec<JoinHandle<()>>,
    alive: Arc<AtomicUsize>,
    gauges: Arc<PoolGauges>,
}

impl EmbedPool {
    /// Start `workers` workers over the shared backend.  Warm-up runs
    /// once here (the backend's compiled-entry cache is shared), so a
    /// broken backend surfaces at pool construction, not mid-stream.
    pub fn start(
        backend: Arc<dyn EmbedBackend>,
        use_aux: bool,
        workers: usize,
        queue_capacity: usize,
    ) -> Result<Self> {
        let mut engines = Vec::with_capacity(workers.max(1));
        for _ in 0..workers.max(1) {
            engines.push(EmbedEngine::new(Arc::clone(&backend), use_aux)?);
        }
        Self::with_engines(engines, queue_capacity)
    }

    /// Single-worker pool that consumes an existing engine (the
    /// single-stream `Pipeline::new` compatibility path).
    pub fn with_engine(engine: EmbedEngine, queue_capacity: usize) -> Result<Self> {
        Self::with_engines(vec![engine], queue_capacity)
    }

    fn with_engines(engines: Vec<EmbedEngine>, queue_capacity: usize) -> Result<Self> {
        engines[0]
            .warmup()
            .context("embed backend warm-up failed; refusing to start the pipeline")?;
        let (tx, rx) = sync_channel::<PoolJob>(queue_capacity.max(1));
        let rx = Arc::new(OrderedMutex::new(ranks::POOL_QUEUE, rx));
        let alive = Arc::new(AtomicUsize::new(engines.len()));
        let gauges = Arc::new(PoolGauges::default());
        let workers = engines
            .into_iter()
            .map(|engine| {
                let rx = Arc::clone(&rx);
                let guard = WorkerAliveGuard(Arc::clone(&alive));
                let gauges = Arc::clone(&gauges);
                std::thread::spawn(move || {
                    let _guard = guard;
                    worker_loop(engine, rx, gauges)
                })
            })
            .collect();
        Ok(Self { tx: Some(tx), workers, alive, gauges })
    }

    /// A job sender for one pipeline front-end.
    pub(crate) fn sender(&self) -> PoolSender {
        PoolSender {
            tx: self.tx.as_ref().expect("pool already shut down").clone(),
            gauges: Arc::clone(&self.gauges),
        }
    }

    /// The shared queue-depth / coalescing gauges.
    pub fn gauges(&self) -> Arc<PoolGauges> {
        Arc::clone(&self.gauges)
    }

    /// Shared alive-worker counter (pipelines use it as a liveness guard
    /// while waiting for their partitions to drain).
    pub(crate) fn alive_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.alive)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Close the queue and join every worker.  Pipelines must have
    /// dropped their senders (i.e. called `finish`) first, or this blocks
    /// until they do.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.tx.take());
        let mut panicked = false;
        for w in self.workers.drain(..) {
            panicked |= w.join().is_err();
        }
        anyhow::ensure!(!panicked, "embed pool worker panicked");
        Ok(())
    }
}

impl Drop for EmbedPool {
    fn drop(&mut self) {
        // best-effort drain on un-shutdown drop (e.g. error unwind paths)
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    mut engine: EmbedEngine,
    rx: Arc<OrderedMutex<Receiver<PoolJob>>>,
    gauges: Arc<PoolGauges>,
) {
    let target = engine.max_image_batch();
    loop {
        let mut jobs = Vec::new();
        let mut pending: usize = 0;
        {
            let guard = rx.lock();
            match guard.recv() {
                Ok(j) => {
                    pending = j.clusters.len();
                    jobs.push(j);
                }
                Err(_) => return, // channel closed: drain complete
            }
            // coalesce across streams up to one full MEM batch; stop the
            // moment the queue runs dry so latency never waits on traffic
            while pending < target {
                match guard.try_recv() {
                    Ok(j) => {
                        pending += j.clusters.len();
                        jobs.push(j);
                    }
                    Err(_) => break,
                }
            }
        } // release the receiver before the slow embed stage
        gauges.on_pickup(jobs.len(), pending);
        process_jobs(&mut engine, jobs);
    }
}

/// Embed every job's centroids in one engine call, then scatter vectors
/// into each job's shard (insert OUTSIDE the embed stage but under each
/// shard's own short write section — queries on other streams never wait).
fn process_jobs(engine: &mut EmbedEngine, jobs: Vec<PoolJob>) {
    let total: usize = jobs.iter().map(|j| j.clusters.len()).sum();
    if total == 0 {
        for j in jobs {
            j.progress.update(|s| s.partitions_done += 1);
        }
        return;
    }

    let refs: Vec<&crate::video::frame::Frame> = jobs
        .iter()
        .flat_map(|j| j.clusters.iter().map(|c| &c.centroid))
        .collect();
    let batches_before = engine.image_times.len();
    let t0 = Instant::now();
    let embs = engine.embed_index_frames(&refs);
    let wall = t0.elapsed().as_secs_f64();
    let batches = engine.image_times.len() - batches_before;

    match embs {
        Err(e) => {
            let msg = format!("{e:#}");
            for j in jobs {
                let msg = msg.clone();
                j.progress.update(move |s| {
                    s.partitions_done += 1;
                    s.error.get_or_insert(msg);
                });
            }
        }
        Ok(embs) => {
            let mut it = embs.into_iter();
            // a stream may contribute several partitions to one coalesced
            // call; count the call's backend batches once per stream, not
            // once per partition, or embed_batches inflates
            let mut counted: Vec<Arc<StreamProgress>> = Vec::new();
            for j in jobs {
                let first_for_stream =
                    !counted.iter().any(|p| Arc::ptr_eq(p, &j.progress));
                if first_for_stream {
                    counted.push(Arc::clone(&j.progress));
                }
                let take = j.clusters.len();
                // consume exactly this job's slice of the batch, so a
                // failed insert never misaligns the next job's embeddings
                let job_embs: Vec<Vec<f32>> = it.by_ref().take(take).collect();
                let mut err: Option<String> = None;
                {
                    let mut shard = j.shard.write();
                    for (c, emb) in j.clusters.iter().zip(&job_embs) {
                        if let Err(e) = shard.insert(
                            emb,
                            ClusterRecord {
                                stream: j.stream,
                                scene_id: j.scene_id,
                                centroid_frame: c.centroid_id,
                                members: c.members.clone(),
                            },
                        ) {
                            err = Some(format!("{e:#}"));
                            break;
                        }
                    }
                }
                let share = wall * take as f64 / total as f64;
                let add_batches = if first_for_stream { batches } else { 0 };
                j.progress.update(move |s| {
                    s.partitions_done += 1;
                    s.clusters += take;
                    s.embedded += take;
                    s.batches += add_batches;
                    s.batch_time_s += share;
                    if let Some(e) = err {
                        s.error.get_or_insert(e);
                    }
                });
            }
        }
    }
}
