//! Scene detection & segmentation (§IV-B-1, Eq. 1).
//!
//! Streaming: each incoming frame's pooled HSL+edge feature vector is
//! compared against the previous frame's; a boundary fires when the
//! weighted L1 score φ exceeds the threshold (debounced by a minimum
//! scene length).  For static cameras with no transitions, a maximum
//! partition duration forces a cut so partitions keep flowing downstream
//! (the paper's "minimum temporal threshold" rule).

use crate::config::IngestConfig;
use crate::features::{frame_features, scene_score, ChannelWeights, FEAT_DIM};
use crate::video::frame::Frame;

/// A completed temporal partition `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    pub id: usize,
    pub start: u64,
    pub end: u64,
}

impl Partition {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Streaming scene segmenter.
pub struct SceneSegmenter {
    threshold: f32,
    min_frames: u64,
    max_frames: u64,
    weights: ChannelWeights,
    prev_feat: Option<Vec<f32>>,
    part_start: u64,
    next_frame: u64,
    next_id: usize,
    /// φ history of the current partition (diagnostics / Fig. 5b-style plots)
    last_score: f32,
}

impl SceneSegmenter {
    pub fn new(cfg: &IngestConfig, fps: f64) -> Self {
        Self {
            threshold: cfg.scene_threshold,
            min_frames: cfg.min_scene_frames,
            max_frames: (cfg.max_partition_s * fps).round().max(1.0) as u64,
            weights: ChannelWeights::default(),
            prev_feat: None,
            part_start: 0,
            next_frame: 0,
            next_id: 0,
            last_score: 0.0,
        }
    }

    /// Most recent φ value (Eq. 1).
    pub fn last_score(&self) -> f32 {
        self.last_score
    }

    /// Feed the next frame (features computed internally); returns a
    /// completed partition if this frame *starts* a new one.
    pub fn push(&mut self, frame: &Frame) -> Option<Partition> {
        let feat = frame_features(frame);
        self.push_features(feat)
    }

    /// Feed a precomputed Eq. 1 feature vector (pipeline fast path —
    /// features are shared with the clustering stage).
    pub fn push_features(&mut self, feat: Vec<f32>) -> Option<Partition> {
        debug_assert_eq!(feat.len(), FEAT_DIM);
        let idx = self.next_frame;
        self.next_frame += 1;

        let mut cut = false;
        if let Some(prev) = &self.prev_feat {
            let phi = scene_score(prev, &feat, self.weights);
            self.last_score = phi;
            let cur_len = idx - self.part_start;
            if phi > self.threshold && cur_len >= self.min_frames {
                cut = true;
            } else if cur_len >= self.max_frames {
                cut = true;
            }
        }
        self.prev_feat = Some(feat);

        if cut {
            let part = Partition { id: self.next_id, start: self.part_start, end: idx };
            self.next_id += 1;
            self.part_start = idx;
            Some(part)
        } else {
            None
        }
    }

    /// Flush the trailing open partition at stream end.
    pub fn finish(&mut self) -> Option<Partition> {
        if self.next_frame > self.part_start {
            let part = Partition {
                id: self.next_id,
                start: self.part_start,
                end: self.next_frame,
            };
            self.next_id += 1;
            self.part_start = self.next_frame;
            Some(part)
        } else {
            None
        }
    }

    pub fn frames_seen(&self) -> u64 {
        self.next_frame
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IngestConfig;
    use crate::util::rng::Pcg64;
    use crate::video::synth::{SynthConfig, VideoSynth};

    fn synth(seed: u64) -> VideoSynth {
        let mut rng = Pcg64::seeded(99);
        let codes = (0..8)
            .map(|_| (0..8 * 8 * 3).map(|_| rng.f32()).collect())
            .collect();
        VideoSynth::new(
            SynthConfig { duration_s: 60.0, seed, ..Default::default() },
            codes,
            8,
        )
    }

    fn segment_all(s: &VideoSynth, cfg: &IngestConfig) -> Vec<Partition> {
        let mut seg = SceneSegmenter::new(cfg, s.config().fps);
        let mut parts = Vec::new();
        for i in 0..s.total_frames() {
            if let Some(p) = seg.push(&s.frame(i)) {
                parts.push(p);
            }
        }
        parts.extend(seg.finish());
        parts
    }

    #[test]
    fn partitions_tile_the_stream() {
        let s = synth(11);
        let parts = segment_all(&s, &IngestConfig::default());
        assert_eq!(parts[0].start, 0);
        for w in parts.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        assert_eq!(parts.last().unwrap().end, s.total_frames());
    }

    #[test]
    fn boundaries_near_ground_truth() {
        let s = synth(12);
        let parts = segment_all(&s, &IngestConfig::default());
        let detected: Vec<u64> = parts.iter().skip(1).map(|p| p.start).collect();
        let truth = s.script().boundaries();
        // every true boundary has a detection within ±2 frames
        let mut hits = 0;
        for t in &truth {
            if detected.iter().any(|d| d.abs_diff(*t) <= 2) {
                hits += 1;
            }
        }
        let recall = hits as f64 / truth.len() as f64;
        assert!(recall >= 0.9, "boundary recall {recall} ({hits}/{})", truth.len());
    }

    #[test]
    fn static_stream_still_cuts_by_max_duration() {
        let cfg = IngestConfig { max_partition_s: 2.0, ..Default::default() };
        let mut seg = SceneSegmenter::new(&cfg, 8.0);
        let frame = crate::video::frame::Frame::filled(64, [0.4, 0.4, 0.4]);
        let mut parts = Vec::new();
        for _ in 0..100 {
            if let Some(p) = seg.push(&frame) {
                parts.push(p);
            }
        }
        // 100 frames / (2s·8fps = 16) ≈ 6 forced cuts
        assert!(parts.len() >= 5, "{}", parts.len());
        for p in &parts {
            assert!(p.len() <= 17);
        }
    }

    #[test]
    fn min_scene_length_debounces() {
        let cfg = IngestConfig { min_scene_frames: 8, ..Default::default() };
        let mut seg = SceneSegmenter::new(&cfg, 8.0);
        let mut parts = Vec::new();
        // alternate wildly different frames — naive thresholding would cut
        // every frame; debounce enforces ≥ 8 frames per partition
        for i in 0..64u64 {
            let c = if i % 2 == 0 { 0.1 } else { 0.9 };
            let f = crate::video::frame::Frame::filled(64, [c, c, c]);
            if let Some(p) = seg.push(&f) {
                parts.push(p);
            }
        }
        for p in &parts {
            assert!(p.len() >= 8, "partition too short: {p:?}");
        }
    }

    #[test]
    fn finish_flushes_tail() {
        let mut seg = SceneSegmenter::new(&IngestConfig::default(), 8.0);
        let f = crate::video::frame::Frame::filled(64, [0.5; 3]);
        for _ in 0..5 {
            seg.push(&f);
        }
        let tail = seg.finish().unwrap();
        assert_eq!((tail.start, tail.end), (0, 5));
        assert!(seg.finish().is_none());
    }
}
