//! # Venus — an edge memory-and-retrieval system for VLM-based online video understanding
//!
//! Reproduction of the CS.DC 2025 paper.  The crate implements the full
//! edge-side system (L3): streaming perception (scene segmentation +
//! incremental clustering), hierarchical memory (raw frame archive + vector
//! index), query-time retrieval (temperature-softmax sampling, Eq. 5, and
//! threshold-driven Adaptive Keyframe Retrieval, Eq. 6–7), and the serving
//! loop — plus every substrate the evaluation needs: a synthetic
//! scene-scripted video/workload generator, a from-scratch vector database,
//! a network simulator, a simulated cloud VLM, and Jetson-class edge device
//! profiles.
//!
//! Frame/text embedding goes through the pluggable [`backend`] layer: the
//! default [`backend::NativeBackend`] runs the dual-encoder MEM forward in
//! pure Rust (self-contained, no artifact files — the paper's edge
//! deployment claim), while the optional `pjrt` cargo feature adds the
//! AOT-compiled XLA artifact [`runtime`] produced by the build-time Python
//! layers (L2 JAX dual-encoder calling L1 Pallas kernels; see
//! `python/compile/`).  Python never executes on the request path.  One
//! backend is constructed per process ([`backend::shared_default`]) and
//! shared by every pipeline, pool worker, and query worker.
//!
//! Beyond the paper's single camera, the memory layer is a multi-tenant
//! **fabric** ([`memory::MemoryFabric`]): per-stream [`memory::Hierarchy`]
//! shards behind independent `RwLock`s, per-stream ingestion [`ingest`]
//! pipelines feeding one shared embed pool that coalesces partitions
//! across cameras into full MEM batches, and stream-scoped queries
//! ([`memory::StreamScope`]) whose `All` path scatter-gathers Eq. 4–5
//! scoring across shards so one answer can cite several cameras.
//!
//! The memory is **durable and tiered** when opened with
//! [`memory::MemoryFabric::open`]: inserts stream through a per-shard WAL
//! into sealed on-disk segments ([`memory::storage`], [`memory::segment`]),
//! a byte-budgeted hot tier demotes the oldest segments to a disk-backed
//! cold tier scored through an LRU block cache, and
//! [`memory::MemoryFabric::recover`] rebuilds every shard — watermarks
//! included — after a restart (DESIGN.md §Storage).
//!
//! Serving goes through the typed [`api`] layer (Serving API v1): a
//! [`api::QueryRequest`] builder (scope, retrieval mode, sampling
//! budget, priority lane, deadline), structured [`api::QueryResponse`]
//! evidence, priority-lane admission with deadline-aware shedding in
//! [`server`], and a fabric-wide semantic query cache
//! ([`api::QueryCache`]) that lets repeat and near-duplicate queries
//! skip the edge hot path entirely.
//!
//! Quickstart: see `examples/quickstart.rs` (single camera, typed API)
//! and `examples/multi_camera.rs` (fabric); architecture: `DESIGN.md`.

pub mod api;
pub mod backend;
pub mod baselines;
pub mod cli;
pub mod cloud;
pub mod coordinator;
pub mod config;
pub mod edge;
pub mod embed;
pub mod eval;
pub mod features;
pub mod ingest;
pub mod memory;
pub mod net;
pub mod obs;
pub mod retrieval;
pub mod runtime;
pub mod server;
pub mod util;
pub mod video;

/// Crate-wide result type (anyhow-based; library APIs return typed data,
/// binaries surface errors with context).
pub type Result<T> = anyhow::Result<T>;
