//! `venus` binary: CLI front-end for the Venus edge serving system.
//! Placeholder main — subcommands are wired up in `cli`.

fn main() {
    if let Err(e) = venus::cli::run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
