//! Multi-camera memory fabric: N per-stream [`Hierarchy`] shards, each
//! behind its own `RwLock`.
//!
//! Sharding rationale (LiveVLM / Mosaic scaling insight): camera A's
//! ingestion writer must never contend with camera B's query readers, so
//! the lock is per-shard — a writer only excludes readers *of its own
//! stream*.  Cross-stream queries take read guards on every scoped shard
//! at once (readers never block each other), merge the per-shard Eq. 4
//! scores into one softmax distribution, and sample from it — so a single
//! answer can cite evidence frames from several cameras.
//!
//! Lock-order note: fabric operations acquire shard guards in ascending
//! `StreamId` order while writers (ingestion pipelines) each hold at most
//! one shard lock at a time — no cycle, no deadlock.  Shard `i` carries
//! lock rank `ranks::shard(i)`, so debug builds enforce the ascending
//! order mechanically (`util::sync`, DESIGN.md §Static-Analysis).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::MemoryConfig;
use crate::memory::hierarchy::{Hierarchy, TierStats};
use crate::memory::raw::RawStore;
use crate::memory::storage::atomic_write;
use crate::util::sync::{ranks, OrderedRwLock};
use crate::video::frame::Frame;

/// Identifies one camera stream (== one shard) in the fabric.
#[derive(Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamId(pub u16);

impl StreamId {
    /// Shard-array index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Debug for StreamId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

impl std::fmt::Display for StreamId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Fabric-global frame address: (stream, stream-local frame index).
///
/// Ordering is lexicographic (stream first), so a sorted selection groups
/// frames by camera and stays ascending-in-time within each camera —
/// exactly the order a multi-camera VLM prompt presents evidence in.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FrameId {
    pub stream: StreamId,
    pub idx: u64,
}

impl FrameId {
    pub fn new(stream: StreamId, idx: u64) -> Self {
        Self { stream, idx }
    }
}

impl std::fmt::Debug for FrameId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}#{}", self.stream, self.idx)
    }
}

/// Which shards a query sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamScope {
    /// A single camera stream.
    One(StreamId),
    /// Scatter-gather over every shard (cross-camera answers).
    All,
}

/// The multi-camera memory fabric: per-stream shards, each independently
/// locked.  Shard `i` owns `StreamId(i)`.
pub struct MemoryFabric {
    shards: Vec<Arc<OrderedRwLock<Hierarchy>>>,
    /// root of the durable layout (`MANIFEST`, `s<K>/` per stream);
    /// `None` for a pure-RAM fabric
    data_dir: Option<PathBuf>,
}

const FABRIC_MANIFEST_HEADER: &str = "venus-fabric-manifest v1";

impl MemoryFabric {
    /// Build an N-shard fabric, one raw store per stream (shard `i` takes
    /// `raws[i]` and owns `StreamId(i)`).
    pub fn new(
        cfg: &MemoryConfig,
        d_embed: usize,
        raws: Vec<Box<dyn RawStore>>,
    ) -> Result<Self> {
        anyhow::ensure!(!raws.is_empty(), "fabric needs at least one stream");
        anyhow::ensure!(
            raws.len() <= u16::MAX as usize,
            "fabric supports at most {} streams",
            u16::MAX
        );
        let mut shards = Vec::with_capacity(raws.len());
        for (i, raw) in raws.into_iter().enumerate() {
            shards.push(Arc::new(OrderedRwLock::new(
                ranks::shard(i),
                Hierarchy::for_stream(cfg, d_embed, raw, StreamId(i as u16))?,
            )));
        }
        Ok(Self { shards, data_dir: None })
    }

    /// Open a durable fabric rooted at `dir`: create it on first use, or
    /// recover every shard from disk when a fabric `MANIFEST` already
    /// exists (sealed segments become each shard's cold tier, flushed WAL
    /// tails its hot tier, and per-shard ingest watermarks are restored —
    /// so the serving cache's staleness logic survives a restart).
    pub fn open(
        cfg: &MemoryConfig,
        d_embed: usize,
        streams: usize,
        frame_size: usize,
        dir: &Path,
    ) -> Result<Self> {
        anyhow::ensure!(streams >= 1, "fabric needs at least one stream");
        anyhow::ensure!(
            streams <= u16::MAX as usize,
            "fabric supports at most {} streams",
            u16::MAX
        );
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating data dir {}", dir.display()))?;
        let manifest = dir.join("MANIFEST");
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)?;
            let (m_streams, m_d, m_fs) = Self::parse_fabric_manifest(&text)?;
            if m_streams != streams || m_d != d_embed || m_fs != frame_size {
                bail!(
                    "fabric at {} was written with streams={m_streams} d_embed={m_d} \
                     frame_size={m_fs}; this open asked for streams={streams} \
                     d_embed={d_embed} frame_size={frame_size}",
                    dir.display()
                );
            }
        } else {
            let text = format!(
                "{FABRIC_MANIFEST_HEADER}\nstreams {streams}\nd_embed {d_embed}\nframe_size {frame_size}\n"
            );
            atomic_write(&manifest, text.as_bytes())?;
        }
        let mut shards = Vec::with_capacity(streams);
        for i in 0..streams {
            let stream = StreamId(i as u16);
            let shard_dir = dir.join(format!("s{i}"));
            shards.push(Arc::new(OrderedRwLock::new(
                ranks::shard(i),
                Hierarchy::durable(cfg, d_embed, stream, &shard_dir, frame_size)?,
            )));
        }
        Ok(Self { shards, data_dir: Some(dir.to_path_buf()) })
    }

    /// Recover a durable fabric that MUST already exist on disk — the
    /// restart path.  Identical to [`MemoryFabric::open`] except that a
    /// missing fabric `MANIFEST` is a typed error instead of a fresh
    /// initialization.
    pub fn recover(
        cfg: &MemoryConfig,
        d_embed: usize,
        streams: usize,
        frame_size: usize,
        dir: &Path,
    ) -> Result<Self> {
        anyhow::ensure!(
            dir.join("MANIFEST").exists(),
            "no fabric manifest at {} — nothing to recover",
            dir.display()
        );
        Self::open(cfg, d_embed, streams, frame_size, dir)
    }

    fn parse_fabric_manifest(text: &str) -> Result<(usize, usize, usize)> {
        let mut lines = text.lines();
        anyhow::ensure!(
            lines.next() == Some(FABRIC_MANIFEST_HEADER),
            "unrecognized fabric manifest header"
        );
        let field = |line: Option<&str>, key: &str| -> Result<usize> {
            let line = line.with_context(|| format!("fabric manifest missing '{key}'"))?;
            let rest = line
                .strip_prefix(key)
                .with_context(|| format!("fabric manifest line '{line}' is not '{key} …'"))?;
            Ok(rest.trim().parse::<usize>()?)
        };
        Ok((
            field(lines.next(), "streams")?,
            field(lines.next(), "d_embed")?,
            field(lines.next(), "frame_size")?,
        ))
    }

    /// Wrap an existing single shard (must own `StreamId(0)`) — the
    /// single-camera deployment and the test/bench convenience path.
    pub fn single(shard: Arc<OrderedRwLock<Hierarchy>>) -> Self {
        debug_assert_eq!(shard.read().stream(), StreamId(0));
        Self { shards: vec![shard], data_dir: None }
    }

    /// Root of the durable layout, when this fabric persists to disk.
    pub fn data_dir(&self) -> Option<&Path> {
        self.data_dir.as_deref()
    }

    /// Whether this fabric persists to disk.
    pub fn is_durable(&self) -> bool {
        self.data_dir.is_some()
    }

    /// Force every shard's WAL tail to disk (a fabric-wide durability
    /// point — the clean-shutdown counterpart of drop-as-crash).
    pub fn flush(&self) -> Result<()> {
        for shard in &self.shards {
            shard.write().flush()?;
        }
        Ok(())
    }

    /// Fabric-wide tier gauges: per-shard stats summed.
    pub fn tier_stats(&self) -> TierStats {
        let mut total = TierStats::default();
        for shard in &self.shards {
            total.merge(&shard.read().tier_stats());
        }
        total
    }

    pub fn n_streams(&self) -> usize {
        self.shards.len()
    }

    pub fn stream_ids(&self) -> impl Iterator<Item = StreamId> + '_ {
        (0..self.shards.len() as u16).map(StreamId)
    }

    /// All shards, in `StreamId` order.
    pub fn shards(&self) -> &[Arc<OrderedRwLock<Hierarchy>>] {
        &self.shards
    }

    /// One stream's shard.
    pub fn shard(&self, stream: StreamId) -> Result<&Arc<OrderedRwLock<Hierarchy>>> {
        self.shards
            .get(stream.index())
            .ok_or_else(|| anyhow::anyhow!("unknown stream {stream} ({}-shard fabric)", self.shards.len()))
    }

    /// The shards a scope covers, in ascending `StreamId` order.
    pub fn scoped(&self, scope: StreamScope) -> Result<Vec<&Arc<OrderedRwLock<Hierarchy>>>> {
        match scope {
            StreamScope::One(s) => Ok(vec![self.shard(s)?]),
            StreamScope::All => Ok(self.shards.iter().collect()),
        }
    }

    /// Fetch one raw frame by fabric-global address.
    pub fn fetch_frame(&self, id: FrameId) -> Result<Frame> {
        self.shard(id.stream)?.read().fetch_frame(id.idx)
    }

    /// Fetch a batch of raw frames (the payload that ships to the cloud).
    /// Groups by stream so each shard's lock is taken once.
    pub fn fetch_frames(&self, ids: &[FrameId]) -> Result<Vec<Frame>> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            let stream = ids[i].stream;
            let shard = self.shard(stream)?;
            let guard = shard.read();
            while i < ids.len() && ids[i].stream == stream {
                out.push(guard.fetch_frame(ids[i].idx)?);
                i += 1;
            }
        }
        Ok(out)
    }

    /// Per-shard ingest watermarks for the shards a scope covers, in
    /// ascending `StreamId` order.  The serving API's semantic query cache
    /// snapshots these at insert time and compares them at lookup time: a
    /// cached selection is reusable only while every touched shard's
    /// watermark has advanced by at most the configured staleness bound.
    pub fn watermarks(&self, scope: StreamScope) -> Result<Vec<(StreamId, u64)>> {
        Ok(self
            .scoped(scope)?
            .iter()
            .map(|s| {
                let g = s.read();
                (g.stream(), g.watermark())
            })
            .collect())
    }

    /// Total indexed vectors across every shard.
    pub fn total_indexed(&self) -> usize {
        self.shards.iter().map(|s| s.read().len()).sum()
    }

    /// Total frames archived across every shard.
    pub fn total_frames(&self) -> u64 {
        self.shards.iter().map(|s| s.read().frames_ingested()).sum()
    }

    /// Run `check_invariants` on every shard.
    pub fn check_invariants(&self) -> Result<()> {
        for shard in &self.shards {
            shard.read().check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::hierarchy::ClusterRecord;
    use crate::memory::raw::InMemoryRaw;

    fn fabric(n: usize) -> MemoryFabric {
        let raws: Vec<Box<dyn RawStore>> =
            (0..n).map(|_| Box::new(InMemoryRaw::new(8)) as Box<dyn RawStore>).collect();
        MemoryFabric::new(&MemoryConfig::default(), 4, raws).unwrap()
    }

    #[test]
    fn shards_own_their_stream_ids() {
        let f = fabric(3);
        assert_eq!(f.n_streams(), 3);
        for (i, s) in f.stream_ids().enumerate() {
            assert_eq!(s, StreamId(i as u16));
            assert_eq!(f.shard(s).unwrap().read().stream(), s);
        }
        assert!(f.shard(StreamId(3)).is_err());
    }

    #[test]
    fn scoped_selects_shards() {
        let f = fabric(4);
        assert_eq!(f.scoped(StreamScope::All).unwrap().len(), 4);
        assert_eq!(f.scoped(StreamScope::One(StreamId(2))).unwrap().len(), 1);
        assert!(f.scoped(StreamScope::One(StreamId(9))).is_err());
    }

    #[test]
    fn fetch_routes_by_stream_and_reports_holes() {
        let f = fabric(2);
        for (sid, fill) in [(0u16, 0.25f32), (1, 0.75)] {
            let shard = f.shard(StreamId(sid)).unwrap();
            let mut g = shard.write();
            for i in 0..4u64 {
                g.archive_frame(i, &Frame::filled(8, [fill; 3])).unwrap();
            }
        }
        let a = f.fetch_frame(FrameId::new(StreamId(0), 1)).unwrap();
        let b = f.fetch_frame(FrameId::new(StreamId(1), 1)).unwrap();
        assert!(a.data()[0] < b.data()[0], "frames came from distinct shards");

        // batched fetch across streams
        let ids = [
            FrameId::new(StreamId(0), 0),
            FrameId::new(StreamId(0), 3),
            FrameId::new(StreamId(1), 2),
        ];
        assert_eq!(f.fetch_frames(&ids).unwrap().len(), 3);

        // holes propagate as errors through the batched path too
        let hole = [FrameId::new(StreamId(1), 99)];
        assert!(f.fetch_frames(&hole).is_err());
        assert!(f.fetch_frame(FrameId::new(StreamId(7), 0)).is_err());
    }

    #[test]
    fn watermarks_follow_scope_and_inserts() {
        let f = fabric(3);
        assert_eq!(
            f.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 0), (StreamId(1), 0), (StreamId(2), 0)]
        );
        {
            let shard = f.shard(StreamId(1)).unwrap();
            let mut g = shard.write();
            g.archive_frame(0, &Frame::filled(8, [0.5; 3])).unwrap();
            g.insert(
                &[1.0, 0.0, 0.0, 0.0],
                ClusterRecord {
                    stream: StreamId(1),
                    scene_id: 0,
                    centroid_frame: 0,
                    members: vec![0],
                },
            )
            .unwrap();
        }
        assert_eq!(
            f.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 0), (StreamId(1), 1), (StreamId(2), 0)]
        );
        assert_eq!(
            f.watermarks(StreamScope::One(StreamId(1))).unwrap(),
            vec![(StreamId(1), 1)]
        );
        assert!(f.watermarks(StreamScope::One(StreamId(9))).is_err());
    }

    #[test]
    fn invariants_cover_every_shard() {
        let f = fabric(2);
        {
            let shard = f.shard(StreamId(1)).unwrap();
            let mut g = shard.write();
            g.archive_frame(0, &Frame::filled(8, [0.5; 3])).unwrap();
            g.insert(
                &[1.0, 0.0, 0.0, 0.0],
                ClusterRecord {
                    stream: StreamId(1),
                    scene_id: 0,
                    centroid_frame: 9, // not a member: invariant violation
                    members: vec![0],
                },
            )
            .unwrap();
        }
        assert!(f.check_invariants().is_err());
    }

    #[test]
    fn durable_fabric_opens_recovers_and_validates_topology() {
        let tmp = crate::memory::storage::tests::TempDir::new("fabric-open");
        let cfg = MemoryConfig { segment_records: 2, ..Default::default() };
        // nothing on disk yet: recover must refuse, open must initialize
        assert!(MemoryFabric::recover(&cfg, 4, 2, 8, &tmp.0).is_err());
        {
            let f = MemoryFabric::open(&cfg, 4, 2, 8, &tmp.0).unwrap();
            assert!(f.is_durable());
            assert_eq!(f.data_dir(), Some(tmp.0.as_path()));
            for sid in 0..2u16 {
                let shard = f.shard(StreamId(sid)).unwrap();
                let mut g = shard.write();
                for i in 0..3u64 {
                    g.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
                    let mut v = vec![0.0f32; 4];
                    v[(sid as usize + i as usize) % 4] = 1.0;
                    g.insert(
                        &v,
                        ClusterRecord {
                            stream: StreamId(sid),
                            scene_id: i as usize,
                            centroid_frame: i,
                            members: vec![i],
                        },
                    )
                    .unwrap();
                }
            }
            f.flush().unwrap();
        }
        // restart: shards rebuilt from disk, watermarks restored
        let f = MemoryFabric::recover(&cfg, 4, 2, 8, &tmp.0).unwrap();
        assert_eq!(
            f.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 3), (StreamId(1), 3)]
        );
        assert_eq!(f.total_frames(), 6);
        f.check_invariants().unwrap();
        let ts = f.tier_stats();
        assert_eq!(ts.cold_records + ts.hot_records, 6);
        assert_eq!(
            ts.cold_segments, 0,
            "unbounded shards promote every sealed span back to RAM: {ts:?}"
        );
        assert_eq!(ts.hot_records, 6);
        // topology mismatches are typed errors
        assert!(MemoryFabric::open(&cfg, 4, 3, 8, &tmp.0).is_err());
        assert!(MemoryFabric::open(&cfg, 5, 2, 8, &tmp.0).is_err());
        assert!(MemoryFabric::open(&cfg, 4, 2, 16, &tmp.0).is_err());
    }

    #[test]
    fn frame_id_orders_stream_major() {
        let a = FrameId::new(StreamId(0), 100);
        let b = FrameId::new(StreamId(1), 5);
        assert!(a < b);
        assert_eq!(format!("{a:?}"), "s0#100");
    }
}
