//! Multi-camera memory fabric: N per-stream [`Hierarchy`] shards, each
//! behind its own `RwLock`.
//!
//! Sharding rationale (LiveVLM / Mosaic scaling insight): camera A's
//! ingestion writer must never contend with camera B's query readers, so
//! the lock is per-shard — a writer only excludes readers *of its own
//! stream*.  Cross-stream queries take read guards on every scoped shard
//! at once (readers never block each other), merge the per-shard Eq. 4
//! scores into one softmax distribution, and sample from it — so a single
//! answer can cite evidence frames from several cameras.
//!
//! Lock-order note: fabric operations acquire shard guards in ascending
//! `StreamId` order while writers (ingestion pipelines) each hold at most
//! one shard lock at a time — no cycle, no deadlock.

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::config::MemoryConfig;
use crate::memory::hierarchy::Hierarchy;
use crate::memory::raw::RawStore;
use crate::video::frame::Frame;

/// Identifies one camera stream (== one shard) in the fabric.
#[derive(Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamId(pub u16);

impl StreamId {
    /// Shard-array index.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Debug for StreamId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

impl std::fmt::Display for StreamId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Fabric-global frame address: (stream, stream-local frame index).
///
/// Ordering is lexicographic (stream first), so a sorted selection groups
/// frames by camera and stays ascending-in-time within each camera —
/// exactly the order a multi-camera VLM prompt presents evidence in.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FrameId {
    pub stream: StreamId,
    pub idx: u64,
}

impl FrameId {
    pub fn new(stream: StreamId, idx: u64) -> Self {
        Self { stream, idx }
    }
}

impl std::fmt::Debug for FrameId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}#{}", self.stream, self.idx)
    }
}

/// Which shards a query sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamScope {
    /// A single camera stream.
    One(StreamId),
    /// Scatter-gather over every shard (cross-camera answers).
    All,
}

/// The multi-camera memory fabric: per-stream shards, each independently
/// locked.  Shard `i` owns `StreamId(i)`.
pub struct MemoryFabric {
    shards: Vec<Arc<RwLock<Hierarchy>>>,
}

impl MemoryFabric {
    /// Build an N-shard fabric, one raw store per stream (shard `i` takes
    /// `raws[i]` and owns `StreamId(i)`).
    pub fn new(
        cfg: &MemoryConfig,
        d_embed: usize,
        raws: Vec<Box<dyn RawStore>>,
    ) -> Result<Self> {
        anyhow::ensure!(!raws.is_empty(), "fabric needs at least one stream");
        anyhow::ensure!(
            raws.len() <= u16::MAX as usize,
            "fabric supports at most {} streams",
            u16::MAX
        );
        let mut shards = Vec::with_capacity(raws.len());
        for (i, raw) in raws.into_iter().enumerate() {
            shards.push(Arc::new(RwLock::new(Hierarchy::for_stream(
                cfg,
                d_embed,
                raw,
                StreamId(i as u16),
            )?)));
        }
        Ok(Self { shards })
    }

    /// Wrap an existing single shard (must own `StreamId(0)`) — the
    /// single-camera deployment and the test/bench convenience path.
    pub fn single(shard: Arc<RwLock<Hierarchy>>) -> Self {
        debug_assert_eq!(shard.read().unwrap().stream(), StreamId(0));
        Self { shards: vec![shard] }
    }

    pub fn n_streams(&self) -> usize {
        self.shards.len()
    }

    pub fn stream_ids(&self) -> impl Iterator<Item = StreamId> + '_ {
        (0..self.shards.len() as u16).map(StreamId)
    }

    /// All shards, in `StreamId` order.
    pub fn shards(&self) -> &[Arc<RwLock<Hierarchy>>] {
        &self.shards
    }

    /// One stream's shard.
    pub fn shard(&self, stream: StreamId) -> Result<&Arc<RwLock<Hierarchy>>> {
        self.shards
            .get(stream.index())
            .ok_or_else(|| anyhow::anyhow!("unknown stream {stream} ({}-shard fabric)", self.shards.len()))
    }

    /// The shards a scope covers, in ascending `StreamId` order.
    pub fn scoped(&self, scope: StreamScope) -> Result<Vec<&Arc<RwLock<Hierarchy>>>> {
        match scope {
            StreamScope::One(s) => Ok(vec![self.shard(s)?]),
            StreamScope::All => Ok(self.shards.iter().collect()),
        }
    }

    /// Fetch one raw frame by fabric-global address.
    pub fn fetch_frame(&self, id: FrameId) -> Result<Frame> {
        self.shard(id.stream)?.read().unwrap().fetch_frame(id.idx)
    }

    /// Fetch a batch of raw frames (the payload that ships to the cloud).
    /// Groups by stream so each shard's lock is taken once.
    pub fn fetch_frames(&self, ids: &[FrameId]) -> Result<Vec<Frame>> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            let stream = ids[i].stream;
            let shard = self.shard(stream)?;
            let guard = shard.read().unwrap();
            while i < ids.len() && ids[i].stream == stream {
                out.push(guard.fetch_frame(ids[i].idx)?);
                i += 1;
            }
        }
        Ok(out)
    }

    /// Per-shard ingest watermarks for the shards a scope covers, in
    /// ascending `StreamId` order.  The serving API's semantic query cache
    /// snapshots these at insert time and compares them at lookup time: a
    /// cached selection is reusable only while every touched shard's
    /// watermark has advanced by at most the configured staleness bound.
    pub fn watermarks(&self, scope: StreamScope) -> Result<Vec<(StreamId, u64)>> {
        Ok(self
            .scoped(scope)?
            .iter()
            .map(|s| {
                let g = s.read().unwrap();
                (g.stream(), g.watermark())
            })
            .collect())
    }

    /// Total indexed vectors across every shard.
    pub fn total_indexed(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Total frames archived across every shard.
    pub fn total_frames(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().frames_ingested())
            .sum()
    }

    /// Run `check_invariants` on every shard.
    pub fn check_invariants(&self) -> Result<()> {
        for shard in &self.shards {
            shard.read().unwrap().check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::hierarchy::ClusterRecord;
    use crate::memory::raw::InMemoryRaw;

    fn fabric(n: usize) -> MemoryFabric {
        let raws: Vec<Box<dyn RawStore>> =
            (0..n).map(|_| Box::new(InMemoryRaw::new(8)) as Box<dyn RawStore>).collect();
        MemoryFabric::new(&MemoryConfig::default(), 4, raws).unwrap()
    }

    #[test]
    fn shards_own_their_stream_ids() {
        let f = fabric(3);
        assert_eq!(f.n_streams(), 3);
        for (i, s) in f.stream_ids().enumerate() {
            assert_eq!(s, StreamId(i as u16));
            assert_eq!(f.shard(s).unwrap().read().unwrap().stream(), s);
        }
        assert!(f.shard(StreamId(3)).is_err());
    }

    #[test]
    fn scoped_selects_shards() {
        let f = fabric(4);
        assert_eq!(f.scoped(StreamScope::All).unwrap().len(), 4);
        assert_eq!(f.scoped(StreamScope::One(StreamId(2))).unwrap().len(), 1);
        assert!(f.scoped(StreamScope::One(StreamId(9))).is_err());
    }

    #[test]
    fn fetch_routes_by_stream_and_reports_holes() {
        let f = fabric(2);
        for (sid, fill) in [(0u16, 0.25f32), (1, 0.75)] {
            let shard = f.shard(StreamId(sid)).unwrap();
            let mut g = shard.write().unwrap();
            for i in 0..4u64 {
                g.archive_frame(i, &Frame::filled(8, [fill; 3]));
            }
        }
        let a = f.fetch_frame(FrameId::new(StreamId(0), 1)).unwrap();
        let b = f.fetch_frame(FrameId::new(StreamId(1), 1)).unwrap();
        assert!(a.data()[0] < b.data()[0], "frames came from distinct shards");

        // batched fetch across streams
        let ids = [
            FrameId::new(StreamId(0), 0),
            FrameId::new(StreamId(0), 3),
            FrameId::new(StreamId(1), 2),
        ];
        assert_eq!(f.fetch_frames(&ids).unwrap().len(), 3);

        // holes propagate as errors through the batched path too
        let hole = [FrameId::new(StreamId(1), 99)];
        assert!(f.fetch_frames(&hole).is_err());
        assert!(f.fetch_frame(FrameId::new(StreamId(7), 0)).is_err());
    }

    #[test]
    fn watermarks_follow_scope_and_inserts() {
        let f = fabric(3);
        assert_eq!(
            f.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 0), (StreamId(1), 0), (StreamId(2), 0)]
        );
        {
            let shard = f.shard(StreamId(1)).unwrap();
            let mut g = shard.write().unwrap();
            g.archive_frame(0, &Frame::filled(8, [0.5; 3]));
            g.insert(
                &[1.0, 0.0, 0.0, 0.0],
                ClusterRecord {
                    stream: StreamId(1),
                    scene_id: 0,
                    centroid_frame: 0,
                    members: vec![0],
                },
            )
            .unwrap();
        }
        assert_eq!(
            f.watermarks(StreamScope::All).unwrap(),
            vec![(StreamId(0), 0), (StreamId(1), 1), (StreamId(2), 0)]
        );
        assert_eq!(
            f.watermarks(StreamScope::One(StreamId(1))).unwrap(),
            vec![(StreamId(1), 1)]
        );
        assert!(f.watermarks(StreamScope::One(StreamId(9))).is_err());
    }

    #[test]
    fn invariants_cover_every_shard() {
        let f = fabric(2);
        {
            let shard = f.shard(StreamId(1)).unwrap();
            let mut g = shard.write().unwrap();
            g.archive_frame(0, &Frame::filled(8, [0.5; 3]));
            g.insert(
                &[1.0, 0.0, 0.0, 0.0],
                ClusterRecord {
                    stream: StreamId(1),
                    scene_id: 0,
                    centroid_frame: 9, // not a member: invariant violation
                    members: vec![0],
                },
            )
            .unwrap();
        }
        assert!(f.check_invariants().is_err());
    }

    #[test]
    fn frame_id_orders_stream_major() {
        let a = FrameId::new(StreamId(0), 100);
        let b = FrameId::new(StreamId(1), 5);
        assert!(a < b);
        assert_eq!(format!("{a:?}"), "s0#100");
    }
}
