//! Hierarchical memory (Fig. 8): the index layer links each stored vector
//! to its scene cluster in the raw layer, enabling the two-phase recall
//! the paper describes — locate relevant scenes via the semantic index,
//! then reconstruct detail from the raw archive.
//!
//! One `Hierarchy` is one *shard* of the multi-camera memory fabric: it
//! owns a single stream's index vectors and raw archive, addressed by
//! stream-local dense frame ids.  Cross-stream composition (scatter-gather
//! scoring, fabric-global `FrameId` addressing) lives in
//! [`crate::memory::fabric`].

use anyhow::Result;

use crate::config::MemoryConfig;
use crate::memory::fabric::StreamId;
use crate::memory::raw::RawStore;
use crate::memory::vectordb::{build_index, Hit, Metric, VectorIndex};

/// Index-layer record: one indexed (centroid) frame and its cluster.
#[derive(Clone, Debug)]
pub struct ClusterRecord {
    /// owning camera stream (== the shard this record lives in)
    pub stream: StreamId,
    /// partition (scene) sequence number from the segmenter
    pub scene_id: usize,
    /// stream-local frame id of the indexed (centroid) frame
    pub centroid_frame: u64,
    /// member frame ids (stream-local), ascending
    pub members: Vec<u64>,
}

/// The hierarchical memory: vector index + cluster links + raw archive.
pub struct Hierarchy {
    stream: StreamId,
    index: Box<dyn VectorIndex>,
    records: Vec<ClusterRecord>,
    raw: Box<dyn RawStore>,
    frames_ingested: u64,
    /// Monotone ingest watermark: total index inserts ever applied to this
    /// shard.  Currently equal to `len()`, but kept as its own counter so
    /// staleness checks (the serving API's semantic query cache) survive a
    /// future compaction/eviction pass that shrinks the index.
    watermark: u64,
}

impl Hierarchy {
    /// Single-stream shard (stream 0) — the default deployment.
    pub fn new(cfg: &MemoryConfig, d_embed: usize, raw: Box<dyn RawStore>) -> Result<Self> {
        Self::for_stream(cfg, d_embed, raw, StreamId(0))
    }

    /// A shard of the memory fabric owning one camera stream.
    pub fn for_stream(
        cfg: &MemoryConfig,
        d_embed: usize,
        raw: Box<dyn RawStore>,
        stream: StreamId,
    ) -> Result<Self> {
        let index = build_index(
            &cfg.index,
            d_embed,
            Metric::Cosine,
            cfg.ivf_nlist,
            cfg.ivf_nprobe,
        )?;
        Ok(Self { stream, index, records: Vec::new(), raw, frames_ingested: 0, watermark: 0 })
    }

    /// The camera stream this shard owns.
    pub fn stream(&self) -> StreamId {
        self.stream
    }

    /// Archive a raw frame (every captured frame flows through here).
    pub fn archive_frame(&mut self, id: u64, frame: &crate::video::frame::Frame) {
        self.raw.put(id, frame);
        self.frames_ingested = self.frames_ingested.max(id + 1);
    }

    /// Insert an indexed frame: embedding vector + cluster record.  The
    /// record must belong to this shard's stream — per-stream isolation is
    /// enforced at the write path, not trusted from callers.
    pub fn insert(&mut self, embedding: &[f32], record: ClusterRecord) -> Result<usize> {
        anyhow::ensure!(
            record.stream == self.stream,
            "record for stream {:?} inserted into shard {:?}",
            record.stream,
            self.stream
        );
        let mut members = record.members.clone();
        members.sort_unstable();
        let id = self.index.insert(embedding)?;
        debug_assert_eq!(id, self.records.len());
        self.records.push(ClusterRecord { members, ..record });
        self.watermark += 1;
        Ok(id)
    }

    /// Monotone count of index inserts ever applied to this shard.  The
    /// serving API's query cache snapshots this per touched shard and
    /// treats an entry as stale once the watermark advances past a bound.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Similarity of the query vector against every indexed vector.
    pub fn score_all(&self, query: &[f32], out: &mut Vec<f32>) {
        self.index.score_all(query, out);
    }

    /// Top-k indexed frames (vanilla greedy retrieval).
    pub fn search_topk(&self, query: &[f32], k: usize) -> Vec<Hit> {
        self.index.search(query, k)
    }

    pub fn record(&self, id: usize) -> &ClusterRecord {
        &self.records[id]
    }

    pub fn records(&self) -> &[ClusterRecord] {
        &self.records
    }

    /// Stored vector by index id.
    pub fn vector(&self, id: usize) -> &[f32] {
        self.index.vector(id)
    }

    /// Number of indexed vectors (== clusters).
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total frames archived in the raw layer.
    pub fn frames_ingested(&self) -> u64 {
        self.frames_ingested
    }

    /// Fetch a raw frame by stream-local id.  A missing frame (hole in
    /// the archive) is an error, not a panic — the query path propagates
    /// it instead of taking down a serving worker.
    pub fn fetch_frame(&self, id: u64) -> Result<crate::video::frame::Frame> {
        self.raw.get(id).ok_or_else(|| {
            anyhow::anyhow!(
                "frame {id} missing from stream {:?} raw archive ({} archived)",
                self.stream,
                self.frames_ingested
            )
        })
    }

    /// Compression ratio: raw frames per indexed vector.
    pub fn sparsity(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.frames_ingested as f64 / self.records.len() as f64
    }

    /// Resident bytes of the raw layer (memory-growth bench).
    pub fn raw_resident_bytes(&self) -> usize {
        self.raw.resident_bytes()
    }

    /// Invariant check (property tests): every record's members are
    /// sorted, contain the centroid, refer to archived frames, and belong
    /// to this shard's stream (per-stream isolation).
    pub fn check_invariants(&self) -> Result<()> {
        anyhow::ensure!(self.records.len() == self.index.len(), "record/index drift");
        for (i, r) in self.records.iter().enumerate() {
            anyhow::ensure!(
                r.stream == self.stream,
                "record {i} cites stream {:?} inside shard {:?}",
                r.stream,
                self.stream
            );
            anyhow::ensure!(!r.members.is_empty(), "record {i} empty");
            anyhow::ensure!(
                r.members.windows(2).all(|w| w[0] < w[1]),
                "record {i} members unsorted"
            );
            anyhow::ensure!(
                r.members.binary_search(&r.centroid_frame).is_ok(),
                "record {i} centroid not a member"
            );
            anyhow::ensure!(
                *r.members.last().unwrap() < self.frames_ingested,
                "record {i} references unarchived frame"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::raw::InMemoryRaw;
    use crate::util::rng::Pcg64;
    use crate::video::frame::Frame;

    fn hierarchy() -> Hierarchy {
        Hierarchy::new(
            &MemoryConfig::default(),
            8,
            Box::new(InMemoryRaw::new(16)),
        )
        .unwrap()
    }

    fn unit(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        crate::util::l2_normalize(&mut v);
        v
    }

    #[test]
    fn insert_and_link() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(1);
        for i in 0..20u64 {
            h.archive_frame(i, &Frame::filled(16, [0.5; 3]));
        }
        let v = unit(&mut rng, 8);
        let id = h
            .insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: 0,
                    centroid_frame: 3,
                    members: vec![3, 4, 5],
                },
            )
            .unwrap();
        assert_eq!(id, 0);
        assert_eq!(h.record(0).members, vec![3, 4, 5]);
        assert_eq!(h.len(), 1);
        h.check_invariants().unwrap();
    }

    #[test]
    fn rejects_foreign_stream_record() {
        let mut h = hierarchy(); // stream 0
        let mut rng = Pcg64::seeded(9);
        h.archive_frame(0, &Frame::filled(16, [0.5; 3]));
        let v = unit(&mut rng, 8);
        let err = h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(3),
                scene_id: 0,
                centroid_frame: 0,
                members: vec![0],
            },
        );
        assert!(err.is_err(), "cross-stream insert must be rejected");
    }

    #[test]
    fn search_returns_inserted() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(2);
        for i in 0..100u64 {
            h.archive_frame(i, &Frame::filled(16, [0.1; 3]));
        }
        let mut vs = Vec::new();
        for i in 0..10u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: i as usize,
                    centroid_frame: i * 10,
                    members: (i * 10..(i + 1) * 10).collect(),
                },
            )
            .unwrap();
            vs.push(v);
        }
        let hits = h.search_topk(&vs[7], 1);
        assert_eq!(hits[0].id, 7);
        h.check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_bad_members() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(3);
        h.archive_frame(0, &Frame::filled(16, [0.0; 3]));
        let v = unit(&mut rng, 8);
        // centroid not in members
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: 0,
                centroid_frame: 9,
                members: vec![0],
            },
        )
        .unwrap();
        assert!(h.check_invariants().is_err());
    }

    #[test]
    fn sparsity_reflects_compression() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(4);
        for i in 0..100u64 {
            h.archive_frame(i, &Frame::filled(16, [0.2; 3]));
        }
        for c in 0..4u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 25,
                    members: (c * 25..(c + 1) * 25).collect(),
                },
            )
            .unwrap();
        }
        assert!((h.sparsity() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn watermark_counts_inserts_not_archives() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(5);
        assert_eq!(h.watermark(), 0);
        for i in 0..10u64 {
            h.archive_frame(i, &Frame::filled(16, [0.5; 3]));
        }
        assert_eq!(h.watermark(), 0, "archiving alone must not advance the watermark");
        for c in 0..3u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 3,
                    members: vec![c * 3, c * 3 + 1, c * 3 + 2],
                },
            )
            .unwrap();
        }
        assert_eq!(h.watermark(), 3);
    }

    #[test]
    fn fetch_frame_reports_holes() {
        let mut h = hierarchy();
        h.archive_frame(0, &Frame::filled(16, [0.5; 3]));
        assert!(h.fetch_frame(0).is_ok());
        let err = h.fetch_frame(7).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("missing"), "diagnostic missing: {msg}");
    }
}
