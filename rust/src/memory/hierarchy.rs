//! Hierarchical memory (Fig. 8): the index layer links each stored vector
//! to its scene cluster in the raw layer, enabling the two-phase recall
//! the paper describes — locate relevant scenes via the semantic index,
//! then reconstruct detail from the raw archive.
//!
//! One `Hierarchy` is one *shard* of the multi-camera memory fabric: it
//! owns a single stream's index vectors and raw archive, addressed by
//! stream-local dense frame ids.  Cross-stream composition (scatter-gather
//! scoring, fabric-global `FrameId` addressing) lives in
//! [`crate::memory::fabric`].
//!
//! # Tiered lifecycle (durable shards)
//!
//! A shard's record space `[0, watermark)` is split at `hot_base`:
//!
//! * **hot tier** `[hot_base, watermark)` — vectors resident in the RAM
//!   index, scored in place; bounded by `memory.hot_budget_bytes`;
//! * **cold tier** `[0, hot_base)` — the oldest records, demoted to
//!   sealed segment files whose vector blocks page through an LRU cache
//!   ([`crate::memory::segment::ColdTier`]).
//!
//! Record *metadata* (scene links, member lists) stays resident for the
//! whole space — selection must expand any drawn cluster without disk
//! round-trips, and the All-scope merged view borrows record slices
//! across shards.  Only vectors (the dominant index mass) and raw frames
//! are tiered.
//!
//! Eviction is watermark-ordered and segment-granular: when the hot
//! tier exceeds its budget, the oldest sealed segment is demoted (the
//! WAL force-seals first if nothing sealed is left to demote).  Because
//! demotion only ever removes the *oldest prefix* and cold segments are
//! scanned in base order, the concatenated cold + hot score vector is in
//! global id order — the exact Eq. 4 distribution an unbounded shard
//! would produce, bit for bit (see `DESIGN.md` §Storage).

use std::path::Path;

use anyhow::Result;

use crate::config::MemoryConfig;
use crate::memory::fabric::StreamId;
use crate::memory::raw::RawStore;
use crate::memory::segment::{ColdSpan, ColdTier, SegmentOptions};
use crate::memory::storage::{DiskRaw, StreamStorage};
use crate::memory::vectordb::{build_index, Hit, Metric, VectorIndex};
use crate::util::scorer::{ScorePool, ScoreTask};

/// Index-layer record: one indexed (centroid) frame and its cluster.
#[derive(Clone, Debug)]
pub struct ClusterRecord {
    /// owning camera stream (== the shard this record lives in)
    pub stream: StreamId,
    /// partition (scene) sequence number from the segmenter
    pub scene_id: usize,
    /// stream-local frame id of the indexed (centroid) frame
    pub centroid_frame: u64,
    /// member frame ids (stream-local), ascending
    pub members: Vec<u64>,
}

/// Row-disjoint decomposition of one shard's scan: built under the
/// shard's read guard by [`Hierarchy::plan_score`], turned into scoring
/// tasks by [`Hierarchy::push_score_tasks`].  The plan records the probe
/// decision, so building it already bumps the shard's scan gauges —
/// callers must follow through and run the tasks.
pub struct ShardScorePlan {
    /// L2-normalized copy of the query for the cold scan (empty when the
    /// shard has no cold tier)
    qn: Vec<f32>,
    spans: Vec<ColdSpan>,
    cold_rows: usize,
    hot_rows: usize,
}

impl ShardScorePlan {
    /// Total rows this shard contributes to the merged score buffer.
    pub fn rows(&self) -> usize {
        self.cold_rows + self.hot_rows
    }

    /// Rows served by the cold tier under this plan.
    pub fn cold_rows(&self) -> usize {
        self.cold_rows
    }

    /// Rows served by the hot index under this plan.
    pub fn hot_rows(&self) -> usize {
        self.hot_rows
    }

    /// Cold segments the coarse probe decided to scan.
    pub fn probed_segments(&self) -> usize {
        self.spans.iter().filter(|s| s.scanned).count()
    }

    /// Cold segments the coarse probe pruned (filled with `-inf`).
    pub fn pruned_segments(&self) -> usize {
        self.spans.iter().filter(|s| !s.scanned).count()
    }
}

/// Per-tier residency and traffic gauges of one shard (or, merged, the
/// whole fabric) — what `server::Snapshot` and `venus serve` report.
#[derive(Clone, Copy, Debug, Default)]
pub struct TierStats {
    /// hot-tier resident bytes: index vectors + their record metadata
    pub hot_bytes: usize,
    pub hot_records: usize,
    /// records demoted to sealed segments
    pub cold_records: usize,
    pub cold_segments: usize,
    /// cold vector blocks currently resident in the LRU cache
    pub cold_resident_bytes: usize,
    /// raw-layer resident bytes (0 for disk/generator-backed archives)
    pub raw_resident_bytes: usize,
    /// records demoted from the hot tier so far
    pub evictions: u64,
    /// cold block-cache hits / misses (the cold-hit rate gauge)
    pub cold_hits: u64,
    pub cold_misses: u64,
    /// cold segments fully scanned vs considered, across all cold
    /// queries (equal unless coarse probing is pruning)
    pub cold_probe_segments: u64,
    pub cold_probe_candidates: u64,
    /// cold rows actually scored (pruned segments score nothing)
    pub cold_rows_scored: u64,
    /// whether cold scans use the SQ8 representation (OR across shards)
    pub cold_quantized: bool,
}

impl TierStats {
    /// Accumulate another shard's gauges (fabric-wide totals).
    pub fn merge(&mut self, o: &TierStats) {
        self.hot_bytes += o.hot_bytes;
        self.hot_records += o.hot_records;
        self.cold_records += o.cold_records;
        self.cold_segments += o.cold_segments;
        self.cold_resident_bytes += o.cold_resident_bytes;
        self.raw_resident_bytes += o.raw_resident_bytes;
        self.evictions += o.evictions;
        self.cold_hits += o.cold_hits;
        self.cold_misses += o.cold_misses;
        self.cold_probe_segments += o.cold_probe_segments;
        self.cold_probe_candidates += o.cold_probe_candidates;
        self.cold_rows_scored += o.cold_rows_scored;
        self.cold_quantized |= o.cold_quantized;
    }

    /// Block-cache hit rate over cold-tier accesses, if any happened.
    pub fn cold_hit_rate(&self) -> Option<f64> {
        let total = self.cold_hits + self.cold_misses;
        if total == 0 {
            None
        } else {
            Some(self.cold_hits as f64 / total as f64)
        }
    }

    /// Serialize to the wire JSON encoding (the gateway's `Stats` reply).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("hot_bytes".into(), Json::Num(self.hot_bytes as f64));
        m.insert("hot_records".into(), Json::Num(self.hot_records as f64));
        m.insert("cold_records".into(), Json::Num(self.cold_records as f64));
        m.insert("cold_segments".into(), Json::Num(self.cold_segments as f64));
        m.insert("cold_resident_bytes".into(), Json::Num(self.cold_resident_bytes as f64));
        m.insert("raw_resident_bytes".into(), Json::Num(self.raw_resident_bytes as f64));
        m.insert("evictions".into(), Json::Num(self.evictions as f64));
        m.insert("cold_hits".into(), Json::Num(self.cold_hits as f64));
        m.insert("cold_misses".into(), Json::Num(self.cold_misses as f64));
        m.insert(
            "cold_probe_segments".into(),
            Json::Num(self.cold_probe_segments as f64),
        );
        m.insert(
            "cold_probe_candidates".into(),
            Json::Num(self.cold_probe_candidates as f64),
        );
        m.insert("cold_rows_scored".into(), Json::Num(self.cold_rows_scored as f64));
        m.insert("cold_quantized".into(), Json::Bool(self.cold_quantized));
        Json::Obj(m)
    }

    /// Parse the wire JSON encoding.  The scan-observability fields are
    /// optional so a newer client can read an older server's reply.
    pub fn from_json(v: &crate::util::json::Json) -> Result<Self> {
        let opt_u64 = |key: &str| -> Result<u64> {
            match v.opt(key) {
                Some(x) => Ok(x.as_usize()? as u64),
                None => Ok(0),
            }
        };
        Ok(Self {
            hot_bytes: v.get("hot_bytes")?.as_usize()?,
            hot_records: v.get("hot_records")?.as_usize()?,
            cold_records: v.get("cold_records")?.as_usize()?,
            cold_segments: v.get("cold_segments")?.as_usize()?,
            cold_resident_bytes: v.get("cold_resident_bytes")?.as_usize()?,
            raw_resident_bytes: v.get("raw_resident_bytes")?.as_usize()?,
            evictions: v.get("evictions")?.as_usize()? as u64,
            cold_hits: v.get("cold_hits")?.as_usize()? as u64,
            cold_misses: v.get("cold_misses")?.as_usize()? as u64,
            cold_probe_segments: opt_u64("cold_probe_segments")?,
            cold_probe_candidates: opt_u64("cold_probe_candidates")?,
            cold_rows_scored: opt_u64("cold_rows_scored")?,
            cold_quantized: match v.opt("cold_quantized") {
                Some(x) => x.as_bool()?,
                None => false,
            },
        })
    }
}

/// The hierarchical memory: vector index + cluster links + raw archive,
/// optionally backed by the durable storage layer (WAL + sealed
/// segments) with a bounded hot tier.
pub struct Hierarchy {
    stream: StreamId,
    cfg: MemoryConfig,
    d_embed: usize,
    /// hot-tier vector index: local id `i` holds global id `hot_base + i`
    index: Box<dyn VectorIndex>,
    /// global record id of the first hot record (== cold record count)
    hot_base: usize,
    /// resident bytes of hot records' metadata (vectors counted via index)
    hot_meta_bytes: usize,
    /// ALL records, hot and cold — selection needs any drawn cluster's
    /// members without a disk round-trip
    records: Vec<ClusterRecord>,
    cold: ColdTier,
    storage: Option<StreamStorage>,
    raw: Box<dyn RawStore>,
    frames_ingested: u64,
    /// Monotone ingest watermark: total index inserts ever applied to this
    /// shard.  Currently equal to `len()`, but kept as its own counter so
    /// staleness checks (the serving API's semantic query cache) survive a
    /// future compaction pass that drops records outright.
    watermark: u64,
    /// records demoted from the hot tier so far
    evictions: u64,
}

impl Hierarchy {
    /// Single-stream shard (stream 0) — the default deployment.
    pub fn new(cfg: &MemoryConfig, d_embed: usize, raw: Box<dyn RawStore>) -> Result<Self> {
        Self::for_stream(cfg, d_embed, raw, StreamId(0))
    }

    /// A pure-RAM shard of the memory fabric owning one camera stream.
    pub fn for_stream(
        cfg: &MemoryConfig,
        d_embed: usize,
        raw: Box<dyn RawStore>,
        stream: StreamId,
    ) -> Result<Self> {
        Self::build(cfg, d_embed, raw, stream, None)
    }

    /// A durable shard rooted at `dir`: raw frames go to the on-disk
    /// frame log, index inserts stream through the WAL, and any state a
    /// previous process sealed (or flushed) is recovered.  Sealed spans
    /// are *promoted* back into the RAM index up to the hot budget,
    /// newest first (an unbounded shard promotes everything — a restart
    /// must not permanently degrade an all-RAM deployment to disk
    /// scans); whatever the budget cannot hold stays demoted as the
    /// cold-tier prefix, and the WAL tail always recovers hot.
    pub fn durable(
        cfg: &MemoryConfig,
        d_embed: usize,
        stream: StreamId,
        dir: &Path,
        frame_size: usize,
    ) -> Result<Self> {
        let raw = Box::new(DiskRaw::open(dir, frame_size, cfg.segment_frames)?);
        let (storage, recovered) =
            StreamStorage::open(dir, stream, d_embed, Self::segment_options(cfg))?;
        let mut h = Self::build(cfg, d_embed, raw, stream, Some(storage))?;
        let metas = h
            .storage
            .as_ref()
            .map(|st| st.segments().to_vec())
            .unwrap_or_default();
        let sealed_meta = recovered.sealed_records;

        // choose the demoted prefix: walk segments newest-first, keeping
        // them hot while the budget (minus the WAL tail's cost) allows
        let mut promote_from = 0usize;
        if cfg.hot_budget_bytes > 0 {
            let wal_bytes: usize = recovered
                .wal_tail
                .iter()
                .map(|(r, v)| v.len() * 4 + Self::record_bytes(r))
                .sum();
            let mut left = cfg.hot_budget_bytes.saturating_sub(wal_bytes);
            promote_from = metas.len();
            while promote_from > 0 {
                let m = &metas[promote_from - 1];
                let bytes = m.count * d_embed * 4
                    + sealed_meta[m.base..m.base + m.count]
                        .iter()
                        .map(Self::record_bytes)
                        .sum::<usize>();
                if bytes > left {
                    break;
                }
                left -= bytes;
                promote_from -= 1;
            }
        }
        for meta in &metas[..promote_from] {
            h.cold.push(meta.clone())?;
        }
        h.hot_base = h.cold.record_count();
        h.records = sealed_meta;

        // promote the surviving suffix back into RAM — stored bytes
        // replayed verbatim via `insert_prepared` (no re-normalization),
        // so every recovered row is bit-identical to the one that was
        // scored before the restart
        for meta in &metas[promote_from..] {
            let block = crate::memory::segment::load_vectors(meta)?;
            for local in 0..meta.count {
                h.index
                    .insert_prepared(&block[local * d_embed..(local + 1) * d_embed])?;
            }
        }
        h.hot_meta_bytes =
            h.records[h.hot_base..].iter().map(Self::record_bytes).sum();
        for (rec, vec) in recovered.wal_tail {
            let local = h.index.insert_prepared(&vec)?;
            debug_assert_eq!(h.hot_base + local, h.records.len());
            h.hot_meta_bytes += Self::record_bytes(&rec);
            h.records.push(rec);
        }
        h.watermark = h.records.len() as u64;
        h.frames_ingested = h.raw.len();
        h.maybe_evict()?; // the budget may be tighter than the WAL tail
        Ok(h)
    }

    /// Seal-time segment layout implied by the `[memory]` config: SQ8
    /// when `memory.quantization = "sq8"`, coarse centroids when
    /// `memory.coarse_centroids_per_segment > 0`.
    fn segment_options(cfg: &MemoryConfig) -> SegmentOptions {
        SegmentOptions {
            sq8: cfg.quantization == "sq8",
            centroids: cfg.coarse_centroids_per_segment,
        }
    }

    fn build(
        cfg: &MemoryConfig,
        d_embed: usize,
        raw: Box<dyn RawStore>,
        stream: StreamId,
        storage: Option<StreamStorage>,
    ) -> Result<Self> {
        anyhow::ensure!(
            cfg.hot_budget_bytes == 0 || storage.is_some(),
            "memory.hot_budget_bytes is set but shard {stream} has no durable \
             storage to demote into — open the fabric with MemoryFabric::open"
        );
        let index = build_index(
            &cfg.index,
            d_embed,
            Metric::Cosine,
            cfg.ivf_nlist,
            cfg.ivf_nprobe,
        )?;
        Ok(Self {
            stream,
            cfg: cfg.clone(),
            d_embed,
            index,
            hot_base: 0,
            hot_meta_bytes: 0,
            records: Vec::new(),
            cold: ColdTier::new(
                cfg.cold_cache_segments,
                cfg.quantization == "sq8",
                cfg.coarse_nprobe,
            ),
            storage,
            raw,
            frames_ingested: 0,
            watermark: 0,
            evictions: 0,
        })
    }

    /// The camera stream this shard owns.
    pub fn stream(&self) -> StreamId {
        self.stream
    }

    /// Whether this shard persists to disk.
    pub fn is_durable(&self) -> bool {
        self.storage.is_some()
    }

    /// Archive a raw frame (every captured frame flows through here).
    /// Fallible: a disk-backed raw store surfaces write errors (e.g. a
    /// full SSD) as typed errors, and the archived watermark only
    /// advances past frames that actually landed.
    pub fn archive_frame(
        &mut self,
        id: u64,
        frame: &crate::video::frame::Frame,
    ) -> Result<()> {
        self.raw.put(id, frame)?;
        self.frames_ingested = self.frames_ingested.max(id + 1);
        Ok(())
    }

    /// Resident metadata bytes of one record (budget accounting).
    fn record_bytes(r: &ClusterRecord) -> usize {
        std::mem::size_of::<ClusterRecord>() + r.members.len() * std::mem::size_of::<u64>()
    }

    /// Hot-tier resident bytes: index vectors + hot record metadata.
    pub fn hot_bytes(&self) -> usize {
        self.index.len() * self.d_embed * std::mem::size_of::<f32>() + self.hot_meta_bytes
    }

    /// Insert an indexed frame: embedding vector + cluster record.  The
    /// record must belong to this shard's stream — per-stream isolation is
    /// enforced at the write path, not trusted from callers.  On durable
    /// shards the insert also streams into the WAL, seals a segment once
    /// `memory.segment_records` accumulate, and demotes the oldest sealed
    /// segments whenever the hot tier exceeds `memory.hot_budget_bytes`.
    pub fn insert(&mut self, embedding: &[f32], record: ClusterRecord) -> Result<usize> {
        anyhow::ensure!(
            record.stream == self.stream,
            "record for stream {:?} inserted into shard {:?}",
            record.stream,
            self.stream
        );
        let mut members = record.members.clone();
        members.sort_unstable();
        let record = ClusterRecord { members, ..record };
        let global = self.records.len();
        let local = self.index.insert(embedding)?;
        debug_assert_eq!(self.hot_base + local, global);
        if let Some(st) = self.storage.as_mut() {
            // the WAL stores the index's post-normalization bytes: what
            // recovery replays is exactly what scoring reads
            st.append(&record, self.index.vector(local));
        }
        self.hot_meta_bytes += Self::record_bytes(&record);
        self.records.push(record);
        self.watermark += 1;
        if let Some(st) = self.storage.as_ref() {
            if st.unsealed_records() >= self.cfg.segment_records {
                self.seal_now()?;
            }
        }
        self.maybe_evict()?;
        Ok(global)
    }

    /// Seal the whole unsealed WAL span into an immutable segment.
    fn seal_now(&mut self) -> Result<()> {
        let (base, count) = match self.storage.as_ref() {
            Some(st) => (st.sealed_records(), st.unsealed_records()),
            None => return Ok(()),
        };
        if count == 0 {
            return Ok(());
        }
        // frames the span cites must be durable before the manifest
        // commits the records that cite them
        self.raw.sync()?;
        let mut vecs = Vec::with_capacity(count * self.d_embed);
        for g in base..base + count {
            vecs.extend_from_slice(self.index.vector(g - self.hot_base));
        }
        match self.storage.as_mut() {
            Some(st) => st.seal(&self.records[base..base + count], &vecs),
            None => Ok(()),
        }
    }

    /// Demote oldest sealed segments until the hot tier fits its budget.
    fn maybe_evict(&mut self) -> Result<()> {
        if self.cfg.hot_budget_bytes == 0 {
            return Ok(());
        }
        while self.hot_bytes() > self.cfg.hot_budget_bytes {
            let demoted = self.cold.segment_count();
            let sealed = self.storage.as_ref().map_or(0, |s| s.segments().len());
            if demoted >= sealed {
                if self.storage.as_ref().map_or(0, |s| s.unsealed_records()) == 0 {
                    break; // hot tier already empty: nothing left to demote
                }
                self.seal_now()?; // force-seal so the span becomes demotable
            }
            self.demote_oldest()?;
        }
        Ok(())
    }

    /// Demote the oldest still-hot sealed segment to the cold tier and
    /// rebuild the hot index over the surviving suffix (bit-exact:
    /// surviving rows re-enter via `insert_prepared`).
    fn demote_oldest(&mut self) -> Result<()> {
        let demoted = self.cold.segment_count();
        let meta = self
            .storage
            .as_ref()
            .and_then(|st| st.segments().get(demoted).cloned())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "demotion requested but shard {:?} has no sealed segment beyond {demoted}",
                    self.stream
                )
            })?;
        let k = meta.count;
        let mut fresh = build_index(
            &self.cfg.index,
            self.d_embed,
            Metric::Cosine,
            self.cfg.ivf_nlist,
            self.cfg.ivf_nprobe,
        )?;
        for local in k..self.index.len() {
            fresh.insert_prepared(self.index.vector(local))?;
        }
        self.index = fresh;
        for r in &self.records[self.hot_base..self.hot_base + k] {
            self.hot_meta_bytes -= Self::record_bytes(r);
        }
        self.hot_base += k;
        self.cold.push(meta)?;
        self.evictions += k as u64;
        Ok(())
    }

    /// Force the WAL tail AND the frame log to disk (a durability point;
    /// no-op for pure-RAM shards).  Dropping a durable shard WITHOUT
    /// flushing is equivalent to a crash: everything since the last
    /// seal/flush is lost.
    pub fn flush(&mut self) -> Result<()> {
        let Some(st) = self.storage.as_mut() else { return Ok(()) };
        // frames first: a durable (replayable) record must never cite a
        // frame the log lost
        self.raw.sync()?;
        st.flush()
    }

    /// Monotone count of index inserts ever applied to this shard.  The
    /// serving API's query cache snapshots this per touched shard and
    /// treats an entry as stale once the watermark advances past a bound.
    /// `MemoryFabric::recover` restores it from disk, so cache staleness
    /// logic keeps working across restarts.
    pub fn watermark(&self) -> u64 {
        self.watermark
    }

    /// Similarity of the query vector against every indexed record, in
    /// global id order: cold segments scan first (base order), then the
    /// hot index scores in place.  When nothing has been demoted this is
    /// exactly the legacy single-index scan.
    pub fn score_all(&self, query: &[f32], out: &mut Vec<f32>) -> Result<()> {
        if self.cold.is_empty() {
            self.index.score_all(query, out);
            return Ok(());
        }
        out.clear();
        out.reserve(self.records.len());
        // the hierarchy always builds a cosine index: prepare the query
        // exactly as the index would, so cold rows score identically.
        // The hot tier deliberately receives the RAW query (normalizing
        // inside `score_all`, same as the all-hot fast path above):
        // passing `qn` would make the index normalize an already-unit
        // vector, and `l2_normalize` is not bit-idempotent — the small
        // duplicate normalization is the price of hot scores staying
        // bit-identical across the tier split.
        let mut qn = query.to_vec();
        crate::util::l2_normalize(&mut qn);
        self.cold.score_into(&qn, out)?;
        let mut hot = Vec::new();
        self.index.score_all(query, &mut hot);
        out.extend_from_slice(&hot);
        Ok(())
    }

    /// Decompose this shard's next scan into a row-disjoint plan for the
    /// scoring pool (DESIGN.md §Parallel-Query).  Mirrors
    /// [`Hierarchy::score_all`] exactly: the cold tier sees an
    /// L2-normalized copy of the query, the hot tier the raw query (the
    /// index normalizes internally — `l2_normalize` is not
    /// bit-idempotent), and the probe decision + scan gauges are the
    /// ones a serial walk of the same query would produce.
    pub fn plan_score(&self, query: &[f32]) -> ShardScorePlan {
        if self.cold.is_empty() {
            return ShardScorePlan {
                qn: Vec::new(),
                spans: Vec::new(),
                cold_rows: 0,
                hot_rows: self.index.len(),
            };
        }
        let mut qn = query.to_vec();
        crate::util::l2_normalize(&mut qn);
        let spans = self.cold.plan(&qn);
        ShardScorePlan {
            qn,
            spans,
            cold_rows: self.cold.record_count(),
            hot_rows: self.index.len(),
        }
    }

    /// Turn a [`ShardScorePlan`] into pool tasks, each owning a disjoint
    /// slice of `out` (`out.len()` must equal `plan.rows()`): one task
    /// per scanned cold segment, a readahead task warming each *next*
    /// scanned segment's block while its predecessor scores, and one
    /// task for the hot index.  Coarse-pruned spans are filled with
    /// `NEG_INFINITY` inline (same value the serial path writes).
    /// Concatenated cold-then-hot output is bit-identical to
    /// [`Hierarchy::score_all`] — parallelism is across segments only,
    /// never inside a row's FP accumulation order.
    pub fn push_score_tasks<'a>(
        &'a self,
        plan: &'a ShardScorePlan,
        query: &'a [f32],
        out: &'a mut [f32],
        pool: &'a ScorePool,
        tasks: &mut Vec<ScoreTask<'a>>,
    ) {
        debug_assert_eq!(out.len(), plan.rows(), "score slice mis-sized for plan");
        let (cold_out, hot_out) = out.split_at_mut(plan.cold_rows);
        // next scanned segment after position k, for readahead pairing
        let mut next_scanned = vec![None; plan.spans.len()];
        let mut next = None;
        for k in (0..plan.spans.len()).rev() {
            next_scanned[k] = next;
            if plan.spans[k].scanned {
                next = Some(plan.spans[k].seg);
            }
        }
        let mut rest = cold_out;
        for (k, span) in plan.spans.iter().enumerate() {
            let (slice, r) = rest.split_at_mut(span.count);
            rest = r;
            if !span.scanned {
                slice.fill(f32::NEG_INFINITY);
                continue;
            }
            if let Some(next_seg) = next_scanned[k] {
                let cold = &self.cold;
                tasks.push(Box::new(move || cold.prefetch(next_seg)));
            }
            let cold = &self.cold;
            let qn = &plan.qn;
            let seg = span.seg;
            tasks.push(Box::new(move || {
                let t0 = std::time::Instant::now();
                let res = cold.score_segment_into(qn, seg, slice);
                pool.note_cold_ns(t0.elapsed().as_nanos() as u64);
                res
            }));
        }
        if plan.hot_rows > 0 {
            let index = &self.index;
            tasks.push(Box::new(move || {
                let t0 = std::time::Instant::now();
                index.score_into(query, hot_out);
                pool.note_hot_ns(t0.elapsed().as_nanos() as u64);
                Ok(())
            }));
        }
    }

    /// Parallel counterpart of [`Hierarchy::score_all`]: run this
    /// shard's decomposed scan on the scoring pool.  Output (and the rng
    /// draws any selector makes over it) is bit-identical to the serial
    /// path at every `score_workers` count.
    pub fn score_all_pooled(
        &self,
        pool: &ScorePool,
        query: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let plan = self.plan_score(query);
        out.clear();
        out.resize(plan.rows(), 0.0);
        let mut tasks = Vec::new();
        self.push_score_tasks(&plan, query, &mut out[..], pool, &mut tasks);
        pool.run_batch(tasks)
    }

    /// Top-k indexed frames (vanilla greedy retrieval), tier-aware.
    ///
    /// Exactness follows the hot index while everything is hot (an IVF
    /// index probes `ivf_nprobe` cells and may miss true top-k ids);
    /// once any span is demoted the merged scan is exact — so with
    /// `memory.index = "ivf"` the hit set can differ across tier states.
    /// The Eq. 4–5 serving path is unaffected: it always goes through
    /// the exact [`Hierarchy::score_all`].
    pub fn search_topk(&self, query: &[f32], k: usize) -> Result<Vec<Hit>> {
        if self.cold.is_empty() {
            return Ok(self.index.search(query, k));
        }
        let mut scores = Vec::new();
        self.score_all(query, &mut scores)?;
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        Ok(order
            .into_iter()
            .take(k)
            .map(|id| Hit { id, score: scores[id] })
            .collect())
    }

    /// Record metadata by global id; `None` for an id this shard never
    /// indexed (e.g. a stale id from a cached selection) — a typed miss,
    /// not a panic.
    pub fn record(&self, id: usize) -> Option<&ClusterRecord> {
        self.records.get(id)
    }

    /// All records (hot and cold), in global id order.
    pub fn records(&self) -> &[ClusterRecord] {
        &self.records
    }

    /// Copy of the stored (post-normalization) vector by global id: read
    /// from the hot index in place, or paged in from the record's cold
    /// segment.  Unknown ids and cold-tier IO failures are typed errors.
    pub fn vector(&self, id: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            id < self.records.len(),
            "vector {id} is not indexed in shard {:?} ({} records)",
            self.stream,
            self.records.len()
        );
        if id >= self.hot_base {
            Ok(self.index.vector(id - self.hot_base).to_vec())
        } else {
            self.cold.vector(id)
        }
    }

    /// Number of indexed vectors (== clusters), across both tiers.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total frames archived in the raw layer.
    pub fn frames_ingested(&self) -> u64 {
        self.frames_ingested
    }

    /// Fetch a raw frame by stream-local id.  A missing frame (hole in
    /// the archive) is an error, not a panic — the query path propagates
    /// it instead of taking down a serving worker.
    pub fn fetch_frame(&self, id: u64) -> Result<crate::video::frame::Frame> {
        self.raw.get(id).ok_or_else(|| {
            anyhow::anyhow!(
                "frame {id} missing from stream {:?} raw archive ({} archived)",
                self.stream,
                self.frames_ingested
            )
        })
    }

    /// Compression ratio: raw frames per indexed vector.
    pub fn sparsity(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.frames_ingested as f64 / self.records.len() as f64
    }

    /// Resident bytes of the raw layer (memory-growth bench).
    pub fn raw_resident_bytes(&self) -> usize {
        self.raw.resident_bytes()
    }

    /// Per-tier residency and traffic gauges.
    pub fn tier_stats(&self) -> TierStats {
        let (cold_resident, hits, misses) = self.cold.cache_stats();
        let (probed, candidates, rows) = self.cold.scan_stats();
        TierStats {
            hot_bytes: self.hot_bytes(),
            hot_records: self.records.len() - self.hot_base,
            cold_records: self.hot_base,
            cold_segments: self.cold.segment_count(),
            cold_resident_bytes: cold_resident,
            raw_resident_bytes: self.raw.resident_bytes(),
            evictions: self.evictions,
            cold_hits: hits,
            cold_misses: misses,
            cold_probe_segments: probed,
            cold_probe_candidates: candidates,
            cold_rows_scored: rows,
            cold_quantized: self.cold.quantized(),
        }
    }

    /// Invariant check (property tests): tier split is consistent, every
    /// record's members are sorted, contain the centroid, refer to
    /// archived frames, and belong to this shard's stream.
    pub fn check_invariants(&self) -> Result<()> {
        anyhow::ensure!(
            self.records.len() == self.hot_base + self.index.len(),
            "record/index drift: {} records != {} cold + {} hot",
            self.records.len(),
            self.hot_base,
            self.index.len()
        );
        anyhow::ensure!(
            self.cold.record_count() == self.hot_base,
            "cold tier covers {} records but hot_base is {}",
            self.cold.record_count(),
            self.hot_base
        );
        if let Some(st) = self.storage.as_ref() {
            anyhow::ensure!(
                st.sealed_records() >= self.hot_base,
                "demoted past the sealed watermark ({} < {})",
                st.sealed_records(),
                self.hot_base
            );
            anyhow::ensure!(
                st.sealed_records() + st.unsealed_records() == self.records.len(),
                "storage covers {}+{} records, shard has {}",
                st.sealed_records(),
                st.unsealed_records(),
                self.records.len()
            );
        }
        for (i, r) in self.records.iter().enumerate() {
            anyhow::ensure!(
                r.stream == self.stream,
                "record {i} cites stream {:?} inside shard {:?}",
                r.stream,
                self.stream
            );
            anyhow::ensure!(!r.members.is_empty(), "record {i} empty");
            anyhow::ensure!(
                r.members.windows(2).all(|w| w[0] < w[1]),
                "record {i} members unsorted"
            );
            anyhow::ensure!(
                r.members.binary_search(&r.centroid_frame).is_ok(),
                "record {i} centroid not a member"
            );
            anyhow::ensure!(
                r.members.last().is_some_and(|m| *m < self.frames_ingested),
                "record {i} references unarchived frame"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemoryConfig;
    use crate::memory::raw::InMemoryRaw;
    use crate::memory::storage::tests::TempDir;
    use crate::util::rng::Pcg64;
    use crate::video::frame::Frame;

    fn hierarchy() -> Hierarchy {
        Hierarchy::new(
            &MemoryConfig::default(),
            8,
            Box::new(InMemoryRaw::new(16)),
        )
        .unwrap()
    }

    fn unit(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        crate::util::l2_normalize(&mut v);
        v
    }

    #[test]
    fn insert_and_link() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(1);
        for i in 0..20u64 {
            h.archive_frame(i, &Frame::filled(16, [0.5; 3])).unwrap();
        }
        let v = unit(&mut rng, 8);
        let id = h
            .insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: 0,
                    centroid_frame: 3,
                    members: vec![3, 4, 5],
                },
            )
            .unwrap();
        assert_eq!(id, 0);
        assert_eq!(h.record(0).unwrap().members, vec![3, 4, 5]);
        assert_eq!(h.len(), 1);
        h.check_invariants().unwrap();
    }

    #[test]
    fn rejects_foreign_stream_record() {
        let mut h = hierarchy(); // stream 0
        let mut rng = Pcg64::seeded(9);
        h.archive_frame(0, &Frame::filled(16, [0.5; 3])).unwrap();
        let v = unit(&mut rng, 8);
        let err = h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(3),
                scene_id: 0,
                centroid_frame: 0,
                members: vec![0],
            },
        );
        assert!(err.is_err(), "cross-stream insert must be rejected");
    }

    #[test]
    fn search_returns_inserted() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(2);
        for i in 0..100u64 {
            h.archive_frame(i, &Frame::filled(16, [0.1; 3])).unwrap();
        }
        let mut vs = Vec::new();
        for i in 0..10u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: i as usize,
                    centroid_frame: i * 10,
                    members: (i * 10..(i + 1) * 10).collect(),
                },
            )
            .unwrap();
            vs.push(v);
        }
        let hits = h.search_topk(&vs[7], 1).unwrap();
        assert_eq!(hits[0].id, 7);
        h.check_invariants().unwrap();
    }

    #[test]
    fn invariants_catch_bad_members() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(3);
        h.archive_frame(0, &Frame::filled(16, [0.0; 3])).unwrap();
        let v = unit(&mut rng, 8);
        // centroid not in members
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: 0,
                centroid_frame: 9,
                members: vec![0],
            },
        )
        .unwrap();
        assert!(h.check_invariants().is_err());
    }

    #[test]
    fn sparsity_reflects_compression() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(4);
        for i in 0..100u64 {
            h.archive_frame(i, &Frame::filled(16, [0.2; 3])).unwrap();
        }
        for c in 0..4u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 25,
                    members: (c * 25..(c + 1) * 25).collect(),
                },
            )
            .unwrap();
        }
        assert!((h.sparsity() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn watermark_counts_inserts_not_archives() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(5);
        assert_eq!(h.watermark(), 0);
        for i in 0..10u64 {
            h.archive_frame(i, &Frame::filled(16, [0.5; 3])).unwrap();
        }
        assert_eq!(h.watermark(), 0, "archiving alone must not advance the watermark");
        for c in 0..3u64 {
            let v = unit(&mut rng, 8);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: c as usize,
                    centroid_frame: c * 3,
                    members: vec![c * 3, c * 3 + 1, c * 3 + 2],
                },
            )
            .unwrap();
        }
        assert_eq!(h.watermark(), 3);
    }

    #[test]
    fn fetch_frame_reports_holes() {
        let mut h = hierarchy();
        h.archive_frame(0, &Frame::filled(16, [0.5; 3])).unwrap();
        assert!(h.fetch_frame(0).is_ok());
        let err = h.fetch_frame(7).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("missing"), "diagnostic missing: {msg}");
    }

    #[test]
    fn typed_accessors_reject_stale_ids() {
        let mut h = hierarchy();
        let mut rng = Pcg64::seeded(6);
        h.archive_frame(0, &Frame::filled(16, [0.5; 3])).unwrap();
        let v = unit(&mut rng, 8);
        h.insert(
            &v,
            ClusterRecord {
                stream: StreamId(0),
                scene_id: 0,
                centroid_frame: 0,
                members: vec![0],
            },
        )
        .unwrap();
        assert!(h.record(0).is_some());
        assert!(h.record(7).is_none(), "stale record id is a typed miss");
        assert!(h.vector(0).is_ok());
        let err = h.vector(7).unwrap_err();
        assert!(format!("{err:#}").contains("not indexed"), "stale vector id is typed");
    }

    #[test]
    fn budget_without_storage_is_rejected() {
        let cfg = MemoryConfig { hot_budget_bytes: 1024, ..Default::default() };
        let err = Hierarchy::new(&cfg, 8, Box::new(InMemoryRaw::new(16)));
        assert!(err.is_err(), "a hot budget needs somewhere to demote into");
    }

    /// Deterministic durable shard filled with `n` single-frame clusters.
    fn fill(h: &mut Hierarchy, n: u64, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        let mut vs = Vec::new();
        for i in 0..n {
            h.archive_frame(i, &Frame::filled(8, [0.5; 3])).unwrap();
            let v = unit(&mut rng, d);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: i as usize,
                    centroid_frame: i,
                    members: vec![i],
                },
            )
            .unwrap();
            vs.push(v);
        }
        vs
    }

    #[test]
    fn eviction_bounds_hot_tier_and_keeps_scores_exact() {
        let tmp = TempDir::new("hier-evict");
        let d = 8usize;
        let cfg = MemoryConfig {
            segment_records: 4,
            cold_cache_segments: 2,
            ..Default::default()
        };
        // unbounded twin: the ground-truth score vector
        let mut free = Hierarchy::durable(&cfg, d, StreamId(0), &tmp.0.join("free"), 8)
            .unwrap();
        let vs = fill(&mut free, 32, d, 42);

        // budget that holds roughly 10 records' vectors+metadata
        let budget = 10 * (d * 4 + std::mem::size_of::<ClusterRecord>() + 8);
        let cfg_b = MemoryConfig { hot_budget_bytes: budget, ..cfg.clone() };
        let mut bounded =
            Hierarchy::durable(&cfg_b, d, StreamId(0), &tmp.0.join("bounded"), 8).unwrap();
        fill(&mut bounded, 32, d, 42);

        assert!(bounded.hot_bytes() <= budget, "hot tier over budget");
        let ts = bounded.tier_stats();
        assert!(ts.cold_segments > 0 && ts.evictions > 0, "eviction never ran: {ts:?}");
        assert_eq!(ts.cold_records + ts.hot_records, 32);
        bounded.check_invariants().unwrap();
        free.check_invariants().unwrap();

        // Eq. 4 scores are bit-identical across the tier split
        let (mut a, mut b) = (Vec::new(), Vec::new());
        free.score_all(&vs[3], &mut a).unwrap();
        bounded.score_all(&vs[3], &mut b).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "score {i} differs across tiers");
        }
        // cold vectors page back bit-exact too
        let v0 = bounded.vector(0).unwrap();
        let f0 = free.vector(0).unwrap();
        assert_eq!(
            v0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            f0.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // tier-aware top-k agrees with the unbounded index
        let top_free = free.search_topk(&vs[5], 3).unwrap();
        let top_bounded = bounded.search_topk(&vs[5], 3).unwrap();
        assert_eq!(
            top_free.iter().map(|h| h.id).collect::<Vec<_>>(),
            top_bounded.iter().map(|h| h.id).collect::<Vec<_>>()
        );
    }

    #[test]
    fn durable_shard_recovers_sealed_plus_flushed() {
        let tmp = TempDir::new("hier-recover");
        let d = 8usize;
        let cfg = MemoryConfig { segment_records: 4, ..Default::default() };
        {
            let mut h = Hierarchy::durable(&cfg, d, StreamId(0), &tmp.0, 8).unwrap();
            fill(&mut h, 10, d, 7); // 2 seals (8 records) + 2 in the WAL
            assert_eq!(h.watermark(), 10);
            // no flush: the 2-record WAL tail is lost on drop
        }
        let h = Hierarchy::durable(&cfg, d, StreamId(0), &tmp.0, 8).unwrap();
        assert_eq!(h.watermark(), 8, "recovery lands on the sealed watermark");
        assert_eq!(h.len(), 8);
        assert_eq!(h.frames_ingested(), 10, "frame log is eager — all frames survive");
        assert_eq!(
            h.tier_stats().cold_records,
            0,
            "unbounded shard promotes every sealed span back to RAM"
        );
        h.check_invariants().unwrap();
        // now extend past the lost tail and flush: everything survives
        let mut h = h;
        let mut rng = Pcg64::seeded(99);
        for i in 8..12u64 {
            h.archive_frame(i.max(h.frames_ingested()), &Frame::filled(8, [0.5; 3])).unwrap();
            let v = unit(&mut rng, d);
            h.insert(
                &v,
                ClusterRecord {
                    stream: StreamId(0),
                    scene_id: i as usize,
                    centroid_frame: i,
                    members: vec![i],
                },
            )
            .unwrap();
        }
        h.flush().unwrap();
        drop(h);
        let h = Hierarchy::durable(&cfg, d, StreamId(0), &tmp.0, 8).unwrap();
        assert_eq!(h.watermark(), 12, "flushed WAL tail survives the restart");
        h.check_invariants().unwrap();
    }
}
