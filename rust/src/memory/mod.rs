//! Hierarchical memory (Fig. 8): raw data layer + semantic index layer,
//! sharded per camera stream by the multi-tenant [`fabric`].
//! The vector database substrate lives in [`vectordb`]; the durable
//! write path (WAL, frame log, manifests) in [`storage`], and the sealed
//! cold tier in [`segment`].

pub mod fabric;
pub mod hierarchy;
pub mod raw;
pub mod segment;
pub mod storage;
pub mod vectordb;

pub use fabric::{FrameId, MemoryFabric, StreamId, StreamScope};
pub use hierarchy::{ClusterRecord, Hierarchy, ShardScorePlan, TierStats};
pub use raw::{InMemoryRaw, RawStore, SynthBackedRaw};
pub use segment::{ColdTier, SegmentMeta, SegmentOptions};
pub use storage::{DiskRaw, StreamStorage};
pub use vectordb::{build_index, FlatIndex, Hit, IvfIndex, Metric, VectorIndex};
