//! Hierarchical memory (Fig. 8): raw data layer + semantic index layer.
//! The vector database substrate lives in [`vectordb`].

pub mod hierarchy;
pub mod raw;
pub mod vectordb;

pub use hierarchy::{ClusterRecord, Hierarchy};
pub use raw::{InMemoryRaw, RawStore, SynthBackedRaw};
pub use vectordb::{build_index, FlatIndex, Hit, IvfIndex, Metric, VectorIndex};
