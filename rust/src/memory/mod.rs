//! Hierarchical memory (Fig. 8): raw data layer + semantic index layer,
//! sharded per camera stream by the multi-tenant [`fabric`].
//! The vector database substrate lives in [`vectordb`].

pub mod fabric;
pub mod hierarchy;
pub mod raw;
pub mod vectordb;

pub use fabric::{FrameId, MemoryFabric, StreamId, StreamScope};
pub use hierarchy::{ClusterRecord, Hierarchy};
pub use raw::{InMemoryRaw, RawStore, SynthBackedRaw};
pub use vectordb::{build_index, FlatIndex, Hit, IvfIndex, Metric, VectorIndex};
