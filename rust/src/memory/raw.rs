//! Raw data layer (Fig. 8, bottom): the persistent archive of original
//! frames, addressed by global frame id.
//!
//! Two backends:
//!  * [`InMemoryRaw`] — frames quantized to u8 RGB (4× smaller than f32);
//!    the default for live serving on bounded streams.
//!  * [`SynthBackedRaw`] — re-renders frames on demand from the seeded
//!    generator; models the paper's NVMe archive for hour-scale streams
//!    where holding every frame in RAM is unrealistic (the deterministic
//!    generator plays the role of the SSD: cheap, byte-exact retrieval).

use std::sync::Arc;

use anyhow::Result;

use crate::video::frame::Frame;
use crate::video::synth::VideoSynth;

/// Frame archive interface.  One store backs one stream's shard; ids are
/// the stream-local dense frame indices.  `Send + Sync` because shards
/// are read concurrently by many query workers.
pub trait RawStore: Send + Sync {
    /// Archive a frame under its stream-local id (ids arrive in order).
    /// Fallible: a disk-backed store's write error (a full edge SSD is
    /// the most likely runtime failure) must surface as a typed error —
    /// a panic here would poison the shard lock and take down every
    /// query worker with it.
    fn put(&mut self, id: u64, frame: &Frame) -> Result<()>;

    /// Fetch a frame by id; `None` when the id was never archived (a hole
    /// in the archive — e.g. a query raced ahead of ingestion, or a
    /// corrupted index cites a missing frame).  Callers propagate this as
    /// an error rather than panicking the worker.
    fn get(&self, id: u64) -> Option<Frame>;

    /// Number of archived frames.
    fn len(&self) -> u64;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Make archived frames durable (fsync for disk-backed stores).
    /// Part of the fabric-wide durability point: records become durable
    /// via the WAL/manifest, so the frames they cite must be too.
    fn sync(&mut self) -> Result<()> {
        Ok(())
    }

    /// Approximate resident bytes (for the memory-growth bench).
    fn resident_bytes(&self) -> usize;
}

/// u8-quantized in-memory archive.
pub struct InMemoryRaw {
    size: usize,
    frames: Vec<Vec<u8>>,
}

impl InMemoryRaw {
    pub fn new(frame_size: usize) -> Self {
        Self { size: frame_size, frames: Vec::new() }
    }
}

impl RawStore for InMemoryRaw {
    fn put(&mut self, id: u64, frame: &Frame) -> Result<()> {
        anyhow::ensure!(
            id == self.frames.len() as u64,
            "InMemoryRaw expects dense sequential ids (got {id}, next is {})",
            self.frames.len()
        );
        anyhow::ensure!(
            frame.size() == self.size,
            "frame size {} != store size {}",
            frame.size(),
            self.size
        );
        let q: Vec<u8> = frame
            .data()
            .iter()
            .map(|&x| (x.clamp(0.0, 1.0) * 255.0).round() as u8)
            .collect();
        self.frames.push(q);
        Ok(())
    }

    fn get(&self, id: u64) -> Option<Frame> {
        let q = self.frames.get(id as usize)?;
        let data: Vec<f32> = q.iter().map(|&b| b as f32 / 255.0).collect();
        Some(Frame::from_data(self.size, data))
    }

    fn len(&self) -> u64 {
        self.frames.len() as u64
    }

    fn resident_bytes(&self) -> usize {
        self.frames.len() * self.size * self.size * 3
    }
}

/// Generator-backed archive (models the NVMe store for long streams).
pub struct SynthBackedRaw {
    synth: Arc<VideoSynth>,
    archived: u64,
}

impl SynthBackedRaw {
    pub fn new(synth: Arc<VideoSynth>) -> Self {
        Self { synth, archived: 0 }
    }
}

impl RawStore for SynthBackedRaw {
    fn put(&mut self, id: u64, _frame: &Frame) -> Result<()> {
        // the "SSD" already persists the stream; just track the watermark
        self.archived = self.archived.max(id + 1);
        Ok(())
    }

    fn get(&self, id: u64) -> Option<Frame> {
        if id >= self.archived {
            return None; // not yet archived: a hole from the reader's view
        }
        Some(self.synth.frame(id))
    }

    fn len(&self) -> u64 {
        self.archived
    }

    fn resident_bytes(&self) -> usize {
        0 // off-RAM by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::video::synth::SynthConfig;

    #[test]
    fn in_memory_roundtrip_quantized() {
        let mut store = InMemoryRaw::new(8);
        let f = Frame::filled(8, [0.25, 0.5, 0.75]);
        store.put(0, &f).unwrap();
        let g = store.get(0).expect("archived frame");
        for (a, b) in f.data().iter().zip(g.data()) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6);
        }
        assert_eq!(store.len(), 1);
        assert_eq!(store.resident_bytes(), 8 * 8 * 3);
        assert!(store.get(1).is_none(), "hole must read as None, not panic");
    }

    #[test]
    fn in_memory_rejects_gaps() {
        // a gap is a typed error now, not a panic that poisons the lock
        let mut store = InMemoryRaw::new(8);
        assert!(store.put(5, &Frame::filled(8, [0.0; 3])).is_err());
    }

    #[test]
    fn synth_backed_returns_exact_frames() {
        let mut rng = Pcg64::seeded(77);
        let codes = (0..4).map(|_| (0..192).map(|_| rng.f32()).collect()).collect();
        let synth = Arc::new(VideoSynth::new(
            SynthConfig { duration_s: 5.0, seed: 2, ..Default::default() },
            codes,
            8,
        ));
        let mut store = SynthBackedRaw::new(synth.clone());
        for i in 0..10 {
            store.put(i, &synth.frame(i)).unwrap();
        }
        assert_eq!(store.get(3), Some(synth.frame(3)));
        assert_eq!(store.resident_bytes(), 0);
    }

    #[test]
    fn synth_backed_guards_unarchived() {
        let mut rng = Pcg64::seeded(78);
        let codes = (0..4).map(|_| (0..192).map(|_| rng.f32()).collect()).collect();
        let synth = Arc::new(VideoSynth::new(SynthConfig::default(), codes, 8));
        let store = SynthBackedRaw::new(synth);
        assert!(store.get(0).is_none(), "unarchived frame is a hole");
    }
}
