//! Immutable sealed segments + the cold tier that scores them.
//!
//! A sealed segment is one contiguous span of a stream's index inserts,
//! frozen into a single file by the WAL compactor:
//!
//! ```text
//! header : magic "VENUSSEG" | version u32 | stream u16 | base u64
//!          | count u32 | d u32 | vec_off u64 | rec_sum u64 | vec_sum u64
//! records: count × (scene u64 | centroid u64 | n u32 | members u64×n)
//! vectors: count × d little-endian f32, row-major, at vec_off
//! ```
//!
//! The two regions carry independent FNV-64 checksums: record metadata is
//! validated once at recovery (it becomes resident), vector blocks are
//! validated on each load (they page in and out of the LRU cache).
//!
//! The stored vector bytes are the index's *post-normalization* rows
//! (read back via `VectorIndex::vector` before sealing), and the cold
//! scan scores them with the same dot product the hot index uses — so a
//! record's Eq. 4 score is bit-identical whether its vector is resident
//! in the hot tier, demoted to a segment, or recovered after restart.

use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::memory::fabric::StreamId;
use crate::memory::hierarchy::ClusterRecord;
use crate::memory::storage::{fnv1a64, put_u16, put_u32, put_u64, ByteReader};
use crate::util::sync::{ranks, OrderedMutex};

const SEG_MAGIC: &[u8; 8] = b"VENUSSEG";
const SEG_VERSION: u32 = 1;
/// magic + version + stream + base + count + d + vec_off + rec_sum + vec_sum
const SEG_HEADER_LEN: usize = 8 + 4 + 2 + 8 + 4 + 4 + 8 + 8 + 8;

/// Metadata of one sealed, immutable segment file.
#[derive(Clone, Debug)]
pub struct SegmentMeta {
    pub path: PathBuf,
    /// file name relative to the stream directory (what MANIFEST lists)
    pub file_name: String,
    /// global record id of the segment's first record
    pub base: usize,
    /// records in the segment
    pub count: usize,
    /// embedding dimension
    pub d: usize,
    vec_off: u64,
    vec_sum: u64,
}

/// Write one sealed segment: records region + vector region, fsync'd.
/// `vectors` is `records.len() * d` floats, row-major, in record order.
pub fn write_segment(
    path: &Path,
    stream: StreamId,
    base: usize,
    records: &[ClusterRecord],
    vectors: &[f32],
    d: usize,
) -> Result<SegmentMeta> {
    anyhow::ensure!(!records.is_empty(), "empty segment");
    anyhow::ensure!(records.len() * d == vectors.len(), "segment vector shape");

    let mut rec_region = Vec::new();
    for r in records {
        put_u64(&mut rec_region, r.scene_id as u64);
        put_u64(&mut rec_region, r.centroid_frame);
        put_u32(&mut rec_region, r.members.len() as u32);
        for &m in &r.members {
            put_u64(&mut rec_region, m);
        }
    }
    let mut vec_region = Vec::with_capacity(vectors.len() * 4);
    for &x in vectors {
        vec_region.extend_from_slice(&x.to_le_bytes());
    }
    let vec_off = (SEG_HEADER_LEN + rec_region.len()) as u64;
    let rec_sum = fnv1a64(&rec_region);
    let vec_sum = fnv1a64(&vec_region);

    let mut header = Vec::with_capacity(SEG_HEADER_LEN);
    header.extend_from_slice(SEG_MAGIC);
    put_u32(&mut header, SEG_VERSION);
    put_u16(&mut header, stream.0);
    put_u64(&mut header, base as u64);
    put_u32(&mut header, records.len() as u32);
    put_u32(&mut header, d as u32);
    put_u64(&mut header, vec_off);
    put_u64(&mut header, rec_sum);
    put_u64(&mut header, vec_sum);
    debug_assert_eq!(header.len(), SEG_HEADER_LEN);

    let mut f = File::create(path)
        .with_context(|| format!("creating segment {}", path.display()))?;
    f.write_all(&header)?;
    f.write_all(&rec_region)?;
    f.write_all(&vec_region)?;
    f.sync_all()?;

    Ok(SegmentMeta {
        path: path.to_path_buf(),
        file_name: path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default(),
        base,
        count: records.len(),
        d,
        vec_off,
        vec_sum,
    })
}

/// Open a sealed segment: validate the header + record-region checksum
/// and return its metadata plus the (resident) record metadata.  Only
/// the header and record region are read — the vector region stays on
/// disk (recovery must not page in the whole cold tier; its checksum is
/// verified lazily on each [`ColdTier`] block load).
pub fn open_segment(
    path: &Path,
    stream: StreamId,
    d: usize,
) -> Result<(SegmentMeta, Vec<ClusterRecord>)> {
    let file = File::open(path)
        .with_context(|| format!("opening segment {}", path.display()))?;
    let file_len = file.metadata()?.len();
    if file_len < SEG_HEADER_LEN as u64 {
        bail!("segment {} shorter than its header", path.display());
    }
    let mut header = vec![0u8; SEG_HEADER_LEN];
    file.read_exact_at(&mut header, 0)
        .with_context(|| format!("reading header of {}", path.display()))?;
    let mut r = ByteReader::new(&header);
    if r.take(8)? != SEG_MAGIC {
        bail!("not a Venus segment");
    }
    if r.u32()? != SEG_VERSION {
        bail!("unsupported segment version");
    }
    let h_stream = r.u16()?;
    let base = r.u64()? as usize;
    let count = r.u32()? as usize;
    let h_d = r.u32()? as usize;
    let vec_off = r.u64()?;
    let rec_sum = r.u64()?;
    let vec_sum = r.u64()?;
    if h_stream != stream.0 || h_d != d {
        bail!("segment is for stream s{h_stream} (d={h_d}), expected {stream} (d={d})");
    }
    if (vec_off as usize) < SEG_HEADER_LEN || vec_off > file_len {
        bail!("segment vector offset out of bounds");
    }
    let mut rec_region = vec![0u8; vec_off as usize - SEG_HEADER_LEN];
    file.read_exact_at(&mut rec_region, SEG_HEADER_LEN as u64)
        .with_context(|| format!("reading record region of {}", path.display()))?;
    let rec_region = &rec_region[..];
    if fnv1a64(rec_region) != rec_sum {
        bail!("segment record region checksum mismatch");
    }
    let mut rr = ByteReader::new(rec_region);
    // cap the reservation by what the (checksummed) region can actually
    // hold — a record is ≥ 20 bytes — so a corrupt, unchecksummed header
    // count yields a typed parse error, not an allocation abort
    let mut records = Vec::with_capacity(count.min(rec_region.len() / 20));
    for _ in 0..count {
        let scene_id = rr.u64()? as usize;
        let centroid_frame = rr.u64()?;
        let n = rr.u32()? as usize;
        let mut members = Vec::with_capacity(n);
        for _ in 0..n {
            members.push(rr.u64()?);
        }
        records.push(ClusterRecord { stream, scene_id, centroid_frame, members });
    }
    if rr.remaining() != 0 {
        bail!("segment record region has trailing bytes");
    }
    if file_len < vec_off + (count * d * 4) as u64 {
        bail!("segment vector region truncated");
    }
    Ok((
        SegmentMeta {
            path: path.to_path_buf(),
            file_name: path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default(),
            base,
            count,
            d,
            vec_off,
            vec_sum,
        },
        records,
    ))
}

/// Load (and checksum-verify) a segment's vector block.  Also used by
/// recovery to promote sealed spans back into the hot index, bit-exact.
pub(crate) fn load_vectors(meta: &SegmentMeta) -> Result<Vec<f32>> {
    let file = File::open(&meta.path)
        .with_context(|| format!("opening segment {}", meta.path.display()))?;
    let mut raw = vec![0u8; meta.count * meta.d * 4];
    file.read_exact_at(&mut raw, meta.vec_off)
        .with_context(|| format!("reading vectors of {}", meta.path.display()))?;
    if fnv1a64(&raw) != meta.vec_sum {
        bail!("segment {} vector checksum mismatch", meta.path.display());
    }
    let mut out = Vec::with_capacity(meta.count * meta.d);
    for chunk in raw.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

/// The cold tier of one memory shard: the demoted prefix of its record
/// space, held as sealed segments whose vector blocks page through a
/// bounded LRU cache.  Scoring walks the segments in base order, so the
/// concatenated cold scores land in global id order — exactly the prefix
/// the hot tier's in-place scores continue.
///
/// Interior mutability: the scan runs under the shard's *read* lock, so
/// the LRU lives behind its own mutex (held across a miss's disk load —
/// concurrent readers of the same shard serialize on cold misses, which
/// keeps duplicate loads out).
pub struct ColdTier {
    segments: Vec<SegmentMeta>,
    records: usize,
    /// MRU-front cache of (segment index, vector block); ranked above the
    /// shard band — the scan acquires it under a shard read guard
    cache: OrderedMutex<Vec<(usize, Arc<Vec<f32>>)>>,
    cache_cap: usize,
    resident_bytes: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ColdTier {
    pub fn new(cache_cap: usize) -> Self {
        Self {
            segments: Vec::new(),
            records: 0,
            cache: OrderedMutex::new(ranks::COLD_BLOCK_CACHE, Vec::new()),
            cache_cap: cache_cap.max(1),
            resident_bytes: AtomicUsize::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Demote the next sealed segment (must extend the tier contiguously).
    pub fn push(&mut self, meta: SegmentMeta) -> Result<()> {
        anyhow::ensure!(
            meta.base == self.records,
            "cold tier gap: segment base {} after {} records",
            meta.base,
            self.records
        );
        self.records += meta.count;
        self.segments.push(meta);
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Demoted segments.
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Demoted records (== the hot tier's base id).
    pub fn record_count(&self) -> usize {
        self.records
    }

    /// Vector block of segment `i`, through the LRU cache.
    fn block(&self, i: usize) -> Result<Arc<Vec<f32>>> {
        let mut cache = self.cache.lock();
        if let Some(pos) = cache.iter().position(|(s, _)| *s == i) {
            let entry = cache.remove(pos);
            let block = Arc::clone(&entry.1);
            cache.insert(0, entry); // MRU to front
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(block);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let block = Arc::new(load_vectors(&self.segments[i])?);
        self.resident_bytes
            .fetch_add(block.len() * 4, Ordering::Relaxed);
        cache.insert(0, (i, Arc::clone(&block)));
        while cache.len() > self.cache_cap {
            let Some((_, evicted)) = cache.pop() else { break };
            self.resident_bytes
                .fetch_sub(evicted.len() * 4, Ordering::Relaxed);
        }
        Ok(block)
    }

    /// Score the query against every cold vector, appending to `out` in
    /// global id order.  `qn` must already be metric-prepared (the
    /// hierarchy L2-normalizes it, matching the hot index's cosine path),
    /// and the row scorer is the same dot product — Eq. 4 values are
    /// bit-identical to scoring the same vector hot.
    pub fn score_into(&self, qn: &[f32], out: &mut Vec<f32>) -> Result<()> {
        for i in 0..self.segments.len() {
            let d = self.segments[i].d;
            let block = self.block(i)?;
            for row in block.chunks_exact(d) {
                out.push(crate::util::dot(qn, row));
            }
        }
        Ok(())
    }

    /// Copy of the stored vector for global id `id` (must be < the cold
    /// record count).
    pub fn vector(&self, id: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(id < self.records, "id {id} is not in the cold tier");
        let i = match self
            .segments
            .binary_search_by(|m| m.base.cmp(&id))
        {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let meta = &self.segments[i];
        let local = id - meta.base;
        let block = self.block(i)?;
        Ok(block[local * meta.d..(local + 1) * meta.d].to_vec())
    }

    /// (resident block bytes, cache hits, cache misses)
    pub fn cache_stats(&self) -> (usize, u64, u64) {
        (
            self.resident_bytes.load(Ordering::Relaxed),
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> crate::memory::storage::tests::TempDir {
        crate::memory::storage::tests::TempDir::new(tag)
    }

    fn seg_records(n: usize, base: usize) -> Vec<ClusterRecord> {
        (0..n)
            .map(|i| ClusterRecord {
                stream: StreamId(0),
                scene_id: base + i,
                centroid_frame: (base + i) as u64,
                members: vec![(base + i) as u64],
            })
            .collect()
    }

    #[test]
    fn segment_round_trips_records_and_vectors() {
        let dir = tmp("seg");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(3, 0);
        let vectors = vec![1.0f32, 0.0, 0.0, 1.0, 0.6, 0.8];
        let meta = write_segment(&path, StreamId(0), 0, &records, &vectors, 2).unwrap();
        assert_eq!(meta.count, 3);
        let (meta2, recs2) = open_segment(&path, StreamId(0), 2).unwrap();
        assert_eq!(meta2.base, 0);
        assert_eq!(recs2.len(), 3);
        assert_eq!(recs2[2].scene_id, 2);
        let loaded = load_vectors(&meta2).unwrap();
        assert_eq!(loaded, vectors);
        // wrong stream / dim are typed errors
        assert!(open_segment(&path, StreamId(1), 2).is_err());
        assert!(open_segment(&path, StreamId(0), 3).is_err());
    }

    #[test]
    fn segment_detects_corruption() {
        let dir = tmp("segcorrupt");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(2, 0);
        write_segment(&path, StreamId(0), 0, &records, &[1.0, 0.0, 0.0, 1.0], 2).unwrap();
        // flip a byte in the vector region (the tail of the file)
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (meta, _) = open_segment(&path, StreamId(0), 2).unwrap();
        assert!(load_vectors(&meta).is_err(), "vector checksum must catch the flip");
    }

    #[test]
    fn cold_tier_scores_in_global_order_with_lru() {
        let dir = tmp("cold");
        let mut tier = ColdTier::new(1); // capacity 1 forces paging
        // two segments: ids 0..2 and 2..4, orthogonal unit vectors
        let v = [[1.0f32, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]];
        for (s, base) in [(0usize, 0usize), (1, 2)] {
            let path = dir.0.join(format!("seg-{s:05}.seg"));
            let records = seg_records(2, base);
            let mut vecs = Vec::new();
            for row in &v[base..base + 2] {
                vecs.extend_from_slice(row);
            }
            let meta = write_segment(&path, StreamId(0), base, &records, &vecs, 2).unwrap();
            tier.push(meta).unwrap();
        }
        assert_eq!(tier.record_count(), 4);
        let mut out = Vec::new();
        tier.score_into(&[1.0, 0.0], &mut out).unwrap();
        assert_eq!(out, vec![1.0, 0.0, -1.0, 0.0]);
        // per-id vector fetch spans the segment boundary
        assert_eq!(tier.vector(3).unwrap(), vec![0.0, -1.0]);
        assert!(tier.vector(4).is_err());
        // capacity-1 cache: the two-segment scan paged blocks in and out
        let (resident, hits, misses) = tier.cache_stats();
        assert!(misses >= 2, "both blocks were loaded at least once");
        assert!(resident <= 2 * 2 * 4, "at most one block resident");
        let _ = hits;
    }

    #[test]
    fn cold_tier_rejects_gaps() {
        let dir = tmp("coldgap");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(2, 5);
        let meta = write_segment(&path, StreamId(0), 5, &records, &[1.0, 0.0, 0.0, 1.0], 2)
            .unwrap();
        let mut tier = ColdTier::new(2);
        assert!(tier.push(meta).is_err(), "segment base 5 cannot start the tier");
    }
}
