//! Immutable sealed segments + the cold tier that scores them.
//!
//! A sealed segment is one contiguous span of a stream's index inserts,
//! frozen into a single file by the WAL compactor.  Two on-disk layouts
//! exist (see `DESIGN.md` §Quantization-and-ANN):
//!
//! ```text
//! v1 (plain):
//! header : magic "VENUSSEG" | version=1 u32 | stream u16 | base u64
//!          | count u32 | d u32 | vec_off u64 | rec_sum u64 | vec_sum u64
//! records: count × (scene u64 | centroid u64 | n u32 | members u64×n)
//! vectors: count × d little-endian f32, row-major, at vec_off
//!
//! v2 (extended — written when SQ8 and/or coarse centroids are enabled):
//! header : v1 fields | flags u32 | cen_k u32 | cen_sum u64
//!          | sq8_off u64 | sq8_sum u64
//! records: as v1
//! cen    : cen_k × d f32 coarse k-means centroids (resident at open)
//! vectors: count × d f32 at vec_off (always present — recovery and
//!          exact mode need the bit-exact rows)
//! sq8    : d f32 mins | d f32 steps | count × d u8 codes, at sq8_off
//!          (flags bit 0; paged through the block cache like vectors)
//! ```
//!
//! Every region carries an independent FNV-64 checksum: record metadata
//! and centroids are validated once at recovery (they become resident);
//! vector and SQ8 blocks are validated on each load (they page in and
//! out of the LRU cache).  A v1 file opens unchanged under the v2
//! reader, and the f32 region is never dropped — SQ8 is a *scan-time*
//! representation, ~4× denser in cache, not a replacement for the
//! stored rows.
//!
//! The stored vector bytes are the index's *post-normalization* rows
//! (read back via `VectorIndex::vector` before sealing), and the exact
//! cold scan scores them with the same batch dot kernel the hot index
//! uses — so a record's Eq. 4 score is bit-identical whether its vector
//! is resident in the hot tier, demoted to a segment, or recovered
//! after restart.  Quantized/coarse scanning is a strictly opt-in
//! approximation (`memory.quantization` / `memory.coarse_nprobe`).

use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::memory::fabric::StreamId;
use crate::memory::hierarchy::ClusterRecord;
use crate::memory::storage::{fnv1a64, put_u16, put_u32, put_u64, ByteReader};
use crate::util::sync::{ranks, OrderedMutex};

const SEG_MAGIC: &[u8; 8] = b"VENUSSEG";
const SEG_VERSION_V1: u32 = 1;
const SEG_VERSION_V2: u32 = 2;
/// magic + version + stream + base + count + d + vec_off + rec_sum + vec_sum
const SEG_HEADER_LEN: usize = 8 + 4 + 2 + 8 + 4 + 4 + 8 + 8 + 8;
/// v2 extension: flags + cen_k + cen_sum + sq8_off + sq8_sum
const SEG_V2_EXT_LEN: usize = 4 + 4 + 8 + 8 + 8;
/// flags bit 0: the segment carries an SQ8 region
const SEG_FLAG_SQ8: u32 = 1;

/// Seal-time options: which optional v2 regions to write.  The default
/// (all off) writes the v1 layout byte-identically to pre-v2 code.
#[derive(Clone, Copy, Debug, Default)]
pub struct SegmentOptions {
    /// Write the SQ8 region (per-dimension min/step + u8 codes).
    pub sq8: bool,
    /// Coarse k-means centroids per segment (0 = none).
    pub centroids: usize,
}

/// Metadata of one sealed, immutable segment file.
#[derive(Clone, Debug)]
pub struct SegmentMeta {
    pub path: PathBuf,
    /// file name relative to the stream directory (what MANIFEST lists)
    pub file_name: String,
    /// global record id of the segment's first record
    pub base: usize,
    /// records in the segment
    pub count: usize,
    /// embedding dimension
    pub d: usize,
    vec_off: u64,
    vec_sum: u64,
    /// resident coarse centroids (k × d row-major; empty when the
    /// segment was sealed without a coarse index)
    pub centroids: Arc<Vec<f32>>,
    /// SQ8 region (offset, checksum) when the segment carries one
    sq8: Option<(u64, u64)>,
}

impl SegmentMeta {
    /// Whether the segment carries an SQ8 scan representation.
    pub fn has_sq8(&self) -> bool {
        self.sq8.is_some()
    }

    /// Coarse centroids recorded for this segment (0 = always scanned).
    pub fn centroid_count(&self) -> usize {
        if self.d == 0 {
            0
        } else {
            self.centroids.len() / self.d
        }
    }
}

/// Deterministic mini k-means over one segment's rows (spherical: the
/// hierarchy stores the cosine index's post-normalization unit rows).
/// Strided init — every n/k-th row — exploits the stream's temporal
/// locality (consecutive rows come from the same scenes) and keeps
/// sealing reproducible without an RNG.  An emptied cell keeps its
/// previous centroid; 4 Lloyd iterations suffice for a coarse router.
pub(crate) fn train_centroids(vectors: &[f32], d: usize, k: usize) -> Vec<f32> {
    if d == 0 {
        return Vec::new();
    }
    let n = vectors.len() / d;
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut cents = Vec::with_capacity(k * d);
    for c in 0..k {
        let r = c * n / k;
        cents.extend_from_slice(&vectors[r * d..(r + 1) * d]);
    }
    let mut scores = Vec::with_capacity(k);
    for _ in 0..4 {
        let mut sums = vec![0.0f32; k * d];
        let mut counts = vec![0usize; k];
        for row in vectors.chunks_exact(d) {
            scores.clear();
            crate::util::simd::dot_batch(row, &cents, d, &mut scores);
            let mut best = 0usize;
            let mut best_s = f32::NEG_INFINITY;
            for (c, &s) in scores.iter().enumerate() {
                if s > best_s {
                    best_s = s;
                    best = c;
                }
            }
            counts[best] += 1;
            for (a, x) in sums[best * d..(best + 1) * d].iter_mut().zip(row) {
                *a += x;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                continue;
            }
            let cen = &mut sums[c * d..(c + 1) * d];
            let inv = 1.0 / counts[c] as f32;
            for x in cen.iter_mut() {
                *x *= inv;
            }
            crate::util::l2_normalize(cen);
            cents[c * d..(c + 1) * d].copy_from_slice(cen);
        }
    }
    cents
}

/// Per-dimension affine SQ8 quantization of a row-major block:
/// `code = round((x - min) / step)` with `step = (max - min) / 255`.
/// Returns `(mins, steps, codes)`.
fn sq8_encode(vectors: &[f32], d: usize) -> (Vec<f32>, Vec<f32>, Vec<u8>) {
    let mut mins = vec![f32::INFINITY; d];
    let mut maxs = vec![f32::NEG_INFINITY; d];
    for row in vectors.chunks_exact(d) {
        for j in 0..d {
            mins[j] = mins[j].min(row[j]);
            maxs[j] = maxs[j].max(row[j]);
        }
    }
    let steps: Vec<f32> = mins
        .iter()
        .zip(&maxs)
        .map(|(lo, hi)| (hi - lo) / 255.0)
        .collect();
    let mut codes = Vec::with_capacity(vectors.len());
    for row in vectors.chunks_exact(d) {
        for j in 0..d {
            let c = if steps[j] > 0.0 {
                ((row[j] - mins[j]) / steps[j]).round().clamp(0.0, 255.0)
            } else {
                0.0
            };
            codes.push(c as u8);
        }
    }
    (mins, steps, codes)
}

/// Write one sealed segment: records region + optional centroid/SQ8
/// regions + vector region, fsync'd.  `vectors` is `records.len() * d`
/// floats, row-major, in record order.  Default options write the v1
/// layout byte-for-byte; SQ8/centroids select the versioned v2 layout.
pub fn write_segment(
    path: &Path,
    stream: StreamId,
    base: usize,
    records: &[ClusterRecord],
    vectors: &[f32],
    d: usize,
    opts: SegmentOptions,
) -> Result<SegmentMeta> {
    anyhow::ensure!(!records.is_empty(), "empty segment");
    anyhow::ensure!(records.len() * d == vectors.len(), "segment vector shape");

    let mut rec_region = Vec::new();
    for r in records {
        put_u64(&mut rec_region, r.scene_id as u64);
        put_u64(&mut rec_region, r.centroid_frame);
        put_u32(&mut rec_region, r.members.len() as u32);
        for &m in &r.members {
            put_u64(&mut rec_region, m);
        }
    }
    let mut vec_region = Vec::with_capacity(vectors.len() * 4);
    for &x in vectors {
        vec_region.extend_from_slice(&x.to_le_bytes());
    }
    let rec_sum = fnv1a64(&rec_region);
    let vec_sum = fnv1a64(&vec_region);

    let centroids = if opts.centroids > 0 {
        train_centroids(vectors, d, opts.centroids)
    } else {
        Vec::new()
    };
    let v2 = opts.sq8 || !centroids.is_empty();
    let header_len = if v2 {
        SEG_HEADER_LEN + SEG_V2_EXT_LEN
    } else {
        SEG_HEADER_LEN
    };
    let mut cen_region = Vec::with_capacity(centroids.len() * 4);
    for &x in &centroids {
        cen_region.extend_from_slice(&x.to_le_bytes());
    }
    let vec_off = (header_len + rec_region.len() + cen_region.len()) as u64;

    let mut sq8_region = Vec::new();
    let mut sq8 = None;
    if opts.sq8 {
        let (mins, steps, codes) = sq8_encode(vectors, d);
        sq8_region.reserve(d * 8 + codes.len());
        for &x in mins.iter().chain(&steps) {
            sq8_region.extend_from_slice(&x.to_le_bytes());
        }
        sq8_region.extend_from_slice(&codes);
        let sq8_off = vec_off + vec_region.len() as u64;
        sq8 = Some((sq8_off, fnv1a64(&sq8_region)));
    }

    let mut header = Vec::with_capacity(header_len);
    header.extend_from_slice(SEG_MAGIC);
    put_u32(&mut header, if v2 { SEG_VERSION_V2 } else { SEG_VERSION_V1 });
    put_u16(&mut header, stream.0);
    put_u64(&mut header, base as u64);
    put_u32(&mut header, records.len() as u32);
    put_u32(&mut header, d as u32);
    put_u64(&mut header, vec_off);
    put_u64(&mut header, rec_sum);
    put_u64(&mut header, vec_sum);
    if v2 {
        let flags = if opts.sq8 { SEG_FLAG_SQ8 } else { 0 };
        put_u32(&mut header, flags);
        put_u32(&mut header, (centroids.len() / d.max(1)) as u32);
        put_u64(&mut header, fnv1a64(&cen_region));
        let (sq8_off, sq8_sum) = sq8.unwrap_or((0, 0));
        put_u64(&mut header, sq8_off);
        put_u64(&mut header, sq8_sum);
    }
    debug_assert_eq!(header.len(), header_len);

    let mut f = File::create(path)
        .with_context(|| format!("creating segment {}", path.display()))?;
    f.write_all(&header)?;
    f.write_all(&rec_region)?;
    f.write_all(&cen_region)?;
    f.write_all(&vec_region)?;
    f.write_all(&sq8_region)?;
    f.sync_all()?;

    Ok(SegmentMeta {
        path: path.to_path_buf(),
        file_name: path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default(),
        base,
        count: records.len(),
        d,
        vec_off,
        vec_sum,
        centroids: Arc::new(centroids),
        sq8,
    })
}

/// Open a sealed segment (v1 or v2): validate the header, record-region
/// and centroid checksums, and return its metadata plus the (resident)
/// record metadata.  Only the header, records, and centroids are read —
/// the vector and SQ8 regions stay on disk (recovery must not page in
/// the whole cold tier; their checksums are verified lazily on each
/// [`ColdTier`] block load).
pub fn open_segment(
    path: &Path,
    stream: StreamId,
    d: usize,
) -> Result<(SegmentMeta, Vec<ClusterRecord>)> {
    let file = File::open(path)
        .with_context(|| format!("opening segment {}", path.display()))?;
    let file_len = file.metadata()?.len();
    if file_len < SEG_HEADER_LEN as u64 {
        bail!("segment {} shorter than its header", path.display());
    }
    let mut header = vec![0u8; SEG_HEADER_LEN];
    file.read_exact_at(&mut header, 0)
        .with_context(|| format!("reading header of {}", path.display()))?;
    let mut r = ByteReader::new(&header);
    if r.take(8)? != SEG_MAGIC {
        bail!("not a Venus segment");
    }
    let version = r.u32()?;
    if version != SEG_VERSION_V1 && version != SEG_VERSION_V2 {
        bail!("unsupported segment version {version}");
    }
    let h_stream = r.u16()?;
    let base = r.u64()? as usize;
    let count = r.u32()? as usize;
    let h_d = r.u32()? as usize;
    let vec_off = r.u64()?;
    let rec_sum = r.u64()?;
    let vec_sum = r.u64()?;
    if h_stream != stream.0 || h_d != d {
        bail!("segment is for stream s{h_stream} (d={h_d}), expected {stream} (d={d})");
    }
    let mut header_len = SEG_HEADER_LEN;
    let mut cen_k = 0usize;
    let mut cen_sum = 0u64;
    let mut sq8 = None;
    if version == SEG_VERSION_V2 {
        header_len += SEG_V2_EXT_LEN;
        if file_len < header_len as u64 {
            bail!("segment {} shorter than its v2 header", path.display());
        }
        let mut ext = vec![0u8; SEG_V2_EXT_LEN];
        file.read_exact_at(&mut ext, SEG_HEADER_LEN as u64)
            .with_context(|| format!("reading v2 header of {}", path.display()))?;
        let mut er = ByteReader::new(&ext);
        let flags = er.u32()?;
        cen_k = er.u32()? as usize;
        cen_sum = er.u64()?;
        let sq8_off = er.u64()?;
        let sq8_sum = er.u64()?;
        if flags & SEG_FLAG_SQ8 != 0 {
            // bounds-check the SQ8 region up front: a truncated file is
            // a typed open error, never a wrong score later
            let sq8_len = (d * 8 + count * d) as u64;
            if sq8_off < vec_off || sq8_off + sq8_len > file_len {
                bail!("segment {} SQ8 region out of bounds", path.display());
            }
            sq8 = Some((sq8_off, sq8_sum));
        }
    }
    let cen_bytes = cen_k * d * 4;
    if (vec_off as usize) < header_len + cen_bytes || vec_off > file_len {
        bail!("segment vector offset out of bounds");
    }
    let rec_len = vec_off as usize - header_len - cen_bytes;
    let mut rec_region = vec![0u8; rec_len];
    file.read_exact_at(&mut rec_region, header_len as u64)
        .with_context(|| format!("reading record region of {}", path.display()))?;
    let rec_region = &rec_region[..];
    if fnv1a64(rec_region) != rec_sum {
        bail!("segment record region checksum mismatch");
    }
    let mut rr = ByteReader::new(rec_region);
    // cap the reservation by what the (checksummed) region can actually
    // hold — a record is ≥ 20 bytes — so a corrupt, unchecksummed header
    // count yields a typed parse error, not an allocation abort
    let mut records = Vec::with_capacity(count.min(rec_region.len() / 20));
    for _ in 0..count {
        let scene_id = rr.u64()? as usize;
        let centroid_frame = rr.u64()?;
        let n = rr.u32()? as usize;
        let mut members = Vec::with_capacity(n);
        for _ in 0..n {
            members.push(rr.u64()?);
        }
        records.push(ClusterRecord { stream, scene_id, centroid_frame, members });
    }
    if rr.remaining() != 0 {
        bail!("segment record region has trailing bytes");
    }
    // centroids are resident: read + verify them now
    let mut centroids = Vec::with_capacity(cen_k * d);
    if cen_k > 0 {
        let mut cen_region = vec![0u8; cen_bytes];
        file.read_exact_at(&mut cen_region, (header_len + rec_len) as u64)
            .with_context(|| format!("reading centroids of {}", path.display()))?;
        if fnv1a64(&cen_region) != cen_sum {
            bail!("segment centroid region checksum mismatch");
        }
        for chunk in cen_region.chunks_exact(4) {
            centroids.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
    }
    if file_len < vec_off + (count * d * 4) as u64 {
        bail!("segment vector region truncated");
    }
    Ok((
        SegmentMeta {
            path: path.to_path_buf(),
            file_name: path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default(),
            base,
            count,
            d,
            vec_off,
            vec_sum,
            centroids: Arc::new(centroids),
            sq8,
        },
        records,
    ))
}

/// Load (and checksum-verify) a segment's vector block.  Also used by
/// recovery to promote sealed spans back into the hot index, bit-exact.
pub(crate) fn load_vectors(meta: &SegmentMeta) -> Result<Vec<f32>> {
    let file = File::open(&meta.path)
        .with_context(|| format!("opening segment {}", meta.path.display()))?;
    let mut raw = vec![0u8; meta.count * meta.d * 4];
    file.read_exact_at(&mut raw, meta.vec_off)
        .with_context(|| format!("reading vectors of {}", meta.path.display()))?;
    if fnv1a64(&raw) != meta.vec_sum {
        bail!("segment {} vector checksum mismatch", meta.path.display());
    }
    let mut out = Vec::with_capacity(meta.count * meta.d);
    for chunk in raw.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

/// One segment's SQ8 scan block: the per-dimension affine map + codes.
pub(crate) struct Sq8Block {
    pub(crate) mins: Vec<f32>,
    pub(crate) steps: Vec<f32>,
    pub(crate) codes: Vec<u8>,
}

impl Sq8Block {
    fn bytes(&self) -> usize {
        (self.mins.len() + self.steps.len()) * 4 + self.codes.len()
    }
}

/// Load (and checksum-verify) a segment's SQ8 region.  A segment without
/// one, a truncated read, or a checksum mismatch are all typed errors —
/// quantized scanning never produces a silently-wrong score.
pub(crate) fn load_sq8(meta: &SegmentMeta) -> Result<Sq8Block> {
    let Some((off, sum)) = meta.sq8 else {
        bail!("segment {} has no SQ8 region", meta.path.display());
    };
    let file = File::open(&meta.path)
        .with_context(|| format!("opening segment {}", meta.path.display()))?;
    let mut raw = vec![0u8; meta.d * 8 + meta.count * meta.d];
    file.read_exact_at(&mut raw, off)
        .with_context(|| format!("reading SQ8 region of {}", meta.path.display()))?;
    if fnv1a64(&raw) != sum {
        bail!("segment {} SQ8 checksum mismatch", meta.path.display());
    }
    let mut floats = Vec::with_capacity(meta.d * 2);
    for chunk in raw[..meta.d * 8].chunks_exact(4) {
        floats.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    let steps = floats.split_off(meta.d);
    Ok(Sq8Block { mins: floats, steps, codes: raw[meta.d * 8..].to_vec() })
}

/// Which representation of a segment a cache entry holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockKind {
    F32,
    Sq8,
}

/// A cached block: full-precision rows or the SQ8 scan representation.
#[derive(Clone)]
enum BlockData {
    F32(Arc<Vec<f32>>),
    Sq8(Arc<Sq8Block>),
}

impl BlockData {
    fn bytes(&self) -> usize {
        match self {
            BlockData::F32(b) => b.len() * 4,
            BlockData::Sq8(b) => b.bytes(),
        }
    }
}

/// The cold tier of one memory shard: the demoted prefix of its record
/// space, held as sealed segments whose vector (or SQ8) blocks page
/// through a bounded LRU cache.  Scoring walks the segments in base
/// order, so the concatenated cold scores land in global id order —
/// exactly the prefix the hot tier's in-place scores continue.
///
/// Two opt-in approximations (`DESIGN.md` §Quantization-and-ANN):
/// `quantized` scans SQ8 codes instead of f32 rows (~4× more vectors
/// resident per cache slot), and `nprobe > 0` routes each query through
/// the segments' coarse centroids, fully scanning only the best
/// `nprobe` segments and filling the rest with `NEG_INFINITY` (softmax
/// mass 0, never selected).  Both off ⇒ the scan is bit-identical to
/// the exact legacy path.
///
/// Interior mutability: the scan runs under the shard's *read* lock, so
/// the LRU lives behind its own mutex (held across a miss's disk load —
/// concurrent readers of the same shard serialize on cold misses, which
/// keeps duplicate loads out).
pub struct ColdTier {
    segments: Vec<SegmentMeta>,
    records: usize,
    /// MRU-front cache of (segment index, kind, block); ranked above the
    /// shard band — the scan acquires it under a shard read guard
    cache: OrderedMutex<Vec<(usize, BlockKind, BlockData)>>,
    cache_cap: usize,
    /// scan SQ8 codes where available (falls back to f32 for v1 segments)
    quantized: bool,
    /// coarse-probe budget: fully scan only the top-`nprobe` segments by
    /// centroid score (0 = scan all; centroid-less segments always scan)
    nprobe: usize,
    resident_bytes: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
    /// cumulative scan gauges (segments actually scanned / segments
    /// considered / rows scored) — the cold-scan observability feed
    probed_segments: AtomicU64,
    probe_candidates: AtomicU64,
    rows_scored: AtomicU64,
    /// blocks warmed by readahead (neither a hit nor a miss)
    prefetches: AtomicU64,
}

/// One segment's share of a cold scan, as planned by [`ColdTier::plan`]:
/// rows `[offset, offset + count)` of the shard's cold region.  A
/// non-`scanned` span was coarse-pruned and is filled with
/// `NEG_INFINITY` instead of scored.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ColdSpan {
    pub seg: usize,
    pub offset: usize,
    pub count: usize,
    pub scanned: bool,
}

impl ColdTier {
    pub fn new(cache_cap: usize, quantized: bool, nprobe: usize) -> Self {
        Self {
            segments: Vec::new(),
            records: 0,
            cache: OrderedMutex::new(ranks::COLD_BLOCK_CACHE, Vec::new()),
            cache_cap: cache_cap.max(1),
            quantized,
            nprobe,
            resident_bytes: AtomicUsize::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            probed_segments: AtomicU64::new(0),
            probe_candidates: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
            prefetches: AtomicU64::new(0),
        }
    }

    /// Demote the next sealed segment (must extend the tier contiguously).
    pub fn push(&mut self, meta: SegmentMeta) -> Result<()> {
        anyhow::ensure!(
            meta.base == self.records,
            "cold tier gap: segment base {} after {} records",
            meta.base,
            self.records
        );
        self.records += meta.count;
        self.segments.push(meta);
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Demoted segments.
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Demoted records (== the hot tier's base id).
    pub fn record_count(&self) -> usize {
        self.records
    }

    /// Whether scans use the SQ8 representation where available.
    pub fn quantized(&self) -> bool {
        self.quantized
    }

    /// Block of segment `i` in the requested representation, through the
    /// LRU cache.
    fn cached(&self, i: usize, kind: BlockKind) -> Result<BlockData> {
        let mut cache = self.cache.lock();
        if let Some(pos) = cache.iter().position(|(s, k, _)| *s == i && *k == kind) {
            let entry = cache.remove(pos);
            let block = entry.2.clone();
            cache.insert(0, entry); // MRU to front
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(block);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let block = match kind {
            BlockKind::F32 => BlockData::F32(Arc::new(load_vectors(&self.segments[i])?)),
            BlockKind::Sq8 => BlockData::Sq8(Arc::new(load_sq8(&self.segments[i])?)),
        };
        self.resident_bytes.fetch_add(block.bytes(), Ordering::Relaxed);
        cache.insert(0, (i, kind, block.clone()));
        while cache.len() > self.cache_cap {
            let Some((_, _, evicted)) = cache.pop() else { break };
            self.resident_bytes
                .fetch_sub(evicted.bytes(), Ordering::Relaxed);
        }
        Ok(block)
    }

    /// Full-precision vector block of segment `i`, through the LRU cache.
    fn block(&self, i: usize) -> Result<Arc<Vec<f32>>> {
        match self.cached(i, BlockKind::F32)? {
            BlockData::F32(b) => Ok(b),
            BlockData::Sq8(_) => bail!("cold cache returned SQ8 for an f32 request"),
        }
    }

    /// SQ8 block of segment `i`, through the LRU cache.
    fn sq8_block(&self, i: usize) -> Result<Arc<Sq8Block>> {
        match self.cached(i, BlockKind::Sq8)? {
            BlockData::Sq8(b) => Ok(b),
            BlockData::F32(_) => bail!("cold cache returned f32 for an SQ8 request"),
        }
    }

    /// Choose which segments the query fully scans.  `nprobe == 0` (or
    /// ≥ the segment count) scans everything; otherwise segments that
    /// carry centroids are ranked by their best centroid score and only
    /// the top `nprobe` scan — centroid-less (v1) segments always scan.
    fn select_probes(&self, qn: &[f32]) -> Vec<bool> {
        let nseg = self.segments.len();
        if self.nprobe == 0 || self.nprobe >= nseg {
            return vec![true; nseg];
        }
        let mut probe = vec![false; nseg];
        let mut ranked: Vec<(usize, f32)> = Vec::new();
        let mut scratch = Vec::new();
        for (i, m) in self.segments.iter().enumerate() {
            if m.centroids.is_empty() {
                probe[i] = true;
                continue;
            }
            scratch.clear();
            crate::util::simd::dot_batch(qn, &m.centroids, m.d, &mut scratch);
            let best = scratch.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            ranked.push((i, best));
        }
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        for &(i, _) in ranked.iter().take(self.nprobe) {
            probe[i] = true;
        }
        probe
    }

    /// Score the query against the cold tier, appending to `out` in
    /// global id order.  `qn` must already be metric-prepared (the
    /// hierarchy L2-normalizes it, matching the hot index's cosine
    /// path).  With both approximations off, every row is scored with
    /// the same batch dot kernel the hot index uses — Eq. 4 values are
    /// bit-identical to scoring the same vector hot.  In quantized mode
    /// SQ8 segments score via the asymmetric kernel; coarse-pruned
    /// segments contribute `NEG_INFINITY` per row.
    pub fn score_into(&self, qn: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let probe = self.select_probes(qn);
        self.probe_candidates
            .fetch_add(self.segments.len() as u64, Ordering::Relaxed);
        for (i, meta) in self.segments.iter().enumerate() {
            if !probe[i] {
                out.resize(out.len() + meta.count, f32::NEG_INFINITY);
                continue;
            }
            self.probed_segments.fetch_add(1, Ordering::Relaxed);
            self.rows_scored
                .fetch_add(meta.count as u64, Ordering::Relaxed);
            if self.quantized && meta.has_sq8() {
                let blk = self.sq8_block(i)?;
                // fold the affine dequantization into the query once per
                // (query, segment): score = dot(q, min) + Σ (q·step)·code
                let offset = crate::util::dot(qn, &blk.mins);
                let w: Vec<f32> =
                    qn.iter().zip(&blk.steps).map(|(q, s)| q * s).collect();
                crate::util::simd::dot_batch_sq8(&w, &blk.codes, meta.d, offset, out);
            } else {
                let block = self.block(i)?;
                crate::util::simd::dot_batch(qn, &block, meta.d, out);
            }
        }
        Ok(())
    }

    /// Row-disjoint decomposition of one cold scan, for the parallel
    /// scoring pool (DESIGN.md §Parallel-Query): one span per segment,
    /// in base order, carrying the same probe decision — and bumping the
    /// same scan gauges — as a serial [`ColdTier::score_into`] walk of
    /// the same query would.
    pub(crate) fn plan(&self, qn: &[f32]) -> Vec<ColdSpan> {
        let probe = self.select_probes(qn);
        self.probe_candidates
            .fetch_add(self.segments.len() as u64, Ordering::Relaxed);
        let mut spans = Vec::with_capacity(self.segments.len());
        let mut offset = 0usize;
        for (i, meta) in self.segments.iter().enumerate() {
            if probe[i] {
                self.probed_segments.fetch_add(1, Ordering::Relaxed);
                self.rows_scored
                    .fetch_add(meta.count as u64, Ordering::Relaxed);
            }
            spans.push(ColdSpan { seg: i, offset, count: meta.count, scanned: probe[i] });
            offset += meta.count;
        }
        spans
    }

    /// Score one scanned segment into its pre-sliced disjoint region of
    /// the merged buffer (`out.len() == the segment's row count`).  The
    /// per-row math is the same kernel call [`ColdTier::score_into`]
    /// makes, so the filled slice is bit-identical to the serial scan's
    /// corresponding rows.
    pub(crate) fn score_segment_into(&self, qn: &[f32], seg: usize, out: &mut [f32]) -> Result<()> {
        let meta = &self.segments[seg];
        debug_assert_eq!(out.len(), meta.count, "segment slice mis-sized");
        if self.quantized && meta.has_sq8() {
            let blk = self.sq8_block(seg)?;
            let offset = crate::util::dot(qn, &blk.mins);
            let w: Vec<f32> = qn.iter().zip(&blk.steps).map(|(q, s)| q * s).collect();
            crate::util::simd::dot_batch_sq8_into(&w, &blk.codes, meta.d, offset, out);
        } else {
            let block = self.block(seg)?;
            crate::util::simd::dot_batch_into(qn, &block, meta.d, out);
        }
        Ok(())
    }

    /// Readahead: warm segment `seg`'s block (in the representation the
    /// next scan would request) into the LRU cache.  Unlike
    /// [`ColdTier::cached`], the disk load runs **outside** the cache
    /// mutex so a prefetch never stalls a concurrent scoring task; the
    /// price is that a racing demand load may duplicate the I/O, in
    /// which case the later arrival is simply dropped.  Counts neither a
    /// hit nor a miss — the demand path's gauges keep their meaning.
    pub(crate) fn prefetch(&self, seg: usize) -> Result<()> {
        let meta = &self.segments[seg];
        let kind = if self.quantized && meta.has_sq8() { BlockKind::Sq8 } else { BlockKind::F32 };
        {
            let cache = self.cache.lock();
            if cache.iter().any(|(s, k, _)| *s == seg && *k == kind) {
                return Ok(());
            }
        }
        let block = match kind {
            BlockKind::F32 => BlockData::F32(Arc::new(load_vectors(meta)?)),
            BlockKind::Sq8 => BlockData::Sq8(Arc::new(load_sq8(meta)?)),
        };
        let mut cache = self.cache.lock();
        if cache.iter().any(|(s, k, _)| *s == seg && *k == kind) {
            return Ok(()); // a demand load won the race; keep its entry
        }
        self.prefetches.fetch_add(1, Ordering::Relaxed);
        self.resident_bytes.fetch_add(block.bytes(), Ordering::Relaxed);
        cache.insert(0, (seg, kind, block));
        while cache.len() > self.cache_cap {
            let Some((_, _, evicted)) = cache.pop() else { break };
            self.resident_bytes
                .fetch_sub(evicted.bytes(), Ordering::Relaxed);
        }
        Ok(())
    }

    /// Blocks warmed by readahead (`prefetch`) so far.
    pub fn prefetch_count(&self) -> u64 {
        self.prefetches.load(Ordering::Relaxed)
    }

    /// Copy of the stored vector for global id `id` (must be < the cold
    /// record count).  Always reads the full-precision region.
    pub fn vector(&self, id: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(id < self.records, "id {id} is not in the cold tier");
        let i = match self
            .segments
            .binary_search_by(|m| m.base.cmp(&id))
        {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let meta = &self.segments[i];
        let local = id - meta.base;
        let block = self.block(i)?;
        Ok(block[local * meta.d..(local + 1) * meta.d].to_vec())
    }

    /// (resident block bytes, cache hits, cache misses)
    pub fn cache_stats(&self) -> (usize, u64, u64) {
        (
            self.resident_bytes.load(Ordering::Relaxed),
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Cumulative scan gauges: (segments scanned, segments considered,
    /// rows scored) across every cold query so far.
    pub fn scan_stats(&self) -> (u64, u64, u64) {
        (
            self.probed_segments.load(Ordering::Relaxed),
            self.probe_candidates.load(Ordering::Relaxed),
            self.rows_scored.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> crate::memory::storage::tests::TempDir {
        crate::memory::storage::tests::TempDir::new(tag)
    }

    fn seg_records(n: usize, base: usize) -> Vec<ClusterRecord> {
        (0..n)
            .map(|i| ClusterRecord {
                stream: StreamId(0),
                scene_id: base + i,
                centroid_frame: (base + i) as u64,
                members: vec![(base + i) as u64],
            })
            .collect()
    }

    fn unit_rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        let mut out = Vec::with_capacity(n * d);
        for _ in 0..n {
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            crate::util::l2_normalize(&mut v);
            out.extend_from_slice(&v);
        }
        out
    }

    #[test]
    fn segment_round_trips_records_and_vectors() {
        let dir = tmp("seg");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(3, 0);
        let vectors = vec![1.0f32, 0.0, 0.0, 1.0, 0.6, 0.8];
        let meta = write_segment(
            &path,
            StreamId(0),
            0,
            &records,
            &vectors,
            2,
            SegmentOptions::default(),
        )
        .unwrap();
        assert_eq!(meta.count, 3);
        assert!(!meta.has_sq8());
        let (meta2, recs2) = open_segment(&path, StreamId(0), 2).unwrap();
        assert_eq!(meta2.base, 0);
        assert_eq!(recs2.len(), 3);
        assert_eq!(recs2[2].scene_id, 2);
        let loaded = load_vectors(&meta2).unwrap();
        assert_eq!(loaded, vectors);
        // wrong stream / dim are typed errors
        assert!(open_segment(&path, StreamId(1), 2).is_err());
        assert!(open_segment(&path, StreamId(0), 3).is_err());
    }

    #[test]
    fn plain_options_write_the_v1_layout_byte_identically() {
        // the exactness contract's foundation: default options reproduce
        // the pre-v2 writer exactly, so old and new sealed files match
        let dir = tmp("segv1");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(2, 0);
        write_segment(
            &path,
            StreamId(0),
            0,
            &records,
            &[1.0, 0.0, 0.0, 1.0],
            2,
            SegmentOptions::default(),
        )
        .unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // v1 header: version field (offset 8) is 1, no extension
        assert_eq!(&bytes[..8], SEG_MAGIC);
        assert_eq!(u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]), 1);
        let rec_len = 2 * (8 + 8 + 4 + 8);
        assert_eq!(bytes.len(), SEG_HEADER_LEN + rec_len + 4 * 2 * 4);
    }

    #[test]
    fn v2_round_trips_sq8_and_centroids() {
        let dir = tmp("segv2");
        let path = dir.0.join("seg-00000.seg");
        let (n, d) = (32usize, 16usize);
        let records = seg_records(n, 0);
        let vectors = unit_rows(n, d, 11);
        let opts = SegmentOptions { sq8: true, centroids: 4 };
        let meta = write_segment(&path, StreamId(0), 0, &records, &vectors, d, opts).unwrap();
        assert!(meta.has_sq8());
        assert_eq!(meta.centroid_count(), 4);
        let (meta2, recs2) = open_segment(&path, StreamId(0), d).unwrap();
        assert_eq!(recs2.len(), n);
        assert!(meta2.has_sq8());
        assert_eq!(meta2.centroid_count(), 4);
        assert_eq!(meta2.centroids, meta.centroids, "centroids survive reopen");
        // f32 region still bit-exact under v2
        assert_eq!(load_vectors(&meta2).unwrap(), vectors);
        // SQ8 reconstruction stays within half a step per dimension
        let blk = load_sq8(&meta2).unwrap();
        for (r, row) in vectors.chunks_exact(d).enumerate() {
            for j in 0..d {
                let deq = blk.mins[j] + blk.steps[j] * blk.codes[r * d + j] as f32;
                assert!(
                    (deq - row[j]).abs() <= blk.steps[j] / 2.0 + 1e-6,
                    "row {r} dim {j}: dequant {deq} vs {}",
                    row[j]
                );
            }
        }
    }

    #[test]
    fn corrupt_sq8_region_is_a_typed_error() {
        let dir = tmp("segsq8bad");
        let path = dir.0.join("seg-00000.seg");
        let (n, d) = (8usize, 4usize);
        let records = seg_records(n, 0);
        let vectors = unit_rows(n, d, 3);
        let opts = SegmentOptions { sq8: true, centroids: 0 };
        write_segment(&path, StreamId(0), 0, &records, &vectors, d, opts).unwrap();
        // flip the last byte (inside the SQ8 code block at the tail)
        let mut bytes = std::fs::read(&path).unwrap();
        let len = bytes.len();
        bytes[len - 1] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (meta, _) = open_segment(&path, StreamId(0), d).unwrap();
        assert!(load_sq8(&meta).is_err(), "SQ8 checksum must catch the flip");
        // the f32 region is untouched and still loads
        assert!(load_vectors(&meta).is_ok());
        // truncating into the SQ8 region is a typed OPEN error
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len as u64 - 3).unwrap();
        drop(f);
        assert!(open_segment(&path, StreamId(0), d).is_err());
    }

    #[test]
    fn cold_tier_scores_in_global_order_with_lru() {
        let dir = tmp("cold");
        let mut tier = ColdTier::new(1, false, 0); // capacity 1 forces paging
        // two segments: ids 0..2 and 2..4, orthogonal unit vectors
        let v = [[1.0f32, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]];
        for (s, base) in [(0usize, 0usize), (1, 2)] {
            let path = dir.0.join(format!("seg-{s:05}.seg"));
            let records = seg_records(2, base);
            let mut vecs = Vec::new();
            for row in &v[base..base + 2] {
                vecs.extend_from_slice(row);
            }
            let meta = write_segment(
                &path,
                StreamId(0),
                base,
                &records,
                &vecs,
                2,
                SegmentOptions::default(),
            )
            .unwrap();
            tier.push(meta).unwrap();
        }
        assert_eq!(tier.record_count(), 4);
        let mut out = Vec::new();
        tier.score_into(&[1.0, 0.0], &mut out).unwrap();
        assert_eq!(out, vec![1.0, 0.0, -1.0, 0.0]);
        // per-id vector fetch spans the segment boundary
        assert_eq!(tier.vector(3).unwrap(), vec![0.0, -1.0]);
        assert!(tier.vector(4).is_err());
        // capacity-1 cache: the two-segment scan paged blocks in and out
        let (resident, hits, misses) = tier.cache_stats();
        assert!(misses >= 2, "both blocks were loaded at least once");
        assert!(resident <= 2 * 2 * 4, "at most one block resident");
        let _ = hits;
        // scan gauges: one query over 2 segments, all probed
        let (probed, candidates, rows) = tier.scan_stats();
        assert_eq!((probed, candidates, rows), (2, 2, 4));
    }

    #[test]
    fn quantized_scan_tracks_exact_within_bound() {
        let dir = tmp("coldsq8");
        let (n, d) = (24usize, 8usize);
        let vectors = unit_rows(n, d, 21);
        let mk_tier = |quantized: bool, tag: &str| {
            let path = dir.0.join(format!("seg-{tag}.seg"));
            let meta = write_segment(
                &path,
                StreamId(0),
                0,
                &seg_records(n, 0),
                &vectors,
                d,
                SegmentOptions { sq8: true, centroids: 0 },
            )
            .unwrap();
            let mut tier = ColdTier::new(2, quantized, 0);
            tier.push(meta).unwrap();
            tier
        };
        let exact = mk_tier(false, "a");
        let quant = mk_tier(true, "b");
        let mut q: Vec<f32> = vectors[..d].to_vec();
        crate::util::l2_normalize(&mut q);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        exact.score_into(&q, &mut a).unwrap();
        quant.score_into(&q, &mut b).unwrap();
        assert_eq!(a.len(), n);
        assert_eq!(b.len(), n);
        for i in 0..n {
            assert!(
                (a[i] - b[i]).abs() < 0.05,
                "row {i}: exact {} vs sq8 {}",
                a[i],
                b[i]
            );
        }
        // SQ8 resident bytes ≈ codes + 2·d f32 ≪ the f32 block
        let (resident_q, _, _) = quant.cache_stats();
        let (resident_f, _, _) = exact.cache_stats();
        assert!(
            resident_q < resident_f / 2,
            "SQ8 block ({resident_q} B) should be far smaller than f32 ({resident_f} B)"
        );
    }

    #[test]
    fn coarse_probe_skips_far_segments_with_neg_infinity() {
        let dir = tmp("coldprobe");
        let d = 4usize;
        // 3 cluster-coherent segments along distinct axes
        let axes = [[1.0f32, 0.0, 0.0, 0.0], [0.0, 1.0, 0.0, 0.0], [0.0, 0.0, 1.0, 0.0]];
        let mut tier = ColdTier::new(4, false, 1); // probe exactly 1 segment
        for (s, axis) in axes.iter().enumerate() {
            let mut vecs = Vec::new();
            for _ in 0..4 {
                vecs.extend_from_slice(axis);
            }
            let meta = write_segment(
                &dir.0.join(format!("seg-{s:05}.seg")),
                StreamId(0),
                s * 4,
                &seg_records(4, s * 4),
                &vecs,
                d,
                SegmentOptions { sq8: false, centroids: 1 },
            )
            .unwrap();
            assert_eq!(meta.centroid_count(), 1);
            tier.push(meta).unwrap();
        }
        let mut out = Vec::new();
        tier.score_into(&[0.0, 1.0, 0.0, 0.0], &mut out).unwrap();
        assert_eq!(out.len(), 12);
        // segment 1 scanned exactly; 0 and 2 pruned to NEG_INFINITY
        assert!(out[..4].iter().all(|s| *s == f32::NEG_INFINITY));
        assert!(out[4..8].iter().all(|s| (*s - 1.0).abs() < 1e-6));
        assert!(out[8..].iter().all(|s| *s == f32::NEG_INFINITY));
        let (probed, candidates, rows) = tier.scan_stats();
        assert_eq!((probed, candidates, rows), (1, 3, 4));
        // nprobe ≥ segment count degrades to the exact scan
        let mut all = ColdTier::new(4, false, 99);
        for (s, axis) in axes.iter().enumerate() {
            let mut vecs = Vec::new();
            for _ in 0..4 {
                vecs.extend_from_slice(axis);
            }
            let meta = write_segment(
                &dir.0.join(format!("seg2-{s:05}.seg")),
                StreamId(0),
                s * 4,
                &seg_records(4, s * 4),
                &vecs,
                d,
                SegmentOptions { sq8: false, centroids: 1 },
            )
            .unwrap();
            all.push(meta).unwrap();
        }
        let mut full = Vec::new();
        all.score_into(&[0.0, 1.0, 0.0, 0.0], &mut full).unwrap();
        assert!(full.iter().all(|s| s.is_finite()), "nprobe=all scans everything");
    }

    #[test]
    fn cold_tier_rejects_gaps() {
        let dir = tmp("coldgap");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(2, 5);
        let meta = write_segment(
            &path,
            StreamId(0),
            5,
            &records,
            &[1.0, 0.0, 0.0, 1.0],
            2,
            SegmentOptions::default(),
        )
        .unwrap();
        let mut tier = ColdTier::new(2, false, 0);
        assert!(tier.push(meta).is_err(), "segment base 5 cannot start the tier");
    }

    #[test]
    fn segment_detects_corruption() {
        let dir = tmp("segcorrupt");
        let path = dir.0.join("seg-00000.seg");
        let records = seg_records(2, 0);
        write_segment(
            &path,
            StreamId(0),
            0,
            &records,
            &[1.0, 0.0, 0.0, 1.0],
            2,
            SegmentOptions::default(),
        )
        .unwrap();
        // flip a byte in the vector region (the tail of the file)
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (meta, _) = open_segment(&path, StreamId(0), 2).unwrap();
        assert!(load_vectors(&meta).is_err(), "vector checksum must catch the flip");
    }

    #[test]
    fn centroid_training_is_deterministic_and_normalized() {
        let (n, d, k) = (40usize, 8usize, 4usize);
        let vectors = unit_rows(n, d, 77);
        let a = train_centroids(&vectors, d, k);
        let b = train_centroids(&vectors, d, k);
        assert_eq!(a.len(), k * d);
        assert_eq!(a, b, "training must be deterministic");
        for cen in a.chunks_exact(d) {
            let norm: f32 = cen.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "centroid norm {norm}");
        }
        // k capped by the row count
        assert_eq!(train_centroids(&vectors[..2 * d], d, 8).len(), 2 * d);
        assert!(train_centroids(&vectors, d, 0).is_empty());
    }
}
