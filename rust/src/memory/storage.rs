//! Durable storage layer (Fig. 8: the raw layer is the device SSD) —
//! the append-only write path behind a durable memory shard.
//!
//! One stream's on-disk state lives in its own directory:
//!
//! ```text
//! s<K>/
//!   MANIFEST            sealed-segment list (atomic tmp+rename updates)
//!   wal.log             write-ahead log of unsealed index inserts
//!   seg-00000.seg       immutable sealed segments (see `segment`)
//!   frames-00000.dat    raw frame log chunks (u8-quantized RGB)
//! ```
//!
//! Write path: every archived frame is appended to the frame log at a
//! computed offset (fixed frame size ⇒ no offset index); every index
//! insert (record metadata + the index's post-normalization embedding
//! bytes) is appended to the WAL.  Once `memory.segment_records` inserts
//! accumulate, the span is sealed: an immutable segment file is written
//! and fsync'd, the stream MANIFEST is atomically replaced to list it,
//! and the WAL resets.
//!
//! Durability points and crash semantics:
//!  * a sealed segment is durable the moment its MANIFEST entry lands
//!    (rename is atomic: recovery either sees the segment or it doesn't);
//!  * WAL appends buffer in memory until [`StreamStorage::flush`] (or a
//!    seal) — dropping the shard WITHOUT flushing is deliberately
//!    equivalent to a crash, which the recovery tests exploit;
//!  * frame-log writes go straight to the file descriptor (readable
//!    immediately, OS-buffered), so recovered records never cite frames
//!    the log can't serve.
//!
//! Recovery replays the MANIFEST's segments, then the WAL's valid prefix
//! (length + checksum framed entries; a torn tail is truncated, not an
//! error).  See `DESIGN.md` §Storage for the invariants.

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::memory::fabric::StreamId;
use crate::memory::hierarchy::ClusterRecord;
use crate::memory::raw::RawStore;
use crate::memory::segment::{self, SegmentMeta, SegmentOptions};
use crate::util::sync::{ranks, OrderedMutex};
use crate::video::frame::Frame;

// ---------------------------------------------------------------------
// little-endian byte helpers shared by the WAL and segment formats
// ---------------------------------------------------------------------

pub(crate) fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// FNV-1a 64-bit: the torn-write detector for WAL entries and segment
/// regions (we need corruption *detection*, not cryptographic strength).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounds-checked little-endian reader over a byte slice.
pub(crate) struct ByteReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub(crate) fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!("truncated: wanted {n} bytes at {}", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }
}

/// Encode one index insert: record metadata + stored embedding bytes.
/// The stream id is NOT encoded — it is context (directory + headers).
pub(crate) fn encode_insert(buf: &mut Vec<u8>, rec: &ClusterRecord, vector: &[f32]) {
    put_u64(buf, rec.scene_id as u64);
    put_u64(buf, rec.centroid_frame);
    put_u32(buf, rec.members.len() as u32);
    for &m in &rec.members {
        put_u64(buf, m);
    }
    for &x in vector {
        put_f32(buf, x);
    }
}

/// Decode one insert encoded by [`encode_insert`].
pub(crate) fn decode_insert(
    r: &mut ByteReader<'_>,
    d: usize,
    stream: StreamId,
) -> Result<(ClusterRecord, Vec<f32>)> {
    let scene_id = r.u64()? as usize;
    let centroid_frame = r.u64()?;
    let n = r.u32()? as usize;
    if n > 1 << 24 {
        bail!("implausible member count {n}");
    }
    let mut members = Vec::with_capacity(n);
    for _ in 0..n {
        members.push(r.u64()?);
    }
    let mut vector = Vec::with_capacity(d);
    for _ in 0..d {
        vector.push(r.f32()?);
    }
    Ok((ClusterRecord { stream, scene_id, centroid_frame, members }, vector))
}

/// Write `bytes` to `path` atomically: tmp file, fsync, rename, then a
/// best-effort directory fsync so the rename itself is durable.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// write-ahead log
// ---------------------------------------------------------------------

const WAL_MAGIC: &[u8; 8] = b"VENUSWAL";
const WAL_VERSION: u32 = 1;
/// magic + version + stream + d + first_id + header checksum
const WAL_HEADER_LEN: u64 = 8 + 4 + 2 + 4 + 8 + 8;
/// Refuse to decode WAL entries larger than this (corrupt length field).
const WAL_MAX_ENTRY: u32 = 1 << 24;

/// Append-only write-ahead log of unsealed index inserts.
///
/// Appends buffer in memory; [`Wal::flush`] writes + fsyncs them.  Drop
/// loses the unflushed tail by design (crash semantics).  The header
/// carries `first_id` — the global record id of the first entry — so
/// recovery can discard entries that a completed seal already covers
/// (the crash window between MANIFEST rename and WAL reset).
struct Wal {
    file: File,
    d: usize,
    stream: StreamId,
    /// entries already written (and fsync'd) to the file
    flushed: usize,
    /// encoded-but-unflushed entries
    pending: Vec<u8>,
    pending_count: usize,
}

impl Wal {
    /// Header with a trailing FNV64 of the preceding bytes: `first_id`
    /// aligns replayed entries with the sealed watermark, so corrupting
    /// it must be *detected* (and the log discarded), never silently
    /// shift durably-flushed records to the wrong global ids.
    fn header_bytes(stream: StreamId, d: usize, first_id: u64) -> Vec<u8> {
        let mut h = Vec::with_capacity(WAL_HEADER_LEN as usize);
        h.extend_from_slice(WAL_MAGIC);
        put_u32(&mut h, WAL_VERSION);
        put_u16(&mut h, stream.0);
        put_u32(&mut h, d as u32);
        put_u64(&mut h, first_id);
        let sum = fnv1a64(&h);
        put_u64(&mut h, sum);
        h
    }

    /// Open (or create) the log, replaying its valid prefix.  Returns the
    /// log positioned for appends plus the replayed tail `(first_id,
    /// entries)`; a torn/corrupt tail is truncated away, never an error.
    fn open(
        path: PathBuf,
        stream: StreamId,
        d: usize,
    ) -> Result<(Self, u64, Vec<(ClusterRecord, Vec<f32>)>)> {
        let existing = std::fs::read(&path).unwrap_or_default();
        let mut entries = Vec::new();
        let mut first_id = 0u64;
        let mut valid_len = 0u64;
        if existing.len() as u64 >= WAL_HEADER_LEN {
            let mut r = ByteReader::new(&existing);
            let magic = r.take(8)?;
            let version = r.u32()?;
            let h_stream = r.u16()?;
            let h_d = r.u32()? as usize;
            if magic != WAL_MAGIC || version != WAL_VERSION {
                bail!("{}: not a Venus WAL", path.display());
            }
            if h_stream != stream.0 || h_d != d {
                bail!(
                    "{}: WAL for stream s{h_stream} (d={h_d}), expected {stream} (d={d})",
                    path.display()
                );
            }
            first_id = r.u64()?;
            let header_sum = r.u64()?;
            if fnv1a64(&existing[..(WAL_HEADER_LEN - 8) as usize]) != header_sum {
                // a corrupt first_id cannot be aligned with the sealed
                // watermark — replaying would silently shift global ids,
                // so the whole log is discarded (sealed state wins)
                first_id = 0;
                valid_len = 0;
            } else {
                valid_len = WAL_HEADER_LEN;
            }
            // replay: [len u32][fnv64 u64][payload] frames until the
            // first torn or corrupt entry (skipped entirely when the
            // header itself failed its checksum)
            while valid_len > 0 {
                if r.remaining() < 12 {
                    break;
                }
                let len = r.u32()?;
                let sum = r.u64()?;
                if len == 0 || len > WAL_MAX_ENTRY || r.remaining() < len as usize {
                    break;
                }
                let payload = r.take(len as usize)?;
                if fnv1a64(payload) != sum {
                    break;
                }
                let mut pr = ByteReader::new(payload);
                match decode_insert(&mut pr, d, stream) {
                    Ok(entry) if pr.remaining() == 0 => entries.push(entry),
                    _ => break,
                }
                valid_len += 12 + len as u64;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        if valid_len == 0 {
            // fresh (or unreadable-header) log: write a clean header
            file.set_len(0)?;
            file.write_all(&Self::header_bytes(stream, d, first_id))?;
        } else {
            // drop any torn tail so appends extend the valid prefix
            file.set_len(valid_len)?;
        }
        file.seek(SeekFrom::End(0))?;
        let flushed = entries.len();
        Ok((
            Self {
                file,
                d,
                stream,
                flushed,
                pending: Vec::new(),
                pending_count: 0,
            },
            first_id,
            entries,
        ))
    }

    /// Buffer one insert (becomes durable on the next flush or seal).
    fn append(&mut self, rec: &ClusterRecord, vector: &[f32]) {
        debug_assert_eq!(vector.len(), self.d);
        let mut payload = Vec::with_capacity(24 + rec.members.len() * 8 + self.d * 4);
        encode_insert(&mut payload, rec, vector);
        put_u32(&mut self.pending, payload.len() as u32);
        put_u64(&mut self.pending, fnv1a64(&payload));
        self.pending.extend_from_slice(&payload);
        self.pending_count += 1;
    }

    /// Write + fsync every buffered entry (a durability point).  A
    /// failed write rewinds the file to its pre-flush length and keeps
    /// `pending` intact, so a later retry cannot append valid entries
    /// behind torn garbage that recovery would truncate away.
    fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let start = self.file.stream_position()?;
        let wrote = self
            .file
            .write_all(&self.pending)
            .and_then(|()| self.file.sync_all());
        if let Err(e) = wrote {
            let _ = self.file.set_len(start);
            let _ = self.file.seek(SeekFrom::Start(start));
            return Err(e.into());
        }
        self.flushed += self.pending_count;
        self.pending.clear();
        self.pending_count = 0;
        Ok(())
    }

    /// Entries in the current (unsealed) span: flushed + pending.
    fn records(&self) -> usize {
        self.flushed + self.pending_count
    }

    /// Reset after a seal: the new generation starts at `first_id`.
    /// In-memory counters clear FIRST: once the caller's seal committed
    /// (manifest renamed), the span must never be double-counted as
    /// unsealed — even if the file ops below fail, recovery's
    /// `first_id`/checksum machinery bounds whatever state the on-disk
    /// log was left in, while a stale in-memory count would make the
    /// next seal slice past the record vector.
    fn reset(&mut self, first_id: u64) -> Result<()> {
        self.flushed = 0;
        self.pending.clear();
        self.pending_count = 0;
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file
            .write_all(&Self::header_bytes(self.stream, self.d, first_id))?;
        self.file.sync_all()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// per-stream manifest
// ---------------------------------------------------------------------

const STREAM_MANIFEST_HEADER: &str = "venus-stream-manifest v1";

fn render_stream_manifest(stream: StreamId, d: usize, sealed: &[SegmentMeta]) -> String {
    let mut out = String::new();
    out.push_str(STREAM_MANIFEST_HEADER);
    out.push('\n');
    out.push_str(&format!("stream {}\n", stream.0));
    out.push_str(&format!("d_embed {d}\n"));
    out.push_str(&format!("sealed {}\n", sealed.len()));
    for m in sealed {
        // v2 segments with a coarse index list their centroid count as an
        // optional 4th field; plain lines stay byte-identical to v1 (and
        // old parsers ignored trailing tokens, so the field is forward-
        // compatible too)
        if m.centroid_count() > 0 {
            out.push_str(&format!(
                "seg {} {} {} {}\n",
                m.file_name,
                m.base,
                m.count,
                m.centroid_count()
            ));
        } else {
            out.push_str(&format!("seg {} {} {}\n", m.file_name, m.base, m.count));
        }
    }
    out
}

/// Parse a stream manifest into `(file_name, base, count, centroids)`
/// tuples; the centroid count is `None` on legacy 3-field lines.
#[allow(clippy::type_complexity)]
fn parse_stream_manifest(
    text: &str,
    stream: StreamId,
    d: usize,
) -> Result<Vec<(String, usize, usize, Option<usize>)>> {
    let mut lines = text.lines();
    if lines.next() != Some(STREAM_MANIFEST_HEADER) {
        bail!("unrecognized stream manifest header");
    }
    let field = |line: Option<&str>, key: &str| -> Result<u64> {
        let line = line.with_context(|| format!("manifest missing '{key}'"))?;
        let rest = line
            .strip_prefix(key)
            .with_context(|| format!("manifest line '{line}' is not '{key} …'"))?;
        Ok(rest.trim().parse::<u64>()?)
    };
    let m_stream = field(lines.next(), "stream")?;
    let m_d = field(lines.next(), "d_embed")? as usize;
    if m_stream != stream.0 as u64 || m_d != d {
        bail!("manifest is for stream s{m_stream} (d={m_d}), expected {stream} (d={d})");
    }
    let n = field(lines.next(), "sealed")? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let line = lines.next().context("manifest truncated in segment list")?;
        let mut parts = line.split_whitespace();
        if parts.next() != Some("seg") {
            bail!("manifest segment line '{line}' malformed");
        }
        let file = parts.next().context("segment file missing")?.to_string();
        let base: usize = parts.next().context("segment base missing")?.parse()?;
        let count: usize = parts.next().context("segment count missing")?.parse()?;
        let centroids = match parts.next() {
            Some(tok) => Some(tok.parse::<usize>().with_context(|| {
                format!("segment centroid count '{tok}' malformed")
            })?),
            None => None,
        };
        out.push((file, base, count, centroids));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// stream storage: WAL + sealed segments + manifest, per shard
// ---------------------------------------------------------------------

/// What recovery reconstructed from one stream's directory.
pub struct RecoveredStream {
    /// record metadata from sealed segments, in global id order
    /// (vectors stay on disk — the cold tier loads them on demand)
    pub sealed_records: Vec<ClusterRecord>,
    /// WAL tail beyond the sealed watermark: these become the hot tier
    pub wal_tail: Vec<(ClusterRecord, Vec<f32>)>,
}

/// One stream's durable storage: the WAL for the unsealed span, the
/// immutable sealed segments, and the manifest tying them together.
pub struct StreamStorage {
    dir: PathBuf,
    stream: StreamId,
    d: usize,
    wal: Wal,
    sealed: Vec<SegmentMeta>,
    sealed_records: usize,
    /// optional v2 regions written at seal time (SQ8, coarse centroids);
    /// existing segments keep whatever layout they were sealed with
    opts: SegmentOptions,
}

impl StreamStorage {
    /// Open (creating or recovering) one stream's storage directory.
    /// `opts` applies to *future* seals; already-sealed segments open
    /// as whatever version they were written with.
    pub fn open(
        dir: &Path,
        stream: StreamId,
        d: usize,
        opts: SegmentOptions,
    ) -> Result<(Self, RecoveredStream)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating stream dir {}", dir.display()))?;

        // 1. sealed segments, exactly as the manifest lists them
        let mut sealed = Vec::new();
        let mut sealed_meta = Vec::new();
        let manifest_path = dir.join("MANIFEST");
        if let Ok(text) = std::fs::read_to_string(&manifest_path) {
            for (file, base, count, centroids) in parse_stream_manifest(&text, stream, d)? {
                let path = dir.join(&file);
                let (meta, records) = segment::open_segment(&path, stream, d)
                    .with_context(|| format!("opening sealed segment {}", path.display()))?;
                if meta.base != base || meta.count != count {
                    bail!(
                        "segment {} header ({}, {}) disagrees with manifest ({base}, {count})",
                        file,
                        meta.base,
                        meta.count
                    );
                }
                if let Some(k) = centroids {
                    if meta.centroid_count() != k {
                        bail!(
                            "segment {} has {} centroids but manifest lists {k}",
                            file,
                            meta.centroid_count()
                        );
                    }
                }
                if meta.base != sealed_meta.len() {
                    bail!(
                        "segment {} base {} leaves a gap (recovered {} records so far)",
                        file,
                        meta.base,
                        sealed_meta.len()
                    );
                }
                sealed_meta.extend(records);
                sealed.push(meta);
            }
        }
        let sealed_records = sealed_meta.len();

        // 2. WAL tail.  `first_id` lets us drop entries a completed seal
        // already covers (crash between manifest rename and WAL reset).
        let (mut wal, first_id, mut entries) =
            Wal::open(dir.join("wal.log"), stream, d)?;
        let mut wal_tail = Vec::new();
        if (first_id as usize) <= sealed_records {
            let skip = sealed_records - first_id as usize;
            if skip < entries.len() {
                wal_tail = entries.split_off(skip);
            }
        } else {
            // WAL claims to start past the sealed watermark: a gap we
            // cannot bridge — keep the sealed (manifest-durable) state.
            entries.clear();
        }
        // normalize: after recovery the WAL holds exactly the unsealed
        // tail, starting at the sealed watermark — so the next seal's
        // bookkeeping (and the next recovery) sees a consistent log
        if first_id != sealed_records as u64 || wal.records() != wal_tail.len() {
            wal.reset(sealed_records as u64)?;
            for (rec, v) in &wal_tail {
                wal.append(rec, v);
            }
            wal.flush()?;
        }

        let storage = Self {
            dir: dir.to_path_buf(),
            stream,
            d,
            wal,
            sealed,
            sealed_records,
            opts,
        };
        Ok((storage, RecoveredStream { sealed_records: sealed_meta, wal_tail }))
    }

    /// Sealed segments, ascending base order.
    pub fn segments(&self) -> &[SegmentMeta] {
        &self.sealed
    }

    /// Total records covered by sealed segments (the sealed watermark).
    pub fn sealed_records(&self) -> usize {
        self.sealed_records
    }

    /// Records in the unsealed (WAL) span.
    pub fn unsealed_records(&self) -> usize {
        self.wal.records()
    }

    /// Append one insert to the WAL (buffered until flush/seal).
    pub fn append(&mut self, rec: &ClusterRecord, vector: &[f32]) {
        self.wal.append(rec, vector);
    }

    /// Force the buffered WAL tail to disk (a durability point).
    pub fn flush(&mut self) -> Result<()> {
        self.wal.flush()
    }

    /// Seal the whole unsealed span into an immutable segment: write +
    /// fsync the segment file, atomically update the manifest, reset the
    /// WAL.  `records` / `vectors` are the span's canonical in-RAM state
    /// (`vectors` is `records.len() * d` floats, row-major).
    pub fn seal(&mut self, records: &[ClusterRecord], vectors: &[f32]) -> Result<()> {
        anyhow::ensure!(
            records.len() == self.wal.records(),
            "seal of {} records but WAL holds {}",
            records.len(),
            self.wal.records()
        );
        anyhow::ensure!(records.len() * self.d == vectors.len(), "seal vector shape");
        if records.is_empty() {
            return Ok(());
        }
        let file_name = format!("seg-{:05}.seg", self.sealed.len());
        let path = self.dir.join(&file_name);
        let meta = segment::write_segment(
            &path,
            self.stream,
            self.sealed_records,
            records,
            vectors,
            self.d,
            self.opts,
        )?;
        // the manifest rename is the commit point: in-memory state only
        // mutates after every fallible step, so a failed seal leaves the
        // WAL span intact for a later retry (the orphan segment file is
        // inert until a manifest lists it, and the retry overwrites it)
        let mut manifest_sealed = self.sealed.clone();
        manifest_sealed.push(meta);
        atomic_write(
            &self.dir.join("MANIFEST"),
            render_stream_manifest(self.stream, self.d, &manifest_sealed).as_bytes(),
        )?;
        self.sealed = manifest_sealed;
        self.sealed_records += records.len();
        self.wal.reset(self.sealed_records as u64)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// disk-backed raw store (the frame log)
// ---------------------------------------------------------------------

/// Raw frame archive on disk: u8-quantized RGB frames appended to
/// fixed-size chunk files (`frames-%05d.dat`, `memory.segment_frames`
/// frames each).  Fixed frame size makes addressing computed — no offset
/// index, ~zero resident bytes.  Writes go straight to the fd, so a
/// just-archived frame is immediately readable; recovery derives the
/// archived watermark from the chunk file lengths (a torn trailing frame
/// is truncated away).
pub struct DiskRaw {
    dir: PathBuf,
    frame_size: usize,
    frame_bytes: usize,
    per_chunk: usize,
    archived: u64,
    /// open chunk for appends (chunk index, file)
    write: Option<(usize, File)>,
    /// single-slot read handle cache (queries touch one chunk at a time);
    /// ranked above the shard band — fetches run under shard read guards
    read_cache: OrderedMutex<Option<(usize, Arc<File>)>>,
}

impl DiskRaw {
    fn chunk_path(dir: &Path, chunk: usize) -> PathBuf {
        dir.join(format!("frames-{chunk:05}.dat"))
    }

    /// Open (or create) the frame log in `dir`.
    pub fn open(dir: &Path, frame_size: usize, per_chunk: usize) -> Result<Self> {
        anyhow::ensure!(frame_size > 0 && per_chunk > 0, "DiskRaw shape");
        std::fs::create_dir_all(dir)?;
        let frame_bytes = frame_size * frame_size * 3;
        // recover the archived watermark from chunk lengths
        let mut archived = 0u64;
        let mut chunk = 0usize;
        loop {
            let path = Self::chunk_path(dir, chunk);
            let Ok(meta) = std::fs::metadata(&path) else { break };
            let frames = (meta.len() / frame_bytes as u64).min(per_chunk as u64);
            archived += frames;
            if frames < per_chunk as u64 {
                break; // partial chunk: nothing can follow it
            }
            chunk += 1;
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            frame_size,
            frame_bytes,
            per_chunk,
            archived,
            write: None,
            read_cache: OrderedMutex::new(ranks::RAW_READ_CACHE, None),
        })
    }

    fn reader(&self, chunk: usize) -> Option<Arc<File>> {
        let mut slot = self.read_cache.lock();
        if let Some((c, f)) = slot.as_ref() {
            if *c == chunk {
                return Some(Arc::clone(f));
            }
        }
        let f = Arc::new(File::open(Self::chunk_path(&self.dir, chunk)).ok()?);
        *slot = Some((chunk, Arc::clone(&f)));
        Some(f)
    }
}

impl RawStore for DiskRaw {
    fn put(&mut self, id: u64, frame: &Frame) -> Result<()> {
        if id < self.archived {
            return Ok(()); // already durable (recovered stream replaying)
        }
        anyhow::ensure!(
            id == self.archived,
            "DiskRaw expects dense sequential ids (got {id}, next is {})",
            self.archived
        );
        anyhow::ensure!(
            frame.size() == self.frame_size,
            "frame size {} != frame-log size {}",
            frame.size(),
            self.frame_size
        );
        let chunk = (id / self.per_chunk as u64) as usize;
        let off = (id % self.per_chunk as u64) * self.frame_bytes as u64;
        if self.write.as_ref().map(|(c, _)| *c) != Some(chunk) {
            // rotating chunks: fsync the full one before moving on, so a
            // completed chunk is durable without waiting for a flush
            if let Some((_, old)) = self.write.take() {
                old.sync_all().context("fsyncing rotated frame-log chunk")?;
            }
            let path = Self::chunk_path(&self.dir, chunk);
            let file = OpenOptions::new()
                .create(true)
                .write(true)
                .open(&path)
                .with_context(|| format!("opening frame-log chunk {}", path.display()))?;
            self.write = Some((chunk, file));
        }
        let q: Vec<u8> = frame
            .data()
            .iter()
            .map(|&x| (x.clamp(0.0, 1.0) * 255.0).round() as u8)
            .collect();
        let Some((_, file)) = self.write.as_ref() else {
            bail!("frame-log write handle missing after chunk rotation");
        };
        // a failed write (full SSD) is a typed error: the frame is simply
        // not archived, the watermark does not advance, and the shard
        // lock is never poisoned
        file.write_all_at(&q, off)
            .with_context(|| format!("appending frame {id} to the frame log"))?;
        self.archived += 1;
        Ok(())
    }

    fn get(&self, id: u64) -> Option<Frame> {
        if id >= self.archived {
            return None;
        }
        let chunk = (id / self.per_chunk as u64) as usize;
        let off = (id % self.per_chunk as u64) * self.frame_bytes as u64;
        let file = self.reader(chunk)?;
        let mut q = vec![0u8; self.frame_bytes];
        file.read_exact_at(&mut q, off).ok()?;
        let data: Vec<f32> = q.iter().map(|&b| b as f32 / 255.0).collect();
        Some(Frame::from_data(self.frame_size, data))
    }

    fn len(&self) -> u64 {
        self.archived
    }

    fn sync(&mut self) -> Result<()> {
        if let Some((_, file)) = self.write.as_ref() {
            file.sync_all().context("fsyncing frame log")?;
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        0 // frames live on disk; handles + counters only
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Unique per-test scratch dir, removed on drop.
    pub(crate) struct TempDir(pub PathBuf);

    impl TempDir {
        pub(crate) fn new(tag: &str) -> Self {
            let path = std::env::temp_dir().join(format!(
                "venus-{tag}-{}-{:x}",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            ));
            let _ = std::fs::remove_dir_all(&path);
            std::fs::create_dir_all(&path).unwrap();
            Self(path)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn rec(scene: usize, centroid: u64, members: Vec<u64>) -> ClusterRecord {
        ClusterRecord {
            stream: StreamId(0),
            scene_id: scene,
            centroid_frame: centroid,
            members,
        }
    }

    #[test]
    fn insert_encoding_round_trips() {
        let r = rec(7, 42, vec![40, 41, 42, 43]);
        let v = vec![0.25f32, -0.5, 1.0];
        let mut buf = Vec::new();
        encode_insert(&mut buf, &r, &v);
        let mut reader = ByteReader::new(&buf);
        let (r2, v2) = decode_insert(&mut reader, 3, StreamId(0)).unwrap();
        assert_eq!(r2.scene_id, 7);
        assert_eq!(r2.centroid_frame, 42);
        assert_eq!(r2.members, vec![40, 41, 42, 43]);
        assert_eq!(v2, v);
        assert_eq!(reader.remaining(), 0);
    }

    #[test]
    fn wal_flush_then_reopen_replays_flushed_only() {
        let tmp = TempDir::new("wal");
        let path = tmp.0.join("wal.log");
        {
            let (mut wal, _, entries) = Wal::open(path.clone(), StreamId(0), 2).unwrap();
            assert!(entries.is_empty());
            wal.append(&rec(0, 0, vec![0]), &[1.0, 0.0]);
            wal.append(&rec(1, 1, vec![1]), &[0.0, 1.0]);
            wal.flush().unwrap();
            // buffered but never flushed: lost on drop (crash semantics)
            wal.append(&rec(2, 2, vec![2]), &[0.5, 0.5]);
        }
        let (wal, first, entries) = Wal::open(path, StreamId(0), 2).unwrap();
        assert_eq!(first, 0);
        assert_eq!(entries.len(), 2, "only the flushed prefix survives");
        assert_eq!(entries[1].0.scene_id, 1);
        assert_eq!(wal.records(), 2);
    }

    #[test]
    fn wal_truncates_torn_tail() {
        let tmp = TempDir::new("torn");
        let path = tmp.0.join("wal.log");
        {
            let (mut wal, _, _) = Wal::open(path.clone(), StreamId(0), 2).unwrap();
            wal.append(&rec(0, 0, vec![0]), &[1.0, 0.0]);
            wal.append(&rec(1, 1, vec![1]), &[0.0, 1.0]);
            wal.flush().unwrap();
        }
        // tear the last entry: chop 5 bytes off the file
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (_, _, entries) = Wal::open(path, StreamId(0), 2).unwrap();
        assert_eq!(entries.len(), 1, "torn tail truncated, valid prefix kept");
        assert_eq!(entries[0].0.scene_id, 0);
    }

    #[test]
    fn wal_discards_log_on_header_corruption() {
        let tmp = TempDir::new("walhdr");
        let path = tmp.0.join("wal.log");
        {
            let (mut wal, _, _) = Wal::open(path.clone(), StreamId(0), 2).unwrap();
            wal.append(&rec(0, 0, vec![0]), &[1.0, 0.0]);
            wal.flush().unwrap();
        }
        // flip a bit in first_id (offset 18 = magic 8 + version 4 +
        // stream 2 + d 4): entries can no longer be aligned with the
        // sealed watermark, so the log must be discarded — NOT replayed
        // at silently shifted global ids
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[18] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        let (_, first, entries) = Wal::open(path, StreamId(0), 2).unwrap();
        assert_eq!(first, 0, "corrupt header resets the log generation");
        assert!(entries.is_empty(), "unalignable entries are discarded");
    }

    #[test]
    fn wal_rejects_foreign_stream_or_dim() {
        let tmp = TempDir::new("walmix");
        let path = tmp.0.join("wal.log");
        {
            let (mut wal, _, _) = Wal::open(path.clone(), StreamId(0), 2).unwrap();
            wal.append(&rec(0, 0, vec![0]), &[1.0, 0.0]);
            wal.flush().unwrap();
        }
        assert!(Wal::open(path.clone(), StreamId(1), 2).is_err());
        assert!(Wal::open(path, StreamId(0), 3).is_err());
    }

    #[test]
    fn storage_seals_and_recovers_sealed_watermark() {
        let tmp = TempDir::new("storage");
        let d = 2usize;
        {
            let (mut st, recovered) = StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).unwrap();
            assert!(recovered.sealed_records.is_empty());
            let records: Vec<ClusterRecord> =
                (0..4).map(|i| rec(i, i as u64, vec![i as u64])).collect();
            let mut vecs = Vec::new();
            for (rec, v) in records.iter().zip([[1.0f32, 0.0], [0.0, 1.0], [0.6, 0.8], [0.8, 0.6]])
            {
                st.append(rec, &v);
                vecs.extend_from_slice(&v);
            }
            st.seal(&records, &vecs).unwrap();
            assert_eq!(st.sealed_records(), 4);
            assert_eq!(st.unsealed_records(), 0);
            // two more inserts, never flushed: lost on drop
            st.append(&rec(9, 9, vec![9]), &[1.0, 0.0]);
            st.append(&rec(10, 10, vec![10]), &[0.0, 1.0]);
        }
        let (st, recovered) = StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).unwrap();
        assert_eq!(st.sealed_records(), 4);
        assert_eq!(recovered.sealed_records.len(), 4, "recovered to the sealed watermark");
        assert!(recovered.wal_tail.is_empty(), "unflushed WAL tail is gone");
        assert_eq!(recovered.sealed_records[2].scene_id, 2);
    }

    #[test]
    fn storage_seals_v2_and_manifest_lists_centroids() {
        let tmp = TempDir::new("storagev2");
        let d = 2usize;
        let opts = SegmentOptions { sq8: true, centroids: 2 };
        {
            let (mut st, _) = StreamStorage::open(&tmp.0, StreamId(0), d, opts).unwrap();
            let records: Vec<ClusterRecord> =
                (0..4).map(|i| rec(i, i as u64, vec![i as u64])).collect();
            let mut vecs = Vec::new();
            for (rec, v) in records.iter().zip([[1.0f32, 0.0], [0.0, 1.0], [0.6, 0.8], [0.8, 0.6]])
            {
                st.append(rec, &v);
                vecs.extend_from_slice(&v);
            }
            st.seal(&records, &vecs).unwrap();
            assert!(st.segments()[0].has_sq8());
            assert_eq!(st.segments()[0].centroid_count(), 2);
        }
        // the seg line carries the centroid count as a 4th field
        let manifest = std::fs::read_to_string(tmp.0.join("MANIFEST")).unwrap();
        let seg_line = manifest.lines().find(|l| l.starts_with("seg ")).unwrap();
        assert_eq!(seg_line.split_whitespace().count(), 5, "seg line: {seg_line}");
        assert!(seg_line.ends_with(" 2"), "centroid count recorded: {seg_line}");
        // reopening with *default* options still reads the v2 segment —
        // options govern future seals, not existing files
        let (st, recovered) =
            StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).unwrap();
        assert_eq!(recovered.sealed_records.len(), 4);
        assert!(st.segments()[0].has_sq8());
        assert_eq!(st.segments()[0].centroid_count(), 2);
        // a manifest/header centroid-count disagreement is a typed error
        let tampered = manifest.replace(" 2\n", " 3\n");
        atomic_write(&tmp.0.join("MANIFEST"), tampered.as_bytes()).unwrap();
        assert!(StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).is_err());
    }

    #[test]
    fn storage_flushed_wal_tail_survives() {
        let tmp = TempDir::new("waltail");
        let d = 2usize;
        {
            let (mut st, _) = StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).unwrap();
            st.append(&rec(0, 0, vec![0]), &[1.0, 0.0]);
            st.flush().unwrap();
        }
        let (_, recovered) = StreamStorage::open(&tmp.0, StreamId(0), d, SegmentOptions::default()).unwrap();
        assert!(recovered.sealed_records.is_empty());
        assert_eq!(recovered.wal_tail.len(), 1);
        assert_eq!(recovered.wal_tail[0].1, vec![1.0, 0.0]);
    }

    #[test]
    fn disk_raw_round_trips_across_chunks() {
        let tmp = TempDir::new("diskraw");
        let mut raw = DiskRaw::open(&tmp.0, 8, 3).unwrap();
        for i in 0..7u64 {
            let shade = i as f32 / 10.0;
            raw.put(i, &Frame::filled(8, [shade, 0.5, 0.25])).unwrap();
        }
        assert_eq!(raw.len(), 7);
        assert_eq!(raw.resident_bytes(), 0);
        // chunking: 3 frames per chunk ⇒ 3 files
        assert!(DiskRaw::chunk_path(&tmp.0, 2).exists());
        let f = raw.get(5).expect("archived frame");
        assert!((f.data()[0] - 0.5).abs() <= 0.5 / 255.0 + 1e-6);
        assert!(raw.get(7).is_none(), "hole reads as None");
        // reopen: watermark recovered from chunk lengths
        drop(raw);
        let raw = DiskRaw::open(&tmp.0, 8, 3).unwrap();
        assert_eq!(raw.len(), 7);
        assert!(raw.get(6).is_some());
    }

    #[test]
    fn atomic_write_replaces_content() {
        let tmp = TempDir::new("atomic");
        let path = tmp.0.join("MANIFEST");
        atomic_write(&path, b"one").unwrap();
        atomic_write(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        assert!(!path.with_extension("tmp").exists());
    }
}
