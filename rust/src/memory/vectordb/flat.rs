//! Exact brute-force index: contiguous row-major storage, linear scan.
//!
//! This is Venus's default index — the paper's memory holds only sparse
//! *indexed frames* (cluster centroids), so even hour-long streams yield
//! a few thousand vectors and exact scan is both exact and fast (see the
//! `hotpath_micro` bench).

use anyhow::{bail, Result};

use super::{finish_topk, metric_score, push_topk, Hit, Metric, VectorIndex};
use crate::util::l2_normalize;

/// Flat (exact) vector index.
pub struct FlatIndex {
    dim: usize,
    metric: Metric,
    data: Vec<f32>,
}

impl FlatIndex {
    pub fn new(dim: usize, metric: Metric) -> Self {
        assert!(dim > 0);
        Self { dim, metric, data: Vec::new() }
    }

    /// Reserve capacity for `n` additional vectors.
    pub fn reserve(&mut self, n: usize) {
        self.data.reserve(n * self.dim);
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }
}

impl VectorIndex for FlatIndex {
    fn insert(&mut self, v: &[f32]) -> Result<usize> {
        if v.len() != self.dim {
            bail!("insert: dim {} != index dim {}", v.len(), self.dim);
        }
        let id = self.len();
        self.data.extend_from_slice(v);
        if self.metric == Metric::Cosine {
            let start = id * self.dim;
            l2_normalize(&mut self.data[start..start + self.dim]);
        }
        Ok(id)
    }

    fn insert_prepared(&mut self, v: &[f32]) -> Result<usize> {
        if v.len() != self.dim {
            bail!("insert_prepared: dim {} != index dim {}", v.len(), self.dim);
        }
        let id = self.len();
        self.data.extend_from_slice(v);
        Ok(id)
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim);
        let q = normalized_query(query, self.metric);
        let mut buf = Vec::with_capacity(k + 1);
        for (id, row) in self.data.chunks_exact(self.dim).enumerate() {
            push_topk(&mut buf, k, Hit { id, score: metric_score(self.metric, &q, row) });
        }
        finish_topk(buf, k)
    }

    fn score_all(&self, query: &[f32], out: &mut Vec<f32>) {
        assert_eq!(query.len(), self.dim);
        let q = normalized_query(query, self.metric);
        out.clear();
        out.reserve(self.len());
        match self.metric {
            // dot-metric scan via the batch kernel (bit-identical per row)
            Metric::Cosine | Metric::InnerProduct => {
                crate::util::simd::dot_batch(&q, &self.data, self.dim, out);
            }
            Metric::L2 => {
                for row in self.data.chunks_exact(self.dim) {
                    out.push(metric_score(self.metric, &q, row));
                }
            }
        }
    }

    fn score_into(&self, query: &[f32], out: &mut [f32]) {
        assert_eq!(query.len(), self.dim);
        assert_eq!(out.len(), self.len());
        let q = normalized_query(query, self.metric);
        match self.metric {
            Metric::Cosine | Metric::InnerProduct => {
                crate::util::simd::dot_batch_into(&q, &self.data, self.dim, out);
            }
            Metric::L2 => {
                for (o, row) in out.iter_mut().zip(self.data.chunks_exact(self.dim)) {
                    *o = metric_score(self.metric, &q, row);
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn vector(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }
}

pub(super) fn normalized_query(query: &[f32], metric: Metric) -> Vec<f32> {
    let mut q = query.to_vec();
    if metric == Metric::Cosine {
        l2_normalize(&mut q);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_exact_search() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        idx.insert(&[1.0, 0.0]).unwrap();
        idx.insert(&[0.0, 1.0]).unwrap();
        idx.insert(&[1.0, 1.0]).unwrap();
        let hits = idx.search(&[1.0, 0.05], 2);
        assert_eq!(hits[0].id, 0);
        assert_eq!(hits.len(), 2);
        assert!(hits[0].score > hits[1].score);
    }

    #[test]
    fn cosine_normalizes_magnitude_away() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        idx.insert(&[10.0, 0.0]).unwrap();
        idx.insert(&[0.0, 0.1]).unwrap();
        let hits = idx.search(&[0.0, 5.0], 1);
        assert_eq!(hits[0].id, 1);
        assert!((hits[0].score - 1.0).abs() < 1e-5);
    }

    #[test]
    fn inner_product_keeps_magnitude() {
        let mut idx = FlatIndex::new(2, Metric::InnerProduct);
        idx.insert(&[10.0, 0.0]).unwrap();
        idx.insert(&[1.0, 0.0]).unwrap();
        let hits = idx.search(&[1.0, 0.0], 1);
        assert_eq!(hits[0].id, 0);
        assert!((hits[0].score - 10.0).abs() < 1e-5);
    }

    #[test]
    fn l2_metric_prefers_close_over_colinear() {
        // [10, 0] is colinear with the query but far; [1.2, 0] is near.
        // IP would pick the big vector; L2 must pick the near one.
        let mut idx = FlatIndex::new(2, Metric::L2);
        idx.insert(&[10.0, 0.0]).unwrap();
        idx.insert(&[1.2, 0.0]).unwrap();
        let hits = idx.search(&[1.0, 0.0], 2);
        assert_eq!(hits[0].id, 1);
        assert!((hits[0].score - (-0.04)).abs() < 1e-5, "score {}", hits[0].score);
        assert!(hits[0].score > hits[1].score);
    }

    #[test]
    fn l2_self_query_scores_zero() {
        let mut idx = FlatIndex::new(3, Metric::L2);
        idx.insert(&[0.3, -0.7, 2.0]).unwrap();
        idx.insert(&[1.0, 1.0, 1.0]).unwrap();
        let mut out = Vec::new();
        idx.score_all(&[0.3, -0.7, 2.0], &mut out);
        assert!(out[0].abs() < 1e-12);
        assert!(out[1] < out[0]);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut idx = FlatIndex::new(3, Metric::Cosine);
        assert!(idx.insert(&[1.0]).is_err());
        assert!(idx.insert_prepared(&[1.0]).is_err());
    }

    #[test]
    fn insert_prepared_round_trips_stored_bytes() {
        // the durable tier replays vector() bytes through insert_prepared:
        // the stored row must be bit-identical (no re-normalization drift)
        let mut a = FlatIndex::new(3, Metric::Cosine);
        a.insert(&[3.0, 4.0, 0.3]).unwrap();
        let stored = a.vector(0).to_vec();
        let mut b = FlatIndex::new(3, Metric::Cosine);
        b.insert_prepared(&stored).unwrap();
        assert_eq!(
            a.vector(0).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.vector(0).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn k_larger_than_len() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        idx.insert(&[1.0, 0.0]).unwrap();
        let hits = idx.search(&[1.0, 0.0], 10);
        assert_eq!(hits.len(), 1);
    }

    #[test]
    fn score_all_id_order() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        for v in [[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0]] {
            idx.insert(&v).unwrap();
        }
        let mut out = Vec::new();
        idx.score_all(&[1.0, 0.0], &mut out);
        assert_eq!(out.len(), 3);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!(out[1].abs() < 1e-6);
        assert!((out[2] + 1.0).abs() < 1e-6);
    }
}
