//! IVF (inverted-file) index: k-means coarse quantizer + posting lists.
//!
//! Mirrors FAISS `IndexIVFFlat`: vectors are assigned to their nearest
//! centroid cell; a query probes only the `nprobe` nearest cells.  The
//! quantizer trains itself once the buffer reaches a threshold and
//! re-trains when the index has grown 8× since the last training (online
//! streams grow without bound; Venus's ingestion keeps inserting for the
//! lifetime of the camera).

use anyhow::{bail, Result};

use super::flat::normalized_query;
use super::{finish_topk, metric_score, push_topk, Hit, Metric, VectorIndex};
use crate::util::l2_normalize;
use crate::util::rng::Pcg64;

/// Inverted-file vector index.
pub struct IvfIndex {
    dim: usize,
    metric: Metric,
    nlist: usize,
    nprobe: usize,
    /// row-major vector storage in insertion order (exact copies)
    data: Vec<f32>,
    /// trained centroids, row-major (empty until trained)
    centroids: Vec<f32>,
    /// posting lists: cell -> vector ids
    cells: Vec<Vec<usize>>,
    /// ids inserted since training (brute-forced until assigned)
    trained_len: usize,
    min_train: usize,
}

impl IvfIndex {
    /// `nlist = 0` selects `sqrt(n)` automatically at training time.
    pub fn new(dim: usize, metric: Metric, nlist: usize, nprobe: usize) -> Self {
        Self {
            dim,
            metric,
            nlist,
            nprobe: nprobe.max(1),
            data: Vec::new(),
            centroids: Vec::new(),
            cells: Vec::new(),
            trained_len: 0,
            min_train: 256,
        }
    }

    fn trained(&self) -> bool {
        !self.centroids.is_empty()
    }

    fn row(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// Nearest cell UNDER THE INDEX METRIC — an L2 index must assign by
    /// Euclidean distance, not raw dot product, or cells and probes rank
    /// incorrectly (big-magnitude centroids would swallow everything).
    fn nearest_cell(&self, v: &[f32]) -> usize {
        let mut best = 0;
        let mut best_score = f32::NEG_INFINITY;
        for (c, cen) in self.centroids.chunks_exact(self.dim).enumerate() {
            let s = metric_score(self.metric, v, cen);
            if s > best_score {
                best_score = s;
                best = c;
            }
        }
        best
    }

    /// K-means under the index metric.  Cosine/IP: maximize dot with
    /// L2-normalized means (spherical k-means).  L2: classic Lloyd —
    /// minimize Euclidean distance to plain means.
    fn train(&mut self) {
        let n = self.len();
        let k = if self.nlist > 0 {
            self.nlist.min(n)
        } else {
            ((n as f64).sqrt() as usize).clamp(4, 1024)
        };
        let mut rng = Pcg64::seeded(TRAIN_SEED);
        // k-means++ style init: random distinct rows
        let mut ids: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut ids);
        let mut centroids: Vec<f32> = Vec::with_capacity(k * self.dim);
        for &id in ids.iter().take(k) {
            centroids.extend_from_slice(self.row(id));
        }
        // Lloyd iterations
        let iters = 8;
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            // assign (same metric the probes will use)
            for i in 0..n {
                let v = self.row(i);
                let mut best = 0;
                let mut best_score = f32::NEG_INFINITY;
                for (c, cen) in centroids.chunks_exact(self.dim).enumerate() {
                    let s = metric_score(self.metric, v, cen);
                    if s > best_score {
                        best_score = s;
                        best = c;
                    }
                }
                assign[i] = best;
            }
            // update
            let mut sums = vec![0.0f32; k * self.dim];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                let row = &self.data[i * self.dim..(i + 1) * self.dim];
                for (s, x) in sums[c * self.dim..(c + 1) * self.dim]
                    .iter_mut()
                    .zip(row)
                {
                    *s += x;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // re-seed empty cell with a random row
                    let id = rng.range(0, n);
                    sums[c * self.dim..(c + 1) * self.dim]
                        .copy_from_slice(self.row(id));
                    counts[c] = 1;
                }
                let cen = &mut sums[c * self.dim..(c + 1) * self.dim];
                let inv = 1.0 / counts[c] as f32;
                for x in cen.iter_mut() {
                    *x *= inv;
                }
                // spherical k-means only for the dot-product metrics; L2
                // centroids are the plain means
                if self.metric != Metric::L2 {
                    l2_normalize(cen);
                }
            }
            centroids = sums;
        }
        self.centroids = centroids;
        // rebuild posting lists
        self.cells = vec![Vec::new(); k];
        for i in 0..n {
            let c = self.nearest_cell(self.row(i));
            self.cells[c].push(i);
        }
        self.trained_len = n;
    }

    fn maybe_retrain(&mut self) {
        let n = self.len();
        if !self.trained() {
            if n >= self.min_train {
                self.train();
            }
            return;
        }
        if n >= self.trained_len * 8 {
            self.train();
        }
    }

    /// Cell occupancy histogram (diagnostics / tests).
    pub fn cell_sizes(&self) -> Vec<usize> {
        self.cells.iter().map(Vec::len).collect()
    }
}

impl VectorIndex for IvfIndex {
    fn insert(&mut self, v: &[f32]) -> Result<usize> {
        if v.len() != self.dim {
            bail!("insert: dim {} != index dim {}", v.len(), self.dim);
        }
        let id = self.len();
        self.data.extend_from_slice(v);
        if self.metric == Metric::Cosine {
            let start = id * self.dim;
            l2_normalize(&mut self.data[start..start + self.dim]);
        }
        if self.trained() {
            let cell = self.nearest_cell(self.row(id));
            self.cells[cell].push(id);
        }
        self.maybe_retrain();
        Ok(id)
    }

    fn insert_prepared(&mut self, v: &[f32]) -> Result<usize> {
        if v.len() != self.dim {
            bail!("insert_prepared: dim {} != index dim {}", v.len(), self.dim);
        }
        let id = self.len();
        self.data.extend_from_slice(v);
        if self.trained() {
            let cell = self.nearest_cell(self.row(id));
            self.cells[cell].push(id);
        }
        self.maybe_retrain();
        Ok(id)
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.dim);
        let q = normalized_query(query, self.metric);
        let mut buf = Vec::with_capacity(k + 1);
        if !self.trained() {
            // cold start: brute force
            for (id, row) in self.data.chunks_exact(self.dim).enumerate() {
                push_topk(&mut buf, k, Hit { id, score: metric_score(self.metric, &q, row) });
            }
            return finish_topk(buf, k);
        }
        // rank cells by centroid similarity UNDER THE METRIC, probe
        // top-nprobe; the dot metrics rank via the batch kernel (the
        // centroid block is contiguous), L2 stays scalar
        let mut cell_scores: Vec<(usize, f32)> = match self.metric {
            Metric::Cosine | Metric::InnerProduct => {
                let mut s = Vec::new();
                crate::util::simd::dot_batch(&q, &self.centroids, self.dim, &mut s);
                s.into_iter().enumerate().collect()
            }
            Metric::L2 => self
                .centroids
                .chunks_exact(self.dim)
                .enumerate()
                .map(|(c, cen)| (c, metric_score(self.metric, &q, cen)))
                .collect(),
        };
        cell_scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        for &(c, _) in cell_scores.iter().take(self.nprobe) {
            for &id in &self.cells[c] {
                push_topk(
                    &mut buf,
                    k,
                    Hit { id, score: metric_score(self.metric, &q, self.row(id)) },
                );
            }
        }
        // ids inserted after the last training that fell into probed cells
        // are already covered; brute-force any unassigned tail (none by
        // construction, since insert() assigns when trained)
        finish_topk(buf, k)
    }

    fn score_all(&self, query: &[f32], out: &mut Vec<f32>) {
        // Exact by definition (Venus retrieval needs all scores).
        assert_eq!(query.len(), self.dim);
        let q = normalized_query(query, self.metric);
        out.clear();
        out.reserve(self.len());
        match self.metric {
            Metric::Cosine | Metric::InnerProduct => {
                crate::util::simd::dot_batch(&q, &self.data, self.dim, out);
            }
            Metric::L2 => {
                for row in self.data.chunks_exact(self.dim) {
                    out.push(metric_score(self.metric, &q, row));
                }
            }
        }
    }

    fn score_into(&self, query: &[f32], out: &mut [f32]) {
        assert_eq!(query.len(), self.dim);
        assert_eq!(out.len(), self.len());
        let q = normalized_query(query, self.metric);
        match self.metric {
            Metric::Cosine | Metric::InnerProduct => {
                crate::util::simd::dot_batch_into(&q, &self.data, self.dim, out);
            }
            Metric::L2 => {
                for (o, row) in out.iter_mut().zip(self.data.chunks_exact(self.dim)) {
                    *o = metric_score(self.metric, &q, row);
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn vector(&self, id: usize) -> &[f32] {
        self.row(id)
    }
}

/// Fixed k-means seed: training is deterministic for a given insert order.
const TRAIN_SEED: u64 = 0x17f5_eed;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn fill(idx: &mut IvfIndex, n: usize, seed: u64) {
        let mut rng = Pcg64::seeded(seed);
        for _ in 0..n {
            let v: Vec<f32> = (0..idx.dim()).map(|_| rng.normal()).collect();
            idx.insert(&v).unwrap();
        }
    }

    #[test]
    fn cold_start_is_exact() {
        let mut idx = IvfIndex::new(8, Metric::Cosine, 4, 2);
        fill(&mut idx, 50, 1); // below min_train
        assert!(!idx.trained());
        let q: Vec<f32> = idx.vector(7).to_vec();
        let hits = idx.search(&q, 1);
        assert_eq!(hits[0].id, 7);
    }

    #[test]
    fn trains_after_threshold() {
        let mut idx = IvfIndex::new(8, Metric::Cosine, 8, 4);
        fill(&mut idx, 300, 2);
        assert!(idx.trained());
        assert_eq!(idx.cell_sizes().iter().sum::<usize>(), 300);
    }

    #[test]
    fn self_query_found_after_training() {
        let mut idx = IvfIndex::new(16, Metric::Cosine, 8, 8); // probe all
        fill(&mut idx, 400, 3);
        for probe_id in [0usize, 133, 399] {
            let q = idx.vector(probe_id).to_vec();
            let hits = idx.search(&q, 1);
            assert_eq!(hits[0].id, probe_id);
        }
    }

    #[test]
    fn inserts_after_training_searchable() {
        let mut idx = IvfIndex::new(8, Metric::Cosine, 8, 8);
        fill(&mut idx, 300, 4);
        let special = vec![9.0f32, -9.0, 9.0, -9.0, 9.0, -9.0, 9.0, -9.0];
        let id = idx.insert(&special).unwrap();
        let hits = idx.search(&special, 1);
        assert_eq!(hits[0].id, id);
    }

    #[test]
    fn l2_round_trip_after_training() {
        // L2 index past the training threshold: self-queries must come
        // back (score 0 = exact), and cell assignment must be Euclidean —
        // under the old raw-dot assignment, large-magnitude vectors all
        // landed in one cell and near-neighbor probes missed.
        let mut idx = IvfIndex::new(8, Metric::L2, 8, 8); // probe all cells
        let mut rng = Pcg64::seeded(61);
        for i in 0..400 {
            // mixed magnitudes: direction clusters × scale 1..16
            let scale = 1.0 + (i % 16) as f32;
            let v: Vec<f32> = (0..8).map(|_| rng.normal() * scale).collect();
            idx.insert(&v).unwrap();
        }
        assert!(idx.trained());
        for probe_id in [0usize, 57, 399] {
            let q = idx.vector(probe_id).to_vec();
            let hits = idx.search(&q, 1);
            assert_eq!(hits[0].id, probe_id);
            assert!(hits[0].score.abs() < 1e-6, "self-distance {}", hits[0].score);
        }
    }

    #[test]
    fn l2_search_agrees_with_flat_ground_truth() {
        use super::super::flat::FlatIndex;
        let dim = 16;
        let mut rng = Pcg64::seeded(62);
        // scene-like clusters so IVF probing is meaningful
        let centers: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..dim).map(|_| rng.normal() * 3.0).collect())
            .collect();
        let mut ivf = IvfIndex::new(dim, Metric::L2, 8, 8); // probe all
        let mut flat = FlatIndex::new(dim, Metric::L2);
        for _ in 0..600 {
            let c = &centers[rng.range(0, 8)];
            let v: Vec<f32> = c.iter().map(|x| x + 0.2 * rng.normal()).collect();
            ivf.insert(&v).unwrap();
            flat.insert(&v).unwrap();
        }
        let q: Vec<f32> = centers[3].iter().map(|x| x + 0.1 * rng.normal()).collect();
        let truth = flat.search(&q, 5);
        let got = ivf.search(&q, 5);
        // probing every cell ⇒ identical exact results
        let t_ids: Vec<usize> = truth.iter().map(|h| h.id).collect();
        let g_ids: Vec<usize> = got.iter().map(|h| h.id).collect();
        assert_eq!(t_ids, g_ids);
        // and score_all agrees elementwise
        let (mut a, mut b) = (Vec::new(), Vec::new());
        flat.score_all(&q, &mut a);
        ivf.score_all(&q, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn retrains_on_growth() {
        let mut idx = IvfIndex::new(8, Metric::Cosine, 0, 4);
        fill(&mut idx, 256, 5);
        let first_train = idx.trained_len;
        fill(&mut idx, 256 * 8, 6);
        assert!(idx.trained_len > first_train, "index should have re-trained");
    }
}
