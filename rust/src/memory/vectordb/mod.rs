//! From-scratch vector database (the paper uses FAISS, unavailable here).
//!
//! Two index kinds behind one trait:
//!   - [`FlatIndex`] — exact brute-force inner-product / cosine search;
//!   - [`IvfIndex`] — inverted-file index (k-means coarse quantizer +
//!     per-cell posting lists), trading recall for sub-linear probes.
//!
//! Vectors are L2-normalized at insert when the metric is cosine, so
//! inner product == cosine similarity and the scoring loop is a plain dot
//! product (the hot path profiled in §Perf).

mod flat;
mod ivf;

pub use flat::FlatIndex;
pub use ivf::IvfIndex;

use anyhow::Result;

/// Similarity metric.  Scores are "higher = more similar" for every
/// variant, so the top-k machinery and the Eq. 5 softmax are metric-
/// agnostic (L2 scores are negated squared distances).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Inner product on raw vectors.
    InnerProduct,
    /// Cosine: vectors are L2-normalized on insert and query.
    Cosine,
    /// Euclidean: score = −‖a − b‖² (no normalization anywhere).
    L2,
}

/// Score one stored row against a (metric-prepared) query.  Every scoring
/// loop in this module — flat scan, IVF cell ranking, IVF posting-list
/// probes, k-means assignment — dispatches through here, so an index never
/// mixes metrics between training and search.
#[inline]
pub(crate) fn metric_score(metric: Metric, q: &[f32], row: &[f32]) -> f32 {
    match metric {
        Metric::InnerProduct | Metric::Cosine => crate::util::dot(q, row),
        Metric::L2 => {
            let mut acc = 0.0f32;
            for (a, b) in q.iter().zip(row) {
                let d = a - b;
                acc += d * d;
            }
            -acc
        }
    }
}

/// A scored search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    /// Insertion id (dense, 0-based).
    pub id: usize,
    pub score: f32,
}

/// Common vector-index interface.  `Send + Sync` because index shards sit
/// behind per-stream `RwLock`s read concurrently by many query workers.
pub trait VectorIndex: Send + Sync {
    /// Insert a vector, returning its dense id.
    fn insert(&mut self, v: &[f32]) -> Result<usize>;

    /// Insert a vector that is ALREADY metric-prepared — i.e. bytes read
    /// back from [`VectorIndex::vector`] (or a durable copy of them).
    /// Skips the insert-time preparation (cosine L2-normalization), so a
    /// stored row round-trips bit-exactly through persistence and
    /// hot-tier rebuilds: re-normalizing an already-normalized vector can
    /// flip low-order bits, and the restart-equivalence guarantee of the
    /// tiered memory needs the scored bytes to be identical.
    fn insert_prepared(&mut self, v: &[f32]) -> Result<usize>;

    /// Top-k most similar vectors to the query.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;

    /// Similarity of the query against EVERY stored vector, in id order
    /// (Venus's sampling retrieval needs the full score vector, Eq. 4).
    fn score_all(&self, query: &[f32], out: &mut Vec<f32>);

    /// Slice form of [`VectorIndex::score_all`]: fill a pre-sized
    /// disjoint region of a merged score buffer (`out.len()` must equal
    /// `self.len()`), bit-identical per row to `score_all`.  The
    /// parallel scoring pool writes hot-tier scores through this; the
    /// default falls back through a temporary vector so third-party
    /// indexes stay correct without opting in.
    fn score_into(&self, query: &[f32], out: &mut [f32]) {
        let mut tmp = Vec::with_capacity(out.len());
        self.score_all(query, &mut tmp);
        out.copy_from_slice(&tmp);
    }

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dim(&self) -> usize;

    /// Raw stored vector by id (post-normalization).
    fn vector(&self, id: usize) -> &[f32];
}

/// Build an index by config name ("flat" | "ivf").
pub fn build_index(
    kind: &str,
    dim: usize,
    metric: Metric,
    ivf_nlist: usize,
    ivf_nprobe: usize,
) -> Result<Box<dyn VectorIndex>> {
    match kind {
        "flat" => Ok(Box::new(FlatIndex::new(dim, metric))),
        "ivf" => Ok(Box::new(IvfIndex::new(dim, metric, ivf_nlist, ivf_nprobe))),
        other => anyhow::bail!("unknown index kind '{other}'"),
    }
}

/// Shared: maintain a bounded top-k as (score, id) pairs.
pub(crate) fn push_topk(heap: &mut Vec<Hit>, k: usize, hit: Hit) {
    if heap.len() < k {
        heap.push(hit);
        if heap.len() == k {
            heap.sort_by(|a, b| {
                b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        return;
    }
    if hit.score > heap[k - 1].score {
        // binary insert into the sorted (descending) buffer
        let pos = heap
            .binary_search_by(|h| {
                hit.score
                    .partial_cmp(&h.score)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(std::cmp::Ordering::Greater)
            })
            .unwrap_or_else(|p| p);
        heap.insert(pos, hit);
        heap.pop();
    }
}

/// Finalize an unsorted candidate list into a descending top-k.
pub(crate) fn finish_topk(mut hits: Vec<Hit>, k: usize) -> Vec<Hit> {
    hits.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    hits.truncate(k);
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_vectors(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect()
    }

    fn clustered_vectors(n: usize, dim: usize, centers: usize, seed: u64) -> Vec<Vec<f32>> {
        // realistic for Venus: index vectors cluster by scene
        let mut rng = Pcg64::seeded(seed);
        let cents = random_vectors(centers, dim, seed ^ 0xabc);
        (0..n)
            .map(|_| {
                let c = &cents[rng.range(0, centers)];
                c.iter().map(|x| x + 0.15 * rng.normal()).collect()
            })
            .collect()
    }

    fn recall_at_10(vs: &[Vec<f32>], queries: &[Vec<f32>], ivf: &IvfIndex, flat: &FlatIndex) -> f64 {
        let _ = vs;
        let mut recall_sum = 0.0;
        for q in queries {
            let truth: std::collections::HashSet<usize> =
                flat.search(q, 10).into_iter().map(|h| h.id).collect();
            let got = ivf.search(q, 10);
            let inter = got.iter().filter(|h| truth.contains(&h.id)).count();
            recall_sum += inter as f64 / 10.0;
        }
        recall_sum / queries.len() as f64
    }

    /// Property: on scene-clustered data (Venus's real distribution) IVF
    /// recall@10 against the flat ground truth stays high.
    #[test]
    fn ivf_recall_against_flat_clustered() {
        let dim = 32;
        let vs = clustered_vectors(2000, dim, 24, 5);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        let mut ivf = IvfIndex::new(dim, Metric::Cosine, 32, 8);
        for v in &vs {
            flat.insert(v).unwrap();
            ivf.insert(v).unwrap();
        }
        let queries = clustered_vectors(20, dim, 24, 6);
        let recall = recall_at_10(&vs, &queries, &ivf, &flat);
        assert!(recall >= 0.85, "IVF recall@10 (clustered) = {recall}");
    }

    /// On structureless (uniform Gaussian) data, probing half the cells
    /// still recovers most of the exact top-10.
    #[test]
    fn ivf_recall_against_flat_random() {
        let dim = 32;
        let vs = random_vectors(2000, dim, 5);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        let mut ivf = IvfIndex::new(dim, Metric::Cosine, 32, 16);
        for v in &vs {
            flat.insert(v).unwrap();
            ivf.insert(v).unwrap();
        }
        let queries = random_vectors(20, dim, 6);
        let recall = recall_at_10(&vs, &queries, &ivf, &flat);
        assert!(recall >= 0.7, "IVF recall@10 (random) = {recall}");
    }

    /// Property: on identical inserts, both indexes return identical
    /// score_all vectors (IVF scoring is still exact; only search prunes).
    #[test]
    fn score_all_identical_across_indexes() {
        let dim = 16;
        let vs = random_vectors(300, dim, 7);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        let mut ivf = IvfIndex::new(dim, Metric::Cosine, 8, 2);
        for v in &vs {
            flat.insert(v).unwrap();
            ivf.insert(v).unwrap();
        }
        let q = &vs[42];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        flat.score_all(q, &mut a);
        ivf.score_all(q, &mut b);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
        // self-similarity tops the list
        let best = a
            .iter()
            .enumerate()
            .max_by(|p, q2| p.1.partial_cmp(q2.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 42);
    }

    #[test]
    fn build_index_by_name() {
        assert!(build_index("flat", 8, Metric::Cosine, 0, 0).is_ok());
        assert!(build_index("ivf", 8, Metric::Cosine, 4, 2).is_ok());
        assert!(build_index("hnsw", 8, Metric::Cosine, 0, 0).is_err());
    }

    #[test]
    fn topk_helper_maintains_order() {
        let mut buf = Vec::new();
        for (i, s) in [0.3f32, 0.9, 0.1, 0.7, 0.5].iter().enumerate() {
            push_topk(&mut buf, 3, Hit { id: i, score: *s });
        }
        let final_ = finish_topk(buf, 3);
        let scores: Vec<f32> = final_.iter().map(|h| h.score).collect();
        assert_eq!(scores, vec![0.9, 0.7, 0.5]);
    }
}
