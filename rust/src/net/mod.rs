//! Edge-cloud networking: the simulated uplink cost model ([`Link`])
//! and the real TCP serving surface ([`wire`]).
//!
//! The paper fixes a 100 Mbps link between the Jetson edge and the L40S
//! cloud (§V-A) and attributes up to 80% of baseline response latency to
//! video upload (Fig. 2).  Transfer time here is the paper's own model:
//! `bytes / bandwidth + RTT`, with frame sizes from a 1080p-JPEG size
//! model (our synthetic pixels are 64×64 for compute, but the *cost*
//! model uses camera-realistic sizes — see DESIGN.md §1).  Baselines that
//! upload "the entire relevant video" ship the frames extracted at the
//! evaluation rate (8 FPS, §V-A), which is what makes communication the
//! dominant term in Fig. 2.

pub mod wire;

use crate::config::NetConfig;

/// What is being shipped up to the cloud.
#[derive(Clone, Copy, Debug)]
pub enum Payload {
    /// N individual JPEG frames.
    Frames(usize),
    /// A full clip: all frames extracted at `fps` over `duration_s`.
    VideoClip { duration_s: f64, fps: f64 },
    /// Raw bytes (query text, auxiliary metadata...).
    Bytes(f64),
}

/// Simulated edge-uplink.
#[derive(Clone, Debug)]
pub struct Link {
    cfg: NetConfig,
}

impl Link {
    pub fn new(cfg: NetConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    /// Payload size in bytes under the size model.
    pub fn payload_bytes(&self, p: Payload) -> f64 {
        match p {
            Payload::Frames(n) => n as f64 * self.cfg.frame_kb * 1024.0,
            Payload::VideoClip { duration_s, fps } => {
                duration_s * fps * self.cfg.frame_kb * 1024.0
            }
            Payload::Bytes(b) => b,
        }
    }

    /// One-way transfer latency in seconds (bandwidth + half RTT).
    pub fn transfer_s(&self, p: Payload) -> f64 {
        let bytes = self.payload_bytes(p);
        bytes * 8.0 / (self.cfg.bandwidth_mbps * 1e6) + self.cfg.rtt_ms / 2.0 * 1e-3
    }

    /// Round-trip request latency: payload up, small answer down.
    pub fn round_trip_s(&self, up: Payload) -> f64 {
        self.transfer_s(up) + self.cfg.rtt_ms / 2.0 * 1e-3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link::new(NetConfig { bandwidth_mbps: 100.0, rtt_ms: 20.0, frame_kb: 450.0 })
    }

    #[test]
    fn frame_upload_is_second_scale() {
        // 32 frames × 450 KB at 100 Mbps ≈ 1.18 s + 10 ms RTT
        let t = link().transfer_s(Payload::Frames(32));
        assert!((t - 1.19).abs() < 0.05, "t = {t}");
    }

    #[test]
    fn clip_upload_matches_paper_scale() {
        let l = link();
        // Video-MME medium (~9 min): paper reports ~2.5–2.8 min upload
        let med = l.transfer_s(Payload::VideoClip { duration_s: 540.0, fps: 8.0 });
        assert!(med > 120.0 && med < 200.0, "medium = {med}");
        // Video-MME long (~45 min): paper reports ~11 min
        let long = l.transfer_s(Payload::VideoClip { duration_s: 2700.0, fps: 8.0 });
        assert!(long > 9.0 * 60.0 && long < 16.0 * 60.0, "long = {long}");
    }

    #[test]
    fn clip_scales_linearly_with_duration() {
        let l = link();
        let a = l.payload_bytes(Payload::VideoClip { duration_s: 100.0, fps: 8.0 });
        let b = l.payload_bytes(Payload::VideoClip { duration_s: 300.0, fps: 8.0 });
        assert!((b / a - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rtt_floor() {
        let t = link().transfer_s(Payload::Bytes(10.0));
        assert!(t >= 0.01 && t < 0.011, "t = {t}");
    }

    #[test]
    fn round_trip_adds_return_leg() {
        let l = link();
        let one = l.transfer_s(Payload::Frames(1));
        let rt = l.round_trip_s(Payload::Frames(1));
        assert!((rt - one - 0.01).abs() < 1e-9);
    }
}
