//! The camera client: paced push-ingest over the wire, with typed
//! backpressure obedience and reconnect-with-resume.
//!
//! A [`Camera`] generates frames from a synthetic stream preset (the
//! same [`VideoSynth`] the in-process ingest path uses), batches them,
//! and pushes `ingest_frames` envelopes at the declared frame rate.
//! Sequencing is server-authoritative end to end:
//!
//!  * on every (re)connect the camera sends `ingest_open` and resumes
//!    from the acked `next_seq` — never from local history, so a dropped
//!    connection can neither duplicate nor silently lose frames against
//!    a durable fabric;
//!  * a `SlowDown{delay_ms}` verdict is obeyed by sleeping before the
//!    next batch; a `Dropped{from_seq,count}` verdict is tallied and the
//!    camera resumes from the advanced watermark (the server kept the
//!    hole deliberately);
//!  * transport errors trigger bounded-backoff reconnects
//!    ([`Camera::max_reconnects`]); protocol errors are fatal — they
//!    mean a bug or a stale lease, not a flaky network.
//!
//! Surface: `venus camera --connect ADDR --stream N` and the
//! `ingest_wire` bench/integration tests.

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::WireConfig;
use crate::util::b64::encode_f32s;
use crate::video::synth::VideoSynth;

use super::frame::{read_frame, write_frame};
use super::ingest::unix_ms_now;
use super::proto::{Backpressure, ClientMsg, IngestFrame, ServerMsg, PROTOCOL_VERSION};

/// A paced push-ingest client for one camera stream.
pub struct Camera {
    /// Gateway address (host:port).
    pub addr: String,
    /// Fabric stream id to claim.
    pub stream: u16,
    /// Frame source; geometry and default pacing come from its config.
    pub synth: Arc<VideoSynth>,
    /// Declared (and enforced, by pacing) capture rate.
    pub fps: f64,
    /// Frames to push this run, on top of the stream's watermark at the
    /// FIRST open (the synth loops as needed).  The absolute target is
    /// pinned there, so mid-run reconnects resume toward the same goal
    /// instead of extending it.
    pub frames: u64,
    /// Frames per `ingest_frames` envelope.
    pub batch_frames: usize,
    /// Client-side socket timeouts ([`WireConfig`] `[wire]` section).
    pub wire: WireConfig,
    /// Transport-failure budget before the run gives up.
    pub max_reconnects: usize,
}

/// What one camera run did, for CLI output and test assertions.
#[derive(Clone, Debug, Default)]
pub struct CameraReport {
    pub stream: u16,
    /// Frames the server accepted into the pipeline.
    pub accepted: u64,
    /// Frames the server shed (`Dropped` verdicts, `drop` policy).
    pub dropped: u64,
    /// Batches answered with a `SlowDown` verdict.
    pub slowed_batches: u64,
    /// The final acked high-watermark (next expected sequence number).
    pub watermark: u64,
    /// Transport failures survived by reconnect-with-resume.
    pub reconnects: usize,
    pub wall_s: f64,
    /// Accepted frames per wall second.
    pub sustained_fps: f64,
}

impl CameraReport {
    pub fn render(&self) -> String {
        format!(
            "camera s{}: {} accepted / {} dropped / {} slowed batches; \
             watermark {} after {:.1}s ({:.1} fps sustained, {} reconnects)",
            self.stream,
            self.accepted,
            self.dropped,
            self.slowed_batches,
            self.watermark,
            self.wall_s,
            self.sustained_fps,
            self.reconnects,
        )
    }
}

/// One connected, handshaken ingest connection.
struct Conn {
    stream: TcpStream,
    max_frame_bytes: usize,
}

impl Conn {
    fn connect(addr: &str, wire: &WireConfig) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting camera to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(wire.read_timeout_ms)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(wire.write_timeout_ms)));
        let mut conn = Self { stream, max_frame_bytes: wire.max_frame_bytes };
        match conn.round_trip(&ClientMsg::Hello { version: PROTOCOL_VERSION })? {
            ServerMsg::HelloAck { version: PROTOCOL_VERSION, .. } => Ok(conn),
            ServerMsg::HelloAck { version, .. } => {
                bail!("server speaks protocol v{version}, this camera speaks v{PROTOCOL_VERSION}")
            }
            ServerMsg::Error { error } => bail!("handshake refused: {error}"),
            other => bail!("unexpected handshake reply: {other:?}"),
        }
    }

    fn round_trip(&mut self, msg: &ClientMsg) -> Result<ServerMsg> {
        let mut w = &self.stream;
        write_frame(&mut w, &msg.to_json(), self.max_frame_bytes)
            .map_err(|e| anyhow::anyhow!("camera write failed: {e}"))?;
        let mut r = &self.stream;
        let reply = read_frame(&mut r, self.max_frame_bytes)
            .map_err(|e| anyhow::anyhow!("camera read failed: {e}"))?;
        ServerMsg::from_json(&reply)
    }
}

/// Build one batch of wire frames: seqs `from..from+n`, pixels from the
/// (looping) synth, capture stamped now.
fn batch_payload(synth: &VideoSynth, from: u64, n: u64) -> Vec<IngestFrame> {
    let total = synth.total_frames().max(1);
    (from..from + n)
        .map(|seq| IngestFrame {
            seq,
            captured_unix_ms: unix_ms_now(),
            data_b64: encode_f32s(synth.frame(seq % total).data()),
        })
        .collect()
}

impl Camera {
    /// A camera over `synth` with the synth's native pacing and length.
    pub fn new(addr: impl Into<String>, stream: u16, synth: Arc<VideoSynth>) -> Self {
        let fps = synth.config().fps;
        let frames = synth.total_frames();
        Self {
            addr: addr.into(),
            stream,
            synth,
            fps,
            frames,
            batch_frames: 8,
            wire: WireConfig::default(),
            max_reconnects: 5,
        }
    }

    /// Run to completion: push frames until the acked watermark reaches
    /// the goal pinned at the first open (its `next_seq` plus
    /// [`Camera::frames`]).  Dropped batches count toward completion
    /// (the server advanced the watermark past them on purpose);
    /// transport failures reconnect and resume; protocol errors are
    /// fatal.
    pub fn run(&self) -> Result<CameraReport> {
        anyhow::ensure!(self.fps > 0.0 && self.fps.is_finite(), "fps must be positive");
        anyhow::ensure!(self.batch_frames > 0, "batch_frames must be at least 1");
        let started = Instant::now();
        let mut report = CameraReport { stream: self.stream, ..Default::default() };
        let frame_size = self.synth.config().frame_size;
        // (base, goal) watermarks, pinned at the FIRST successful open —
        // reconnects resume toward the same goal and pacing stays on the
        // capture clock of the frames THIS run owns
        let mut span: Option<(u64, u64)> = None;

        'connection: loop {
            let mut conn = match Conn::connect(&self.addr, &self.wire) {
                Ok(c) => c,
                Err(e) => {
                    self.backoff(&mut report, e)?;
                    continue 'connection;
                }
            };
            let open = ClientMsg::IngestOpen {
                stream: self.stream,
                frame_size,
                fps: self.fps,
            };
            let mut next_seq = match conn.round_trip(&open) {
                Ok(ServerMsg::IngestOpenAck { stream, next_seq }) if stream == self.stream => {
                    next_seq
                }
                Ok(ServerMsg::Error { error }) => bail!("ingest_open refused: {error}"),
                Ok(other) => bail!("unexpected reply to ingest_open: {other:?}"),
                Err(e) => {
                    self.backoff(&mut report, e)?;
                    continue 'connection;
                }
            };
            let (base, goal) = *span.get_or_insert((next_seq, next_seq + self.frames));
            // the open ack is itself an authoritative watermark report
            // (a reconnect may discover the goal was already reached)
            report.watermark = report.watermark.max(next_seq);

            while next_seq < goal {
                let n = (goal - next_seq).min(self.batch_frames as u64);
                // open-loop pacing: the last frame of this batch is due at
                // (seq - base)/fps on this run's capture clock
                let due_s = (next_seq + n - base) as f64 / self.fps;
                let elapsed = started.elapsed().as_secs_f64();
                if due_s > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(due_s - elapsed));
                }
                let frames = batch_payload(&self.synth, next_seq, n);
                match conn.round_trip(&ClientMsg::IngestFrames { stream: self.stream, frames }) {
                    Ok(ServerMsg::IngestAck { high_watermark, backpressure, .. }) => {
                        next_seq = high_watermark;
                        report.watermark = high_watermark;
                        match backpressure {
                            Backpressure::None => report.accepted += n,
                            Backpressure::SlowDown { delay_ms } => {
                                report.accepted += n;
                                report.slowed_batches += 1;
                                std::thread::sleep(Duration::from_millis(delay_ms));
                            }
                            Backpressure::Dropped { count, .. } => report.dropped += count,
                        }
                    }
                    Ok(ServerMsg::Error { error }) => bail!("ingest rejected: {error}"),
                    Ok(other) => bail!("unexpected reply to ingest_frames: {other:?}"),
                    Err(e) => {
                        // transport failure mid-batch: the server may or
                        // may not have applied it — re-open and let the
                        // authoritative next_seq arbitrate (exactly-once
                        // against a durable fabric)
                        self.backoff(&mut report, e)?;
                        continue 'connection;
                    }
                }
            }
            break;
        }
        report.wall_s = started.elapsed().as_secs_f64();
        report.sustained_fps = if report.wall_s > 0.0 {
            report.accepted as f64 / report.wall_s
        } else {
            0.0
        };
        Ok(report)
    }

    /// Count a transport failure against the reconnect budget and sleep
    /// a linearly growing backoff.
    fn backoff(&self, report: &mut CameraReport, err: anyhow::Error) -> Result<()> {
        report.reconnects += 1;
        if report.reconnects > self.max_reconnects {
            return Err(err.context(format!(
                "camera gave up after {} reconnect attempts",
                self.max_reconnects
            )));
        }
        std::thread::sleep(Duration::from_millis(50 * report.reconnects as u64));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::b64::decode_f32s;
    use crate::video::synth::SynthConfig;

    fn tiny_synth() -> Arc<VideoSynth> {
        let be = crate::backend::shared_default().unwrap();
        let cfg = SynthConfig { duration_s: 4.0, ..Default::default() };
        Arc::new(VideoSynth::new(cfg, be.concept_codes().unwrap(), be.model().patch))
    }

    #[test]
    fn batch_payload_is_contiguous_and_bit_exact() {
        let synth = tiny_synth();
        let total = synth.total_frames();
        assert!(total >= 4);
        // a batch that wraps the synth's end keeps seqs contiguous while
        // looping pixel content
        let from = total - 2;
        let frames = batch_payload(&synth, from, 4);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.seq, from + i as u64);
            let px = decode_f32s(&f.data_b64).unwrap();
            let want = synth.frame(f.seq % total);
            assert_eq!(px.len(), want.data().len());
            for (a, b) in px.iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn unreachable_server_exhausts_the_reconnect_budget() {
        let synth = tiny_synth();
        // reserved port on localhost with nothing listening
        let mut cam = Camera::new("127.0.0.1:1", 0, synth);
        cam.max_reconnects = 2;
        let err = cam.run().unwrap_err();
        assert!(format!("{err:#}").contains("gave up"), "{err:#}");
    }

    #[test]
    fn report_renders_the_headline_numbers() {
        let r = CameraReport {
            stream: 3,
            accepted: 960,
            dropped: 64,
            slowed_batches: 2,
            watermark: 1024,
            reconnects: 1,
            wall_s: 120.0,
            sustained_fps: 8.0,
        };
        let s = r.render();
        for needle in ["s3", "960 accepted", "64 dropped", "watermark 1024", "1 reconnects"] {
            assert!(s.contains(needle), "missing {needle} in {s}");
        }
    }
}
