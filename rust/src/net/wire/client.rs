//! Blocking wire client over the typed query protocol.
//!
//! One [`WireClient`] is one TCP connection and one *session*: the
//! gateway mints a session id at handshake time, and the client records
//! every turn (request + typed response or error) with the same
//! [`SessionTurn`] type the in-process [`crate::api::Session`] uses —
//! so per-session history and cache-hit accounting read identically
//! whether the service is a function call or a socket away.
//!
//! Error layering: transport problems (connect failure, protocol
//! violation, oversized frame, server busy) surface as `anyhow` errors —
//! the connection is dead or never existed.  Serving-layer refusals
//! (admission rejection, deadline shed, engine failure) surface as
//! `Ok(Err(ApiError))` — typed, retryable per the [`ApiError`] taxonomy,
//! on a connection that remains usable.

use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::api::{ApiError, QueryRequest, QueryResponse, SessionTurn};
use crate::config::WireConfig;
use crate::obs::{Trace, TraceId};
use crate::server::Snapshot;

use super::frame::{read_frame, write_frame};
use super::proto::{ClientMsg, ServerMsg, WireError, PROTOCOL_VERSION};

/// Session-history bound: the client keeps between this many and twice
/// this many recent turns (amortized O(1) trimming).  Long-lived
/// clients — the load generator fires hundreds of thousands of queries
/// per connection — must not grow memory without bound for a history
/// nothing reads back that far.
const MAX_HISTORY_TURNS: usize = 1024;

/// A connected, handshaken wire client.
pub struct WireClient {
    stream: TcpStream,
    max_frame_bytes: usize,
    session: u64,
    streams: usize,
    history: Vec<SessionTurn>,
}

impl WireClient {
    /// Connect with the default [`WireConfig`] timeouts and frame bound.
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Self> {
        Self::connect_with(addr, &WireConfig::default())
    }

    /// Connect, handshake, and return a ready client.  `cfg` supplies
    /// the client-side read/write timeouts and frame bound (`listen` is
    /// ignored — the address is explicit).
    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        cfg: &WireConfig,
    ) -> Result<Self> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connecting to venus gateway at {addr:?}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms)))?;
        stream.set_write_timeout(Some(Duration::from_millis(cfg.write_timeout_ms)))?;
        let mut client = Self {
            stream,
            max_frame_bytes: cfg.max_frame_bytes,
            session: 0,
            streams: 0,
            history: Vec::new(),
        };
        let hello = ClientMsg::Hello { version: PROTOCOL_VERSION };
        match client.round_trip(&hello)? {
            ServerMsg::HelloAck { version, session, streams } => {
                if version != PROTOCOL_VERSION {
                    bail!("server speaks protocol v{version}, this client v{PROTOCOL_VERSION}");
                }
                client.session = session;
                client.streams = streams;
                Ok(client)
            }
            ServerMsg::Error { error } => {
                Err(anyhow::Error::new(error).context("handshake refused"))
            }
            other => bail!("expected hello_ack, got {other:?}"),
        }
    }

    /// The session id the gateway minted for this connection.
    pub fn session_id(&self) -> u64 {
        self.session
    }

    /// Camera streams in the server's fabric (from the handshake).
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// Send one typed query and block for the reply.  Outer error =
    /// transport/protocol failure (connection unusable); inner error =
    /// typed serving refusal (connection still fine).  The turn is
    /// recorded in the session history either way the *serving* layer
    /// answered.
    pub fn query(
        &mut self,
        request: QueryRequest,
    ) -> Result<std::result::Result<QueryResponse, ApiError>> {
        let msg = ClientMsg::Query { request: request.clone() };
        let response = match self.round_trip(&msg)? {
            ServerMsg::Response { response } => Ok(response),
            ServerMsg::Error { error: WireError::Api(api) } => Err(api),
            ServerMsg::Error { error } => {
                return Err(anyhow::Error::new(error).context("query failed at the wire layer"))
            }
            other => bail!("expected response, got {other:?}"),
        };
        if self.history.len() >= MAX_HISTORY_TURNS * 2 {
            self.history.drain(..MAX_HISTORY_TURNS);
        }
        self.history.push(SessionTurn { request, response: response.clone() });
        Ok(response)
    }

    /// Fetch the server's live metrics snapshot (per-lane counters and
    /// queue-depth gauges, latency percentiles, memory gauges).
    pub fn stats(&mut self) -> Result<Snapshot> {
        match self.round_trip(&ClientMsg::Stats)? {
            ServerMsg::Stats { snapshot } => Ok(*snapshot),
            ServerMsg::Error { error } => Err(anyhow::Error::new(error).context("stats refused")),
            other => bail!("expected stats, got {other:?}"),
        }
    }

    /// Fetch one query's span tree by id.  `Ok(None)` when the server
    /// no longer holds it (bounded ring, evicted) or never sampled it.
    pub fn trace(&mut self, id: TraceId) -> Result<Option<Trace>> {
        let msg = ClientMsg::Trace { id: Some(id), last: 1, slow: false };
        match self.round_trip(&msg)? {
            ServerMsg::Trace { traces } => Ok(traces.into_iter().next()),
            ServerMsg::Error { error } => Err(anyhow::Error::new(error).context("trace refused")),
            other => bail!("expected trace, got {other:?}"),
        }
    }

    /// Fetch the last `n` completed traces, or — with `slow` — the last
    /// `n` entries of the slow-query ring (newest first in both cases).
    pub fn recent_traces(&mut self, n: usize, slow: bool) -> Result<Vec<Trace>> {
        let msg = ClientMsg::Trace { id: None, last: n, slow };
        match self.round_trip(&msg)? {
            ServerMsg::Trace { traces } => Ok(traces),
            ServerMsg::Error { error } => Err(anyhow::Error::new(error).context("trace refused")),
            other => bail!("expected trace, got {other:?}"),
        }
    }

    /// Fetch the server's metrics in Prometheus text exposition format
    /// (the same counters as [`WireClient::stats`], plus span-derived
    /// per-stage histograms).
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.round_trip(&ClientMsg::MetricsText)? {
            ServerMsg::MetricsText { text } => Ok(text),
            ServerMsg::Error { error } => {
                Err(anyhow::Error::new(error).context("metrics refused"))
            }
            other => bail!("expected metrics_text, got {other:?}"),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        match self.round_trip(&ClientMsg::Ping)? {
            ServerMsg::Pong => Ok(()),
            other => bail!("expected pong, got {other:?}"),
        }
    }

    /// Ask the server to shut down gracefully.  The server acknowledges,
    /// then closes this connection; the serve loop drains and flushes.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.round_trip(&ClientMsg::Shutdown)? {
            ServerMsg::ShutdownAck => Ok(()),
            other => bail!("expected shutdown_ack, got {other:?}"),
        }
    }

    /// Recent turns of this session, in order (the same record type the
    /// in-process [`crate::api::Session`] keeps).  Bounded: only the
    /// most recent ~1024–2048 turns are retained.
    pub fn history(&self) -> &[SessionTurn] {
        &self.history
    }

    /// Retained turns served from the semantic query cache.
    pub fn cache_hits(&self) -> usize {
        self.history
            .iter()
            .filter(|t| t.response.as_ref().is_ok_and(|r| r.cache.is_hit()))
            .count()
    }

    /// Retained turns that ended in a typed serving error (shed,
    /// rejected, ...).
    pub fn errors(&self) -> usize {
        self.history.iter().filter(|t| t.response.is_err()).count()
    }

    fn round_trip(&mut self, msg: &ClientMsg) -> Result<ServerMsg> {
        write_frame(&mut self.stream, &msg.to_json(), self.max_frame_bytes)?;
        let frame = read_frame(&mut self.stream, self.max_frame_bytes)
            .map_err(|e| anyhow::Error::new(e).context("reading server reply"))?;
        ServerMsg::from_json(&frame)
    }
}
