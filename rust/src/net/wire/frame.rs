//! Length-prefixed JSON frame codec.
//!
//! One frame = a 4-byte big-endian payload length followed by that many
//! bytes of UTF-8 JSON (the [`crate::util::json`] encoding).  The length
//! bound is enforced *before* allocating, so a hostile 4 GiB prefix
//! costs nothing; every failure mode is a typed [`FrameError`] the
//! caller maps to "close this one connection".

use std::fmt;
use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Bytes in the length prefix.
pub const LEN_PREFIX_BYTES: usize = 4;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// The peer closed the connection cleanly between frames (EOF at a
    /// frame boundary) — the normal end of a conversation.
    Closed,
    /// The length prefix exceeds the configured frame bound (or is 0).
    /// Nothing was allocated; the connection is no longer in sync.
    TooLarge { len: usize, max: usize },
    /// Socket error, read timeout, or EOF *inside* a frame (a truncated
    /// peer write).  The connection is no longer in sync.
    Io(std::io::Error),
    /// The payload was not valid JSON.
    BadJson(String),
}

impl FrameError {
    /// Did the read fail because the socket's read timeout elapsed?
    /// (Unix reports `WouldBlock` for `SO_RCVTIMEO`, Windows `TimedOut`.)
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            FrameError::Io(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
        )
    }
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "peer closed the connection"),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte bound")
            }
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::BadJson(msg) => write!(f, "frame payload is not valid JSON: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Read one frame.  `max_bytes` bounds the payload length (a
/// `TooLarge` error is returned before any payload allocation).
pub fn read_frame(r: &mut impl Read, max_bytes: usize) -> std::result::Result<Json, FrameError> {
    let mut prefix = [0u8; LEN_PREFIX_BYTES];
    // the first byte is read separately so a clean close *between*
    // frames (EOF before any prefix byte) is distinguishable from a
    // truncated prefix
    match r.read(&mut prefix[..1]) {
        Ok(0) => return Err(FrameError::Closed),
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::Interrupted => {
            return read_frame(r, max_bytes);
        }
        Err(e) => return Err(FrameError::Io(e)),
    }
    r.read_exact(&mut prefix[1..]).map_err(FrameError::Io)?;
    let len = u32::from_be_bytes(prefix) as usize;
    if len == 0 || len > max_bytes {
        return Err(FrameError::TooLarge { len, max: max_bytes });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(FrameError::Io)?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| FrameError::BadJson(format!("invalid utf-8: {e}")))?;
    Json::parse(text).map_err(|e| FrameError::BadJson(format!("{e:#}")))
}

/// Write one frame (length prefix + serialized JSON) and flush.  An
/// encoding larger than `max_bytes` is an error — the peer would refuse
/// it anyway, so it is never put on the wire.
pub fn write_frame(w: &mut impl Write, msg: &Json, max_bytes: usize) -> Result<()> {
    write_frame_text(w, &msg.to_string(), max_bytes)
}

/// Write an already-serialized JSON payload as one frame.  Lets callers
/// that need the encoded size beforehand (e.g. to answer an oversized
/// reply with a typed error) serialize exactly once.
pub fn write_frame_text(w: &mut impl Write, payload: &str, max_bytes: usize) -> Result<()> {
    let bytes = payload.as_bytes();
    if bytes.is_empty() || bytes.len() > max_bytes {
        bail!("frame of {} bytes exceeds the {max_bytes}-byte bound", bytes.len());
    }
    let prefix = (bytes.len() as u32).to_be_bytes();
    w.write_all(&prefix)?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const MAX: usize = 4096;

    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut buf = (payload.len() as u32).to_be_bytes().to_vec();
        buf.extend_from_slice(payload);
        buf
    }

    #[test]
    fn frames_round_trip() {
        let msgs = [
            Json::parse(r#"{"type":"ping"}"#).unwrap(),
            Json::parse(r#"{"a":[1,2,3],"b":"héllo → 世界"}"#).unwrap(),
            Json::Num(42.0),
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, m, MAX).unwrap();
        }
        let mut r = Cursor::new(buf);
        for m in &msgs {
            assert_eq!(&read_frame(&mut r, MAX).unwrap(), m);
        }
        // EOF at the frame boundary is a clean close
        assert!(matches!(read_frame(&mut r, MAX), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocating() {
        // 4 GiB-scale prefix: must fail with TooLarge, never allocate
        let mut r = Cursor::new(0xffff_ffffu32.to_be_bytes().to_vec());
        match read_frame(&mut r, MAX) {
            Err(FrameError::TooLarge { len, max }) => {
                assert_eq!(len, 0xffff_ffff);
                assert_eq!(max, MAX);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // zero-length frames are equally invalid
        let mut r = Cursor::new(0u32.to_be_bytes().to_vec());
        assert!(matches!(read_frame(&mut r, MAX), Err(FrameError::TooLarge { len: 0, .. })));
    }

    #[test]
    fn truncations_are_io_errors_not_panics() {
        // truncated mid-prefix
        let mut r = Cursor::new(vec![0x00, 0x00]);
        assert!(matches!(read_frame(&mut r, MAX), Err(FrameError::Io(_))));
        // truncated mid-payload
        let mut full = framed(br#"{"type":"ping"}"#);
        full.truncate(LEN_PREFIX_BYTES + 3);
        let mut r = Cursor::new(full);
        assert!(matches!(read_frame(&mut r, MAX), Err(FrameError::Io(_))));
    }

    #[test]
    fn garbage_payloads_are_typed_errors() {
        for bad in [&b"not json at all"[..], b"{\"unterminated\":", b"\xff\xfe\x00"] {
            let mut r = Cursor::new(framed(bad));
            assert!(
                matches!(read_frame(&mut r, MAX), Err(FrameError::BadJson(_))),
                "payload {bad:?}"
            );
        }
    }

    #[test]
    fn writer_refuses_oversized_frames() {
        let big = Json::Str("x".repeat(MAX));
        let mut buf = Vec::new();
        assert!(write_frame(&mut buf, &big, MAX).is_err());
        assert!(buf.is_empty(), "nothing hit the wire");
    }

    #[test]
    fn timeout_detection_covers_both_unix_and_windows_kinds() {
        for kind in [ErrorKind::WouldBlock, ErrorKind::TimedOut] {
            assert!(FrameError::Io(std::io::Error::from(kind)).is_timeout());
        }
        assert!(!FrameError::Io(std::io::Error::from(ErrorKind::BrokenPipe)).is_timeout());
        assert!(!FrameError::Closed.is_timeout());
    }
}
