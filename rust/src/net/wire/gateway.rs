//! The TCP gateway: accepts connections and feeds remote queries into
//! the in-process [`Service`] — so priority-lane admission, deadline
//! shedding, the semantic query cache, and per-lane metrics apply to
//! wire traffic exactly as they do to in-process callers.
//!
//! Threading model: one accept thread plus one handler thread per live
//! connection (the protocol is strictly request/response per
//! connection, so a handler is either blocked reading the next frame or
//! executing one query inside [`Service::call`]).  The connection
//! budget bounds handler count; accepts beyond it are answered with a
//! typed `busy` error and closed — never queued, never dropped
//! silently.
//!
//! Failure containment: every per-connection failure (malformed frame,
//! oversized length prefix, handshake mismatch, socket error, idle
//! timeout) ends at most that one connection.  The accept loop and
//! every other handler keep serving; nothing panics across a socket.
//!
//! Shutdown is two-phase so durable memory can flush *after* the wire
//! is quiet: [`Gateway::shutdown`] first stops the accept loop, then
//! half-closes every live socket's read side — a handler blocked
//! between frames wakes to a clean EOF, while a handler mid-query still
//! writes its response before it sees the EOF.  Only after every
//! handler has exited does the caller tear down the service (draining
//! the lanes) and flush the fabric.

use std::collections::{BTreeMap, HashMap};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::WireConfig;
use crate::obs::{stage, Span, TraceId};
use crate::server::Service;
use crate::util::sync::{ranks, OrderedCondvar, OrderedMutex};

use super::frame::{read_frame, write_frame, write_frame_text, FrameError};
use super::ingest::IngestHub;
use super::proto::{ClientMsg, ServerMsg, WireError, PROTOCOL_VERSION};

/// Monotone wire-level traffic counters (connection plane only — query
/// accounting lives in the service's per-lane [`crate::server::Metrics`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// connections admitted past the budget check
    pub accepted_conns: u64,
    /// connections answered with `busy` and closed at accept time
    pub refused_conns: u64,
    /// connections that ended on a protocol violation (bad frame, bad
    /// message, handshake mismatch)
    pub protocol_errors: u64,
    /// connections that ended on an idle read timeout
    pub idle_timeouts: u64,
    /// handler threads that panicked while serving a connection — the
    /// panic is caught at the `conn_loop` boundary so it ends only that
    /// connection (never the gateway, never a poisoned registry)
    pub handler_panics: u64,
    /// admitted connections that have fully ended (any reason)
    pub closed_conns: u64,
}

impl WireStats {
    /// Admitted connections still live.
    pub fn open_conns(&self) -> u64 {
        self.accepted_conns.saturating_sub(self.closed_conns)
    }

    pub fn render(&self) -> String {
        format!(
            "wire: {} conns accepted ({} open) / {} refused at budget / {} protocol errors / {} idle timeouts / {} handler panics",
            self.accepted_conns,
            self.open_conns(),
            self.refused_conns,
            self.protocol_errors,
            self.idle_timeouts,
            self.handler_panics,
        )
    }
}

/// The shutdown request signal, deliberately its OWN allocation: a
/// [`ShutdownHandle`] held by a long-lived thread (a stdin watcher)
/// must not pin [`Shared`] — and through it the `Arc<Service>` — alive
/// past [`Gateway::shutdown`], or the caller could never unwrap the
/// service to drain and flush it.
struct ShutdownSignal {
    flag: OrderedMutex<bool>,
    cv: OrderedCondvar,
}

impl Default for ShutdownSignal {
    fn default() -> Self {
        Self {
            flag: OrderedMutex::new(ranks::WIRE_SHUTDOWN_SIGNAL, false),
            cv: OrderedCondvar::new(),
        }
    }
}

impl ShutdownSignal {
    fn request(&self) {
        *self.flag.lock() = true;
        self.cv.notify_all();
    }

    fn requested(&self) -> bool {
        *self.flag.lock()
    }

    fn wait(&self) {
        let mut flag = self.flag.lock();
        while !*flag {
            flag = self.cv.wait(flag);
        }
    }
}

struct Shared {
    service: Arc<Service>,
    /// Push-ingest state (per-stream sessions + the shared embed pool);
    /// `None` on query-only gateways — ingest envelopes are then a
    /// typed protocol error.
    hub: Option<Arc<IngestHub>>,
    cfg: WireConfig,
    /// accept-loop gate: false once shutdown begins
    accepting: AtomicBool,
    /// set by a remote `Shutdown` message or `request_shutdown`
    signal: Arc<ShutdownSignal>,
    /// live handler registry: socket clones for the half-close nudge
    conns: OrderedMutex<HashMap<u64, TcpStream>>,
    /// refusal threads currently parked reading a hello (bounded)
    refusals: std::sync::atomic::AtomicUsize,
    next_conn: AtomicU64,
    next_session: AtomicU64,
    stats: OrderedMutex<WireStats>,
    /// test hook: the next query served panics mid-handler (one-shot).
    /// Exercises the catch-unwind containment path end to end.
    panic_next_query: AtomicBool,
}

/// A running TCP gateway over one [`Service`].
pub struct Gateway {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    handlers: Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
}

/// A cheap cloneable handle that can request gateway shutdown from
/// another thread (e.g. a stdin watcher) while the main thread blocks
/// in [`Gateway::wait_for_shutdown_request`].
#[derive(Clone)]
pub struct ShutdownHandle {
    signal: Arc<ShutdownSignal>,
}

impl ShutdownHandle {
    /// Same effect as a remote `Shutdown` message.
    pub fn request(&self) {
        self.signal.request();
    }
}

impl Gateway {
    /// Bind `cfg.listen` (port 0 = ephemeral) and start accepting.
    /// The gateway holds its own handle to the service; the caller keeps
    /// one too and tears the service down *after* [`Gateway::shutdown`].
    pub fn start(cfg: &WireConfig, service: Arc<Service>) -> Result<Self> {
        Self::start_with(cfg, service, None)
    }

    /// [`Gateway::start`] plus an optional ingest hub: with `Some`,
    /// camera connections can push frames (`ingest_open`/`ingest_frames`)
    /// and `stats` replies carry the live [`IngestSnapshot`]
    /// (`crate::server::IngestSnapshot`) gauges.
    pub fn start_with(
        cfg: &WireConfig,
        service: Arc<Service>,
        hub: Option<Arc<IngestHub>>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding wire listener on {}", cfg.listen))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            service,
            hub,
            cfg: cfg.clone(),
            accepting: AtomicBool::new(true),
            signal: Arc::new(ShutdownSignal::default()),
            conns: OrderedMutex::new(ranks::WIRE_CONNS, HashMap::new()),
            refusals: std::sync::atomic::AtomicUsize::new(0),
            next_conn: AtomicU64::new(0),
            next_session: AtomicU64::new(0),
            stats: OrderedMutex::new(ranks::WIRE_STATS, WireStats::default()),
            panic_next_query: AtomicBool::new(false),
        });
        let handlers = Arc::new(OrderedMutex::new(ranks::WIRE_HANDLERS, Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::spawn(move || accept_loop(listener, shared, handlers))
        };
        Ok(Self { local_addr, shared, accept: Some(accept), handlers })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Wire-level traffic counters.
    pub fn stats(&self) -> WireStats {
        *self.shared.stats.lock()
    }

    /// Test hook: make the NEXT query served by any handler panic
    /// mid-request.  One-shot; exists so the integration suite can prove
    /// a panicking handler ends only its own connection (see
    /// [`WireStats::handler_panics`]).
    #[doc(hidden)]
    pub fn inject_handler_panic(&self) {
        self.shared.panic_next_query.store(true, Ordering::SeqCst);
    }

    /// Ask the gateway to stop (same effect as a remote `Shutdown`
    /// message): wakes [`Gateway::wait_for_shutdown_request`] waiters.
    pub fn request_shutdown(&self) {
        self.shared.signal.request();
    }

    /// A handle other threads can use to request shutdown.  It holds
    /// only the signal — never the service — so a forgotten handle (a
    /// stdin watcher parked on a read) cannot keep the service alive
    /// after [`Gateway::shutdown`].
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { signal: Arc::clone(&self.shared.signal) }
    }

    /// Has anyone (remote client or local caller) requested shutdown?
    pub fn shutdown_requested(&self) -> bool {
        self.shared.signal.requested()
    }

    /// Block until a shutdown request arrives (remote `Shutdown` message
    /// or [`Gateway::request_shutdown`]).
    pub fn wait_for_shutdown_request(&self) {
        self.shared.signal.wait();
    }

    /// Stop accepting, let in-flight queries finish, join every thread,
    /// and return the final wire counters.  After this returns the wire
    /// is quiet: the caller can tear down the service (draining the
    /// lanes) and flush durable memory with nothing racing them.
    pub fn shutdown(mut self) -> WireStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        let accept = match self.accept.take() {
            Some(h) => h,
            None => return,
        };
        self.shared.accepting.store(false, Ordering::SeqCst);
        self.shared.signal.request();
        // the accept loop is blocked in accept(): nudge it with a
        // throwaway connection so it observes the closed gate.  A
        // wildcard bind (0.0.0.0 / ::) is not self-connectable on every
        // platform — rewrite to loopback first
        let mut nudge = self.local_addr;
        if nudge.ip().is_unspecified() {
            nudge.set_ip(match nudge.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let nudged = TcpStream::connect_timeout(&nudge, Duration::from_millis(250)).is_ok();
        if nudged {
            let _ = accept.join();
        } else {
            // self-connect blocked (hairpin-filtered interface, odd
            // network policy): detaching the parked accept thread is
            // better than wedging shutdown — the gate is closed, so it
            // drops any later connection and exits; meanwhile the lane
            // drain and durable flush below still happen
            drop(accept);
        }
        // half-close every live socket's read side: handlers blocked
        // between frames wake to a clean EOF; a handler mid-query still
        // writes its response first
        for stream in self.shared.conns.lock().values() {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        let handles: Vec<JoinHandle<()>> = self.handlers.lock().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Dropping a gateway without an explicit [`Gateway::shutdown`] (error
/// paths, test teardown) must not leak blocked threads.
impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    handlers: Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let (mut stream, _peer) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) if !shared.accepting.load(Ordering::SeqCst) => break,
            Err(_) => {
                // transient accept failure (fd pressure): back off instead
                // of spinning hot; the gate is re-checked next iteration
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if !shared.accepting.load(Ordering::SeqCst) {
            break; // the shutdown nudge (or a late client): drop it
        }
        // socket options + budget check happen here so the handler
        // thread only ever exists for admitted connections
        let cfg = &shared.cfg;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(cfg.write_timeout_ms)));
        {
            let mut st = shared.stats.lock();
            if st.open_conns() >= cfg.max_conns as u64 {
                st.refused_conns += 1;
                drop(st);
                refuse(&shared, &handlers, stream);
                continue;
            }
            st.accepted_conns += 1;
        }
        let conn_id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
        match stream.try_clone() {
            Ok(clone) => {
                shared.conns.lock().insert(conn_id, clone);
            }
            Err(_) => {
                // fd pressure: a connection we cannot register for the
                // shutdown half-close is a connection we cannot reliably
                // wake — drop it now (rebalancing the open-conns gauge)
                // rather than risk stalling shutdown on it
                shared.stats.lock().closed_conns += 1;
                continue;
            }
        }
        let shared2 = Arc::clone(&shared);
        let handle = std::thread::spawn(move || {
            conn_loop(stream, conn_id, shared2);
        });
        let mut hs = handlers.lock();
        // opportunistic reap: finished handlers are joined here, not
        // accumulated for the gateway's whole lifetime
        hs.retain(|h| !h.is_finished());
        hs.push(handle);
    }
}

/// Concurrent refusal-thread bound: the polite busy reply is best
/// effort — a flood of silent excess connections gets dropped outright
/// rather than parking one thread each.
const MAX_REFUSAL_THREADS: usize = 8;

/// How long a refusal thread waits for the excess client's hello before
/// closing anyway (deliberately much shorter than the serving read
/// timeout — this thread exists only to deliver one busy frame).
const REFUSAL_READ_TIMEOUT: Duration = Duration::from_millis(1000);

/// Budget refusal: answered in a short-lived thread (bounded by
/// [`MAX_REFUSAL_THREADS`]) so the accept loop never blocks on a slow
/// peer; registered in the conn registry so shutdown's half-close nudge
/// reaches a silent one.
fn refuse(
    shared: &Arc<Shared>,
    handlers: &Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
    stream: TcpStream,
) {
    use std::sync::atomic::AtomicUsize;
    let refusals: &AtomicUsize = &shared.refusals;
    if refusals.fetch_add(1, Ordering::SeqCst) >= MAX_REFUSAL_THREADS {
        // over the refusal bound: drop without the polite reply — the
        // budget must bound total threads, not just serving handlers
        refusals.fetch_sub(1, Ordering::SeqCst);
        return;
    }
    let max_conns = shared.cfg.max_conns;
    let max_frame_bytes = shared.cfg.max_frame_bytes;
    let conn_id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
    if let Ok(clone) = stream.try_clone() {
        shared.conns.lock().insert(conn_id, clone);
    }
    let shared2 = Arc::clone(shared);
    let handle = std::thread::spawn(move || {
        refuse_conn(stream, max_conns, max_frame_bytes);
        shared2.conns.lock().remove(&conn_id);
        shared2.refusals.fetch_sub(1, Ordering::SeqCst);
    });
    handlers.lock().push(handle);
}

/// Read (and discard) the client's hello first so the busy reply is not
/// lost to a TCP reset when the socket closes with unread data still
/// buffered, then answer and close.
fn refuse_conn(stream: TcpStream, max_conns: usize, max_frame_bytes: usize) {
    let mut reader = DeadlineReader::new(&stream, REFUSAL_READ_TIMEOUT);
    let _ = read_frame(&mut reader, max_frame_bytes);
    let busy = ServerMsg::Error { error: WireError::Busy { max_conns } };
    let mut w = &stream;
    let _ = write_frame(&mut w, &busy.to_json(), max_frame_bytes);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Per-FRAME read deadline over a `TcpStream`.  A bare `SO_RCVTIMEO`
/// re-arms on every received byte, so a peer trickling one byte per
/// timeout window could hold a handler (and a `max_conns` slot)
/// forever.  This wrapper gives each frame one total budget: before
/// every recv it re-arms the socket timeout with the REMAINING budget,
/// so a frame either completes or times out within ~one budget.
struct DeadlineReader<'a> {
    stream: &'a TcpStream,
    budget: Duration,
    deadline: Option<Instant>,
}

impl<'a> DeadlineReader<'a> {
    fn new(stream: &'a TcpStream, budget: Duration) -> Self {
        Self { stream, budget, deadline: None }
    }

    /// Reset the budget (call before each frame).  The clock starts at
    /// the first recv, so idle time between frames is budgeted the same
    /// way as a slow frame.
    fn arm(&mut self) {
        self.deadline = None;
    }
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let deadline = *self.deadline.get_or_insert_with(|| Instant::now() + self.budget);
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
        }
        self.stream.set_read_timeout(Some(remaining.max(Duration::from_millis(1))))?;
        let mut s = self.stream;
        s.read(buf)
    }
}

/// Outcome classification for the connection's end-of-life accounting.
enum ConnEnd {
    Clean,
    ProtocolError,
    IdleTimeout,
}

fn conn_loop(stream: TcpStream, conn_id: u64, shared: Arc<Shared>) {
    // A panic inside the handler (a bug in query execution, or the
    // injected test panic) must end exactly one connection.  Without
    // this boundary the unwinding thread would die between the
    // accounting below and the registry cleanup — leaking the conn
    // entry, skewing the open-conns gauge, and (pre-`util::sync`)
    // poisoning every lock it held for the rest of the process.
    let end = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_conn(&stream, conn_id, &shared)
    }));
    {
        let mut st = shared.stats.lock();
        st.closed_conns += 1;
        match end {
            Ok(ConnEnd::Clean) => {}
            Ok(ConnEnd::ProtocolError) => st.protocol_errors += 1,
            Ok(ConnEnd::IdleTimeout) => st.idle_timeouts += 1,
            Err(_) => st.handler_panics += 1,
        }
    }
    shared.conns.lock().remove(&conn_id);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Best-effort error reply; the connection is closing either way.
fn send_error(stream: &TcpStream, error: WireError, max_frame_bytes: usize) {
    let msg = ServerMsg::Error { error };
    let mut w = stream;
    let _ = write_frame(&mut w, &msg.to_json(), max_frame_bytes);
}

fn serve_conn(stream: &TcpStream, conn_id: u64, shared: &Shared) -> ConnEnd {
    let max = shared.cfg.max_frame_bytes;
    let mut reader =
        DeadlineReader::new(stream, Duration::from_millis(shared.cfg.read_timeout_ms));
    let mut w = stream;

    // handshake: the first frame must be a version-matched Hello
    let hello = match read_frame(&mut reader, max) {
        Ok(v) => v,
        Err(FrameError::Closed) => return ConnEnd::Clean,
        Err(e) if e.is_timeout() => return ConnEnd::IdleTimeout,
        Err(FrameError::Io(_)) => return ConnEnd::Clean,
        Err(e) => {
            send_error(stream, WireError::Protocol(e.to_string()), max);
            return ConnEnd::ProtocolError;
        }
    };
    match ClientMsg::from_json(&hello) {
        Ok(ClientMsg::Hello { version }) if version == PROTOCOL_VERSION => {}
        Ok(ClientMsg::Hello { version }) => {
            let msg = format!(
                "protocol version {version} not supported (this server speaks {PROTOCOL_VERSION})"
            );
            send_error(stream, WireError::Protocol(msg), max);
            return ConnEnd::ProtocolError;
        }
        Ok(_) => {
            let msg = "first frame must be a hello".to_string();
            send_error(stream, WireError::Protocol(msg), max);
            return ConnEnd::ProtocolError;
        }
        Err(e) => {
            send_error(stream, WireError::Protocol(format!("{e:#}")), max);
            return ConnEnd::ProtocolError;
        }
    }
    let session = shared.next_session.fetch_add(1, Ordering::Relaxed);
    let ack = ServerMsg::HelloAck {
        version: PROTOCOL_VERSION,
        session,
        streams: shared.service.n_streams(),
    };
    if write_frame(&mut w, &ack.to_json(), max).is_err() {
        return ConnEnd::Clean;
    }

    // request/response loop
    loop {
        reader.arm(); // fresh per-frame budget
        let t_read = Instant::now();
        let frame = match read_frame(&mut reader, max) {
            Ok(v) => v,
            Err(FrameError::Closed) => return ConnEnd::Clean,
            Err(e) if e.is_timeout() => {
                let msg = format!("idle for over {} ms", shared.cfg.read_timeout_ms);
                send_error(stream, WireError::Protocol(msg), max);
                return ConnEnd::IdleTimeout;
            }
            Err(FrameError::Io(_)) => return ConnEnd::Clean,
            Err(e) => {
                send_error(stream, WireError::Protocol(e.to_string()), max);
                return ConnEnd::ProtocolError;
            }
        };
        let read_us = t_read.elapsed().as_micros() as u64;
        // traced query replies get their wire I/O appended post-hoc as
        // child spans (`gateway/read` before the trace was born at
        // offset 0, `gateway/write` after the write below completes)
        let mut io_trace: Option<(TraceId, u64)> = None;
        let reply = match ClientMsg::from_json(&frame) {
            Ok(ClientMsg::Query { request }) => {
                if shared.panic_next_query.swap(false, Ordering::SeqCst) {
                    std::panic::panic_any("injected handler panic (test hook)");
                }
                match shared.service.call(request) {
                    Ok(response) => {
                        if let Some(id) = response.trace_id {
                            io_trace = Some((id, (response.total_s() * 1e6) as u64));
                            shared.service.tracer.append_span(
                                id,
                                Span {
                                    stage: stage::GATEWAY_READ.into(),
                                    start_us: 0,
                                    dur_us: read_us,
                                    counters: BTreeMap::new(),
                                },
                            );
                        }
                        ServerMsg::Response { response }
                    }
                    Err(api) => ServerMsg::Error { error: WireError::Api(api) },
                }
            }
            Ok(ClientMsg::Trace { id, last, slow }) => {
                let tracer = &shared.service.tracer;
                let traces = match id {
                    Some(id) => tracer.lookup(id).into_iter().collect(),
                    None if slow => tracer.slow_recent(last),
                    None => tracer.recent(last),
                };
                ServerMsg::Trace { traces }
            }
            Ok(ClientMsg::MetricsText) => {
                let mut snapshot = shared.service.snapshot();
                if let Some(hub) = &shared.hub {
                    snapshot.ingest = Some(hub.snapshot());
                }
                let text =
                    crate::obs::prometheus_text(&snapshot, Some(shared.service.tracer.as_ref()));
                ServerMsg::MetricsText { text }
            }
            Ok(ClientMsg::Stats) => {
                let mut snapshot = shared.service.snapshot();
                if let Some(hub) = &shared.hub {
                    snapshot.ingest = Some(hub.snapshot());
                }
                ServerMsg::Stats { snapshot: Box::new(snapshot) }
            }
            Ok(ClientMsg::IngestOpen { stream: sid, frame_size, fps }) => {
                let hub = match &shared.hub {
                    Some(h) => h,
                    None => {
                        let msg = "ingest not enabled on this server".to_string();
                        send_error(stream, WireError::Protocol(msg), max);
                        return ConnEnd::ProtocolError;
                    }
                };
                match hub.open(sid, frame_size, fps, conn_id) {
                    Ok(next_seq) => ServerMsg::IngestOpenAck { stream: sid, next_seq },
                    Err(e) => {
                        send_error(stream, WireError::Protocol(format!("{e:#}")), max);
                        return ConnEnd::ProtocolError;
                    }
                }
            }
            Ok(ClientMsg::IngestFrames { stream: sid, frames }) => {
                let hub = match &shared.hub {
                    Some(h) => h,
                    None => {
                        let msg = "ingest not enabled on this server".to_string();
                        send_error(stream, WireError::Protocol(msg), max);
                        return ConnEnd::ProtocolError;
                    }
                };
                match hub.push_batch(sid, conn_id, &frames) {
                    Ok((high_watermark, backpressure)) => {
                        ServerMsg::IngestAck { stream: sid, high_watermark, backpressure }
                    }
                    Err(e) => {
                        // the connection dies, the SESSION does not: the
                        // camera re-opens and resumes from the watermark
                        send_error(stream, WireError::Protocol(format!("{e:#}")), max);
                        return ConnEnd::ProtocolError;
                    }
                }
            }
            Ok(ClientMsg::Ping) => ServerMsg::Pong,
            Ok(ClientMsg::Shutdown) => {
                let _ = write_frame(&mut w, &ServerMsg::ShutdownAck.to_json(), max);
                shared.signal.request();
                return ConnEnd::Clean;
            }
            Ok(ClientMsg::Hello { .. }) => {
                let msg = "duplicate hello after handshake".to_string();
                send_error(stream, WireError::Protocol(msg), max);
                return ConnEnd::ProtocolError;
            }
            Err(e) => {
                send_error(stream, WireError::Protocol(format!("{e:#}")), max);
                return ConnEnd::ProtocolError;
            }
        };
        // an oversized reply is OUR problem, not the peer's — but it
        // still gets the typed error (nothing was written, so the frame
        // stream is in sync to carry it) and then the documented
        // protocol-error close, which is also what clients expect
        let payload = reply.to_json().to_string();
        if payload.len() > max {
            let msg = format!(
                "reply of {} bytes exceeds the {max}-byte frame bound \
                 (raise [wire] max_frame_bytes or lower the query budget)",
                payload.len()
            );
            send_error(stream, WireError::Protocol(msg), max);
            return ConnEnd::ProtocolError;
        }
        let t_write = Instant::now();
        if write_frame_text(&mut w, &payload, max).is_err() {
            return ConnEnd::Clean; // peer gone mid-write
        }
        if let Some((id, start_us)) = io_trace {
            let mut counters = BTreeMap::new();
            counters.insert("bytes".to_string(), payload.len() as f64);
            shared.service.tracer.append_span(
                id,
                Span {
                    stage: stage::GATEWAY_WRITE.into(),
                    start_us,
                    dur_us: t_write.elapsed().as_micros() as u64,
                    counters,
                },
            );
        }
    }
}
