//! The wire-ingest hub: server-side state for push-style camera ingest.
//!
//! One hub serves every camera connection on a gateway.  Each opened
//! stream gets a [`crate::ingest::Pipeline`] front-end attached to the
//! hub's ONE shared [`EmbedPool`] — so frames arriving over different
//! TCP connections coalesce into full MEM batches exactly like the
//! in-process multi-camera path coalesces across streams.
//!
//! Three properties the protocol rests on:
//!
//!  * **Server-authoritative sequencing.**  `ingest_open` answers with
//!    the stream's durable frame count as `next_seq`; a reconnecting
//!    camera resumes from the ack, not from local history, so a dropped
//!    connection can neither duplicate nor silently lose frames against
//!    a durable fabric.  Within a connection, batches must be exactly
//!    contiguous from the watermark — anything else is a protocol error
//!    (the camera re-opens and resumes).
//!  * **Sessions outlive connections.**  The per-stream session (its
//!    pipeline, watermark, counters) survives a dropped socket;
//!    re-opening steals ownership (the newest connection is the
//!    reconnecting camera), and a late batch from the stale connection
//!    is a protocol error instead of interleaved corruption.
//!  * **Typed backpressure from an admission controller.**  Ingest
//!    yields to the Interactive query lane while queries are queued, but
//!    is never starved past `[ingest] staleness_bound_ms`: once the
//!    stream's capture→queryable lag exceeds the bound, batches are
//!    admitted regardless of query pressure.  Yielding is either
//!    `SlowDown{delay_ms}` (batch accepted, camera paces down — nothing
//!    lost) or `Dropped{from_seq,count}` (batch shed whole, watermark
//!    advanced past the hole) per `[ingest] drop_policy`.
//!
//! Lock order: the stream registry ([`ranks::WIRE_INGEST_STREAMS`]) is
//! released before the per-stream session lock
//! ([`ranks::WIRE_INGEST_SESSION`]) does any work; the session lock is
//! held across `Pipeline::push_frame`, which takes its shard's write
//! guard (shard band) — all strictly ascending.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::api::Priority;
use crate::config::{IngestConfig, VenusConfig};
use crate::ingest::{EmbedPool, IngestStats, Pipeline};
use crate::obs::{stage, Tracer};
use crate::memory::MemoryFabric;
use crate::server::{IngestSnapshot, IngestStreamSnapshot, Metrics};
use crate::util::b64;
use crate::util::stats::Samples;
use crate::util::sync::{ranks, OrderedMutex};
use crate::video::frame::Frame;

use super::proto::{Backpressure, IngestFrame};

/// Milliseconds since the unix epoch (the freshness clock cameras stamp
/// their frames against).
pub fn unix_ms_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// One stream's wire-ingest session.  Lives under its own lock so slow
/// work on one stream (a `push_frame` blocked on embed backpressure)
/// never stalls batches, opens, or snapshots on other streams.
struct StreamSession {
    /// `None` until the first `ingest_open` attaches the pipeline.
    pipeline: Option<Pipeline>,
    /// The connection currently allowed to push (newest open wins).
    owner_conn: u64,
    /// Declared pixel geometry (side length); re-opens must match.
    frame_size: usize,
    /// Next expected sequence number == durable high-watermark.
    next_seq: u64,
    accepted: u64,
    dropped: u64,
    slowed: u64,
    /// Partition-submission watermark already recorded into `pending`.
    recorded_submissions: usize,
    /// (submission index, capture unix-ms of the frame that sealed it)
    /// for partitions submitted to the pool but not yet completed.
    pending: VecDeque<(usize, u64)>,
    /// Capture→queryable latency samples, milliseconds.
    freshness: Samples,
    /// Capture-ms of the newest QUERYABLE frame (stream-open time until
    /// the first partition completes) — the admission controller's
    /// staleness anchor.
    freshness_anchor_ms: u64,
}

struct StreamEntry {
    session: OrderedMutex<StreamSession>,
}

/// The per-batch admission verdict, before it is rendered into a
/// [`Backpressure`] reply.
enum Admission {
    Proceed,
    Yield,
}

/// Server-side ingest state shared by every gateway connection.
pub struct IngestHub {
    cfg: IngestConfig,
    fabric: Arc<MemoryFabric>,
    metrics: Arc<Metrics>,
    pool: EmbedPool,
    streams: OrderedMutex<HashMap<u16, Arc<StreamEntry>>>,
    /// `Some` when the co-located service's tracer should head-sample
    /// ingest batches alongside queries (wired by `venus serve` via
    /// [`IngestHub::with_tracer`]); `None` leaves ingest untraced.
    tracer: Option<Arc<Tracer>>,
}

impl IngestHub {
    /// Build a hub over `fabric` with its own shared embed pool of
    /// `workers` workers.  `metrics` must be the serving metrics of the
    /// co-located [`crate::server::Service`] — the admission controller
    /// reads the Interactive lane's live queue depth from it.
    pub fn new(
        cfg: &VenusConfig,
        fabric: Arc<MemoryFabric>,
        metrics: Arc<Metrics>,
        workers: usize,
    ) -> Result<Self> {
        let backend = crate::backend::shared_default()?;
        let pool = EmbedPool::start(
            backend,
            cfg.ingest.aux_models,
            workers.max(1),
            cfg.ingest.queue_capacity,
        )
        .context("starting the wire-ingest embed pool")?;
        Ok(Self {
            cfg: cfg.ingest.clone(),
            fabric,
            metrics,
            pool,
            streams: OrderedMutex::new(ranks::WIRE_INGEST_STREAMS, HashMap::new()),
            tracer: None,
        })
    }

    /// Attach the serving tracer so sampled ingest batches publish
    /// `ingest_decode`/`ingest_push` span trees (kind `"ingest"`) into
    /// the same rings the query traces land in.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Handle `ingest_open`: attach (or re-claim) the stream and return
    /// the authoritative next sequence number.  Errors are protocol
    /// errors — the gateway replies typed and closes the connection.
    pub fn open(&self, stream: u16, frame_size: usize, fps: f64, conn_id: u64) -> Result<u64> {
        if (stream as usize) >= self.fabric.n_streams() {
            bail!(
                "stream {stream} out of range: this fabric has {} stream(s)",
                self.fabric.n_streams()
            );
        }
        if frame_size == 0 || frame_size > 1024 {
            bail!("frame_size {frame_size} out of range (1..=1024)");
        }
        if !(fps.is_finite() && fps > 0.0) {
            bail!("fps must be a positive finite number, got {fps}");
        }
        let entry = self.entry(stream);
        let mut sess = entry.session.lock();
        if let Some(_pipe) = &sess.pipeline {
            // reconnect (or a second camera racing for the stream): the
            // newest open wins; geometry is part of the stream's identity
            if sess.frame_size != frame_size {
                bail!(
                    "stream {stream} is open with frame_size {} (got {frame_size})",
                    sess.frame_size
                );
            }
        } else {
            let shard = Arc::clone(&self.fabric.shards()[stream as usize]);
            let next_seq = shard.read().frames_ingested();
            let pipeline = Pipeline::attach(&self.cfg, fps, &self.pool, shard)
                .with_context(|| format!("attaching ingest pipeline for stream {stream}"))?;
            sess.pipeline = Some(pipeline);
            sess.frame_size = frame_size;
            sess.next_seq = next_seq;
            sess.freshness_anchor_ms = unix_ms_now();
        }
        sess.owner_conn = conn_id;
        Ok(sess.next_seq)
    }

    /// Handle one `ingest_frames` batch: validate, admit or shed, and
    /// return `(high_watermark, verdict)` for the `ingest_ack`.  Errors
    /// are protocol errors (the connection is closed; the session and
    /// its watermark survive for the reconnect).
    pub fn push_batch(
        &self,
        stream: u16,
        conn_id: u64,
        frames: &[IngestFrame],
    ) -> Result<(u64, Backpressure)> {
        let entry = match self.streams.lock().get(&stream) {
            Some(e) => Arc::clone(e),
            None => bail!("stream {stream} not opened (send ingest_open first)"),
        };
        let mut sess = entry.session.lock();
        if sess.pipeline.is_none() {
            bail!("stream {stream} not opened (send ingest_open first)");
        }
        if sess.owner_conn != conn_id {
            bail!(
                "stream {stream} was re-opened by another connection; \
                 this connection's ingest lease is stale"
            );
        }
        if frames.is_empty() {
            bail!("empty ingest_frames batch");
        }
        if frames.len() > self.cfg.max_batch_frames {
            bail!(
                "batch of {} frames exceeds [ingest] max_batch_frames = {}",
                frames.len(),
                self.cfg.max_batch_frames
            );
        }
        for (i, f) in frames.iter().enumerate() {
            let want = sess.next_seq + i as u64;
            if f.seq != want {
                bail!(
                    "out-of-order batch on stream {stream}: frame {i} has seq {} \
                     but the watermark expects {want} (re-open to resume)",
                    f.seq
                );
            }
        }
        let mut trace = self
            .tracer
            .as_ref()
            .and_then(|t| t.mint("ingest", &format!("stream {stream} x{}", frames.len())));
        let t_decode = Instant::now();
        // decode before the admission decision: a malformed payload is a
        // protocol error regardless of whether the batch would be shed
        let size = sess.frame_size;
        let want_len = size * size * 3;
        let mut decoded = Vec::with_capacity(frames.len());
        for f in frames {
            let data = b64::decode_f32s(&f.data_b64)
                .with_context(|| format!("frame seq {}: bad pixel payload", f.seq))?;
            if data.len() != want_len {
                bail!(
                    "frame seq {}: {} floats, expected {want_len} \
                     ({size}x{size}x3 for the declared frame_size)",
                    f.seq,
                    data.len(),
                );
            }
            decoded.push(Frame::from_data(sess.frame_size, data));
        }
        if let Some(tc) = trace.as_mut() {
            tc.record_counters(
                stage::INGEST_DECODE,
                t_decode,
                t_decode.elapsed(),
                &[("frames", frames.len() as f64)],
            );
        }

        let now_ms = unix_ms_now();
        let t_push = Instant::now();
        let verdict = match self.admit(&sess, now_ms) {
            Admission::Proceed => {
                self.apply(&mut sess, frames, &decoded)?;
                Backpressure::None
            }
            Admission::Yield if self.cfg.drop_policy == "drop" => {
                // shed whole: the watermark advances past the hole (the
                // archive tolerates gaps), the camera learns exactly what
                // was lost and resumes from the ack
                let from_seq = sess.next_seq;
                let count = frames.len() as u64;
                sess.next_seq += count;
                sess.dropped += count;
                Backpressure::Dropped { from_seq, count }
            }
            Admission::Yield => {
                // slowdown policy: nothing is lost — the batch lands, the
                // camera paces down
                self.apply(&mut sess, frames, &decoded)?;
                sess.slowed += 1;
                Backpressure::SlowDown { delay_ms: self.cfg.slowdown_ms }
            }
        };
        Self::poll_freshness(&mut sess, unix_ms_now());
        if let Some(mut tc) = trace {
            let dropped = match &verdict {
                Backpressure::Dropped { count, .. } => *count as f64,
                _ => 0.0,
            };
            tc.record_counters(
                stage::INGEST_PUSH,
                t_push,
                t_push.elapsed(),
                &[("frames", frames.len() as f64), ("dropped", dropped)],
            );
            if let Some(tr) = &self.tracer {
                let total = tc.started().elapsed();
                tr.finish(tc, total);
            }
        }
        Ok((sess.next_seq, verdict))
    }

    /// Per-stream counters + freshness tails + pool gauges, for the
    /// `stats` wire reply and `venus serve` shutdown output.
    pub fn snapshot(&self) -> IngestSnapshot {
        let mut entries: Vec<(u16, Arc<StreamEntry>)> = self
            .streams
            .lock()
            .iter()
            .map(|(id, e)| (*id, Arc::clone(e)))
            .collect();
        entries.sort_by_key(|(id, _)| *id);
        let now_ms = unix_ms_now();
        let streams = entries
            .iter()
            .map(|(id, e)| {
                let mut sess = e.session.lock();
                Self::poll_freshness(&mut sess, now_ms);
                let pct = |q: f64| {
                    if sess.freshness.is_empty() {
                        None
                    } else {
                        Some(sess.freshness.percentile(q))
                    }
                };
                IngestStreamSnapshot {
                    stream: *id,
                    accepted: sess.accepted,
                    acked: sess.next_seq,
                    dropped: sess.dropped,
                    slowed: sess.slowed,
                    freshness_p50_ms: pct(50.0),
                    freshness_p95_ms: pct(95.0),
                }
            })
            .collect();
        let pool = self.pool.gauges().snapshot();
        IngestSnapshot {
            streams,
            pool_queue_depth: pool.queue_depth,
            pool_batches: pool.batches,
            pool_mean_batch_clusters: pool.mean_batch_clusters,
            pool_max_batch_clusters: pool.max_batch_clusters,
        }
    }

    /// Close every stream: flush open partitions and wait for the pool
    /// to drain them, returning per-stream ingest statistics.  Call
    /// AFTER the gateway is down (no connection can race new batches in)
    /// and BEFORE the fabric flush (so the WAL tail covers every
    /// acknowledged frame).
    pub fn finish_all(&self) -> Result<Vec<(u16, IngestStats)>> {
        let mut entries: Vec<(u16, Arc<StreamEntry>)> =
            self.streams.lock().drain().collect();
        entries.sort_by_key(|(id, _)| *id);
        let mut out = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for (id, e) in entries {
            let pipeline = e.session.lock().pipeline.take();
            if let Some(p) = pipeline {
                match p.finish() {
                    Ok(stats) => out.push((id, stats)),
                    Err(err) => {
                        let err = err.context(format!("finishing ingest stream {id}"));
                        first_err.get_or_insert(err);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn entry(&self, stream: u16) -> Arc<StreamEntry> {
        let mut reg = self.streams.lock();
        let e = reg.entry(stream).or_insert_with(|| {
            Arc::new(StreamEntry {
                session: OrderedMutex::new(ranks::WIRE_INGEST_SESSION, StreamSession {
                    pipeline: None,
                    owner_conn: u64::MAX,
                    frame_size: 0,
                    next_seq: 0,
                    accepted: 0,
                    dropped: 0,
                    slowed: 0,
                    recorded_submissions: 0,
                    pending: VecDeque::new(),
                    freshness: Samples::default(),
                    freshness_anchor_ms: 0,
                }),
            })
        });
        Arc::clone(e)
    }

    /// The admission controller: yield to queued Interactive queries,
    /// but never past the staleness bound.
    fn admit(&self, sess: &StreamSession, now_ms: u64) -> Admission {
        let queued = self.metrics.queued_depth(Priority::Interactive);
        if queued <= self.cfg.yield_queue_depth as u64 {
            return Admission::Proceed;
        }
        let lag_ms = now_ms.saturating_sub(sess.freshness_anchor_ms);
        if lag_ms >= self.cfg.staleness_bound_ms {
            // starvation guard: this stream's queryable view is already
            // at the bound — admit regardless of query pressure
            return Admission::Proceed;
        }
        Admission::Yield
    }

    /// Push an admitted batch through the pipeline, recording partition
    /// submissions for the freshness ledger as they happen.
    fn apply(
        &self,
        sess: &mut StreamSession,
        frames: &[IngestFrame],
        decoded: &[Frame],
    ) -> Result<()> {
        for (f, frame) in frames.iter().zip(decoded) {
            let pipe = match sess.pipeline.as_mut() {
                Some(p) => p,
                None => bail!("stream closed mid-batch"),
            };
            pipe.push_frame(f.seq, frame)
                .with_context(|| format!("ingesting frame seq {}", f.seq))?;
            sess.accepted += 1;
            sess.next_seq = f.seq + 1;
            let submitted = pipe.partitions_submitted();
            if submitted > sess.recorded_submissions {
                sess.recorded_submissions = submitted;
                sess.pending.push_back((submitted, f.captured_unix_ms));
            }
        }
        Ok(())
    }

    /// Drain the pending-partition ledger against the pool's completion
    /// counter: each completed partition yields one freshness sample and
    /// advances the staleness anchor.
    fn poll_freshness(sess: &mut StreamSession, now_ms: u64) {
        let done = match &sess.pipeline {
            Some(p) => p.partitions_completed(),
            None => return,
        };
        while let Some(&(idx, cap_ms)) = sess.pending.front() {
            if idx > done {
                break;
            }
            sess.pending.pop_front();
            sess.freshness.push(now_ms.saturating_sub(cap_ms) as f64);
            sess.freshness_anchor_ms = sess.freshness_anchor_ms.max(cap_ms);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VenusConfig;
    use crate::memory::{InMemoryRaw, RawStore};
    use crate::util::b64::encode_f32s;

    const SIZE: usize = 64;

    fn hub_with(mutate: impl FnOnce(&mut VenusConfig)) -> IngestHub {
        let mut cfg = VenusConfig::default();
        mutate(&mut cfg);
        let d = crate::backend::shared_default().unwrap().model().d_embed;
        let raws: Vec<Box<dyn RawStore>> = (0..2)
            .map(|_| Box::new(InMemoryRaw::new(SIZE)) as Box<dyn RawStore>)
            .collect();
        let fabric = Arc::new(MemoryFabric::new(&cfg.memory, d, raws).unwrap());
        IngestHub::new(&cfg, fabric, Arc::new(Metrics::default()), 1).unwrap()
    }

    fn wire_frame(seq: u64, shade: f32) -> IngestFrame {
        let f = Frame::filled(SIZE, [shade, 0.2, 0.2]);
        IngestFrame {
            seq,
            captured_unix_ms: unix_ms_now(),
            data_b64: encode_f32s(f.data()),
        }
    }

    fn batch(from: u64, n: u64) -> Vec<IngestFrame> {
        (from..from + n)
            .map(|s| wire_frame(s, (s % 8) as f32 / 8.0))
            .collect()
    }

    #[test]
    fn contiguous_batches_advance_the_watermark() {
        let hub = hub_with(|_| {});
        assert_eq!(hub.open(0, SIZE, 8.0, 1).unwrap(), 0);
        let (hw, bp) = hub.push_batch(0, 1, &batch(0, 4)).unwrap();
        assert_eq!(hw, 4);
        assert_eq!(bp, Backpressure::None);
        let (hw, _) = hub.push_batch(0, 1, &batch(4, 4)).unwrap();
        assert_eq!(hw, 8);
        let snap = hub.snapshot();
        assert_eq!(snap.streams.len(), 1);
        assert_eq!(snap.streams[0].accepted, 8);
        assert_eq!(snap.streams[0].acked, 8);
        hub.finish_all().unwrap();
    }

    #[test]
    fn protocol_violations_are_typed_errors() {
        let hub = hub_with(|c| c.ingest.max_batch_frames = 4);
        // frames before open
        assert!(hub.push_batch(0, 1, &batch(0, 1)).is_err());
        // unknown stream / bad geometry / bad fps
        assert!(hub.open(9, SIZE, 8.0, 1).is_err());
        assert!(hub.open(0, 0, 8.0, 1).is_err());
        assert!(hub.open(0, SIZE, f64::NAN, 1).is_err());
        hub.open(0, SIZE, 8.0, 1).unwrap();
        // geometry is part of the stream identity
        assert!(hub.open(0, SIZE / 2, 8.0, 1).is_err());
        // out-of-order sequence
        let err = hub.push_batch(0, 1, &batch(3, 2)).unwrap_err();
        assert!(format!("{err:#}").contains("out-of-order"), "{err:#}");
        // oversized batch
        assert!(hub.push_batch(0, 1, &batch(0, 5)).is_err());
        // ragged pixel payload
        let bad = vec![IngestFrame {
            seq: 0,
            captured_unix_ms: 0,
            data_b64: encode_f32s(&[0.5; 7]),
        }];
        assert!(hub.push_batch(0, 1, &bad).is_err());
        // the session survives every rejected batch
        let (hw, _) = hub.push_batch(0, 1, &batch(0, 4)).unwrap();
        assert_eq!(hw, 4);
        hub.finish_all().unwrap();
    }

    #[test]
    fn reopen_steals_ownership_and_resumes_the_sequence() {
        let hub = hub_with(|_| {});
        hub.open(1, SIZE, 8.0, 7).unwrap();
        hub.push_batch(1, 7, &batch(0, 3)).unwrap();
        // the reconnecting camera (new conn) resumes exactly at the watermark
        assert_eq!(hub.open(1, SIZE, 8.0, 8).unwrap(), 3);
        // ...and the stale connection's lease is gone
        let err = hub.push_batch(1, 7, &batch(3, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("stale"), "{err:#}");
        let (hw, _) = hub.push_batch(1, 8, &batch(3, 3)).unwrap();
        assert_eq!(hw, 6);
        let snap = hub.snapshot();
        assert_eq!(snap.streams[0].accepted, 6);
        assert_eq!(snap.streams[0].dropped, 0);
        hub.finish_all().unwrap();
    }

    #[test]
    fn admission_yields_under_query_pressure_but_not_past_staleness() {
        let hub = hub_with(|c| {
            c.ingest.drop_policy = "drop".into();
            c.ingest.yield_queue_depth = 0;
            c.ingest.staleness_bound_ms = 3_600_000; // effectively never stale
        });
        hub.open(0, SIZE, 8.0, 1).unwrap();
        // healthy lane: admitted
        let (_, bp) = hub.push_batch(0, 1, &batch(0, 2)).unwrap();
        assert_eq!(bp, Backpressure::None);
        // queued interactive query: the batch is shed whole, watermark
        // advances past the hole
        hub.metrics.on_accepted(Priority::Interactive);
        let (hw, bp) = hub.push_batch(0, 1, &batch(2, 2)).unwrap();
        assert_eq!(hw, 4);
        assert_eq!(bp, Backpressure::Dropped { from_seq: 2, count: 2 });
        // lane drains: admitted again, resuming AFTER the hole
        hub.metrics.on_dequeued(Priority::Interactive);
        let (hw, bp) = hub.push_batch(0, 1, &batch(4, 2)).unwrap();
        assert_eq!(hw, 6);
        assert_eq!(bp, Backpressure::None);
        let snap = hub.snapshot();
        assert_eq!(snap.streams[0].accepted, 4);
        assert_eq!(snap.streams[0].dropped, 2);
        hub.finish_all().unwrap();
    }

    #[test]
    fn slowdown_policy_accepts_while_pacing_and_staleness_overrides_yield() {
        let hub = hub_with(|c| {
            c.ingest.drop_policy = "slowdown".into();
            c.ingest.yield_queue_depth = 0;
            c.ingest.slowdown_ms = 40;
            c.ingest.staleness_bound_ms = 1;
        });
        hub.open(0, SIZE, 8.0, 1).unwrap();
        hub.metrics.on_accepted(Priority::Interactive);
        // no partition has completed yet and the anchor is stream-open
        // time; with a 1 ms bound the stream is already past staleness by
        // the time the batch arrives — the starvation guard admits it
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (_, bp) = hub.push_batch(0, 1, &batch(0, 2)).unwrap();
        assert_eq!(bp, Backpressure::None, "staleness bound must override the yield");
        let snap = hub.snapshot();
        assert_eq!(snap.streams[0].accepted, 2);
        assert_eq!(snap.streams[0].dropped, 0);
        hub.finish_all().unwrap();

        // fresh hub with a huge bound: the same pressure now slows the
        // camera down instead — accepted, nothing dropped, paced reply
        let hub = hub_with(|c| {
            c.ingest.drop_policy = "slowdown".into();
            c.ingest.yield_queue_depth = 0;
            c.ingest.slowdown_ms = 40;
            c.ingest.staleness_bound_ms = 3_600_000;
        });
        hub.open(0, SIZE, 8.0, 1).unwrap();
        hub.metrics.on_accepted(Priority::Interactive);
        let (hw, bp) = hub.push_batch(0, 1, &batch(0, 2)).unwrap();
        assert_eq!(hw, 2);
        assert_eq!(bp, Backpressure::SlowDown { delay_ms: 40 });
        let snap = hub.snapshot();
        assert_eq!(snap.streams[0].accepted, 2);
        assert_eq!(snap.streams[0].slowed, 1);
        hub.finish_all().unwrap();
    }

    #[test]
    fn traced_batches_publish_ingest_span_trees() {
        let tracer = Arc::new(Tracer::new(&crate::config::ObsConfig::default()));
        let hub = hub_with(|_| {}).with_tracer(Arc::clone(&tracer));
        hub.open(0, SIZE, 8.0, 1).unwrap();
        hub.push_batch(0, 1, &batch(0, 4)).unwrap();
        let recent = tracer.recent(1);
        assert_eq!(recent.len(), 1, "default sampling traces the batch");
        let t = &recent[0];
        assert_eq!(t.kind, "ingest");
        assert!(t.span(stage::INGEST_DECODE).is_some());
        let push = t.span(stage::INGEST_PUSH).expect("push span");
        assert_eq!(push.counters["frames"], 4.0);
        assert_eq!(push.counters["dropped"], 0.0);
        hub.finish_all().unwrap();
    }

    #[test]
    fn finish_all_drains_and_freshness_appears_after_completion() {
        let hub = hub_with(|c| c.ingest.max_partition_s = 0.5);
        hub.open(0, SIZE, 8.0, 1).unwrap();
        hub.open(1, SIZE, 8.0, 2).unwrap();
        for b in 0..8u64 {
            hub.push_batch(0, 1, &batch(b * 8, 8)).unwrap();
            hub.push_batch(1, 2, &batch(b * 8, 8)).unwrap();
        }
        let stats = hub.finish_all().unwrap();
        assert_eq!(stats.len(), 2);
        for (_, s) in &stats {
            assert_eq!(s.frames, 64);
            assert!(s.embedded > 0, "stream embedded nothing");
        }
        // after the drain, every submitted partition completed — the
        // pool's coalescing gauges saw the work
        let snap = hub.snapshot();
        assert!(snap.pool_batches > 0);
        assert_eq!(snap.pool_queue_depth, 0);
        // double finish is a no-op, not an error
        assert!(hub.finish_all().unwrap().is_empty());
    }
}
