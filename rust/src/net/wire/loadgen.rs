//! Multi-threaded open-loop load generator for the wire gateway.
//!
//! N client threads each own one connection and fire queries on a fixed
//! arrival schedule (the aggregate rate split evenly across clients).
//! Arrivals are *open-loop*: the schedule does not slow down because the
//! server is slow — if a response is still outstanding when the next
//! arrival comes due, the next send happens late and the lateness counts
//! into that query's latency.  Latency is therefore measured from the
//! *scheduled* arrival time, the standard correction for coordinated
//! omission: a saturated server shows its real tail, not the tail of a
//! politely waiting client.
//!
//! Every serving outcome is counted separately (completed / rejected /
//! deadline-shed / failed), so admission control and shedding behavior
//! under overload are first-class results, not noise.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::api::{ApiError, Priority, QueryRequest};
use crate::config::WireConfig;
use crate::util::stats::{fmt_duration, Samples};
use crate::util::sync::{ranks, OrderedMutex};

use super::client::WireClient;

/// One load-generation run's parameters.
#[derive(Clone, Debug)]
pub struct LoadGen {
    /// Gateway address ("host:port").
    pub addr: String,
    /// Concurrent client connections.
    pub clients: usize,
    /// Aggregate target arrival rate, queries/second, split evenly
    /// across clients.
    pub rate_qps: f64,
    /// Run length (measured from the first scheduled arrival).
    pub duration: Duration,
    /// Query texts, rotated round-robin across the arrival sequence.
    pub texts: Vec<String>,
    /// Fraction of arrivals sent on the interactive lane (the rest are
    /// batch), interleaved deterministically.
    pub interactive_share: f64,
    /// Optional per-query deadline (exercises shedding under overload).
    pub deadline: Option<Duration>,
    /// Client-side socket timeouts + frame bound.
    pub wire: WireConfig,
}

impl LoadGen {
    /// A small sane default aimed at `addr`; callers override fields.
    pub fn new(addr: impl Into<String>, texts: Vec<String>) -> Self {
        Self {
            addr: addr.into(),
            clients: 4,
            rate_qps: 32.0,
            duration: Duration::from_secs(5),
            texts,
            interactive_share: 0.5,
            deadline: None,
            wire: WireConfig::default(),
        }
    }

    /// Run the load: connect all clients, fire the schedule, merge the
    /// per-thread tallies.  Fails only if *no* client could connect or
    /// the generator is misconfigured; per-query failures are counted,
    /// not fatal.
    pub fn run(&self) -> Result<LoadReport> {
        anyhow::ensure!(self.clients > 0, "loadgen needs at least one client");
        anyhow::ensure!(self.rate_qps > 0.0, "loadgen rate must be positive");
        anyhow::ensure!(!self.texts.is_empty(), "loadgen needs at least one query text");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.interactive_share),
            "interactive_share must be a fraction in [0, 1], got {}",
            self.interactive_share
        );
        let interval = Duration::from_secs_f64(self.clients as f64 / self.rate_qps);
        let tallies: OrderedMutex<Vec<Tally>> =
            OrderedMutex::new(ranks::LOADGEN_TALLIES, Vec::new());
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..self.clients {
                let tallies = &tallies;
                scope.spawn(move || {
                    let tally = self.drive_client(c, interval, t0);
                    tallies.lock().push(tally);
                });
            }
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let mut report = LoadReport {
            clients: self.clients,
            target_qps: self.rate_qps,
            wall_s,
            ..LoadReport::default()
        };
        for tally in tallies.into_inner() {
            report.sent += tally.sent;
            report.completed += tally.completed;
            report.cache_hits += tally.cache_hits;
            report.rejected += tally.rejected;
            report.shed += tally.shed;
            report.failed += tally.failed;
            report.transport_errors += tally.transport_errors;
            for x in tally.latencies {
                report.latency.push(x);
            }
        }
        // one post-run stats round trip: the server's own lifetime
        // throughput (completions over uptime) rides along so the report
        // can show sustained-run QPS next to the server's view of itself
        if let Ok(mut probe) = WireClient::connect_with(self.addr.as_str(), &self.wire) {
            if let Ok(snap) = probe.stats() {
                report.server_qps = Some(snap.derived_qps());
            }
        }
        Ok(report)
    }

    /// One client thread: connect, then fire arrivals `c, c+K, c+2K, ...`
    /// of the global schedule (K = client count).
    fn drive_client(&self, c: usize, interval: Duration, t0: Instant) -> Tally {
        let mut tally = Tally::default();
        let mut client = match WireClient::connect_with(self.addr.as_str(), &self.wire) {
            Ok(client) => client,
            Err(_) => {
                tally.transport_errors += 1;
                return tally;
            }
        };
        // client c's first arrival is staggered by c sub-intervals so the
        // aggregate schedule is evenly spaced, not K-bursty
        let offset = interval.mul_f64(c as f64 / self.clients.max(1) as f64);
        let mut seq: u64 = 0;
        loop {
            let scheduled = t0 + offset + interval.mul_f64(seq as f64);
            let since_start = scheduled.saturating_duration_since(t0);
            if since_start >= self.duration {
                break;
            }
            let now = Instant::now();
            if let Some(wait) = scheduled.checked_duration_since(now) {
                std::thread::sleep(wait);
            }
            let request = self.request_for(c, seq);
            tally.sent += 1;
            match client.query(request) {
                Ok(Ok(response)) => {
                    tally.completed += 1;
                    if response.cache.is_hit() {
                        tally.cache_hits += 1;
                    }
                    // open-loop latency: from the *scheduled* arrival
                    tally.latencies.push(scheduled.elapsed().as_secs_f64());
                }
                Ok(Err(ApiError::Rejected { .. })) => tally.rejected += 1,
                Ok(Err(ApiError::DeadlineExceeded)) => tally.shed += 1,
                Ok(Err(_)) => tally.failed += 1,
                Err(_) => {
                    tally.transport_errors += 1;
                    break; // connection unusable: this client is done
                }
            }
            seq += 1;
        }
        tally
    }

    fn request_for(&self, c: usize, seq: u64) -> QueryRequest {
        let global = seq as usize * self.clients + c;
        let text = &self.texts[global % self.texts.len()];
        // deterministic priority interleave: arrival g is interactive iff
        // its position in a repeating 100-slot pattern is below the share
        let slot = (global % 100) as f64 / 100.0;
        let priority = if slot < self.interactive_share {
            Priority::Interactive
        } else {
            Priority::Batch
        };
        let mut request = QueryRequest::new(text.clone()).priority(priority);
        if let Some(d) = self.deadline {
            request = request.deadline(d);
        }
        request
    }
}

#[derive(Default)]
struct Tally {
    sent: u64,
    completed: u64,
    cache_hits: u64,
    rejected: u64,
    shed: u64,
    failed: u64,
    transport_errors: u64,
    latencies: Vec<f64>,
}

/// Merged result of one load-generation run.
#[derive(Debug, Default)]
pub struct LoadReport {
    pub clients: usize,
    pub target_qps: f64,
    pub wall_s: f64,
    pub sent: u64,
    pub completed: u64,
    /// completions served from the semantic query cache
    pub cache_hits: u64,
    /// admission-control rejections (lane full)
    pub rejected: u64,
    /// deadline-shed at dequeue
    pub shed: u64,
    /// engine/shutdown failures
    pub failed: u64,
    /// connect failures + dead connections
    pub transport_errors: u64,
    /// end-to-end wire latency of completed queries, seconds, measured
    /// from the scheduled arrival (coordinated-omission corrected)
    pub latency: Samples,
    /// the server's lifetime queries/second (completions over uptime)
    /// from a post-run stats probe; `None` if the probe failed
    pub server_qps: Option<f64>,
}

impl LoadReport {
    /// Sustained completion throughput over the run.
    pub fn qps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        let pct = |p: f64| {
            if self.latency.is_empty() {
                "n/a".to_string()
            } else {
                fmt_duration(self.latency.percentile(p))
            }
        };
        let server = match self.server_qps {
            Some(q) => format!(" | server lifetime {q:.1} q/s"),
            None => String::new(),
        };
        format!(
            "{} clients @ target {:.1} q/s: {} sent, {} ok ({} cache-hit) in {:.1}s -> {:.1} q/s sustained | wire p50 {} p95 {} p99 {} | {} rejected / {} shed / {} failed / {} transport{}",
            self.clients,
            self.target_qps,
            self.sent,
            self.completed,
            self.cache_hits,
            self.wall_s,
            self.qps(),
            pct(50.0),
            pct(95.0),
            pct(99.0),
            self.rejected,
            self.shed,
            self.failed,
            self.transport_errors,
            server,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn misconfiguration_is_rejected_before_connecting() {
        let mut lg = LoadGen::new("127.0.0.1:1", vec!["q".into()]);
        lg.clients = 0;
        assert!(lg.run().is_err());
        let mut lg = LoadGen::new("127.0.0.1:1", vec!["q".into()]);
        lg.rate_qps = 0.0;
        assert!(lg.run().is_err());
        let lg = LoadGen::new("127.0.0.1:1", Vec::new());
        assert!(lg.run().is_err());
        // a "50%" share typed as 50 must error, not skew the whole mix
        let mut lg = LoadGen::new("127.0.0.1:1", vec!["q".into()]);
        lg.interactive_share = 50.0;
        assert!(lg.run().is_err());
    }

    #[test]
    fn unreachable_server_counts_transport_errors_not_panics() {
        // port 1 is essentially never bound; every client fails to
        // connect and the run still returns a merged report
        let mut lg = LoadGen::new("127.0.0.1:1", vec!["q".into()]);
        lg.clients = 3;
        lg.duration = Duration::from_millis(50);
        let report = lg.run().unwrap();
        assert_eq!(report.transport_errors, 3);
        assert_eq!(report.sent, 0);
        assert_eq!(report.completed, 0);
        assert!(report.render().contains("3 transport"));
    }

    #[test]
    fn priority_interleave_follows_the_share() {
        let mut lg = LoadGen::new("x", vec!["q".into()]);
        lg.clients = 1;
        lg.interactive_share = 0.3;
        let interactive = (0..100)
            .filter(|&i| lg.request_for(0, i as u64).priority == Priority::Interactive)
            .count();
        assert_eq!(interactive, 30);
        lg.interactive_share = 0.0;
        assert_eq!(lg.request_for(0, 7).priority, Priority::Batch);
        lg.interactive_share = 1.0;
        assert_eq!(lg.request_for(0, 7).priority, Priority::Interactive);
    }
}
