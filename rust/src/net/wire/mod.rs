//! Wire serving — the TCP surface over the typed query protocol.
//!
//! PR 3 made the query protocol *typed* ([`crate::api`]); this module
//! makes it a *network protocol*, turning the edge box into the real
//! disaggregated serving endpoint of the paper's §III architecture:
//!
//!  * [`frame`] — length-prefixed JSON frames over any `Read`/`Write`
//!    pair, reusing the in-tree [`crate::util::json`] codec.  Every
//!    decode failure is a typed [`frame::FrameError`]; a malformed or
//!    oversized frame can fail one connection, never the process.
//!  * [`proto`] — the message envelopes: `Hello`/`HelloAck` (protocol
//!    version handshake + session-id assignment), `Query`/`Response`
//!    (the PR 3 [`crate::api::QueryRequest`]/[`crate::api::QueryResponse`]
//!    JSON encodings verbatim), `Stats` (a full
//!    [`crate::server::Snapshot`] incl. live lane queue-depth gauges),
//!    `Ping`/`Pong`, and `Shutdown` (remote graceful stop).
//!  * [`gateway`] — the multi-threaded accept loop: bounded connection
//!    budget, per-connection read/write timeouts, one handler thread per
//!    connection feeding [`crate::server::Service`] — so priority-lane
//!    admission, deadline shedding, and the semantic query cache apply
//!    to remote traffic unchanged.
//!  * [`client`] — the blocking [`WireClient`]: connect/handshake,
//!    query, stats, ping, remote shutdown; per-connection session
//!    history recorded with the same
//!    [`crate::api::SessionTurn`] type the in-process sessions use.
//!  * [`loadgen`] — a multi-threaded open-loop load generator (paced
//!    arrivals, coordinated-omission-corrected latency) behind the
//!    `wire_throughput` bench and `venus loadgen`.
//!  * [`ingest`] — the push-ingest hub (PR 8): per-stream sessions that
//!    outlive connections, server-authoritative sequence numbers,
//!    cross-connection batch coalescing through one shared
//!    [`crate::ingest::EmbedPool`], and an admission controller that
//!    yields to the Interactive lane under load without starving any
//!    stream past `[ingest] staleness_bound_ms`.
//!  * [`camera`] — the paced camera client: frame generation from the
//!    synthetic presets, typed-backpressure obedience, and
//!    reconnect-with-resume (`venus camera`).
//!
//! Surface: `venus serve --listen ADDR`, `venus query --connect ADDR`,
//! `venus loadgen --connect ADDR`, `venus camera --connect ADDR`, and
//! the `[wire]`/`[ingest]` config sections.  Protocol details:
//! DESIGN.md §Wire-Protocol and §Ingest-Wire.

pub mod camera;
pub mod client;
pub mod frame;
pub mod gateway;
pub mod ingest;
pub mod loadgen;
pub mod proto;

pub use camera::{Camera, CameraReport};
pub use client::WireClient;
pub use frame::{read_frame, write_frame, write_frame_text, FrameError};
pub use gateway::{Gateway, ShutdownHandle, WireStats};
pub use ingest::IngestHub;
pub use loadgen::{LoadGen, LoadReport};
pub use proto::{Backpressure, ClientMsg, IngestFrame, ServerMsg, WireError, PROTOCOL_VERSION};
