//! Wire serving — the TCP surface over the typed query protocol.
//!
//! PR 3 made the query protocol *typed* ([`crate::api`]); this module
//! makes it a *network protocol*, turning the edge box into the real
//! disaggregated serving endpoint of the paper's §III architecture:
//!
//!  * [`frame`] — length-prefixed JSON frames over any `Read`/`Write`
//!    pair, reusing the in-tree [`crate::util::json`] codec.  Every
//!    decode failure is a typed [`frame::FrameError`]; a malformed or
//!    oversized frame can fail one connection, never the process.
//!  * [`proto`] — the message envelopes: `Hello`/`HelloAck` (protocol
//!    version handshake + session-id assignment), `Query`/`Response`
//!    (the PR 3 [`crate::api::QueryRequest`]/[`crate::api::QueryResponse`]
//!    JSON encodings verbatim), `Stats` (a full
//!    [`crate::server::Snapshot`] incl. live lane queue-depth gauges),
//!    `Ping`/`Pong`, and `Shutdown` (remote graceful stop).
//!  * [`gateway`] — the multi-threaded accept loop: bounded connection
//!    budget, per-connection read/write timeouts, one handler thread per
//!    connection feeding [`crate::server::Service`] — so priority-lane
//!    admission, deadline shedding, and the semantic query cache apply
//!    to remote traffic unchanged.
//!  * [`client`] — the blocking [`WireClient`]: connect/handshake,
//!    query, stats, ping, remote shutdown; per-connection session
//!    history recorded with the same
//!    [`crate::api::SessionTurn`] type the in-process sessions use.
//!  * [`loadgen`] — a multi-threaded open-loop load generator (paced
//!    arrivals, coordinated-omission-corrected latency) behind the
//!    `wire_throughput` bench and `venus loadgen`.
//!
//! Surface: `venus serve --listen ADDR`, `venus query --connect ADDR`,
//! `venus loadgen --connect ADDR`, and the `[wire]` config section.
//! Protocol details: DESIGN.md §Wire-Protocol.

pub mod client;
pub mod frame;
pub mod gateway;
pub mod loadgen;
pub mod proto;

pub use client::WireClient;
pub use frame::{read_frame, write_frame, write_frame_text, FrameError};
pub use gateway::{Gateway, ShutdownHandle, WireStats};
pub use loadgen::{LoadGen, LoadReport};
pub use proto::{ClientMsg, ServerMsg, WireError, PROTOCOL_VERSION};
