//! Wire message envelopes: what travels inside the frames.
//!
//! Every message is a JSON object with a `"type"` tag.  Query traffic
//! reuses the PR 3 [`QueryRequest`]/[`QueryResponse`] JSON encodings
//! verbatim (they were wire-round-trip tested before a wire existed);
//! the control plane adds `hello`/`hello_ack` (version handshake +
//! session assignment), `stats` (a full serving [`Snapshot`]), `ping`/
//! `pong`, and `shutdown` (remote graceful stop).
//!
//! The ingest plane (PR 8) adds push-style envelopes: `ingest_open`
//! declares a stream plus its frame geometry and pacing, `ingest_frames`
//! carries a batch of sequence-numbered base64 frames, and every batch
//! is answered by `ingest_ack` carrying the stream's durable
//! high-watermark plus a typed [`Backpressure`] verdict (`SlowDown` vs
//! `Dropped` per the configured drop policy).  Sequence numbers are
//! server-authoritative: `ingest_open_ack` tells the camera exactly
//! which frame to send next, which is what makes reconnect-with-resume
//! duplicate-free against a durable fabric.
//!
//! Versioning rule: the handshake carries a single integer protocol
//! version; the gateway serves only its own version ([`PROTOCOL_VERSION`])
//! and answers anything else with a typed protocol error before any
//! query is accepted.  Encoding changes that break old clients must bump
//! the version (see DESIGN.md §Wire-Protocol).

use std::fmt;

use anyhow::{bail, Result};

use crate::api::{ApiError, QueryRequest, QueryResponse};
use crate::obs::{Trace, TraceId};
use crate::server::Snapshot;
use crate::util::json::Json;

/// The one protocol version this build speaks.
pub const PROTOCOL_VERSION: u32 = 1;

/// Decode a protocol version, rejecting values that don't fit a `u32`
/// instead of silently wrapping (2^32 + 1 must not pass the v1 check).
fn version_from(v: &Json) -> Result<u32> {
    let version = v.as_usize()?;
    if version > u32::MAX as usize {
        bail!("protocol version {version} out of range (max {})", u32::MAX);
    }
    Ok(version as u32)
}

/// Decode a stream id, rejecting values past the `u16` shard-id space.
fn stream_from(v: &Json) -> Result<u16> {
    let stream = v.as_usize()?;
    if stream > u16::MAX as usize {
        bail!("stream id {stream} out of range (max {})", u16::MAX);
    }
    Ok(stream as u16)
}

/// Decode a non-negative integer that must fit the 2^53 exactly-
/// representable band (sequence numbers, unix milliseconds, counts).
fn u64_from(v: &Json) -> Result<u64> {
    Ok(v.as_usize()? as u64)
}

/// One frame inside an [`ClientMsg::IngestFrames`] batch: its position
/// in the stream, the capture timestamp the freshness metric is measured
/// from, and the pixel payload (base64 over little-endian `f32` bytes —
/// bit-exact, see [`crate::util::b64`]).
#[derive(Clone, Debug, PartialEq)]
pub struct IngestFrame {
    pub seq: u64,
    pub captured_unix_ms: u64,
    pub data_b64: String,
}

impl IngestFrame {
    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("seq".into(), Json::Num(self.seq as f64));
        m.insert("captured_unix_ms".into(), Json::Num(self.captured_unix_ms as f64));
        m.insert("data".into(), Json::Str(self.data_b64.clone()));
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(IngestFrame {
            seq: u64_from(v.get("seq")?)?,
            captured_unix_ms: u64_from(v.get("captured_unix_ms")?)?,
            data_b64: v.get("data")?.as_str()?.to_string(),
        })
    }
}

/// The admission controller's per-batch verdict, carried in every
/// [`ServerMsg::IngestAck`].  `SlowDown` means the batch was accepted
/// but the camera must pace down; `Dropped` means the batch was shed
/// whole (the high-watermark advanced past it — the archive tolerates
/// the hole) and the camera must resume from the acked watermark.
#[derive(Clone, Debug, PartialEq)]
pub enum Backpressure {
    /// Healthy: keep the declared pace.
    None,
    /// Accepted, but interactive queries are contending for the embed
    /// backend — insert this delay before the next batch.
    SlowDown { delay_ms: u64 },
    /// Shed under the `drop` policy: `count` frames starting at
    /// `from_seq` were discarded without entering the pipeline.
    Dropped { from_seq: u64, count: u64 },
}

impl Backpressure {
    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            Backpressure::None => {
                m.insert("kind".into(), Json::Str("none".into()));
            }
            Backpressure::SlowDown { delay_ms } => {
                m.insert("kind".into(), Json::Str("slow_down".into()));
                m.insert("delay_ms".into(), Json::Num(*delay_ms as f64));
            }
            Backpressure::Dropped { from_seq, count } => {
                m.insert("kind".into(), Json::Str("dropped".into()));
                m.insert("from_seq".into(), Json::Num(*from_seq as f64));
                m.insert("count".into(), Json::Num(*count as f64));
            }
        }
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "none" => Ok(Backpressure::None),
            "slow_down" => Ok(Backpressure::SlowDown { delay_ms: u64_from(v.get("delay_ms")?)? }),
            "dropped" => Ok(Backpressure::Dropped {
                from_seq: u64_from(v.get("from_seq")?)?,
                count: u64_from(v.get("count")?)?,
            }),
            other => bail!("unknown backpressure kind '{other}'"),
        }
    }
}

/// Client → gateway messages.
#[derive(Clone, Debug)]
pub enum ClientMsg {
    /// Must be the first frame on every connection.
    Hello { version: u32 },
    /// One typed query; the reply is `Response` or an `api`-scope
    /// `Error` (the connection stays usable either way).
    Query { request: QueryRequest },
    /// Request a metrics snapshot (lane counters, live queue depths,
    /// latency percentiles, memory gauges).
    Stats,
    /// Liveness probe.
    Ping,
    /// Ask the server to shut down gracefully (stop accepting, drain
    /// in-flight work, flush durable memory).
    Shutdown,
    /// Claim a stream for push ingest, declaring the frame geometry
    /// (`frame_size` pixels per side) and intended pacing.  The reply's
    /// `next_seq` is authoritative: resume from there, not from local
    /// history.  Re-opening an already-open stream steals ownership
    /// (newest camera wins — it is the reconnecting one).
    IngestOpen { stream: u16, frame_size: usize, fps: f64 },
    /// A batch of frames for an opened stream.  Sequence numbers must be
    /// exactly contiguous from the server's watermark; anything else is
    /// a protocol error (the camera should re-open and resume).
    IngestFrames { stream: u16, frames: Vec<IngestFrame> },
    /// Fetch span trees from the server's trace rings: a specific trace
    /// by id (the `trace_id` echoed in a [`QueryResponse`]), or the
    /// last-`last` completed traces; `slow` reads the slow-query ring
    /// instead of the completed ring.
    Trace { id: Option<TraceId>, last: usize, slow: bool },
    /// Fetch the Prometheus text-format rendering of the serving
    /// snapshot + span-derived per-stage histograms.
    MetricsText,
}

/// Gateway → client messages.
#[derive(Clone, Debug)]
pub enum ServerMsg {
    /// Handshake accept: the server's protocol version, the session id
    /// minted for this connection, and the fabric's stream count.
    HelloAck { version: u32, session: u64, streams: usize },
    /// A completed query.
    Response { response: QueryResponse },
    /// A typed failure — `api` errors leave the connection usable,
    /// `protocol` errors are followed by a close.
    Error { error: WireError },
    /// Metrics snapshot reply (boxed: a `Snapshot` is an order of
    /// magnitude larger than the other variants).
    Stats { snapshot: Box<Snapshot> },
    /// Liveness reply.
    Pong,
    /// Graceful-shutdown acknowledgement (sent before the close).
    ShutdownAck,
    /// Ingest-open accept: the exact sequence number the server expects
    /// next on this stream (its durable frame count — on a recovered
    /// fabric this is where the previous life stopped).
    IngestOpenAck { stream: u16, next_seq: u64 },
    /// Per-batch acknowledgement: `high_watermark` is the next sequence
    /// number the server expects (every frame below it is archived or
    /// deliberately dropped), plus the admission verdict.
    IngestAck { stream: u16, high_watermark: u64, backpressure: Backpressure },
    /// Trace reply: the requested span trees, newest first (empty when
    /// the id was never sampled or already evicted from the ring).
    Trace { traces: Vec<Trace> },
    /// Prometheus text-format metrics reply.
    MetricsText { text: String },
}

/// The wire-level error taxonomy.
#[derive(Clone, Debug)]
pub enum WireError {
    /// The serving layer refused or failed the query (admission, deadline,
    /// shutdown, engine) — retry semantics follow [`ApiError`]; the
    /// connection itself is healthy.
    Api(ApiError),
    /// The peer violated the protocol (bad frame, bad message, handshake
    /// mismatch).  The offending connection is closed; the process and
    /// every other connection keep serving.
    Protocol(String),
    /// The gateway's connection budget is exhausted; try again later.
    Busy { max_conns: usize },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Api(e) => write!(f, "api error: {e}"),
            WireError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            WireError::Busy { max_conns } => {
                write!(f, "server at its {max_conns}-connection budget")
            }
        }
    }
}

impl std::error::Error for WireError {}

fn tagged(tag: &str) -> std::collections::BTreeMap<String, Json> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("type".into(), Json::Str(tag.into()));
    m
}

impl WireError {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            WireError::Api(e) => {
                m.insert("scope".into(), Json::Str("api".into()));
                m.insert("error".into(), e.to_json());
            }
            WireError::Protocol(msg) => {
                m.insert("scope".into(), Json::Str("protocol".into()));
                m.insert("message".into(), Json::Str(msg.clone()));
            }
            WireError::Busy { max_conns } => {
                m.insert("scope".into(), Json::Str("busy".into()));
                m.insert("max_conns".into(), Json::Num(*max_conns as f64));
            }
        }
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("scope")?.as_str()? {
            "api" => Ok(WireError::Api(ApiError::from_json(v.get("error")?)?)),
            "protocol" => Ok(WireError::Protocol(v.get("message")?.as_str()?.to_string())),
            "busy" => Ok(WireError::Busy { max_conns: v.get("max_conns")?.as_usize()? }),
            other => bail!("unknown wire error scope '{other}'"),
        }
    }
}

impl ClientMsg {
    pub fn to_json(&self) -> Json {
        match self {
            ClientMsg::Hello { version } => {
                let mut m = tagged("hello");
                m.insert("version".into(), Json::Num(*version as f64));
                Json::Obj(m)
            }
            ClientMsg::Query { request } => {
                let mut m = tagged("query");
                m.insert("request".into(), request.to_json());
                Json::Obj(m)
            }
            ClientMsg::Stats => Json::Obj(tagged("stats")),
            ClientMsg::Ping => Json::Obj(tagged("ping")),
            ClientMsg::Shutdown => Json::Obj(tagged("shutdown")),
            ClientMsg::IngestOpen { stream, frame_size, fps } => {
                let mut m = tagged("ingest_open");
                m.insert("stream".into(), Json::Num(*stream as f64));
                m.insert("frame_size".into(), Json::Num(*frame_size as f64));
                m.insert("fps".into(), Json::Num(*fps));
                Json::Obj(m)
            }
            ClientMsg::IngestFrames { stream, frames } => {
                let mut m = tagged("ingest_frames");
                m.insert("stream".into(), Json::Num(*stream as f64));
                m.insert("frames".into(), Json::Arr(frames.iter().map(|f| f.to_json()).collect()));
                Json::Obj(m)
            }
            ClientMsg::Trace { id, last, slow } => {
                let mut m = tagged("trace");
                if let Some(id) = id {
                    m.insert("id".into(), Json::Str(id.to_string()));
                }
                m.insert("last".into(), Json::Num(*last as f64));
                if *slow {
                    m.insert("slow".into(), Json::Bool(true));
                }
                Json::Obj(m)
            }
            ClientMsg::MetricsText => Json::Obj(tagged("metrics_text")),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("type")?.as_str()? {
            "hello" => Ok(ClientMsg::Hello { version: version_from(v.get("version")?)? }),
            "query" => {
                Ok(ClientMsg::Query { request: QueryRequest::from_json(v.get("request")?)? })
            }
            "stats" => Ok(ClientMsg::Stats),
            "ping" => Ok(ClientMsg::Ping),
            "shutdown" => Ok(ClientMsg::Shutdown),
            "ingest_open" => Ok(ClientMsg::IngestOpen {
                stream: stream_from(v.get("stream")?)?,
                frame_size: v.get("frame_size")?.as_usize()?,
                fps: v.get("fps")?.as_f64()?,
            }),
            "ingest_frames" => Ok(ClientMsg::IngestFrames {
                stream: stream_from(v.get("stream")?)?,
                frames: v
                    .get("frames")?
                    .as_arr()?
                    .iter()
                    .map(IngestFrame::from_json)
                    .collect::<Result<Vec<_>>>()?,
            }),
            "trace" => {
                let id = match v.opt("id") {
                    Some(x) => {
                        let s = x.as_str()?;
                        match TraceId::parse(s) {
                            Some(id) => Some(id),
                            None => bail!("unparseable trace id '{s}'"),
                        }
                    }
                    None => None,
                };
                Ok(ClientMsg::Trace {
                    id,
                    last: v.opt("last").map(|x| x.as_usize()).transpose()?.unwrap_or(1),
                    slow: v.opt("slow").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
                })
            }
            "metrics_text" => Ok(ClientMsg::MetricsText),
            other => bail!("unknown client message type '{other}'"),
        }
    }
}

impl ServerMsg {
    pub fn to_json(&self) -> Json {
        match self {
            ServerMsg::HelloAck { version, session, streams } => {
                let mut m = tagged("hello_ack");
                m.insert("version".into(), Json::Num(*version as f64));
                m.insert("session".into(), Json::Num(*session as f64));
                m.insert("streams".into(), Json::Num(*streams as f64));
                Json::Obj(m)
            }
            ServerMsg::Response { response } => {
                let mut m = tagged("response");
                m.insert("response".into(), response.to_json());
                Json::Obj(m)
            }
            ServerMsg::Error { error } => {
                let mut m = tagged("error");
                m.insert("error".into(), error.to_json());
                Json::Obj(m)
            }
            ServerMsg::Stats { snapshot } => {
                let mut m = tagged("stats");
                m.insert("snapshot".into(), snapshot.to_json());
                Json::Obj(m)
            }
            ServerMsg::Pong => Json::Obj(tagged("pong")),
            ServerMsg::ShutdownAck => Json::Obj(tagged("shutdown_ack")),
            ServerMsg::IngestOpenAck { stream, next_seq } => {
                let mut m = tagged("ingest_open_ack");
                m.insert("stream".into(), Json::Num(*stream as f64));
                m.insert("next_seq".into(), Json::Num(*next_seq as f64));
                Json::Obj(m)
            }
            ServerMsg::IngestAck { stream, high_watermark, backpressure } => {
                let mut m = tagged("ingest_ack");
                m.insert("stream".into(), Json::Num(*stream as f64));
                m.insert("high_watermark".into(), Json::Num(*high_watermark as f64));
                m.insert("backpressure".into(), backpressure.to_json());
                Json::Obj(m)
            }
            ServerMsg::Trace { traces } => {
                let mut m = tagged("trace");
                m.insert("traces".into(), Json::Arr(traces.iter().map(|t| t.to_json()).collect()));
                Json::Obj(m)
            }
            ServerMsg::MetricsText { text } => {
                let mut m = tagged("metrics_text");
                m.insert("text".into(), Json::Str(text.clone()));
                Json::Obj(m)
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("type")?.as_str()? {
            "hello_ack" => Ok(ServerMsg::HelloAck {
                version: version_from(v.get("version")?)?,
                session: v.get("session")?.as_usize()? as u64,
                streams: v.get("streams")?.as_usize()?,
            }),
            "response" => {
                Ok(ServerMsg::Response { response: QueryResponse::from_json(v.get("response")?)? })
            }
            "error" => Ok(ServerMsg::Error { error: WireError::from_json(v.get("error")?)? }),
            "stats" => Ok(ServerMsg::Stats {
                snapshot: Box::new(Snapshot::from_json(v.get("snapshot")?)?),
            }),
            "pong" => Ok(ServerMsg::Pong),
            "shutdown_ack" => Ok(ServerMsg::ShutdownAck),
            "ingest_open_ack" => Ok(ServerMsg::IngestOpenAck {
                stream: stream_from(v.get("stream")?)?,
                next_seq: u64_from(v.get("next_seq")?)?,
            }),
            "ingest_ack" => Ok(ServerMsg::IngestAck {
                stream: stream_from(v.get("stream")?)?,
                high_watermark: u64_from(v.get("high_watermark")?)?,
                backpressure: Backpressure::from_json(v.get("backpressure")?)?,
            }),
            "trace" => Ok(ServerMsg::Trace {
                traces: v
                    .get("traces")?
                    .as_arr()?
                    .iter()
                    .map(Trace::from_json)
                    .collect::<Result<Vec<_>>>()?,
            }),
            "metrics_text" => {
                Ok(ServerMsg::MetricsText { text: v.get("text")?.as_str()?.to_string() })
            }
            other => bail!("unknown server message type '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Priority;
    use crate::server::Metrics;

    #[test]
    fn client_messages_round_trip() {
        let msgs = [
            ClientMsg::Hello { version: PROTOCOL_VERSION },
            ClientMsg::Query {
                request: QueryRequest::new("what happened with concept03").budget(8),
            },
            ClientMsg::Stats,
            ClientMsg::Ping,
            ClientMsg::Shutdown,
        ];
        for msg in msgs {
            let wire = msg.to_json().to_string();
            let back = ClientMsg::from_json(&Json::parse(&wire).unwrap()).unwrap();
            match (&msg, &back) {
                (ClientMsg::Hello { version: a }, ClientMsg::Hello { version: b }) => {
                    assert_eq!(a, b)
                }
                (ClientMsg::Query { request: a }, ClientMsg::Query { request: b }) => {
                    assert_eq!(a, b)
                }
                (ClientMsg::Stats, ClientMsg::Stats)
                | (ClientMsg::Ping, ClientMsg::Ping)
                | (ClientMsg::Shutdown, ClientMsg::Shutdown) => {}
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn ingest_messages_round_trip() {
        use crate::util::b64::{decode_f32s, encode_f32s};

        let pixels = vec![0.25f32, -1.0, 3.5e-5, f32::MIN_POSITIVE];
        let frames = vec![
            IngestFrame { seq: 41, captured_unix_ms: 1_754_000_000_123, data_b64: encode_f32s(&pixels) },
            IngestFrame { seq: 42, captured_unix_ms: 1_754_000_000_165, data_b64: String::new() },
        ];
        let open = ClientMsg::IngestOpen { stream: 3, frame_size: 64, fps: 24.0 };
        let wire = open.to_json().to_string();
        match ClientMsg::from_json(&Json::parse(&wire).unwrap()).unwrap() {
            ClientMsg::IngestOpen { stream, frame_size, fps } => {
                assert_eq!((stream, frame_size, fps), (3, 64, 24.0));
            }
            other => panic!("variant changed across the wire: {other:?}"),
        }
        let batch = ClientMsg::IngestFrames { stream: 3, frames: frames.clone() };
        let wire = batch.to_json().to_string();
        match ClientMsg::from_json(&Json::parse(&wire).unwrap()).unwrap() {
            ClientMsg::IngestFrames { stream, frames: back } => {
                assert_eq!(stream, 3);
                assert_eq!(back, frames);
                // the pixel payload is bit-exact after the full JSON trip
                let decoded = decode_f32s(&back[0].data_b64).unwrap();
                for (a, b) in pixels.iter().zip(&decoded) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("variant changed across the wire: {other:?}"),
        }

        let acks = [
            ServerMsg::IngestOpenAck { stream: 3, next_seq: 41 },
            ServerMsg::IngestAck { stream: 3, high_watermark: 43, backpressure: Backpressure::None },
            ServerMsg::IngestAck {
                stream: 0,
                high_watermark: 43,
                backpressure: Backpressure::SlowDown { delay_ms: 125 },
            },
            ServerMsg::IngestAck {
                stream: 9,
                high_watermark: 50,
                backpressure: Backpressure::Dropped { from_seq: 43, count: 7 },
            },
        ];
        for msg in acks {
            let wire = msg.to_json().to_string();
            match (&msg, &ServerMsg::from_json(&Json::parse(&wire).unwrap()).unwrap()) {
                (
                    ServerMsg::IngestOpenAck { stream: a, next_seq: b },
                    ServerMsg::IngestOpenAck { stream: x, next_seq: y },
                ) => assert_eq!((a, b), (x, y)),
                (
                    ServerMsg::IngestAck { stream: a, high_watermark: b, backpressure: c },
                    ServerMsg::IngestAck { stream: x, high_watermark: y, backpressure: z },
                ) => assert_eq!((a, b, c), (x, y, z)),
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_ingest_payloads_rejected() {
        for wire in [
            // stream id past the u16 shard space
            r#"{"type":"ingest_open","stream":65536,"frame_size":64,"fps":24.0}"#,
            // missing geometry
            r#"{"type":"ingest_open","stream":0}"#,
            // frames must be an array of objects
            r#"{"type":"ingest_frames","stream":0,"frames":7}"#,
            r#"{"type":"ingest_frames","stream":0,"frames":[{"seq":1}]}"#,
            // negative sequence number
            r#"{"type":"ingest_frames","stream":0,"frames":[{"seq":-1,"captured_unix_ms":0,"data":""}]}"#,
        ] {
            assert!(ClientMsg::from_json(&Json::parse(wire).unwrap()).is_err(), "accepted {wire}");
        }
        let bad = r#"{"type":"ingest_ack","stream":0,"high_watermark":1,"backpressure":{"kind":"warp"}}"#;
        assert!(ServerMsg::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn server_messages_round_trip() {
        let m = Metrics::default();
        m.on_accepted(Priority::Interactive);
        let msgs = [
            ServerMsg::HelloAck { version: 1, session: 7, streams: 4 },
            ServerMsg::Error { error: WireError::Api(ApiError::DeadlineExceeded) },
            ServerMsg::Error { error: WireError::Protocol("bad frame".into()) },
            ServerMsg::Error { error: WireError::Busy { max_conns: 64 } },
            ServerMsg::Stats { snapshot: Box::new(m.snapshot()) },
            ServerMsg::Pong,
            ServerMsg::ShutdownAck,
        ];
        for msg in msgs {
            let wire = msg.to_json().to_string();
            let back = ServerMsg::from_json(&Json::parse(&wire).unwrap()).unwrap();
            match (&msg, &back) {
                (
                    ServerMsg::HelloAck { version: a, session: b, streams: c },
                    ServerMsg::HelloAck { version: x, session: y, streams: z },
                ) => {
                    assert_eq!((a, b, c), (x, y, z));
                }
                (ServerMsg::Error { error: a }, ServerMsg::Error { error: b }) => {
                    assert_eq!(a.to_string(), b.to_string());
                }
                (ServerMsg::Stats { snapshot: a }, ServerMsg::Stats { snapshot: b }) => {
                    assert_eq!(a.interactive.accepted, b.interactive.accepted);
                    assert_eq!(a.interactive.queued, b.interactive.queued);
                }
                (ServerMsg::Pong, ServerMsg::Pong)
                | (ServerMsg::ShutdownAck, ServerMsg::ShutdownAck) => {}
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn trace_and_metrics_envelopes_round_trip() {
        use crate::obs::{Span, Trace, TraceId};

        for msg in [
            ClientMsg::Trace { id: Some(TraceId(0xbeef)), last: 1, slow: false },
            ClientMsg::Trace { id: None, last: 5, slow: true },
            ClientMsg::MetricsText,
        ] {
            let wire = msg.to_json().to_string();
            match (&msg, &ClientMsg::from_json(&Json::parse(&wire).unwrap()).unwrap()) {
                (
                    ClientMsg::Trace { id: a, last: b, slow: c },
                    ClientMsg::Trace { id: x, last: y, slow: z },
                ) => assert_eq!((a, b, c), (x, y, z)),
                (ClientMsg::MetricsText, ClientMsg::MetricsText) => {}
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
        // a bare {"type":"trace"} defaults to last-1, completed ring
        let min = ClientMsg::from_json(&Json::parse(r#"{"type":"trace"}"#).unwrap()).unwrap();
        assert!(matches!(min, ClientMsg::Trace { id: None, last: 1, slow: false }));

        let tr = Trace {
            id: TraceId(77),
            kind: "query".into(),
            label: "what happened".into(),
            unix_ms: 1_754_000_000_000,
            total_us: 1_500,
            spans: vec![Span {
                stage: "embed".into(),
                start_us: 10,
                dur_us: 90,
                counters: std::collections::BTreeMap::new(),
            }],
        };
        for msg in [
            ServerMsg::Trace { traces: vec![tr.clone()] },
            ServerMsg::Trace { traces: vec![] },
            ServerMsg::MetricsText { text: "venus_uptime_seconds 1\n".into() },
        ] {
            let wire = msg.to_json().to_string();
            match (&msg, &ServerMsg::from_json(&Json::parse(&wire).unwrap()).unwrap()) {
                (ServerMsg::Trace { traces: a }, ServerMsg::Trace { traces: b }) => {
                    assert_eq!(a.len(), b.len());
                    if let (Some(a), Some(b)) = (a.first(), b.first()) {
                        assert_eq!(a.id, b.id);
                        assert_eq!(a.spans.len(), b.spans.len());
                        assert_eq!(a.total_us, b.total_us);
                    }
                }
                (ServerMsg::MetricsText { text: a }, ServerMsg::MetricsText { text: b }) => {
                    assert_eq!(a, b);
                }
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_trace_and_metrics_payloads_rejected() {
        for wire in [
            // unparseable trace id (not hex)
            r#"{"type":"trace","id":"not-a-trace-id"}"#,
            // id must be a string, not a number
            r#"{"type":"trace","id":123}"#,
            // negative ring size
            r#"{"type":"trace","last":-3}"#,
            // slow must be a boolean
            r#"{"type":"trace","slow":"yes"}"#,
        ] {
            assert!(ClientMsg::from_json(&Json::parse(wire).unwrap()).is_err(), "accepted {wire}");
        }
        for wire in [
            // traces must be an array of span-tree objects
            r#"{"type":"trace","traces":7}"#,
            // a trace object without its id is unusable
            r#"{"type":"trace","traces":[{"kind":"query"}]}"#,
            // metrics text body is required
            r#"{"type":"metrics_text"}"#,
            r#"{"type":"metrics_text","text":42}"#,
        ] {
            assert!(ServerMsg::from_json(&Json::parse(wire).unwrap()).is_err(), "accepted {wire}");
        }
    }

    #[test]
    fn out_of_range_versions_rejected_not_wrapped() {
        // 2^32 + 1 would wrap to 1 under a bare `as u32` and sneak past
        // the v1 handshake; it must be a parse error instead
        let wire = r#"{"type":"hello","version":4294967297}"#;
        assert!(ClientMsg::from_json(&Json::parse(wire).unwrap()).is_err());
        let wire = r#"{"type":"hello_ack","session":0,"streams":1,"version":4294967297}"#;
        assert!(ServerMsg::from_json(&Json::parse(wire).unwrap()).is_err());
        // the boundary value itself still parses
        let wire = format!(r#"{{"type":"hello","version":{}}}"#, u32::MAX);
        assert!(ClientMsg::from_json(&Json::parse(&wire).unwrap()).is_ok());
    }

    #[test]
    fn unknown_types_and_scopes_rejected() {
        let bad = Json::parse(r#"{"type":"teleport"}"#).unwrap();
        assert!(ClientMsg::from_json(&bad).is_err());
        assert!(ServerMsg::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"type":"error","error":{"scope":"cosmic"}}"#).unwrap();
        assert!(ServerMsg::from_json(&bad).is_err());
        // a tag-less object is rejected, not a panic
        assert!(ClientMsg::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(ClientMsg::from_json(&Json::parse("[1,2]").unwrap()).is_err());
    }
}
