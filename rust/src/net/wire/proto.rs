//! Wire message envelopes: what travels inside the frames.
//!
//! Every message is a JSON object with a `"type"` tag.  Query traffic
//! reuses the PR 3 [`QueryRequest`]/[`QueryResponse`] JSON encodings
//! verbatim (they were wire-round-trip tested before a wire existed);
//! the control plane adds `hello`/`hello_ack` (version handshake +
//! session assignment), `stats` (a full serving [`Snapshot`]), `ping`/
//! `pong`, and `shutdown` (remote graceful stop).
//!
//! Versioning rule: the handshake carries a single integer protocol
//! version; the gateway serves only its own version ([`PROTOCOL_VERSION`])
//! and answers anything else with a typed protocol error before any
//! query is accepted.  Encoding changes that break old clients must bump
//! the version (see DESIGN.md §Wire-Protocol).

use std::fmt;

use anyhow::{bail, Result};

use crate::api::{ApiError, QueryRequest, QueryResponse};
use crate::server::Snapshot;
use crate::util::json::Json;

/// The one protocol version this build speaks.
pub const PROTOCOL_VERSION: u32 = 1;

/// Decode a protocol version, rejecting values that don't fit a `u32`
/// instead of silently wrapping (2^32 + 1 must not pass the v1 check).
fn version_from(v: &Json) -> Result<u32> {
    let version = v.as_usize()?;
    if version > u32::MAX as usize {
        bail!("protocol version {version} out of range (max {})", u32::MAX);
    }
    Ok(version as u32)
}

/// Client → gateway messages.
#[derive(Clone, Debug)]
pub enum ClientMsg {
    /// Must be the first frame on every connection.
    Hello { version: u32 },
    /// One typed query; the reply is `Response` or an `api`-scope
    /// `Error` (the connection stays usable either way).
    Query { request: QueryRequest },
    /// Request a metrics snapshot (lane counters, live queue depths,
    /// latency percentiles, memory gauges).
    Stats,
    /// Liveness probe.
    Ping,
    /// Ask the server to shut down gracefully (stop accepting, drain
    /// in-flight work, flush durable memory).
    Shutdown,
}

/// Gateway → client messages.
#[derive(Clone, Debug)]
pub enum ServerMsg {
    /// Handshake accept: the server's protocol version, the session id
    /// minted for this connection, and the fabric's stream count.
    HelloAck { version: u32, session: u64, streams: usize },
    /// A completed query.
    Response { response: QueryResponse },
    /// A typed failure — `api` errors leave the connection usable,
    /// `protocol` errors are followed by a close.
    Error { error: WireError },
    /// Metrics snapshot reply (boxed: a `Snapshot` is an order of
    /// magnitude larger than the other variants).
    Stats { snapshot: Box<Snapshot> },
    /// Liveness reply.
    Pong,
    /// Graceful-shutdown acknowledgement (sent before the close).
    ShutdownAck,
}

/// The wire-level error taxonomy.
#[derive(Clone, Debug)]
pub enum WireError {
    /// The serving layer refused or failed the query (admission, deadline,
    /// shutdown, engine) — retry semantics follow [`ApiError`]; the
    /// connection itself is healthy.
    Api(ApiError),
    /// The peer violated the protocol (bad frame, bad message, handshake
    /// mismatch).  The offending connection is closed; the process and
    /// every other connection keep serving.
    Protocol(String),
    /// The gateway's connection budget is exhausted; try again later.
    Busy { max_conns: usize },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Api(e) => write!(f, "api error: {e}"),
            WireError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            WireError::Busy { max_conns } => {
                write!(f, "server at its {max_conns}-connection budget")
            }
        }
    }
}

impl std::error::Error for WireError {}

fn tagged(tag: &str) -> std::collections::BTreeMap<String, Json> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("type".into(), Json::Str(tag.into()));
    m
}

impl WireError {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            WireError::Api(e) => {
                m.insert("scope".into(), Json::Str("api".into()));
                m.insert("error".into(), e.to_json());
            }
            WireError::Protocol(msg) => {
                m.insert("scope".into(), Json::Str("protocol".into()));
                m.insert("message".into(), Json::Str(msg.clone()));
            }
            WireError::Busy { max_conns } => {
                m.insert("scope".into(), Json::Str("busy".into()));
                m.insert("max_conns".into(), Json::Num(*max_conns as f64));
            }
        }
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("scope")?.as_str()? {
            "api" => Ok(WireError::Api(ApiError::from_json(v.get("error")?)?)),
            "protocol" => Ok(WireError::Protocol(v.get("message")?.as_str()?.to_string())),
            "busy" => Ok(WireError::Busy { max_conns: v.get("max_conns")?.as_usize()? }),
            other => bail!("unknown wire error scope '{other}'"),
        }
    }
}

impl ClientMsg {
    pub fn to_json(&self) -> Json {
        match self {
            ClientMsg::Hello { version } => {
                let mut m = tagged("hello");
                m.insert("version".into(), Json::Num(*version as f64));
                Json::Obj(m)
            }
            ClientMsg::Query { request } => {
                let mut m = tagged("query");
                m.insert("request".into(), request.to_json());
                Json::Obj(m)
            }
            ClientMsg::Stats => Json::Obj(tagged("stats")),
            ClientMsg::Ping => Json::Obj(tagged("ping")),
            ClientMsg::Shutdown => Json::Obj(tagged("shutdown")),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("type")?.as_str()? {
            "hello" => Ok(ClientMsg::Hello { version: version_from(v.get("version")?)? }),
            "query" => {
                Ok(ClientMsg::Query { request: QueryRequest::from_json(v.get("request")?)? })
            }
            "stats" => Ok(ClientMsg::Stats),
            "ping" => Ok(ClientMsg::Ping),
            "shutdown" => Ok(ClientMsg::Shutdown),
            other => bail!("unknown client message type '{other}'"),
        }
    }
}

impl ServerMsg {
    pub fn to_json(&self) -> Json {
        match self {
            ServerMsg::HelloAck { version, session, streams } => {
                let mut m = tagged("hello_ack");
                m.insert("version".into(), Json::Num(*version as f64));
                m.insert("session".into(), Json::Num(*session as f64));
                m.insert("streams".into(), Json::Num(*streams as f64));
                Json::Obj(m)
            }
            ServerMsg::Response { response } => {
                let mut m = tagged("response");
                m.insert("response".into(), response.to_json());
                Json::Obj(m)
            }
            ServerMsg::Error { error } => {
                let mut m = tagged("error");
                m.insert("error".into(), error.to_json());
                Json::Obj(m)
            }
            ServerMsg::Stats { snapshot } => {
                let mut m = tagged("stats");
                m.insert("snapshot".into(), snapshot.to_json());
                Json::Obj(m)
            }
            ServerMsg::Pong => Json::Obj(tagged("pong")),
            ServerMsg::ShutdownAck => Json::Obj(tagged("shutdown_ack")),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        match v.get("type")?.as_str()? {
            "hello_ack" => Ok(ServerMsg::HelloAck {
                version: version_from(v.get("version")?)?,
                session: v.get("session")?.as_usize()? as u64,
                streams: v.get("streams")?.as_usize()?,
            }),
            "response" => {
                Ok(ServerMsg::Response { response: QueryResponse::from_json(v.get("response")?)? })
            }
            "error" => Ok(ServerMsg::Error { error: WireError::from_json(v.get("error")?)? }),
            "stats" => Ok(ServerMsg::Stats {
                snapshot: Box::new(Snapshot::from_json(v.get("snapshot")?)?),
            }),
            "pong" => Ok(ServerMsg::Pong),
            "shutdown_ack" => Ok(ServerMsg::ShutdownAck),
            other => bail!("unknown server message type '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Priority;
    use crate::server::Metrics;

    #[test]
    fn client_messages_round_trip() {
        let msgs = [
            ClientMsg::Hello { version: PROTOCOL_VERSION },
            ClientMsg::Query {
                request: QueryRequest::new("what happened with concept03").budget(8),
            },
            ClientMsg::Stats,
            ClientMsg::Ping,
            ClientMsg::Shutdown,
        ];
        for msg in msgs {
            let wire = msg.to_json().to_string();
            let back = ClientMsg::from_json(&Json::parse(&wire).unwrap()).unwrap();
            match (&msg, &back) {
                (ClientMsg::Hello { version: a }, ClientMsg::Hello { version: b }) => {
                    assert_eq!(a, b)
                }
                (ClientMsg::Query { request: a }, ClientMsg::Query { request: b }) => {
                    assert_eq!(a, b)
                }
                (ClientMsg::Stats, ClientMsg::Stats)
                | (ClientMsg::Ping, ClientMsg::Ping)
                | (ClientMsg::Shutdown, ClientMsg::Shutdown) => {}
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn server_messages_round_trip() {
        let m = Metrics::default();
        m.on_accepted(Priority::Interactive);
        let msgs = [
            ServerMsg::HelloAck { version: 1, session: 7, streams: 4 },
            ServerMsg::Error { error: WireError::Api(ApiError::DeadlineExceeded) },
            ServerMsg::Error { error: WireError::Protocol("bad frame".into()) },
            ServerMsg::Error { error: WireError::Busy { max_conns: 64 } },
            ServerMsg::Stats { snapshot: Box::new(m.snapshot()) },
            ServerMsg::Pong,
            ServerMsg::ShutdownAck,
        ];
        for msg in msgs {
            let wire = msg.to_json().to_string();
            let back = ServerMsg::from_json(&Json::parse(&wire).unwrap()).unwrap();
            match (&msg, &back) {
                (
                    ServerMsg::HelloAck { version: a, session: b, streams: c },
                    ServerMsg::HelloAck { version: x, session: y, streams: z },
                ) => {
                    assert_eq!((a, b, c), (x, y, z));
                }
                (ServerMsg::Error { error: a }, ServerMsg::Error { error: b }) => {
                    assert_eq!(a.to_string(), b.to_string());
                }
                (ServerMsg::Stats { snapshot: a }, ServerMsg::Stats { snapshot: b }) => {
                    assert_eq!(a.interactive.accepted, b.interactive.accepted);
                    assert_eq!(a.interactive.queued, b.interactive.queued);
                }
                (ServerMsg::Pong, ServerMsg::Pong)
                | (ServerMsg::ShutdownAck, ServerMsg::ShutdownAck) => {}
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }

    #[test]
    fn out_of_range_versions_rejected_not_wrapped() {
        // 2^32 + 1 would wrap to 1 under a bare `as u32` and sneak past
        // the v1 handshake; it must be a parse error instead
        let wire = r#"{"type":"hello","version":4294967297}"#;
        assert!(ClientMsg::from_json(&Json::parse(wire).unwrap()).is_err());
        let wire = r#"{"type":"hello_ack","session":0,"streams":1,"version":4294967297}"#;
        assert!(ServerMsg::from_json(&Json::parse(wire).unwrap()).is_err());
        // the boundary value itself still parses
        let wire = format!(r#"{{"type":"hello","version":{}}}"#, u32::MAX);
        assert!(ClientMsg::from_json(&Json::parse(&wire).unwrap()).is_ok());
    }

    #[test]
    fn unknown_types_and_scopes_rejected() {
        let bad = Json::parse(r#"{"type":"teleport"}"#).unwrap();
        assert!(ClientMsg::from_json(&bad).is_err());
        assert!(ServerMsg::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"type":"error","error":{"scope":"cosmic"}}"#).unwrap();
        assert!(ServerMsg::from_json(&bad).is_err());
        // a tag-less object is rejected, not a panic
        assert!(ClientMsg::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(ClientMsg::from_json(&Json::parse("[1,2]").unwrap()).is_err());
    }
}
